"""L1 Pallas kernels — the hot spots inside the L2 graphs.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot run
real-TPU Mosaic custom-calls, so interpret mode lowers them to plain HLO
that any backend (including the rust-side CPU client) executes. The
BlockSpec structure is still authored for TPU (VMEM-sized tiles hitting
the MXU as matmuls) — see DESIGN.md §Hardware-Adaptation.
"""

from .loglikes import gmm_loglikes
from .precision import precision_matrices
from .chol import chol_solve, chol_solve_and_inverse

__all__ = [
    "gmm_loglikes",
    "precision_matrices",
    "chol_solve",
    "chol_solve_and_inverse",
]
