"""Batched Cholesky + solves in pure `lax` ops.

jax's `jnp.linalg.{cholesky,solve}` lower to LAPACK custom-calls whose
registration names differ between jax 0.8 and the xla_extension 0.5.1
runtime behind the rust `xla` crate — they would fail to load. These
hand-rolled versions lower to plain HLO (fori_loop + dynamic slicing)
and round-trip cleanly. R ≤ 64 keeps the sequential factor loop cheap
relative to the batched O(B·R²) work per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def batched_cholesky(a):
    """Lower-triangular L with A = L Lᵀ for a batch of SPD matrices.

    a: (..., R, R) — assumed symmetric positive definite (the E-step
    precision L(u) = I + Σ n_c M_c always is).
    """
    r = a.shape[-1]

    def body(j, l):
        # pivot
        d = jnp.sqrt(jnp.maximum(a[..., j, j] - jnp.sum(l[..., j, :] ** 2, axis=-1), 1e-20))
        # column below the pivot: (A[:, j] - L @ L[j, :]) / d
        col = (a[..., :, j] - jnp.einsum("...ik,...k->...i", l, l[..., j, :])) / d[..., None]
        mask = (jnp.arange(r) > j).astype(a.dtype)
        col = col * mask
        l = l.at[..., :, j].set(col)
        l = l.at[..., j, j].set(d)
        return l

    return lax.fori_loop(0, r, body, jnp.zeros_like(a))


def forward_solve(l, b):
    """Solve L y = b (lower-triangular), batched.

    l: (..., R, R), b: (..., R, N) or (..., R). Returns same shape as b.
    """
    vec = b.ndim == l.ndim - 1
    if vec:
        b = b[..., None]
    r = l.shape[-1]

    def body(i, y):
        # y[i] = (b[i] - L[i, :] @ y) / L[i, i]
        acc = jnp.einsum("...k,...kn->...n", l[..., i, :], y)
        yi = (b[..., i, :] - acc) / l[..., i, i][..., None]
        return y.at[..., i, :].set(yi)

    y = lax.fori_loop(0, r, body, jnp.zeros_like(b))
    return y[..., 0] if vec else y


def backward_solve(l, y):
    """Solve Lᵀ x = y (upper-triangular via the lower factor), batched."""
    vec = y.ndim == l.ndim - 1
    if vec:
        y = y[..., None]
    r = l.shape[-1]

    def body(k, x):
        i = r - 1 - k
        acc = jnp.einsum("...k,...kn->...n", l[..., :, i], x)
        xi = (y[..., i, :] - acc) / l[..., i, i][..., None]
        return x.at[..., i, :].set(xi)

    x = lax.fori_loop(0, r, body, jnp.zeros_like(y))
    return x[..., 0] if vec else x


def chol_solve(a, b):
    """x = A⁻¹ b for batched SPD A (via Cholesky)."""
    l = batched_cholesky(a)
    return backward_solve(l, forward_solve(l, b))


def chol_solve_and_inverse(a, b):
    """(A⁻¹ b, A⁻¹) for batched SPD A — the E-step needs both the
    posterior mean φ = L(u)⁻¹ rhs and covariance Φ = L(u)⁻¹."""
    r = a.shape[-1]
    l = batched_cholesky(a)
    x = backward_solve(l, forward_solve(l, b))
    eye = jnp.broadcast_to(jnp.eye(r, dtype=a.dtype), a.shape)
    inv = backward_solve(l, forward_solve(l, eye))
    # symmetrize against fp accumulation drift
    inv = 0.5 * (inv + jnp.swapaxes(inv, -1, -2))
    return x, inv
