"""Pallas kernel: batched GMM log-likelihoods as ONE tiled matmul.

The paper's frame-posterior hot spot (3000× real time on the Titan V)
is, after algebraic expansion, a single dense contraction:

    loglike[b, c] = const[c] + q(x_b) · w_c

where for the *diagonal* model  q(x) = [x, x²]            (dim 2F)
and for the *full-cov* model    q(x) = [x, vec(x xᵀ)]     (dim F + F²)
with the per-component weights packed accordingly:

    diag:  w_c = [Σ_c⁻¹ m_c, -½ diag(Σ_c⁻¹)]
    full:  w_c = [Σ_c⁻¹ m_c, -½ vec(Σ_c⁻¹)]

The expansion is built in plain jnp (cheap, fusable); the contraction —
the flops — is this kernel: a (B, D) × (D, C) matmul tiled over frame
blocks. On TPU each (block_b, D)×(D, C) tile is MXU-shaped and the
BlockSpec keeps one frame block + the whole (D, C) weight panel in VMEM
(D·C ≤ 600·64 floats ≈ 154 KiB — comfortably resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _loglikes_kernel(q_ref, wt_ref, const_ref, out_ref):
    """One frame-block: out = q @ wt + const (broadcast over rows)."""
    out_ref[...] = (
        jnp.dot(q_ref[...], wt_ref[...], preferred_element_type=jnp.float32)
        + const_ref[...]
    )


@functools.partial(jax.named_call, name="gmm_loglikes")
def gmm_loglikes(q, w, const, *, block_b: int = 128):
    """loglike[b, c] = const[c] + q[b] · w[c].

    q:     (B, D) expanded frame features
    w:     (C, D) packed component weights
    const: (C,)   per-component constants
    returns (B, C) f32
    """
    b, d = q.shape
    c = w.shape[0]
    assert w.shape[1] == d and const.shape == (c,)
    block_b = min(block_b, b)
    assert b % block_b == 0, f"frame batch {b} not divisible by block {block_b}"
    wt = w.T  # (D, C) panel, kept whole in VMEM
    grid = (b // block_b,)
    return pl.pallas_call(
        _loglikes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,  # CPU-PJRT target; see module docstring
    )(q, wt, const)


def expand_diag(x):
    """q(x) for the diagonal model: [x, x²] — (B, 2F)."""
    return jnp.concatenate([x, x * x], axis=-1)


def expand_full(x):
    """q(x) for the full-cov model: [x, vec(xxᵀ)] — (B, F + F²)."""
    b, f = x.shape
    outer = (x[:, :, None] * x[:, None, :]).reshape(b, f * f)
    return jnp.concatenate([x, outer], axis=-1)


def pack_diag_weights(means, inv_vars, log_weights):
    """Pack diagonal-model parameters for `gmm_loglikes`.

    Returns (w, const): w (C, 2F), const (C,) with
    const_c = log w_c − ½(F log 2π + Σ log σ²_cj + Σ m²_cj/σ²_cj).
    """
    f = means.shape[1]
    lin = means * inv_vars                      # Σ⁻¹ m
    quad = -0.5 * inv_vars                      # -½ diag(Σ⁻¹)
    w = jnp.concatenate([lin, quad], axis=-1)
    const = (
        log_weights
        - 0.5 * (f * jnp.log(2.0 * jnp.pi)
                 - jnp.sum(jnp.log(inv_vars), axis=-1)
                 + jnp.sum(means * lin, axis=-1))
    )
    return w, const


def pack_full_weights(means, inv_covs, log_weights, logdets):
    """Pack full-cov parameters: w (C, F+F²), const (C,).

    inv_covs: (C, F, F) Σ_c⁻¹;  logdets: (C,) log|Σ_c|.
    """
    c, f, _ = inv_covs.shape
    lin = jnp.einsum("cfg,cg->cf", inv_covs, means)          # Σ⁻¹ m
    quad = -0.5 * inv_covs.reshape(c, f * f)                 # -½ vec(Σ⁻¹)
    w = jnp.concatenate([lin, quad], axis=-1)
    const = (
        log_weights
        - 0.5 * (f * jnp.log(2.0 * jnp.pi) + logdets
                 + jnp.sum(means * lin, axis=-1))
    )
    return w, const
