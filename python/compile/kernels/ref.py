"""Pure-jnp oracles for the L1 kernels and L2 graph pieces.

Everything here is written in the most direct (unoptimized, obviously
correct) form; pytest asserts the kernels and graphs against these.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gmm_loglikes_ref(q, w, const):
    """Direct einsum version of the loglikes kernel."""
    return jnp.einsum("bd,cd->bc", q, w) + const[None, :]


def diag_loglikes_direct(x, means, variances, weights):
    """Textbook diagonal GMM log w_c N(x | m_c, diag v_c) — numpy."""
    x = np.asarray(x)
    b, f = x.shape
    c = means.shape[0]
    out = np.zeros((b, c))
    for ci in range(c):
        d = x - means[ci]
        out[:, ci] = (
            np.log(weights[ci])
            - 0.5 * (f * np.log(2 * np.pi)
                     + np.sum(np.log(variances[ci]))
                     + np.sum(d * d / variances[ci], axis=1))
        )
    return out


def full_loglikes_direct(x, means, covs, weights):
    """Textbook full-covariance GMM loglikes — numpy."""
    x = np.asarray(x)
    b, f = x.shape
    c = means.shape[0]
    out = np.zeros((b, c))
    for ci in range(c):
        d = x - means[ci]
        inv = np.linalg.inv(covs[ci])
        _, logdet = np.linalg.slogdet(covs[ci])
        quad = np.einsum("bf,fg,bg->b", d, inv, d)
        out[:, ci] = np.log(weights[ci]) - 0.5 * (f * np.log(2 * np.pi) + logdet + quad)
    return out


def precision_ref(n, tt_si_t):
    """Direct version of the precision kernel."""
    r = tt_si_t.shape[1]
    return jnp.eye(r) + jnp.einsum("bc,crs->brs", n, tt_si_t)


def estep_ref(n, f, t_mat, sigma_inv, prior_mean):
    """Per-utterance E-step, fully direct (numpy):

    L(u)  = I + Σ_c n_c TᵀΣ⁻¹T
    φ(u)  = L⁻¹ (p + Σ_c TᵀΣ⁻¹ f_c)
    Φ(u)  = L⁻¹

    n: (B, C), f: (B, C, F), t_mat: (C, F, R), sigma_inv: (C, F, F),
    prior_mean: (R,). Returns (phi (B,R), cov (B,R,R)).
    """
    n = np.asarray(n)
    f = np.asarray(f)
    b, c = n.shape
    r = t_mat.shape[2]
    tt_si = np.einsum("cfr,cfg->crg", t_mat, sigma_inv)   # TᵀΣ⁻¹ (C,R,F)
    tt_si_t = np.einsum("crf,cfs->crs", tt_si, t_mat)     # TᵀΣ⁻¹T (C,R,R)
    phi = np.zeros((b, r))
    cov = np.zeros((b, r, r))
    for u in range(b):
        l_mat = np.eye(r) + np.einsum("c,crs->rs", n[u], tt_si_t)
        rhs = prior_mean + np.einsum("crf,cf->r", tt_si, f[u])
        cov[u] = np.linalg.inv(l_mat)
        phi[u] = cov[u] @ rhs
    return phi, cov


def align_ref(x, diag_means, diag_vars, diag_weights,
              full_means, full_covs, full_weights, k, min_post):
    """Reference two-stage alignment (numpy): diag top-K → full-cov
    refinement → softmax over selected → prune → renormalize.

    Returns (posts (B, K), idx (B, K)): entries beyond the surviving
    count are zero-posterior (idx still valid).
    """
    dll = diag_loglikes_direct(x, diag_means, diag_vars, diag_weights)
    fll = full_loglikes_direct(x, full_means, full_covs, full_weights)
    b = dll.shape[0]
    posts = np.zeros((b, k), dtype=np.float32)
    idx = np.zeros((b, k), dtype=np.int32)
    for t in range(b):
        sel = np.argsort(-dll[t])[:k]
        ll = fll[t, sel]
        p = np.exp(ll - ll.max())
        p /= p.sum()
        keep = p >= min_post
        if not keep.any():
            keep = p == p.max()
        p = np.where(keep, p, 0.0)
        p /= p.sum()
        order = np.argsort(-p, kind="stable")
        posts[t] = p[order]
        idx[t] = sel[order]
    return posts, idx


def plda_score_ref(enroll, test, p_mat, q_mat):
    """Two-covariance PLDA LLR reference:
    score(e, t) = ½ eᵀQe + ½ tᵀQt + eᵀPt   (constants dropped —
    detection metrics are threshold-invariant)."""
    e_q = 0.5 * np.einsum("nd,de,ne->n", enroll, q_mat, enroll)
    t_q = 0.5 * np.einsum("md,de,me->m", test, q_mat, test)
    cross = enroll @ p_mat @ test.T
    return e_q[:, None] + t_q[None, :] + cross
