"""Pallas kernel: per-utterance precision matrices.

The E-step's per-utterance matrix (paper eq. 3)

    L(u) = I + Σ_c n_c(u) · TᵀΣ⁻¹T|_c

is, with the per-component R×R blocks flattened, one contraction:

    L[b] = I + (n[b, :] @ M)        with  M: (C, R²)

i.e. a (B, C) × (C, R²) matmul — MXU-shaped on TPU with the whole M
panel resident in VMEM (C·R² = 64·4096 floats ≈ 1 MiB at the scaled
dims; at paper scale this tiles over component blocks instead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _precision_kernel(n_ref, m_ref, eye_ref, out_ref):
    """One utterance-block: out = n @ M + vec(I) (broadcast)."""
    out_ref[...] = (
        jnp.dot(n_ref[...], m_ref[...], preferred_element_type=jnp.float32)
        + eye_ref[...]
    )


@functools.partial(jax.named_call, name="precision_matrices")
def precision_matrices(n, tt_si_t, *, block_b: int = 64):
    """L[b] = I_R + Σ_c n[b, c] · tt_si_t[c].

    n:        (B, C) occupancies
    tt_si_t:  (C, R, R) per-component TᵀΣ⁻¹T
    returns   (B, R, R) f32
    """
    b, c = n.shape
    r = tt_si_t.shape[1]
    assert tt_si_t.shape == (c, r, r)
    block_b = min(block_b, b)
    assert b % block_b == 0
    m = tt_si_t.reshape(c, r * r)
    eye = jnp.eye(r, dtype=jnp.float32).reshape(1, r * r)
    out = pl.pallas_call(
        _precision_kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((c, r * r), lambda i: (0, 0)),
            pl.BlockSpec((1, r * r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, r * r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r * r), jnp.float32),
        interpret=True,  # CPU-PJRT target
    )(n, m, eye)
    return out.reshape(b, r, r)
