"""Shared HLO-text export helper.

HLO *text* (not serialized HloModuleProto) is the interchange format with
the rust side: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a jax lowered computation to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, example_args, out_path: str) -> str:
    """jit + lower `fn` at `example_args` and write HLO text to `out_path`."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return text
