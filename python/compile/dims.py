"""Canonical static shapes shared by the AOT graphs and the rust side.

XLA executables are shape-specialized, so every graph is exported at
these dimensions; the rust coordinator pads final partial batches and
masks the padding. `write_manifest` records the dims next to the
artifacts so the rust runtime can validate its config against them.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Dims:
    # model dims (DESIGN.md scaled recipe)
    C: int = 64            # UBM components        (paper: 2048)
    F: int = 24            # feature dim           (paper: 72)
    R: int = 64            # i-vector dim          (paper: 400)
    K: int = 20            # top-K gaussians       (paper: 20)
    # batch shapes
    BF: int = 4096         # frames per align/ubm_acc dispatch
    BU: int = 64           # utterances per estep/extract dispatch
    # scoring shapes
    D: int = 32            # backend (post-LDA) dim (paper: 200)
    NE: int = 256          # enroll vectors per plda_score dispatch
    NT: int = 256          # test vectors per plda_score dispatch
    # constants baked into graphs
    min_post: float = 0.025

    @property
    def Q(self) -> int:
        """Expanded quadratic-feature dim for full-cov loglikes."""
        return self.F + self.F * self.F


DIMS = Dims()


def write_manifest(dims: Dims, path: str) -> None:
    """TOML-subset manifest the rust Config can check at load time."""
    lines = ["[dims]"] + [
        f"{name} = {getattr(dims, name)}"
        for name in ("C", "F", "R", "K", "BF", "BU", "D", "NE", "NT")
    ] + [f"min_post = {dims.min_post}"]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
