"""Build-time compile path: L2 jax graphs + L1 pallas kernels → HLO text.

Never imported at runtime — the rust binary consumes only the emitted
``artifacts/*.hlo.txt``.
"""
