"""AOT export: lower every L2 graph to HLO text under artifacts/.

Run once via `make artifacts` (python never executes at runtime):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits HLO *text* per graph (jax ≥ 0.5 serialized protos carry 64-bit
instruction ids that xla_extension 0.5.1 rejects; text re-parses
cleanly) plus `manifest.toml` recording the static dims so the rust
side can validate its config.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from . import model
from .dims import DIMS, write_manifest
from .hlo_export import export


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def graph_specs(d=DIMS):
    """(name, builder(), example-arg specs) for every exported graph."""
    return [
        (
            "align_topk",
            model.build_align_topk(d.K, d.min_post),
            (f32(d.BF, d.F), f32(d.C, 2 * d.F), f32(d.C), f32(d.C, d.Q), f32(d.C)),
        ),
        (
            "precompute",
            model.build_precompute(),
            (f32(d.C, d.F, d.R), f32(d.C, d.F, d.F)),
        ),
        (
            "estep",
            model.build_estep(),
            (
                f32(d.BU, d.C),
                f32(d.BU, d.C, d.F),
                f32(d.BU),
                f32(d.C, d.R, d.F),
                f32(d.C, d.R, d.R),
                f32(d.R),
            ),
        ),
        (
            "extract",
            model.build_extract(),
            (
                f32(d.BU, d.C),
                f32(d.BU, d.C, d.F),
                f32(d.C, d.R, d.F),
                f32(d.C, d.R, d.R),
                f32(d.R),
            ),
        ),
        (
            "ubm_acc",
            model.build_ubm_acc(),
            (f32(d.BF, d.F), f32(d.BF), f32(d.C, d.Q), f32(d.C)),
        ),
        (
            "plda_score",
            model.build_plda_score(),
            (f32(d.NE, d.D), f32(d.NT, d.D), f32(d.D, d.D), f32(d.D, d.D)),
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="export a single graph by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for name, fn, specs in graph_specs():
        if args.only and name != args.only:
            continue
        out = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = export(fn, specs, out)
        print(f"  {name:<12} {len(text):>9} chars -> {out}")

    write_manifest(DIMS, os.path.join(args.out_dir, "manifest.toml"))
    print(f"  manifest     -> {os.path.join(args.out_dir, 'manifest.toml')}")


if __name__ == "__main__":
    main()
