"""L2 JAX compute graphs — the device side of the stack.

Each builder returns a tuple-output jax function that `aot.py` lowers to
HLO text at the static shapes in `dims.py`. Constraints imposed by the
rust-side runtime (xla_extension 0.5.1):

* no `lax.top_k` (lowers to an unsupported `topk` instruction) —
  top-K is an iterative-argmax scan;
* no `jnp.linalg.*` (LAPACK custom-calls) — Cholesky/solves come from
  `kernels.chol` (pure lax);
* f32/i32 IO only.

Padding: batches are shape-specialized, so partial batches are padded
and a `mask` input (1.0 for real rows) zeroes padded contributions to
every accumulator output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import chol, loglikes, precision


def manual_top_k(x, k):
    """(values, indices) of the k largest entries per row.

    Iterative argmax — `lax.top_k` emits a `topk` HLO instruction that
    the 0.5.1 text parser rejects. k passes over a (B, C) array is
    cheap for k=20, C=64 (and on TPU stays in VMEM).
    """
    b = x.shape[0]
    rows = jnp.arange(b)

    def body(cur, _):
        idx = jnp.argmax(cur, axis=-1)
        val = jnp.take_along_axis(cur, idx[:, None], axis=-1)[:, 0]
        cur = cur.at[rows, idx].set(-jnp.inf)
        return cur, (val, idx.astype(jnp.int32))

    _, (vals, idx) = lax.scan(body, x, None, length=k)
    return jnp.moveaxis(vals, 0, -1), jnp.moveaxis(idx, 0, -1)


def build_align_topk(k: int, min_post: float):
    """Frame alignment graph (paper §4.2, the 3000×-RT hot path).

    inputs:  frames (BF, F), diag_w (C, 2F), diag_const (C,),
             full_w (C, F+F²), full_const (C,)
    outputs: posts (BF, K) f32, idx (BF, K) i32
    """

    def align(frames, diag_w, diag_const, full_w, full_const):
        qd = loglikes.expand_diag(frames)
        dll = loglikes.gmm_loglikes(qd, diag_w, diag_const)
        _, idx = manual_top_k(dll, k)

        qf = loglikes.expand_full(frames)
        fll = loglikes.gmm_loglikes(qf, full_w, full_const)
        sel = jnp.take_along_axis(fll, idx, axis=-1)            # (BF, K)

        # softmax over the selected components only
        m = jnp.max(sel, axis=-1, keepdims=True)
        p = jnp.exp(sel - m)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        # prune + keep-at-least-the-best + renormalize (Kaldi semantics)
        best = p >= jnp.max(p, axis=-1, keepdims=True)
        keep = (p >= min_post) | best
        p = jnp.where(keep, p, 0.0)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        return p, idx

    return align


def build_precompute():
    """Per-EM-iteration constants (paper eq. 3–4 inner terms).

    inputs:  t_mat (C, F, R), sigma_inv (C, F, F)
    outputs: tt_si (C, R, F) = TᵀΣ⁻¹,  tt_si_t (C, R, R) = TᵀΣ⁻¹T
    """

    def precompute(t_mat, sigma_inv):
        tt_si = jnp.einsum("cfr,cfg->crg", t_mat, sigma_inv)
        tt_si_t = jnp.einsum("crf,cfs->crs", tt_si, t_mat)
        # enforce exact symmetry (downstream cholesky assumes it)
        tt_si_t = 0.5 * (tt_si_t + jnp.swapaxes(tt_si_t, -1, -2))
        return tt_si, tt_si_t

    return precompute


def build_estep():
    """TVM training E-step over one utterance batch (paper §3, step 2).

    inputs:  n (BU, C), f (BU, C, F), mask (BU,),
             tt_si (C, R, F), tt_si_t (C, R, R), prior_mean (R,)
    outputs: acc_a (C, R, R)   Σ_u n_c(u)(Φ+φφᵀ)      [T-update lhs]
             acc_b (C, F, R)   Σ_u f_c(u) φ(u)ᵀ        [T-update rhs]
             acc_h (R,)        Σ_u φ(u)                [min-div, eq. 6]
             acc_hh (R, R)     Σ_u (Φ+φφᵀ)            [min-div, eq. 7]
             count ()          Σ_u mask
             phi (BU, R)       posterior means (masked)
    """

    def estep(n, f, mask, tt_si, tt_si_t, prior_mean):
        l_mat = precision.precision_matrices(n, tt_si_t)            # (B,R,R)
        rhs = prior_mean[None, :] + jnp.einsum("crf,bcf->br", tt_si, f)
        phi, cov = chol.chol_solve_and_inverse(l_mat, rhs)
        msk = mask[:, None]
        second = cov + phi[:, :, None] * phi[:, None, :]            # Φ+φφᵀ
        second_m = second * mask[:, None, None]
        n_m = n * msk
        acc_a = jnp.einsum("bc,brs->crs", n_m, second_m)
        acc_b = jnp.einsum("bcf,br->cfr", f * mask[:, None, None], phi)
        acc_h = jnp.sum(phi * msk, axis=0)
        acc_hh = jnp.sum(second_m, axis=0)
        count = jnp.sum(mask)
        return acc_a, acc_b, acc_h, acc_hh, count, phi * msk

    return estep


def build_extract():
    """I-vector extraction (paper §4.2, the 10 000×-RT path): posterior
    means only — no covariance, no accumulators.

    inputs:  n (BU, C), f (BU, C, F), tt_si (C,R,F), tt_si_t (C,R,R),
             prior_mean (R,)
    outputs: phi (BU, R)
    """

    def extract(n, f, tt_si, tt_si_t, prior_mean):
        l_mat = precision.precision_matrices(n, tt_si_t)
        rhs = prior_mean[None, :] + jnp.einsum("crf,bcf->br", tt_si, f)
        phi = chol.chol_solve(l_mat, rhs)
        return (phi,)

    return extract


def build_ubm_acc():
    """Full-covariance UBM EM accumulator over one frame batch.

    inputs:  frames (BF, F), mask (BF,), full_w (C, F+F²), full_const (C,)
    outputs: acc_n (C,), acc_f (C, F), acc_s (C, F, F), loglike ()
    """

    def ubm_acc(frames, mask, full_w, full_const):
        qf = loglikes.expand_full(frames)
        fll = loglikes.gmm_loglikes(qf, full_w, full_const)       # (BF, C)
        m = jnp.max(fll, axis=-1, keepdims=True)
        p = jnp.exp(fll - m)
        s = jnp.sum(p, axis=-1, keepdims=True)
        gamma = (p / s) * mask[:, None]
        acc_n = jnp.sum(gamma, axis=0)
        acc_f = jnp.einsum("bc,bf->cf", gamma, frames)
        acc_s = jnp.einsum("bc,bf,bg->cfg", gamma, frames, frames)
        loglike = jnp.sum((jnp.log(s[:, 0]) + m[:, 0]) * mask)
        return acc_n, acc_f, acc_s, loglike

    return ubm_acc


def build_plda_score():
    """Batch PLDA trial scoring.

    inputs:  enroll (NE, D), test (NT, D), p_mat (D, D), q_mat (D, D)
    outputs: scores (NE, NT) with
             score(e,t) = ½eᵀQe + ½tᵀQt + eᵀPt
    """

    def score(enroll, test, p_mat, q_mat):
        e_q = 0.5 * jnp.einsum("nd,de,ne->n", enroll, q_mat, enroll)
        t_q = 0.5 * jnp.einsum("md,de,me->m", test, q_mat, test)
        cross = enroll @ p_mat @ test.T
        return (e_q[:, None] + t_q[None, :] + cross,)

    return score
