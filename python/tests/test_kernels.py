"""L1 kernel correctness: pallas kernels vs pure-jnp/numpy oracles,
with hypothesis sweeping shapes and seeds."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chol, loglikes, precision, ref

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------- loglikes


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([4, 16, 64, 128, 256]),
    c=st.integers(2, 40),
    d=st.integers(1, 50),
    seed=st.integers(0, 2**31),
)
def test_loglikes_kernel_matches_ref(b, c, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, b, d)
    w = rand(rng, c, d)
    const = rand(rng, c)
    got = loglikes.gmm_loglikes(q, w, const, block_b=min(64, b))
    want = ref.gmm_loglikes_ref(q, w, const)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([8, 32]), c=st.integers(2, 12), f=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_diag_packing_reproduces_textbook_loglikes(b, c, f, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, f))
    means = rng.standard_normal((c, f))
    variances = rng.uniform(0.3, 2.0, (c, f))
    weights = rng.dirichlet(np.ones(c))
    w, const = loglikes.pack_diag_weights(
        jnp.asarray(means, jnp.float32),
        jnp.asarray(1.0 / variances, jnp.float32),
        jnp.asarray(np.log(weights), jnp.float32),
    )
    got = loglikes.gmm_loglikes(loglikes.expand_diag(jnp.asarray(x, jnp.float32)), w, const, block_b=b)
    want = ref.diag_loglikes_direct(x, means, variances, weights)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([8, 32]), c=st.integers(2, 8), f=st.integers(2, 8), seed=st.integers(0, 2**31))
def test_full_packing_reproduces_textbook_loglikes(b, c, f, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, f))
    means = rng.standard_normal((c, f))
    covs = np.stack([_spd(rng, f) for _ in range(c)])
    weights = rng.dirichlet(np.ones(c))
    inv_covs = np.linalg.inv(covs)
    logdets = np.linalg.slogdet(covs)[1]
    w, const = loglikes.pack_full_weights(
        jnp.asarray(means, jnp.float32),
        jnp.asarray(inv_covs, jnp.float32),
        jnp.asarray(np.log(weights), jnp.float32),
        jnp.asarray(logdets, jnp.float32),
    )
    got = loglikes.gmm_loglikes(loglikes.expand_full(jnp.asarray(x, jnp.float32)), w, const, block_b=b)
    want = ref.full_loglikes_direct(x, means, covs, weights)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=2e-3)


def _spd(rng, f):
    m = rng.standard_normal((f, f))
    return m @ m.T + f * np.eye(f)


# ---------------------------------------------------------------- precision


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([2, 8, 64]),
    c=st.integers(1, 24),
    r=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_precision_kernel_matches_ref(b, c, r, seed):
    rng = np.random.default_rng(seed)
    n = jnp.asarray(rng.uniform(0, 50, (b, c)), jnp.float32)
    m = rand(rng, c, r, r)
    got = precision.precision_matrices(n, m, block_b=min(32, b))
    want = ref.precision_ref(n, m)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- cholesky


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 8), r=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_batched_cholesky_reconstructs(b, r, seed):
    rng = np.random.default_rng(seed)
    a = np.stack([_spd(rng, r) for _ in range(b)]).astype(np.float32)
    l = chol.batched_cholesky(jnp.asarray(a))
    rec = np.einsum("bik,bjk->bij", l, l)
    np.testing.assert_allclose(rec, a, rtol=1e-3, atol=1e-3)
    # lower triangular
    upper = np.triu(np.asarray(l), k=1)
    np.testing.assert_allclose(upper, 0.0, atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 8), r=st.integers(1, 32), seed=st.integers(0, 2**31))
def test_chol_solve_matches_numpy(b, r, seed):
    rng = np.random.default_rng(seed)
    a = np.stack([_spd(rng, r) for _ in range(b)]).astype(np.float32)
    rhs = rng.standard_normal((b, r)).astype(np.float32)
    got = chol.chol_solve(jnp.asarray(a), jnp.asarray(rhs))
    want = np.linalg.solve(a, rhs[..., None])[..., 0]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_chol_solve_and_inverse():
    rng = np.random.default_rng(0)
    a = np.stack([_spd(rng, 16) for _ in range(4)]).astype(np.float32)
    rhs = rng.standard_normal((4, 16)).astype(np.float32)
    x, inv = chol.chol_solve_and_inverse(jnp.asarray(a), jnp.asarray(rhs))
    eye = np.broadcast_to(np.eye(16, dtype=np.float32), (4, 16, 16))
    np.testing.assert_allclose(np.einsum("bij,bjk->bik", a, inv), eye, atol=2e-3)
    np.testing.assert_allclose(x, np.einsum("bij,bj->bi", inv, rhs), rtol=2e-3, atol=2e-3)
    # inverse is symmetric by construction
    np.testing.assert_allclose(inv, np.swapaxes(np.asarray(inv), 1, 2), atol=1e-6)


def test_solves_are_jittable():
    # guards the export path: everything must trace under jit
    rng = np.random.default_rng(3)
    a = np.stack([_spd(rng, 8) for _ in range(2)]).astype(np.float32)
    rhs = rng.standard_normal((2, 8)).astype(np.float32)
    got = jax.jit(chol.chol_solve)(jnp.asarray(a), jnp.asarray(rhs))
    want = np.linalg.solve(a, rhs[..., None])[..., 0]
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
