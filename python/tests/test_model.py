"""L2 graph semantics: jitted graph builders vs direct numpy references."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import loglikes, ref


def _spd(rng, f, ridge=None):
    m = rng.standard_normal((f, f))
    return m @ m.T + (ridge if ridge is not None else f) * np.eye(f)


def _gmm_params(rng, c, f):
    means = rng.standard_normal((c, f))
    covs = np.stack([_spd(rng, f) for _ in range(c)])
    dvars = rng.uniform(0.3, 2.0, (c, f))
    weights = rng.dirichlet(np.ones(c))
    return means, covs, dvars, weights


def _packed(means, covs, dvars, weights):
    diag_w, diag_const = loglikes.pack_diag_weights(
        jnp.asarray(means, jnp.float32),
        jnp.asarray(1.0 / dvars, jnp.float32),
        jnp.asarray(np.log(weights), jnp.float32),
    )
    inv = np.linalg.inv(covs)
    logdet = np.linalg.slogdet(covs)[1]
    full_w, full_const = loglikes.pack_full_weights(
        jnp.asarray(means, jnp.float32),
        jnp.asarray(inv, jnp.float32),
        jnp.asarray(np.log(weights), jnp.float32),
        jnp.asarray(logdet, jnp.float32),
    )
    return diag_w, diag_const, full_w, full_const


# ------------------------------------------------------------- align_topk


def test_align_matches_reference_semantics():
    rng = np.random.default_rng(42)
    b, c, f, k, min_post = 16, 12, 4, 5, 0.025
    means, covs, dvars, weights = _gmm_params(rng, c, f)
    x = rng.standard_normal((b, f)).astype(np.float32)

    align = jax.jit(model.build_align_topk(k, min_post))
    posts, idx = align(jnp.asarray(x), *_packed(means, covs, dvars, weights))
    posts, idx = np.asarray(posts), np.asarray(idx)

    want_posts, want_idx = ref.align_ref(
        x, means, dvars, weights, means, covs, weights, k, min_post
    )

    for t in range(b):
        got = {int(i): float(p) for i, p in zip(idx[t], posts[t]) if p > 0}
        want = {int(i): float(p) for i, p in zip(want_idx[t], want_posts[t]) if p > 0}
        assert set(got) == set(want), f"frame {t}: {got} vs {want}"
        for i in got:
            assert got[i] == pytest.approx(want[i], rel=2e-3, abs=2e-4)


def test_align_posteriors_sum_to_one_and_pruned():
    rng = np.random.default_rng(7)
    b, c, f, k, min_post = 32, 16, 3, 6, 0.025
    means, covs, dvars, weights = _gmm_params(rng, c, f)
    x = rng.standard_normal((b, f)).astype(np.float32)
    align = jax.jit(model.build_align_topk(k, min_post))
    posts, idx = align(jnp.asarray(x), *_packed(means, covs, dvars, weights))
    posts = np.asarray(posts)
    np.testing.assert_allclose(posts.sum(axis=1), 1.0, rtol=1e-5)
    nz = posts[posts > 0]
    assert (nz >= min_post - 1e-6).all()
    # indices within range and unique per frame
    idx = np.asarray(idx)
    assert (idx >= 0).all() and (idx < c).all()
    for t in range(b):
        assert len(set(idx[t].tolist())) == k


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), k=st.integers(1, 8))
def test_manual_top_k_matches_numpy(seed, k):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, 12)).astype(np.float32)
    vals, idx = jax.jit(lambda a: model.manual_top_k(a, k))(jnp.asarray(x))
    want = np.sort(x, axis=1)[:, ::-1][:, :k]
    np.testing.assert_allclose(vals, want, rtol=1e-6)
    # indices actually point at the values
    np.testing.assert_allclose(
        np.take_along_axis(x, np.asarray(idx), axis=1), want, rtol=1e-6
    )


# ------------------------------------------------------------- estep


def _tvm_inputs(rng, b, c, f, r):
    n = rng.uniform(0, 20, (b, c)).astype(np.float32)
    fs = rng.standard_normal((b, c, f)).astype(np.float32)
    t_mat = (rng.standard_normal((c, f, r)) * 0.3).astype(np.float32)
    sigma_inv = np.stack([np.linalg.inv(_spd(rng, f)) for _ in range(c)]).astype(np.float32)
    p = np.zeros(r, dtype=np.float32)
    p[0] = 10.0
    return n, fs, t_mat, sigma_inv, p


def test_estep_phi_matches_reference():
    rng = np.random.default_rng(3)
    b, c, f, r = 8, 6, 4, 10
    n, fs, t_mat, sigma_inv, p = _tvm_inputs(rng, b, c, f, r)

    pre = jax.jit(model.build_precompute())
    tt_si, tt_si_t = pre(jnp.asarray(t_mat), jnp.asarray(sigma_inv))
    estep = jax.jit(model.build_estep())
    mask = np.ones(b, dtype=np.float32)
    acc_a, acc_b, acc_h, acc_hh, count, phi = estep(
        jnp.asarray(n), jnp.asarray(fs), jnp.asarray(mask), tt_si, tt_si_t, jnp.asarray(p)
    )

    want_phi, want_cov = ref.estep_ref(n, fs, t_mat, sigma_inv, p)
    np.testing.assert_allclose(phi, want_phi, rtol=2e-3, atol=2e-3)
    assert float(count) == b

    # accumulators vs direct sums
    second = want_cov + np.einsum("br,bs->brs", want_phi, want_phi)
    np.testing.assert_allclose(
        acc_a, np.einsum("bc,brs->crs", n, second), rtol=3e-3, atol=3e-3
    )
    np.testing.assert_allclose(
        acc_b, np.einsum("bcf,br->cfr", fs, want_phi), rtol=3e-3, atol=3e-3
    )
    np.testing.assert_allclose(acc_h, want_phi.sum(0), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(acc_hh, second.sum(0), rtol=3e-3, atol=3e-3)


def test_estep_mask_zeroes_padding():
    rng = np.random.default_rng(5)
    b, c, f, r = 8, 6, 4, 10
    n, fs, t_mat, sigma_inv, p = _tvm_inputs(rng, b, c, f, r)
    pre = jax.jit(model.build_precompute())
    tt_si, tt_si_t = pre(jnp.asarray(t_mat), jnp.asarray(sigma_inv))
    estep = jax.jit(model.build_estep())

    # full batch on first half only, second half zero-masked
    mask = np.array([1.0] * 4 + [0.0] * 4, dtype=np.float32)
    out_masked = estep(jnp.asarray(n), jnp.asarray(fs), jnp.asarray(mask), tt_si, tt_si_t, jnp.asarray(p))
    # reference: just the first half, padded with zeros
    n2 = n.copy()
    fs2 = fs.copy()
    n2[4:] = 0
    fs2[4:] = 0
    out_zero = estep(jnp.asarray(n2), jnp.asarray(fs2), jnp.asarray(np.ones(b, np.float32) * mask), tt_si, tt_si_t, jnp.asarray(p))
    for a, z in zip(out_masked[:5], out_zero[:5]):
        np.testing.assert_allclose(a, z, rtol=1e-4, atol=1e-4)
    assert float(out_masked[4]) == 4.0
    # masked phi rows are exactly zero
    np.testing.assert_allclose(np.asarray(out_masked[5])[4:], 0.0)


def test_extract_matches_estep_phi():
    rng = np.random.default_rng(11)
    b, c, f, r = 8, 6, 4, 10
    n, fs, t_mat, sigma_inv, p = _tvm_inputs(rng, b, c, f, r)
    pre = jax.jit(model.build_precompute())
    tt_si, tt_si_t = pre(jnp.asarray(t_mat), jnp.asarray(sigma_inv))
    (phi_ex,) = jax.jit(model.build_extract())(
        jnp.asarray(n), jnp.asarray(fs), tt_si, tt_si_t, jnp.asarray(p)
    )
    mask = jnp.ones(b, jnp.float32)
    phi_es = jax.jit(model.build_estep())(
        jnp.asarray(n), jnp.asarray(fs), mask, tt_si, tt_si_t, jnp.asarray(p)
    )[5]
    np.testing.assert_allclose(phi_ex, phi_es, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- precompute


def test_precompute_matches_direct_einsum():
    rng = np.random.default_rng(13)
    c, f, r = 5, 4, 7
    t_mat = rng.standard_normal((c, f, r)).astype(np.float32)
    sigma_inv = np.stack([np.linalg.inv(_spd(rng, f)) for _ in range(c)]).astype(np.float32)
    tt_si, tt_si_t = jax.jit(model.build_precompute())(jnp.asarray(t_mat), jnp.asarray(sigma_inv))
    np.testing.assert_allclose(
        tt_si, np.einsum("cfr,cfg->crg", t_mat, sigma_inv), rtol=1e-4, atol=1e-4
    )
    want = np.einsum("cfr,cfg,cgs->crs", t_mat, sigma_inv, t_mat)
    np.testing.assert_allclose(tt_si_t, 0.5 * (want + np.swapaxes(want, 1, 2)), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- ubm_acc


def test_ubm_acc_matches_direct():
    rng = np.random.default_rng(17)
    b, c, f = 32, 6, 4
    means, covs, _, weights = _gmm_params(rng, c, f)
    x = rng.standard_normal((b, f)).astype(np.float32)
    mask = np.ones(b, dtype=np.float32)
    mask[-5:] = 0.0
    _, _, full_w, full_const = _packed(means, covs, np.ones((c, f)), weights)

    acc_n, acc_f, acc_s, ll = jax.jit(model.build_ubm_acc())(
        jnp.asarray(x), jnp.asarray(mask), full_w, full_const
    )

    fll = ref.full_loglikes_direct(x, means, covs, weights)
    gamma = np.exp(fll - fll.max(axis=1, keepdims=True))
    gamma /= gamma.sum(axis=1, keepdims=True)
    gamma *= mask[:, None]
    np.testing.assert_allclose(acc_n, gamma.sum(0), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(acc_f, np.einsum("bc,bf->cf", gamma, x), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        acc_s, np.einsum("bc,bf,bg->cfg", gamma, x, x), rtol=2e-3, atol=2e-3
    )
    from scipy.special import logsumexp

    np.testing.assert_allclose(ll, (logsumexp(fll, axis=1) * mask).sum(), rtol=1e-3)


# ------------------------------------------------------------- plda_score


def test_plda_score_matches_ref():
    rng = np.random.default_rng(19)
    ne, nt, d = 6, 9, 5
    enroll = rng.standard_normal((ne, d)).astype(np.float32)
    test = rng.standard_normal((nt, d)).astype(np.float32)
    p_mat = _spd(rng, d).astype(np.float32)
    q_mat = (-_spd(rng, d)).astype(np.float32)
    (got,) = jax.jit(model.build_plda_score())(
        jnp.asarray(enroll), jnp.asarray(test), jnp.asarray(p_mat), jnp.asarray(q_mat)
    )
    want = ref.plda_score_ref(enroll, test, p_mat, q_mat)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
