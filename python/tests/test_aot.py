"""AOT export path: HLO text emission + runtime-compatibility lint.

The rust-side XLA (xla_extension 0.5.1) rejects certain jax-0.8
lowerings; these tests lint the emitted text so breakage is caught at
build time, not when the coordinator loads the artifact.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest

from compile import aot
from compile.dims import DIMS, Dims, write_manifest
from compile.hlo_export import to_hlo_text

import jax


# instructions/attributes the 0.5.1 HLO text parser rejects
FORBIDDEN = [
    " topk(",          # lax.top_k lowering
    "custom-call",     # LAPACK / Mosaic custom-calls can't be resolved
    "f64[",            # graphs must stay f32 (x64 would also break protos)
    "s64[",
]


@pytest.fixture(scope="module")
def lowered_texts():
    out = {}
    for name, fn, specs in aot.graph_specs():
        lowered = jax.jit(fn).lower(*specs)
        out[name] = to_hlo_text(lowered)
    return out


def test_all_graphs_lower(lowered_texts):
    assert set(lowered_texts) == {
        "align_topk",
        "precompute",
        "estep",
        "extract",
        "ubm_acc",
        "plda_score",
    }
    for name, text in lowered_texts.items():
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_no_forbidden_instructions(lowered_texts):
    for name, text in lowered_texts.items():
        for bad in FORBIDDEN:
            assert bad not in text, f"{name} contains forbidden `{bad}`"


def test_entry_shapes_match_dims(lowered_texts):
    d = DIMS
    text = lowered_texts["estep"]
    # entry computation mentions the utterance-batch input shape
    assert f"f32[{d.BU},{d.C}]" in text
    assert f"f32[{d.BU},{d.C},{d.F}]" in text
    text = lowered_texts["align_topk"]
    assert f"f32[{d.BF},{d.F}]" in text
    assert f"s32[{d.BF},{d.K}]" in text


def test_manifest_roundtrip(tmp_path):
    p = tmp_path / "manifest.toml"
    write_manifest(Dims(), str(p))
    content = p.read_text()
    assert "[dims]" in content
    assert f"C = {Dims().C}" in content
    assert f"min_post = {Dims().min_post}" in content


def test_export_writes_files(tmp_path):
    # export the cheapest graph end-to-end through the CLI-equivalent path
    from compile.hlo_export import export
    import jax.numpy as jnp

    name, fn, specs = [g for g in aot.graph_specs() if g[0] == "precompute"][0]
    out = tmp_path / f"{name}.hlo.txt"
    text = export(fn, specs, str(out))
    assert out.exists()
    assert out.read_text() == text
