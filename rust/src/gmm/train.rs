//! UBM training recipe (the paper delegates this to Kaldi; we build it):
//! global-stats init → binary splitting → diagonal EM → full-cov EM.

use anyhow::Result;

use crate::config::UbmConfig;
use crate::io::FeatArchive;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::stats::BwStats;

use super::{select_posteriors, DiagGmm, FullGmm};

/// The trained UBM pair: diagonal (pre-select) + full (refine).
pub struct UbmPair {
    pub diag: DiagGmm,
    pub full: FullGmm,
}

/// Subsample up to `max_frames` frames from the archive (round-robin
/// over utterances, deterministic).
pub fn pool_frames(archive: &FeatArchive, max_frames: usize, seed: u64) -> Mat {
    let total: usize = archive.total_frames();
    let dim = archive.dim();
    let take = total.min(max_frames);
    let mut rng = Rng::seed(seed);
    // keep-probability subsampling, then truncate
    let keep_p = take as f64 / total as f64;
    let mut out = Mat::zeros(take, dim);
    let mut k = 0;
    'outer: for u in &archive.utts {
        for t in 0..u.feats.rows() {
            if rng.uniform() <= keep_p {
                out.row_mut(k).copy_from_slice(u.feats.row(t));
                k += 1;
                if k == take {
                    break 'outer;
                }
            }
        }
    }
    if k < take {
        // fill the tail from the beginning (rare rounding shortfall)
        let mut idx = 0usize;
        while k < take {
            let u = &archive.utts[idx % archive.utts.len()];
            out.row_mut(k).copy_from_slice(u.feats.row(idx % u.feats.rows()));
            k += 1;
            idx += 1;
        }
    }
    out
}

/// Initialize a 1-component diagonal GMM from global stats, then grow
/// to `target` components by binary splitting + EM.
fn init_diag_by_splitting(
    data: &Mat,
    target: usize,
    em_iters: usize,
    var_floor: f64,
    seed: u64,
) -> DiagGmm {
    let dim = data.cols();
    let t_len = data.rows();
    // global mean/var
    let mut mean = vec![0.0; dim];
    let mut var = vec![0.0; dim];
    for t in 0..t_len {
        for (j, &x) in data.row(t).iter().enumerate() {
            mean[j] += x;
            var[j] += x * x;
        }
    }
    for j in 0..dim {
        mean[j] /= t_len as f64;
        var[j] = (var[j] / t_len as f64 - mean[j] * mean[j]).max(var_floor);
    }
    let mut g = DiagGmm {
        weights: vec![1.0],
        means: Mat::from_vec(mean, 1, dim),
        vars: Mat::from_vec(var, 1, dim),
    };
    let mut rng = Rng::seed(seed);
    while g.num_components() < target {
        g = split_gmm(&g, target, &mut rng);
        for _ in 0..em_iters.max(1) {
            g.em_step(data, var_floor);
        }
    }
    g
}

/// Binary splitting: each component splits into two, means perturbed
/// ±0.1·σ along each axis (Kaldi's `gmm-global-init-from-feats` style).
fn split_gmm(g: &DiagGmm, cap: usize, rng: &mut Rng) -> DiagGmm {
    let c_old = g.num_components();
    let c_new = (2 * c_old).min(cap);
    let dim = g.dim();
    let mut weights = Vec::with_capacity(c_new);
    let mut means = Mat::zeros(c_new, dim);
    let mut vars = Mat::zeros(c_new, dim);
    // split the heaviest components first when capped
    let mut order: Vec<usize> = (0..c_old).collect();
    order.sort_by(|&a, &b| g.weights[b].partial_cmp(&g.weights[a]).unwrap());
    let n_split = c_new - c_old;
    let mut slot = 0;
    for (rank, &c) in order.iter().enumerate() {
        if rank < n_split {
            for sign in [-1.0, 1.0] {
                weights.push(g.weights[c] / 2.0);
                for j in 0..dim {
                    let sigma = g.vars.get(c, j).sqrt();
                    means.set(slot, j, g.means.get(c, j) + sign * (0.1 + 0.02 * rng.uniform()) * sigma);
                    vars.set(slot, j, g.vars.get(c, j));
                }
                slot += 1;
            }
        } else {
            weights.push(g.weights[c]);
            means.row_mut(slot).copy_from_slice(g.means.row(c));
            vars.row_mut(slot).copy_from_slice(g.vars.row(c));
            slot += 1;
        }
    }
    DiagGmm { weights, means, vars }
}

/// Full UBM recipe over a training archive. Returns the diag + full
/// pair and the per-iteration mean log-likelihoods (diagnostics).
pub fn train_ubm(archive: &FeatArchive, cfg: &UbmConfig, seed: u64) -> Result<(UbmPair, Vec<f64>)> {
    let data = pool_frames(archive, cfg.train_frames, seed);
    let mut lls = Vec::new();

    // stage 1: diagonal UBM by splitting + EM
    let mut diag = init_diag_by_splitting(&data, cfg.components, 2, cfg.var_floor, seed);
    for _ in 0..cfg.diag_em_iters {
        lls.push(diag.em_step(&data, cfg.var_floor));
    }

    // stage 2: full-covariance EM, initialized from the diagonal model.
    // E-step via the production alignment path (top-K + pruning) so the
    // UBM sees exactly the posteriors the extractor will. Parallelized
    // over frame chunks (this stage dominated experiment setup time
    // single-threaded — see EXPERIMENTS.md §Perf).
    let workers = crate::exec::default_workers();
    let mut full = FullGmm::from_diag(&diag)?;
    for _ in 0..cfg.full_em_iters {
        let chunk_rows = data.rows().div_ceil(workers).max(1);
        let n_chunks = data.rows().div_ceil(chunk_rows);
        let partials = crate::exec::map_parallel(n_chunks, workers, |k| {
            let lo = k * chunk_rows;
            let hi = ((k + 1) * chunk_rows).min(data.rows());
            let mut block = Mat::zeros(hi - lo, data.cols());
            for t in lo..hi {
                block.row_mut(t - lo).copy_from_slice(data.row(t));
            }
            let posts = select_posteriors(&diag, &full, &block, cfg.components.min(20), 1e-8);
            BwStats::accumulate(&block, &posts, cfg.components, true)
        });
        let mut acc = BwStats::zeros(cfg.components, data.cols(), true);
        for p in &partials {
            acc.merge(p);
        }
        full.update_from_stats(&acc, cfg.var_floor)?;
    }
    Ok((UbmPair { diag, full }, lls))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::frontend::synth::generate_corpus;

    fn tiny_corpus() -> FeatArchive {
        let cfg = CorpusConfig {
            n_train_speakers: 6,
            utts_per_train_speaker: 3,
            n_eval_speakers: 2,
            utts_per_eval_speaker: 2,
            min_frames: 60,
            max_frames: 90,
            base_dim: 4,
            true_components: 8,
            speaker_rank: 4,
            speaker_scale: 0.4,
            channel_rank: 2,
            channel_scale: 0.15,
            stay_prob: 0.85,
            silence_frac: 0.1,
            seed: 77,
        };
        generate_corpus(&cfg).unwrap().train
    }

    #[test]
    fn pool_frames_bounds() {
        let arch = tiny_corpus();
        let pooled = pool_frames(&arch, 500, 1);
        assert_eq!(pooled.rows(), 500.min(arch.total_frames()));
        assert_eq!(pooled.cols(), arch.dim());
    }

    #[test]
    fn splitting_reaches_target_and_em_converges() {
        let arch = tiny_corpus();
        let data = pool_frames(&arch, 2000, 2);
        let g = init_diag_by_splitting(&data, 8, 2, 1e-3, 3);
        assert_eq!(g.num_components(), 8);
        assert!((g.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn split_respects_cap() {
        let data = Mat::from_fn(100, 2, |t, j| (t % 7) as f64 + j as f64);
        let g = init_diag_by_splitting(&data, 5, 1, 1e-3, 4);
        assert_eq!(g.num_components(), 5);
    }

    #[test]
    fn full_ubm_training_improves_likelihood() {
        let arch = tiny_corpus();
        let cfg = UbmConfig {
            components: 8,
            diag_em_iters: 4,
            full_em_iters: 2,
            train_frames: 3000,
            var_floor: 1e-3,
        };
        let (pair, lls) = train_ubm(&arch, &cfg, 5).unwrap();
        assert_eq!(pair.full.num_components(), 8);
        // diagonal EM non-decreasing
        for w in lls.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "diag EM decreased: {w:?}");
        }
        // full model beats the diagonal model on pooled data
        let data = pool_frames(&arch, 500, 9);
        let mut diag_ll = 0.0;
        let mut full_ll = 0.0;
        for t in 0..data.rows() {
            diag_ll += pair.diag.frame_log_like(data.row(t));
            full_ll += pair.full.frame_log_like(data.row(t));
        }
        assert!(full_ll > diag_ll, "full {full_ll} vs diag {diag_ll}");
    }
}
