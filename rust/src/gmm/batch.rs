//! Batched, GEMM-shaped CPU frame alignment.
//!
//! The scalar reference ([`super::select_posteriors_scalar`]) walks one
//! frame at a time and re-derives `ln v` and `1/v` for every (frame,
//! component, dim) triple inside `DiagGmm::log_likes`. This module
//! mirrors what the accelerated `align_topk` graph does on device —
//! and what `pack_diag_params` feeds it: the diagonal scores of a whole
//! frame block become one `[x; x²] · Wᵀ` matrix product against a
//! packed `(C × 2F)` weight matrix whose per-component constants absorb
//! every log/divide, followed by top-K selection and full-covariance
//! rescoring of only the K survivors.
//!
//! All scratch lives in the aligner, so the per-frame inner loop
//! allocates nothing beyond the output posting lists.

use crate::io::Posting;
use crate::linalg::Mat;

use super::select::{prune_posteriors, top_k_into};
use super::{DiagGmm, FullGmm, LOG_2PI};

/// Frames scored per matrix product. Big enough that the packed weight
/// matrix is re-read from cache across the block, small enough that the
/// score block (`BLOCK × C`) stays modest.
const BLOCK: usize = 128;

/// Shared-dimension panel width for the score product (2F is usually
/// below this, i.e. a single panel).
const QB: usize = 512;

/// The precomputed diagonal score expansion (the f64 mirror of
/// [`crate::ivector::accel::pack_diag_params`]): a pure function of the
/// diagonal UBM, so long-lived callers (the serving engine's
/// [`crate::serve::ServeModel`]) pack once per model and share it
/// across requests instead of re-deriving every ln/divide per aligner.
#[derive(Debug, Clone)]
pub struct PackedDiag {
    /// Packed diagonal score weights (C × 2F): row c = [m/v ; −½/v].
    w: Mat,
    /// Per-component constants folding ln w_c, ln v and m²/v.
    consts: Vec<f64>,
    /// Feature dim F.
    dim: usize,
}

impl PackedDiag {
    /// Pack the diagonal UBM.
    pub fn new(diag: &DiagGmm) -> Self {
        let (c_n, f_dim) = (diag.num_components(), diag.dim());
        let mut w = Mat::zeros(c_n, 2 * f_dim);
        let mut consts = vec![0.0; c_n];
        for c in 0..c_n {
            let mut const_c =
                diag.weights[c].max(1e-300).ln() - 0.5 * f_dim as f64 * LOG_2PI;
            let m = diag.means.row(c);
            let v = diag.vars.row(c);
            let wr = w.row_mut(c);
            for j in 0..f_dim {
                let vinv = 1.0 / v[j];
                wr[j] = m[j] * vinv;
                wr[f_dim + j] = -0.5 * vinv;
                const_c -= 0.5 * (v[j].ln() + m[j] * m[j] * vinv);
            }
            consts[c] = const_c;
        }
        Self { w, consts, dim: f_dim }
    }

    /// Components C.
    pub fn num_components(&self) -> usize {
        self.w.rows()
    }

    /// Feature dim F.
    pub fn feat_dim(&self) -> usize {
        self.dim
    }
}

/// The aligner's reusable scratch buffers, split from the model refs so
/// long-lived callers (the serving engine) can pool them across
/// requests the way batch workers reuse an `EstepWorkspace`. At paper
/// dims (C = 2048, F = 60) the two block buffers alone are
/// `BLOCK × (2F + C) × 8 B ≈ 2.2 MB` — rebuilding that per request is
/// pure allocator churn, since the buffers depend only on (F, C), never
/// on the utterance.
#[derive(Debug, Clone)]
pub struct AlignScratch {
    /// Augmented frame block [x ; x²] (BLOCK × 2F).
    aug: Mat,
    /// Diagonal scores (BLOCK × C).
    scores: Mat,
    /// Top-K selection buffer.
    sel: Vec<u32>,
    /// Full-covariance log-likes of the selected components.
    ll_sel: Vec<f64>,
}

impl AlignScratch {
    /// Allocate scratch for a (feature dim, component count) shape.
    pub fn new(f_dim: usize, c_n: usize) -> Self {
        Self {
            aug: Mat::zeros(BLOCK, 2 * f_dim),
            scores: Mat::zeros(BLOCK, c_n),
            sel: Vec::new(),
            ll_sel: Vec::new(),
        }
    }

    /// Whether this scratch was sized for the given model shape.
    pub fn fits(&self, f_dim: usize, c_n: usize) -> bool {
        self.aug.cols() == 2 * f_dim && self.scores.cols() == c_n
    }
}

/// Batched two-stage aligner with reusable scratch buffers.
///
/// Equivalent to the scalar path up to floating-point rounding: the
/// packed expansion evaluates `x·(m/v) − ½x²/v + const_c` instead of
/// `−½(x−m)²/v − ½ ln v + ln w_c + …`, which agrees to ~1e-12 relative.
pub struct BatchAligner<'g> {
    full: &'g FullGmm,
    top_k: usize,
    min_post: f64,
    /// Diagonal score expansion (owned, or borrowed from a caller that
    /// amortizes the pack across many aligners).
    packed: std::borrow::Cow<'g, PackedDiag>,
    /// Working buffers (owned here; poolable via [`Self::with_scratch`]
    /// / [`Self::into_scratch`]).
    scratch: AlignScratch,
}

impl<'g> BatchAligner<'g> {
    /// Pack the diagonal UBM once and build the aligner.
    pub fn new(diag: &DiagGmm, full: &'g FullGmm, top_k: usize, min_post: f64) -> Self {
        let packed = std::borrow::Cow::Owned(PackedDiag::new(diag));
        let scratch = AlignScratch::new(packed.dim, packed.num_components());
        Self::build(packed, full, top_k, min_post, scratch)
    }

    /// Build over an already-packed diagonal UBM (the pack is
    /// per-model, only the scratch is per-aligner).
    pub fn with_packed(
        packed: &'g PackedDiag,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
    ) -> Self {
        let scratch = AlignScratch::new(packed.dim, packed.num_components());
        Self::build(std::borrow::Cow::Borrowed(packed), full, top_k, min_post, scratch)
    }

    /// Build over a shared pack **and** recycled scratch — the serving
    /// hot path (zero per-request buffer builds). Scratch of the wrong
    /// shape is defensively replaced rather than trusted.
    pub fn with_scratch(
        packed: &'g PackedDiag,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        scratch: AlignScratch,
    ) -> Self {
        let scratch = if scratch.fits(packed.dim, packed.num_components()) {
            scratch
        } else {
            AlignScratch::new(packed.dim, packed.num_components())
        };
        Self::build(std::borrow::Cow::Borrowed(packed), full, top_k, min_post, scratch)
    }

    /// Recover the scratch for reuse (pool check-in).
    pub fn into_scratch(self) -> AlignScratch {
        self.scratch
    }

    fn build(
        packed: std::borrow::Cow<'g, PackedDiag>,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        scratch: AlignScratch,
    ) -> Self {
        Self { full, top_k, min_post, packed, scratch }
    }

    /// Align a whole utterance, streaming BLOCK-sized frame blocks.
    pub fn align_utterance(&mut self, feats: &Mat) -> Vec<Vec<Posting>> {
        assert_eq!(feats.cols(), self.packed.dim, "feature dim mismatch");
        let mut out = Vec::with_capacity(feats.rows());
        let mut start = 0;
        while start < feats.rows() {
            let n = (feats.rows() - start).min(BLOCK);
            self.align_block(feats, start, n, &mut out);
            start += n;
        }
        out
    }

    /// Score + select + rescore + prune one block of `n` frames
    /// starting at row `start`, appending per-frame postings to `out`.
    fn align_block(&mut self, feats: &Mat, start: usize, n: usize, out: &mut Vec<Vec<Posting>>) {
        let f_dim = self.packed.dim;
        for t in 0..n {
            let x = feats.row(start + t);
            let arow = self.scratch.aug.row_mut(t);
            for (j, &xj) in x.iter().enumerate() {
                arow[j] = xj;
                arow[f_dim + j] = xj * xj;
            }
        }
        score_rows(
            &self.scratch.aug,
            n,
            &self.packed.w,
            &self.packed.consts,
            &mut self.scratch.scores,
        );
        for t in 0..n {
            top_k_into(self.scratch.scores.row(t), self.top_k, &mut self.scratch.sel);
            self.scratch.ll_sel.resize(self.scratch.sel.len(), 0.0);
            self.full.log_likes_select(
                feats.row(start + t),
                &self.scratch.sel,
                &mut self.scratch.ll_sel,
            );
            out.push(prune_posteriors(&self.scratch.sel, &self.scratch.ll_sel, self.min_post));
        }
    }
}

/// `out[t] = consts + aug[t] · wᵀ` for the first `n_rows` rows, with
/// the shared dimension panel-blocked so the weight rows are re-read
/// from cache across the frame sweep.
fn score_rows(aug: &Mat, n_rows: usize, w: &Mat, consts: &[f64], out: &mut Mat) {
    debug_assert!(n_rows <= aug.rows() && n_rows <= out.rows());
    debug_assert_eq!(out.cols(), w.rows());
    let q = w.cols();
    for t in 0..n_rows {
        out.row_mut(t).copy_from_slice(consts);
    }
    for qb in (0..q).step_by(QB) {
        let qe = (qb + QB).min(q);
        for t in 0..n_rows {
            let a_seg = &aug.row(t)[qb..qe];
            let orow = out.row_mut(t);
            for (c, o) in orow.iter_mut().enumerate() {
                *o += crate::linalg::dot(a_seg, &w.row(c)[qb..qe]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::select_posteriors_scalar;
    use super::*;
    use crate::proptest::{forall, gen_dim};
    use crate::rng::Rng;

    fn random_ubm(c: usize, f: usize, rng: &mut Rng) -> (DiagGmm, FullGmm) {
        let diag = DiagGmm {
            weights: rng.dirichlet(2.0, c),
            means: Mat::from_fn(c, f, |_, _| 2.0 * rng.normal()),
            vars: Mat::from_fn(c, f, |_, _| rng.uniform_in(0.3, 2.5)),
        };
        let full = FullGmm::from_diag(&diag).unwrap();
        (diag, full)
    }

    #[test]
    fn batched_scores_match_diag_loglikes() {
        let mut rng = Rng::seed(71);
        let (diag, full) = random_ubm(9, 4, &mut rng);
        let feats = Mat::from_fn(30, 4, |_, _| 2.0 * rng.normal());
        let mut aligner = BatchAligner::new(&diag, &full, 9, 0.0);
        // score one block through the packed GEMM path
        let mut ll_ref = vec![0.0; 9];
        let n = feats.rows();
        for t in 0..n {
            let x = feats.row(t);
            let arow = aligner.scratch.aug.row_mut(t);
            for (j, &xj) in x.iter().enumerate() {
                arow[j] = xj;
                arow[4 + j] = xj * xj;
            }
        }
        score_rows(
            &aligner.scratch.aug,
            n,
            &aligner.packed.w,
            &aligner.packed.consts,
            &mut aligner.scratch.scores,
        );
        for t in 0..n {
            diag.log_likes(feats.row(t), &mut ll_ref);
            for c in 0..9 {
                let got = aligner.scratch.scores.get(t, c);
                assert!(
                    (got - ll_ref[c]).abs() < 1e-10 * (1.0 + ll_ref[c].abs()),
                    "t={t} c={c}: {got} vs {}",
                    ll_ref[c]
                );
            }
        }
    }

    #[test]
    fn prop_batched_align_matches_scalar() {
        forall(
            7007,
            32,
            |rng| {
                let c = gen_dim(rng, 2, 24);
                let f = gen_dim(rng, 1, 6);
                let k = gen_dim(rng, 1, c);
                // more frames than BLOCK sometimes, to cross block seams
                let t_len = gen_dim(rng, 1, 300);
                let (diag, full) = random_ubm(c, f, rng);
                let feats = Mat::from_fn(t_len, f, |_, _| 2.0 * rng.normal());
                (diag, full, feats, k)
            },
            |(diag, full, feats, k)| {
                let batched = BatchAligner::new(diag, full, *k, 0.025).align_utterance(feats);
                let scalar = select_posteriors_scalar(diag, full, feats, *k, 0.025);
                if batched.len() != scalar.len() {
                    return Err(format!("frame count {} vs {}", batched.len(), scalar.len()));
                }
                for (t, (b, s)) in batched.iter().zip(&scalar).enumerate() {
                    if b.len() != s.len() {
                        return Err(format!("frame {t}: {} vs {} postings", b.len(), s.len()));
                    }
                    for (pb, ps) in b.iter().zip(s) {
                        if pb.idx != ps.idx {
                            return Err(format!("frame {t}: idx {} vs {}", pb.idx, ps.idx));
                        }
                        if (pb.post - ps.post).abs() > 1e-5 {
                            return Err(format!(
                                "frame {t} idx {}: post {} vs {}",
                                pb.idx, pb.post, ps.post
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shared_packed_weights_match_owned_pack() {
        let mut rng = Rng::seed(79);
        let (diag, full) = random_ubm(10, 4, &mut rng);
        let feats = Mat::from_fn(200, 4, |_, _| 1.5 * rng.normal());
        let packed = PackedDiag::new(&diag);
        assert_eq!(packed.num_components(), 10);
        let owned = BatchAligner::new(&diag, &full, 5, 0.025).align_utterance(&feats);
        let shared =
            BatchAligner::with_packed(&packed, &full, 5, 0.025).align_utterance(&feats);
        assert_eq!(owned.len(), shared.len());
        for (a, b) in owned.iter().zip(&shared) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }

    #[test]
    fn recycled_scratch_matches_fresh_scratch() {
        // pool round-trip: align, recover the scratch, align a second
        // utterance with it — identical postings to a fresh aligner
        let mut rng = Rng::seed(83);
        let (diag, full) = random_ubm(12, 5, &mut rng);
        let packed = PackedDiag::new(&diag);
        assert_eq!(packed.feat_dim(), 5);
        let u1 = Mat::from_fn(150, 5, |_, _| 1.5 * rng.normal());
        let u2 = Mat::from_fn(90, 5, |_, _| 1.5 * rng.normal());

        let mut first = BatchAligner::with_packed(&packed, &full, 6, 0.025);
        let _ = first.align_utterance(&u1);
        let scratch = first.into_scratch();
        assert!(scratch.fits(5, 12));

        let recycled =
            BatchAligner::with_scratch(&packed, &full, 6, 0.025, scratch).align_utterance(&u2);
        let fresh = BatchAligner::with_packed(&packed, &full, 6, 0.025).align_utterance(&u2);
        assert_eq!(recycled.len(), fresh.len());
        for (a, b) in recycled.iter().zip(&fresh) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }

        // wrong-shape scratch is replaced, not trusted
        let bad = AlignScratch::new(3, 4);
        assert!(!bad.fits(5, 12));
        let via_bad =
            BatchAligner::with_scratch(&packed, &full, 6, 0.025, bad).align_utterance(&u2);
        assert_eq!(via_bad.len(), fresh.len());
    }

    #[test]
    fn wrapper_routes_through_batched_path() {
        let mut rng = Rng::seed(73);
        let (diag, full) = random_ubm(8, 3, &mut rng);
        let feats = Mat::from_fn(140, 3, |_, _| rng.normal());
        let via_wrapper = super::super::select_posteriors(&diag, &full, &feats, 5, 0.025);
        let via_aligner = BatchAligner::new(&diag, &full, 5, 0.025).align_utterance(&feats);
        assert_eq!(via_wrapper.len(), via_aligner.len());
        for (a, b) in via_wrapper.iter().zip(&via_aligner) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }
}
