//! Batched, GEMM-shaped CPU frame alignment — f64 and mixed-precision
//! f32 paths.
//!
//! The scalar reference ([`super::select_posteriors_scalar`]) walks one
//! frame at a time and re-derives `ln v` and `1/v` for every (frame,
//! component, dim) triple inside `DiagGmm::log_likes`. This module
//! mirrors what the accelerated `align_topk` graph does on device —
//! and what `pack_diag_params` feeds it: the diagonal scores of a whole
//! frame block become one `[x; x²] · Wᵀ` matrix product against a
//! packed `(C × 2F)` weight matrix whose per-component constants absorb
//! every log/divide, followed by top-K selection and full-covariance
//! rescoring of only the K survivors.
//!
//! **Precision split** ([`AlignPrecision`]): the diagonal score GEMM and
//! the top-K selection exist in both f64 and f32 — the f32 path
//! ([`PackedDiagF32`], [`crate::linalg::MatF32`]) runs the hottest
//! kernel with twice the SIMD lanes and half the memory traffic, and
//! mirrors the device runtime's native f32. Everything *downstream* of
//! selection stays f64 regardless: the full-covariance rescoring,
//! log-sum-exp, posterior normalization, and the Baum-Welch/E-step
//! accumulation they feed — so extractor training statistics are
//! bit-identical between precisions whenever the selected top-K set
//! agrees, and the only f32-induced difference is an occasional swap of
//! near-tied components at the selection boundary.
//!
//! All scratch lives in the aligner, so the per-frame inner loop
//! allocates nothing beyond the output posting lists.

use std::borrow::Cow;

use crate::io::Posting;
use crate::linalg::f32::narrow;
use crate::linalg::{Mat, MatF32};

use super::select::{prune_posteriors, top_k_into};
use super::{DiagGmm, FullGmm, LOG_2PI};

/// Frames scored per matrix product. Big enough that the packed weight
/// matrix is re-read from cache across the block, small enough that the
/// score block (`BLOCK × C`) stays modest.
const BLOCK: usize = 128;

/// Shared-dimension panel width for the score product (2F is usually
/// below this, i.e. a single panel).
const QB: usize = 512;

/// Scalar width of the diagonal-scoring stage. The default is f64
/// (bit-stable against the scalar oracle); f32 roughly doubles
/// alignment throughput on SIMD CPUs and mirrors device precision.
/// Selected by the `[align] precision` config knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlignPrecision {
    F64,
    F32,
}

impl AlignPrecision {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f64" => Ok(Self::F64),
            "f32" => Ok(Self::F32),
            other => anyhow::bail!("precision must be \"f32\" or \"f64\", got `{other}`"),
        }
    }

    /// The config/CLI spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::F64 => "f64",
            Self::F32 => "f32",
        }
    }
}

impl std::fmt::Display for AlignPrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The precomputed diagonal score expansion (the f64 mirror of
/// [`crate::ivector::accel::pack_diag_params`]): a pure function of the
/// diagonal UBM, so long-lived callers (the serving engine's
/// [`crate::serve::ServeModel`]) pack once per model and share it
/// across requests instead of re-deriving every ln/divide per aligner.
#[derive(Debug, Clone)]
pub struct PackedDiag {
    /// Packed diagonal score weights (C × 2F): row c = [m/v ; −½/v].
    w: Mat,
    /// Per-component constants folding ln w_c, ln v and m²/v.
    consts: Vec<f64>,
    /// Feature dim F.
    dim: usize,
}

impl PackedDiag {
    /// Pack the diagonal UBM.
    pub fn new(diag: &DiagGmm) -> Self {
        let (c_n, f_dim) = (diag.num_components(), diag.dim());
        let mut w = Mat::zeros(c_n, 2 * f_dim);
        let mut consts = vec![0.0; c_n];
        for c in 0..c_n {
            let mut const_c =
                diag.weights[c].max(1e-300).ln() - 0.5 * f_dim as f64 * LOG_2PI;
            let m = diag.means.row(c);
            let v = diag.vars.row(c);
            let wr = w.row_mut(c);
            for j in 0..f_dim {
                let vinv = 1.0 / v[j];
                wr[j] = m[j] * vinv;
                wr[f_dim + j] = -0.5 * vinv;
                const_c -= 0.5 * (v[j].ln() + m[j] * m[j] * vinv);
            }
            consts[c] = const_c;
        }
        Self { w, consts, dim: f_dim }
    }

    /// Components C.
    pub fn num_components(&self) -> usize {
        self.w.rows()
    }

    /// Feature dim F.
    pub fn feat_dim(&self) -> usize {
        self.dim
    }
}

/// The f32 twin of [`PackedDiag`]: same layout, narrowed weights. All
/// the ln/divide work happens once, in f64, inside [`PackedDiag::new`];
/// this type only narrows the result, so the two packs can never drift
/// in how they derive the expansion.
#[derive(Debug, Clone)]
pub struct PackedDiagF32 {
    /// Packed diagonal score weights (C × 2F), narrowed.
    w: MatF32,
    /// Per-component constants, narrowed.
    consts: Vec<f32>,
    /// Feature dim F.
    dim: usize,
}

impl PackedDiagF32 {
    /// Pack the diagonal UBM (derives in f64, then narrows).
    pub fn new(diag: &DiagGmm) -> Self {
        Self::from_f64(&PackedDiag::new(diag))
    }

    /// Narrow an existing f64 pack (shared conversion idiom with the
    /// device-tensor boundary — see [`crate::linalg::f32::narrow`]).
    pub fn from_f64(p: &PackedDiag) -> Self {
        Self {
            w: MatF32::from_mat(&p.w),
            consts: narrow(&p.consts),
            dim: p.dim,
        }
    }

    /// Components C.
    pub fn num_components(&self) -> usize {
        self.w.rows()
    }

    /// Feature dim F.
    pub fn feat_dim(&self) -> usize {
        self.dim
    }
}

/// Precision-specific block buffers of an [`AlignScratch`].
#[derive(Debug, Clone)]
enum ScratchBufs {
    F64 {
        /// Augmented frame block [x ; x²] (BLOCK × 2F).
        aug: Mat,
        /// Diagonal scores (BLOCK × C).
        scores: Mat,
    },
    F32 {
        aug: MatF32,
        scores: MatF32,
    },
}

/// The aligner's reusable scratch buffers, split from the model refs so
/// long-lived callers (the serving engine) can pool them across
/// requests the way batch workers reuse an `EstepWorkspace`. At paper
/// dims (C = 2048, F = 60) the two block buffers alone are
/// `BLOCK × (2F + C) × 8 B ≈ 2.2 MB` in f64 (half that in f32) —
/// rebuilding that per request is pure allocator churn, since the
/// buffers depend only on (F, C, precision), never on the utterance.
#[derive(Debug, Clone)]
pub struct AlignScratch {
    bufs: ScratchBufs,
    /// Top-K selection buffer.
    sel: Vec<u32>,
    /// Full-covariance log-likes of the selected components (always
    /// f64 — rescoring stays double regardless of scoring precision).
    ll_sel: Vec<f64>,
}

impl AlignScratch {
    /// Allocate f64 scratch for a (feature dim, component count) shape.
    pub fn new(f_dim: usize, c_n: usize) -> Self {
        Self::with_precision(AlignPrecision::F64, f_dim, c_n)
    }

    /// Allocate scratch for a shape at an explicit precision.
    pub fn with_precision(precision: AlignPrecision, f_dim: usize, c_n: usize) -> Self {
        let bufs = match precision {
            AlignPrecision::F64 => ScratchBufs::F64 {
                aug: Mat::zeros(BLOCK, 2 * f_dim),
                scores: Mat::zeros(BLOCK, c_n),
            },
            AlignPrecision::F32 => ScratchBufs::F32 {
                aug: MatF32::zeros(BLOCK, 2 * f_dim),
                scores: MatF32::zeros(BLOCK, c_n),
            },
        };
        Self { bufs, sel: Vec::new(), ll_sel: Vec::new() }
    }

    /// The precision this scratch was allocated for.
    pub fn precision(&self) -> AlignPrecision {
        match self.bufs {
            ScratchBufs::F64 { .. } => AlignPrecision::F64,
            ScratchBufs::F32 { .. } => AlignPrecision::F32,
        }
    }

    /// Whether this scratch was sized for the given model shape
    /// (precision-agnostic; see [`AlignScratch::precision`]).
    pub fn fits(&self, f_dim: usize, c_n: usize) -> bool {
        match &self.bufs {
            ScratchBufs::F64 { aug, scores } => {
                aug.cols() == 2 * f_dim && scores.cols() == c_n
            }
            ScratchBufs::F32 { aug, scores } => {
                aug.cols() == 2 * f_dim && scores.cols() == c_n
            }
        }
    }
}

/// The aligner's diagonal score expansion, either precision (owned, or
/// borrowed from a caller that amortizes the pack across aligners).
enum Pack<'g> {
    F64(Cow<'g, PackedDiag>),
    F32(Cow<'g, PackedDiagF32>),
}

impl Pack<'_> {
    fn feat_dim(&self) -> usize {
        match self {
            Pack::F64(p) => p.dim,
            Pack::F32(p) => p.dim,
        }
    }

    fn num_components(&self) -> usize {
        match self {
            Pack::F64(p) => p.num_components(),
            Pack::F32(p) => p.num_components(),
        }
    }

    fn precision(&self) -> AlignPrecision {
        match self {
            Pack::F64(_) => AlignPrecision::F64,
            Pack::F32(_) => AlignPrecision::F32,
        }
    }
}

/// Batched two-stage aligner with reusable scratch buffers.
///
/// Equivalent to the scalar path up to floating-point rounding: the
/// packed expansion evaluates `x·(m/v) − ½x²/v + const_c` instead of
/// `−½(x−m)²/v − ½ ln v + ln w_c + …`, which agrees to ~1e-12 relative
/// in f64 and ~1e-6 relative in f32 — and because the f32 path only
/// *selects* (rescoring, log-sum-exp and normalization stay f64), its
/// output posteriors differ from f64 only when near-tied components
/// swap at the top-K boundary.
pub struct BatchAligner<'g> {
    full: &'g FullGmm,
    top_k: usize,
    min_post: f64,
    /// Diagonal score expansion (either precision).
    packed: Pack<'g>,
    /// Working buffers (owned here; poolable via [`Self::with_scratch`]
    /// / [`Self::into_scratch`]).
    scratch: AlignScratch,
}

impl<'g> BatchAligner<'g> {
    /// Pack the diagonal UBM once and build the f64 aligner.
    pub fn new(diag: &DiagGmm, full: &'g FullGmm, top_k: usize, min_post: f64) -> Self {
        Self::with_precision(diag, full, top_k, min_post, AlignPrecision::F64)
    }

    /// Pack the diagonal UBM once at the requested scoring precision.
    pub fn with_precision(
        diag: &DiagGmm,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        precision: AlignPrecision,
    ) -> Self {
        let packed = match precision {
            AlignPrecision::F64 => Pack::F64(Cow::Owned(PackedDiag::new(diag))),
            AlignPrecision::F32 => Pack::F32(Cow::Owned(PackedDiagF32::new(diag))),
        };
        let scratch =
            AlignScratch::with_precision(precision, packed.feat_dim(), packed.num_components());
        Self::build(packed, full, top_k, min_post, scratch)
    }

    /// Build over an already-packed f64 diagonal UBM (the pack is
    /// per-model, only the scratch is per-aligner).
    pub fn with_packed(
        packed: &'g PackedDiag,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
    ) -> Self {
        let scratch = AlignScratch::new(packed.dim, packed.num_components());
        Self::build(Pack::F64(Cow::Borrowed(packed)), full, top_k, min_post, scratch)
    }

    /// [`Self::with_packed`] for the f32 pack.
    pub fn with_packed_f32(
        packed: &'g PackedDiagF32,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
    ) -> Self {
        let scratch = AlignScratch::with_precision(
            AlignPrecision::F32,
            packed.dim,
            packed.num_components(),
        );
        Self::build(Pack::F32(Cow::Borrowed(packed)), full, top_k, min_post, scratch)
    }

    /// Build over a shared pack **and** recycled scratch — the serving
    /// hot path (zero per-request buffer builds). Scratch of the wrong
    /// shape or precision is defensively replaced rather than trusted.
    pub fn with_scratch(
        packed: &'g PackedDiag,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        scratch: AlignScratch,
    ) -> Self {
        let pack = Pack::F64(Cow::Borrowed(packed));
        let scratch = Self::validate_scratch(&pack, scratch);
        Self::build(pack, full, top_k, min_post, scratch)
    }

    /// [`Self::with_scratch`] for the f32 pack.
    pub fn with_scratch_f32(
        packed: &'g PackedDiagF32,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        scratch: AlignScratch,
    ) -> Self {
        let pack = Pack::F32(Cow::Borrowed(packed));
        let scratch = Self::validate_scratch(&pack, scratch);
        Self::build(pack, full, top_k, min_post, scratch)
    }

    fn validate_scratch(pack: &Pack<'_>, scratch: AlignScratch) -> AlignScratch {
        if scratch.precision() == pack.precision()
            && scratch.fits(pack.feat_dim(), pack.num_components())
        {
            scratch
        } else {
            AlignScratch::with_precision(pack.precision(), pack.feat_dim(), pack.num_components())
        }
    }

    /// Recover the scratch for reuse (pool check-in).
    pub fn into_scratch(self) -> AlignScratch {
        self.scratch
    }

    /// The scoring precision this aligner runs at.
    pub fn precision(&self) -> AlignPrecision {
        self.packed.precision()
    }

    fn build(
        packed: Pack<'g>,
        full: &'g FullGmm,
        top_k: usize,
        min_post: f64,
        scratch: AlignScratch,
    ) -> Self {
        Self { full, top_k, min_post, packed, scratch }
    }

    /// Align a whole utterance, streaming BLOCK-sized frame blocks.
    pub fn align_utterance(&mut self, feats: &Mat) -> Vec<Vec<Posting>> {
        assert_eq!(feats.cols(), self.packed.feat_dim(), "feature dim mismatch");
        let mut out = Vec::with_capacity(feats.rows());
        let mut start = 0;
        while start < feats.rows() {
            let n = (feats.rows() - start).min(BLOCK);
            self.align_block(feats, start, n, &mut out);
            start += n;
        }
        out
    }

    /// Score + select + rescore + prune one block of `n` frames
    /// starting at row `start`, appending per-frame postings to `out`.
    /// Scoring and selection run at the pack's precision; rescoring and
    /// pruning are the shared f64 tail.
    fn align_block(&mut self, feats: &Mat, start: usize, n: usize, out: &mut Vec<Vec<Posting>>) {
        let f_dim = self.packed.feat_dim();
        let AlignScratch { bufs, sel, ll_sel } = &mut self.scratch;
        match (&self.packed, bufs) {
            (Pack::F64(p), ScratchBufs::F64 { aug, scores }) => {
                for t in 0..n {
                    let x = feats.row(start + t);
                    let arow = aug.row_mut(t);
                    for (j, &xj) in x.iter().enumerate() {
                        arow[j] = xj;
                        arow[f_dim + j] = xj * xj;
                    }
                }
                score_rows(aug, n, &p.w, &p.consts, scores);
                for t in 0..n {
                    top_k_into(scores.row(t), self.top_k, sel);
                    finish_frame(
                        self.full,
                        feats.row(start + t),
                        sel,
                        ll_sel,
                        self.min_post,
                        out,
                    );
                }
            }
            (Pack::F32(p), ScratchBufs::F32 { aug, scores }) => {
                for t in 0..n {
                    let x = feats.row(start + t);
                    let arow = aug.row_mut(t);
                    for (j, &xj) in x.iter().enumerate() {
                        // narrow first, square in f32: the pure-f32
                        // pipeline the device path runs
                        let xj = xj as f32;
                        arow[j] = xj;
                        arow[f_dim + j] = xj * xj;
                    }
                }
                score_rows_f32(aug, n, &p.w, &p.consts, scores);
                for t in 0..n {
                    top_k_into(scores.row(t), self.top_k, sel);
                    finish_frame(
                        self.full,
                        feats.row(start + t),
                        sel,
                        ll_sel,
                        self.min_post,
                        out,
                    );
                }
            }
            // constructors pair pack and scratch by construction
            _ => unreachable!("scratch precision mismatches pack"),
        }
    }
}

/// The shared f64 tail of both precisions: full-covariance rescoring of
/// the selected components, softmax + pruning, posting emission.
fn finish_frame(
    full: &FullGmm,
    x: &[f64],
    sel: &[u32],
    ll_sel: &mut Vec<f64>,
    min_post: f64,
    out: &mut Vec<Vec<Posting>>,
) {
    ll_sel.resize(sel.len(), 0.0);
    full.log_likes_select(x, sel, ll_sel);
    out.push(prune_posteriors(sel, ll_sel, min_post));
}

/// `out[t] = consts + aug[t] · wᵀ` for the first `n_rows` rows, with
/// the shared dimension panel-blocked so the weight rows are re-read
/// from cache across the frame sweep.
fn score_rows(aug: &Mat, n_rows: usize, w: &Mat, consts: &[f64], out: &mut Mat) {
    debug_assert!(n_rows <= aug.rows() && n_rows <= out.rows());
    debug_assert_eq!(out.cols(), w.rows());
    let q = w.cols();
    for t in 0..n_rows {
        out.row_mut(t).copy_from_slice(consts);
    }
    for qb in (0..q).step_by(QB) {
        let qe = (qb + QB).min(q);
        for t in 0..n_rows {
            let a_seg = &aug.row(t)[qb..qe];
            let orow = out.row_mut(t);
            for (c, o) in orow.iter_mut().enumerate() {
                *o += crate::linalg::dot(a_seg, &w.row(c)[qb..qe]);
            }
        }
    }
}

/// The f32 twin of [`score_rows`]: constants broadcast into the output
/// rows, then the shared panel-blocked GEMM core
/// ([`MatF32::matmul_nt_acc_rows`], 8-wide [`crate::linalg::dot_f32`]
/// inner product — explicit `std::simd` lanes under the `simd`
/// feature) accumulates `aug[t] · wᵀ` on top.
fn score_rows_f32(aug: &MatF32, n_rows: usize, w: &MatF32, consts: &[f32], out: &mut MatF32) {
    debug_assert_eq!(out.cols(), w.rows());
    for t in 0..n_rows {
        out.row_mut(t).copy_from_slice(consts);
    }
    aug.matmul_nt_acc_rows(n_rows, w, out);
}

#[cfg(test)]
mod tests {
    use super::super::select_posteriors_scalar;
    use super::*;
    use crate::proptest::{forall, gen_dim};
    use crate::rng::Rng;

    fn random_ubm(c: usize, f: usize, rng: &mut Rng) -> (DiagGmm, FullGmm) {
        let diag = DiagGmm {
            weights: rng.dirichlet(2.0, c),
            means: Mat::from_fn(c, f, |_, _| 2.0 * rng.normal()),
            vars: Mat::from_fn(c, f, |_, _| rng.uniform_in(0.3, 2.5)),
        };
        let full = FullGmm::from_diag(&diag).unwrap();
        (diag, full)
    }

    /// Tolerant posting comparison for the mixed-precision path. The
    /// f32 stage only *selects* — rescoring and normalization stay f64 —
    /// so two alignments can differ in exactly one way: near-tied
    /// components swapping at the top-K boundary. Contract enforced
    /// here (the documented f32 tolerance):
    /// * postings for a shared component agree within `val_tol`;
    /// * components present on only one side pair up across sides by
    ///   posterior value within `swap_tol` (a boundary swap relabels a
    ///   tie, it cannot move mass);
    /// * an unpaired leftover must sit at the pruning threshold
    ///   (`≤ min_post + swap_tol`) — the straddling-the-cutoff case.
    fn posts_close(
        a: &[Vec<Posting>],
        b: &[Vec<Posting>],
        val_tol: f32,
        swap_tol: f32,
        min_post: f32,
    ) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("frame count {} vs {}", a.len(), b.len()));
        }
        for (t, (fa, fb)) in a.iter().zip(b).enumerate() {
            let in_b: std::collections::BTreeMap<u32, f32> =
                fb.iter().map(|p| (p.idx, p.post)).collect();
            let in_a: std::collections::BTreeMap<u32, f32> =
                fa.iter().map(|p| (p.idx, p.post)).collect();
            let mut only_a: Vec<f32> = Vec::new();
            for p in fa {
                match in_b.get(&p.idx) {
                    Some(&q) if (p.post - q).abs() <= val_tol => {}
                    Some(&q) => {
                        return Err(format!("frame {t} idx {}: post {} vs {q}", p.idx, p.post))
                    }
                    None => only_a.push(p.post),
                }
            }
            let mut only_b: Vec<f32> =
                fb.iter().filter(|p| !in_a.contains_key(&p.idx)).map(|p| p.post).collect();
            only_a.sort_by(|x, y| y.partial_cmp(x).unwrap());
            only_b.sort_by(|x, y| y.partial_cmp(x).unwrap());
            let pairs = only_a.len().min(only_b.len());
            for i in 0..pairs {
                if (only_a[i] - only_b[i]).abs() > swap_tol {
                    return Err(format!(
                        "frame {t}: boundary-swapped posts {} vs {} beyond tol",
                        only_a[i], only_b[i]
                    ));
                }
            }
            for &p in only_a[pairs..].iter().chain(&only_b[pairs..]) {
                if p > min_post + swap_tol {
                    return Err(format!("frame {t}: unpaired posting {p} above threshold"));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn batched_scores_match_diag_loglikes() {
        let mut rng = Rng::seed(71);
        let (diag, full) = random_ubm(9, 4, &mut rng);
        let feats = Mat::from_fn(30, 4, |_, _| 2.0 * rng.normal());
        let mut aligner = BatchAligner::new(&diag, &full, 9, 0.0);
        // score one block through the packed GEMM path
        let n = feats.rows();
        let (Pack::F64(packed), ScratchBufs::F64 { aug, scores }) =
            (&aligner.packed, &mut aligner.scratch.bufs)
        else {
            panic!("default aligner must be f64");
        };
        for t in 0..n {
            let x = feats.row(t);
            let arow = aug.row_mut(t);
            for (j, &xj) in x.iter().enumerate() {
                arow[j] = xj;
                arow[4 + j] = xj * xj;
            }
        }
        score_rows(aug, n, &packed.w, &packed.consts, scores);
        let mut ll_ref = vec![0.0; 9];
        for t in 0..n {
            diag.log_likes(feats.row(t), &mut ll_ref);
            for c in 0..9 {
                let got = scores.get(t, c);
                assert!(
                    (got - ll_ref[c]).abs() < 1e-10 * (1.0 + ll_ref[c].abs()),
                    "t={t} c={c}: {got} vs {}",
                    ll_ref[c]
                );
            }
        }
    }

    #[test]
    fn prop_batched_align_matches_scalar() {
        forall(
            7007,
            32,
            |rng| {
                let c = gen_dim(rng, 2, 24);
                let f = gen_dim(rng, 1, 6);
                let k = gen_dim(rng, 1, c);
                // more frames than BLOCK sometimes, to cross block seams
                let t_len = gen_dim(rng, 1, 300);
                let (diag, full) = random_ubm(c, f, rng);
                let feats = Mat::from_fn(t_len, f, |_, _| 2.0 * rng.normal());
                (diag, full, feats, k)
            },
            |(diag, full, feats, k)| {
                let batched = BatchAligner::new(diag, full, *k, 0.025).align_utterance(feats);
                let scalar = select_posteriors_scalar(diag, full, feats, *k, 0.025);
                if batched.len() != scalar.len() {
                    return Err(format!("frame count {} vs {}", batched.len(), scalar.len()));
                }
                for (t, (b, s)) in batched.iter().zip(&scalar).enumerate() {
                    if b.len() != s.len() {
                        return Err(format!("frame {t}: {} vs {} postings", b.len(), s.len()));
                    }
                    for (pb, ps) in b.iter().zip(s) {
                        if pb.idx != ps.idx {
                            return Err(format!("frame {t}: idx {} vs {}", pb.idx, ps.idx));
                        }
                        if (pb.post - ps.post).abs() > 1e-5 {
                            return Err(format!(
                                "frame {t} idx {}: post {} vs {}",
                                pb.idx, pb.post, ps.post
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Tentpole acceptance: the mixed-precision f32 path matches the
    /// f64 scalar oracle within the documented tolerance (shared
    /// components to 1e-4, boundary-tie swaps to 2e-3) across random
    /// models, dims, and block seams.
    #[test]
    fn prop_f32_align_matches_scalar_oracle() {
        forall(
            3209,
            32,
            |rng| {
                let c = gen_dim(rng, 2, 24);
                let f = gen_dim(rng, 1, 6);
                let k = gen_dim(rng, 1, c);
                let t_len = gen_dim(rng, 1, 300);
                let (diag, full) = random_ubm(c, f, rng);
                let feats = Mat::from_fn(t_len, f, |_, _| 2.0 * rng.normal());
                (diag, full, feats, k)
            },
            |(diag, full, feats, k)| {
                let f32_posts =
                    BatchAligner::with_precision(diag, full, *k, 0.025, AlignPrecision::F32)
                        .align_utterance(feats);
                let scalar = select_posteriors_scalar(diag, full, feats, *k, 0.025);
                posts_close(&f32_posts, &scalar, 1e-4, 2e-3, 0.025)
            },
        );
    }

    /// Paper-shaped dims (F = 60, top-20 of C = 256 — C scaled down
    /// from 2048 only to keep tier-1 debug-build time sane; the kernel
    /// shape per frame is the paper's): f32 ≡ scalar oracle, crossing a
    /// BLOCK seam.
    #[test]
    fn f32_align_matches_oracle_at_paper_shape() {
        let mut rng = Rng::seed(2048);
        let (c, f, k) = (256, 60, 20);
        let (diag, full) = random_ubm(c, f, &mut rng);
        let feats = Mat::from_fn(150, f, |_, _| 2.0 * rng.normal());
        let f32_posts = BatchAligner::with_precision(&diag, &full, k, 0.025, AlignPrecision::F32)
            .align_utterance(&feats);
        let scalar = select_posteriors_scalar(&diag, &full, &feats, k, 0.025);
        posts_close(&f32_posts, &scalar, 1e-4, 2e-3, 0.025).unwrap();
    }

    /// Adversarial dynamic range: features and means two orders of
    /// magnitude apart push the diagonal scores to O(−10⁵), where a
    /// naive all-f32 pipeline (f32 log-sum-exp over f32 rescores) loses
    /// the inter-component differences entirely (f32 quantum at 1e5 is
    /// ~0.008, comparable to posterior-relevant log-like gaps). The
    /// mixed-precision contract keeps LSE + rescoring in f64, so only
    /// *selection* sees f32 — and with well-separated components the
    /// selected set is stable, making the output posteriors exactly the
    /// oracle's.
    #[test]
    fn f32_selection_survives_large_dynamic_range() {
        let mut rng = Rng::seed(919);
        let (c, f) = (32, 8);
        // means spread over ±300, unit-ish variances: score magnitudes
        // hit ~1e5 while the top components stay separated by ≫ the f32
        // rounding of the scores
        let diag = DiagGmm {
            weights: rng.dirichlet(2.0, c),
            means: Mat::from_fn(c, f, |_, _| 300.0 * rng.normal()),
            vars: Mat::from_fn(c, f, |_, _| rng.uniform_in(0.5, 2.0)),
        };
        let full = FullGmm::from_diag(&diag).unwrap();
        // frames near random components, plus far-field outliers
        let feats = Mat::from_fn(200, f, |t, j| {
            let m = diag.means.get(t % c, j);
            if t % 7 == 0 {
                m + 40.0 * rng.normal() // outlier: every score huge-negative
            } else {
                m + rng.normal()
            }
        });
        let f32_posts = BatchAligner::with_precision(&diag, &full, 5, 0.025, AlignPrecision::F32)
            .align_utterance(&feats);
        let scalar = select_posteriors_scalar(&diag, &full, &feats, 5, 0.025);
        // swaps are still tolerated at ties, but shared components must
        // match tightly — the f64 tail wipes out the f32 score error
        posts_close(&f32_posts, &scalar, 1e-5, 2e-3, 0.025).unwrap();
    }

    #[test]
    fn shared_packed_weights_match_owned_pack() {
        let mut rng = Rng::seed(79);
        let (diag, full) = random_ubm(10, 4, &mut rng);
        let feats = Mat::from_fn(200, 4, |_, _| 1.5 * rng.normal());
        let packed = PackedDiag::new(&diag);
        assert_eq!(packed.num_components(), 10);
        let owned = BatchAligner::new(&diag, &full, 5, 0.025).align_utterance(&feats);
        let shared =
            BatchAligner::with_packed(&packed, &full, 5, 0.025).align_utterance(&feats);
        assert_eq!(owned.len(), shared.len());
        for (a, b) in owned.iter().zip(&shared) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }

    #[test]
    fn shared_f32_pack_matches_owned_f32_pack() {
        let mut rng = Rng::seed(81);
        let (diag, full) = random_ubm(10, 4, &mut rng);
        let feats = Mat::from_fn(200, 4, |_, _| 1.5 * rng.normal());
        let packed = PackedDiagF32::new(&diag);
        assert_eq!(packed.num_components(), 10);
        assert_eq!(packed.feat_dim(), 4);
        let owned = BatchAligner::with_precision(&diag, &full, 5, 0.025, AlignPrecision::F32)
            .align_utterance(&feats);
        let shared =
            BatchAligner::with_packed_f32(&packed, &full, 5, 0.025).align_utterance(&feats);
        assert_eq!(owned.len(), shared.len());
        for (a, b) in owned.iter().zip(&shared) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }

    #[test]
    fn recycled_scratch_matches_fresh_scratch() {
        // pool round-trip: align, recover the scratch, align a second
        // utterance with it — identical postings to a fresh aligner
        let mut rng = Rng::seed(83);
        let (diag, full) = random_ubm(12, 5, &mut rng);
        let packed = PackedDiag::new(&diag);
        assert_eq!(packed.feat_dim(), 5);
        let u1 = Mat::from_fn(150, 5, |_, _| 1.5 * rng.normal());
        let u2 = Mat::from_fn(90, 5, |_, _| 1.5 * rng.normal());

        let mut first = BatchAligner::with_packed(&packed, &full, 6, 0.025);
        let _ = first.align_utterance(&u1);
        let scratch = first.into_scratch();
        assert!(scratch.fits(5, 12));
        assert_eq!(scratch.precision(), AlignPrecision::F64);

        let recycled =
            BatchAligner::with_scratch(&packed, &full, 6, 0.025, scratch).align_utterance(&u2);
        let fresh = BatchAligner::with_packed(&packed, &full, 6, 0.025).align_utterance(&u2);
        assert_eq!(recycled.len(), fresh.len());
        for (a, b) in recycled.iter().zip(&fresh) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }

        // wrong-shape scratch is replaced, not trusted
        let bad = AlignScratch::new(3, 4);
        assert!(!bad.fits(5, 12));
        let via_bad =
            BatchAligner::with_scratch(&packed, &full, 6, 0.025, bad).align_utterance(&u2);
        assert_eq!(via_bad.len(), fresh.len());
    }

    #[test]
    fn f32_scratch_recycles_and_rejects_precision_mismatch() {
        let mut rng = Rng::seed(87);
        let (diag, full) = random_ubm(12, 5, &mut rng);
        let packed = PackedDiagF32::new(&diag);
        let u1 = Mat::from_fn(140, 5, |_, _| 1.5 * rng.normal());
        let u2 = Mat::from_fn(70, 5, |_, _| 1.5 * rng.normal());

        let mut first = BatchAligner::with_packed_f32(&packed, &full, 6, 0.025);
        assert_eq!(first.precision(), AlignPrecision::F32);
        let _ = first.align_utterance(&u1);
        let scratch = first.into_scratch();
        assert_eq!(scratch.precision(), AlignPrecision::F32);
        assert!(scratch.fits(5, 12));

        let recycled = BatchAligner::with_scratch_f32(&packed, &full, 6, 0.025, scratch)
            .align_utterance(&u2);
        let fresh = BatchAligner::with_packed_f32(&packed, &full, 6, 0.025).align_utterance(&u2);
        assert_eq!(recycled.len(), fresh.len());
        for (a, b) in recycled.iter().zip(&fresh) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }

        // right shape, wrong precision: defensively replaced (an f64
        // scratch handed to an f32 aligner must not panic or misalign)
        let f64_scratch = AlignScratch::new(5, 12);
        assert!(f64_scratch.fits(5, 12));
        let via_mismatch =
            BatchAligner::with_scratch_f32(&packed, &full, 6, 0.025, f64_scratch)
                .align_utterance(&u2);
        assert_eq!(via_mismatch.len(), fresh.len());
        for (a, b) in via_mismatch.iter().zip(&fresh) {
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }

    #[test]
    fn wrapper_routes_through_batched_path() {
        let mut rng = Rng::seed(73);
        let (diag, full) = random_ubm(8, 3, &mut rng);
        let feats = Mat::from_fn(140, 3, |_, _| rng.normal());
        let via_wrapper = super::super::select_posteriors(&diag, &full, &feats, 5, 0.025);
        let via_aligner = BatchAligner::new(&diag, &full, 5, 0.025).align_utterance(&feats);
        assert_eq!(via_wrapper.len(), via_aligner.len());
        for (a, b) in via_wrapper.iter().zip(&via_aligner) {
            assert_eq!(a.len(), b.len());
            for (pa, pb) in a.iter().zip(b) {
                assert_eq!(pa.idx, pb.idx);
                assert_eq!(pa.post, pb.post);
            }
        }
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!(AlignPrecision::parse("f32").unwrap(), AlignPrecision::F32);
        assert_eq!(AlignPrecision::parse("f64").unwrap(), AlignPrecision::F64);
        assert!(AlignPrecision::parse("f16").is_err());
        assert_eq!(AlignPrecision::F32.as_str(), "f32");
        assert_eq!(AlignPrecision::F64.to_string(), "f64");
    }
}
