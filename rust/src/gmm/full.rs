//! Full-covariance GMM — the i-vector UBM proper.
//!
//! Log-likelihoods use the expanded quadratic form with cached
//! `Σ_c⁻¹`, `Σ_c⁻¹ m_c` and per-component constants, which is also
//! exactly the layout the accelerated `align_topk` graph consumes
//! (one big matmul against a (C, F + F²) weight matrix).

use anyhow::Result;

use crate::io::Serialize;
use crate::linalg::{Cholesky, Mat};
use crate::stats::BwStats;

use super::diag::log_sum_exp;
use super::{DiagGmm, LOG_2PI};

/// Full-covariance GMM with cached inverse-covariance expansion.
#[derive(Debug, Clone)]
pub struct FullGmm {
    pub weights: Vec<f64>,
    /// Means (C × F).
    pub means: Mat,
    /// Full covariances, C matrices of F × F.
    pub covs: Vec<Mat>,
    // ---- caches (rebuilt by `refresh`) ----
    /// Σ_c⁻¹ per component.
    inv_covs: Vec<Mat>,
    /// Σ_c⁻¹ m_c per component (C × F).
    lin: Mat,
    /// log w_c − ½(F log 2π + log|Σ_c| + m_cᵀ Σ_c⁻¹ m_c).
    consts: Vec<f64>,
}

impl FullGmm {
    /// Build from parameters (computes caches).
    pub fn new(weights: Vec<f64>, means: Mat, covs: Vec<Mat>) -> Result<Self> {
        let mut g = Self {
            weights,
            means,
            covs,
            inv_covs: Vec::new(),
            lin: Mat::zeros(0, 0),
            consts: Vec::new(),
        };
        g.refresh()?;
        Ok(g)
    }

    /// Promote a diagonal GMM (initialization of full-cov EM).
    pub fn from_diag(d: &DiagGmm) -> Result<Self> {
        let covs = (0..d.num_components()).map(|c| Mat::diag(d.vars.row(c))).collect();
        Self::new(d.weights.clone(), d.means.clone(), covs)
    }

    /// Rebuild the inverse/constant caches after mutating parameters.
    /// Regularizes any non-PD covariance with the minimal ridge.
    pub fn refresh(&mut self) -> Result<()> {
        let c_n = self.weights.len();
        let dim = self.means.cols();
        let mut inv_covs = Vec::with_capacity(c_n);
        let mut lin = Mat::zeros(c_n, dim);
        let mut consts = Vec::with_capacity(c_n);
        for c in 0..c_n {
            let (chol, _ridge) = Cholesky::new_regularized(&self.covs[c]);
            let inv = chol.inverse();
            let m = self.means.row(c);
            let sm = inv.matvec(m);
            lin.row_mut(c).copy_from_slice(&sm);
            let quad = crate::linalg::dot(m, &sm);
            consts.push(
                self.weights[c].max(1e-300).ln()
                    - 0.5 * (dim as f64 * LOG_2PI + chol.logdet() + quad),
            );
            inv_covs.push(inv);
        }
        self.inv_covs = inv_covs;
        self.lin = lin;
        self.consts = consts;
        Ok(())
    }

    pub fn num_components(&self) -> usize {
        self.weights.len()
    }

    pub fn dim(&self) -> usize {
        self.means.cols()
    }

    /// Cached Σ_c⁻¹ (used by the TVM precompute and the align graph
    /// parameter packing).
    pub fn inv_cov(&self, c: usize) -> &Mat {
        &self.inv_covs[c]
    }

    /// Per-component log w_c·N(x|…) for a *subset* of components
    /// (the top-K refinement path): `out[i] = ll(select[i])`.
    pub fn log_likes_select(&self, x: &[f64], select: &[u32], out: &mut [f64]) {
        debug_assert_eq!(select.len(), out.len());
        for (i, &c) in select.iter().enumerate() {
            out[i] = self.log_like_one(x, c as usize);
        }
    }

    /// log w_c·N(x | m_c, Σ_c) for one component via the expansion
    /// const_c + xᵀ(Σ⁻¹m) − ½ xᵀΣ⁻¹x.
    pub fn log_like_one(&self, x: &[f64], c: usize) -> f64 {
        let dim = self.dim();
        let inv = &self.inv_covs[c];
        let mut quad = 0.0;
        for i in 0..dim {
            let row = inv.row(i);
            let xi = x[i];
            // exploit symmetry: diagonal once, off-diagonal doubled
            quad += row[i] * xi * xi;
            for j in (i + 1)..dim {
                quad += 2.0 * row[j] * xi * x[j];
            }
        }
        self.consts[c] + crate::linalg::dot(x, self.lin.row(c)) - 0.5 * quad
    }

    /// All-component log-likes of one frame.
    pub fn log_likes(&self, x: &[f64], out: &mut [f64]) {
        for c in 0..self.num_components() {
            out[c] = self.log_like_one(x, c);
        }
    }

    /// Frame total log-likelihood.
    pub fn frame_log_like(&self, x: &[f64]) -> f64 {
        let mut ll = vec![0.0; self.num_components()];
        self.log_likes(x, &mut ll);
        log_sum_exp(&ll)
    }

    /// M-step from accumulated (raw) Baum-Welch statistics: standard
    /// full-covariance GMM re-estimation with covariance flooring.
    pub fn update_from_stats(&mut self, acc: &BwStats, var_floor: f64) -> Result<()> {
        let c_n = self.num_components();
        let dim = self.dim();
        let s = acc.s.as_ref().expect("full-cov update needs second-order stats");
        let total_n: f64 = acc.n.iter().sum();
        for c in 0..c_n {
            let nc = acc.n[c];
            if nc < dim as f64 * 0.5 {
                continue; // starved component: keep old parameters
            }
            self.weights[c] = nc / total_n;
            let mean: Vec<f64> = acc.f.row(c).iter().map(|&v| v / nc).collect();
            let mut cov = s[c].clone();
            cov.scale(1.0 / nc);
            for i in 0..dim {
                for j in 0..dim {
                    let v = cov.get(i, j) - mean[i] * mean[j];
                    cov.set(i, j, v);
                }
            }
            cov.symmetrize();
            for i in 0..dim {
                let v = cov.get(i, i).max(var_floor);
                cov.set(i, i, v);
            }
            self.means.row_mut(c).copy_from_slice(&mean);
            self.covs[c] = cov;
        }
        self.refresh()
    }

    /// Replace the means (the §3.2 realignment step: UBM means get the
    /// updated bias terms) and refresh caches.
    pub fn set_means(&mut self, means: Mat) -> Result<()> {
        assert_eq!((means.rows(), means.cols()), (self.means.rows(), self.means.cols()));
        self.means = means;
        self.refresh()
    }
}

impl Serialize for FullGmm {
    fn write(&self, w: &mut crate::io::BinWriter) -> anyhow::Result<()> {
        self.weights.write(w)?;
        self.means.write(w)?;
        self.covs.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> anyhow::Result<Self> {
        let weights = Vec::<f64>::read(r)?;
        let means = Mat::read(r)?;
        let covs = Vec::<Mat>::read(r)?;
        FullGmm::new(weights, means, covs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::Posting;
    use crate::rng::Rng;

    fn demo_full() -> FullGmm {
        FullGmm::new(
            vec![0.3, 0.7],
            Mat::from_rows(&[&[0.0, 0.0], &[2.0, -1.0]]),
            vec![
                Mat::from_rows(&[&[1.0, 0.3], &[0.3, 1.5]]),
                Mat::from_rows(&[&[0.8, -0.2], &[-0.2, 0.6]]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn full_loglike_matches_direct_formula() {
        let g = demo_full();
        let x = [0.7, -0.4];
        for c in 0..2 {
            // direct: log w − ½(F log2π + log|Σ| + (x−m)ᵀΣ⁻¹(x−m))
            let m = g.means.row(c);
            let d = [x[0] - m[0], x[1] - m[1]];
            let chol = Cholesky::new(&g.covs[c]).unwrap();
            let sd = chol.solve_vec(&d);
            let quad = d[0] * sd[0] + d[1] * sd[1];
            let want = g.weights[c].ln() - 0.5 * (2.0 * LOG_2PI + chol.logdet() + quad);
            let got = g.log_like_one(&x, c);
            assert!((got - want).abs() < 1e-10, "c={c}: {got} vs {want}");
        }
    }

    #[test]
    fn diag_promotion_agrees_with_diag_loglikes() {
        let d = DiagGmm {
            weights: vec![0.5, 0.5],
            means: Mat::from_rows(&[&[0.0, 1.0], &[2.0, 3.0]]),
            vars: Mat::from_rows(&[&[1.0, 2.0], &[0.5, 1.5]]),
        };
        let f = FullGmm::from_diag(&d).unwrap();
        let x = [0.3, -0.8];
        let mut ll_d = [0.0; 2];
        let mut ll_f = [0.0; 2];
        d.log_likes(&x, &mut ll_d);
        f.log_likes(&x, &mut ll_f);
        for c in 0..2 {
            assert!((ll_d[c] - ll_f[c]).abs() < 1e-10);
        }
    }

    #[test]
    fn em_from_stats_recovers_cluster() {
        // frames all assigned to comp 0 with weight 1 → mean/cov must
        // match the sample moments
        let mut rng = Rng::seed(31);
        let t_len = 2000;
        let data = Mat::from_fn(t_len, 2, |_, j| if j == 0 { 1.0 + rng.normal() } else { -2.0 + 0.5 * rng.normal() });
        let posts: Vec<Vec<Posting>> =
            (0..t_len).map(|_| vec![Posting { idx: 0, post: 1.0 }]).collect();
        let acc = BwStats::accumulate(&data, &posts, 2, true);
        let mut g = demo_full();
        g.update_from_stats(&acc, 1e-4).unwrap();
        assert!((g.means.get(0, 0) - 1.0).abs() < 0.1);
        assert!((g.means.get(0, 1) + 2.0).abs() < 0.1);
        assert!((g.covs[0].get(1, 1) - 0.25).abs() < 0.05);
        // comp 1 starved → untouched means
        assert_eq!(g.means.get(1, 0), 2.0);
    }

    #[test]
    fn set_means_refreshes_cache() {
        let mut g = demo_full();
        let x = [0.2, 0.4];
        let before = g.log_like_one(&x, 0);
        g.set_means(Mat::from_rows(&[&[5.0, 5.0], &[2.0, -1.0]])).unwrap();
        let after = g.log_like_one(&x, 0);
        assert!(after < before, "moving the mean away must lower the loglike");
    }

    #[test]
    fn serialization_roundtrip() {
        let g = demo_full();
        let dir = std::env::temp_dir().join("ivtv_gmm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("full.bin");
        crate::io::save(&g, &p).unwrap();
        let back: FullGmm = crate::io::load(&p).unwrap();
        assert!(back.means.approx_eq(&g.means, 0.0));
        let x = [0.1, 0.9];
        assert!((back.frame_log_like(&x) - g.frame_log_like(&x)).abs() < 1e-12);
    }
}
