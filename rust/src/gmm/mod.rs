//! GMM / UBM substrate (paper relies on Kaldi for this stage).
//!
//! * [`DiagGmm`] — diagonal-covariance GMM used for the cheap top-K
//!   Gaussian pre-selection (paper §4.2: "we use a UBM with diagonal
//!   covariance matrices to select the top-20 Gaussian components").
//! * [`FullGmm`] — full-covariance GMM used to refine the posteriors of
//!   the selected components, and as the i-vector extractor's UBM.
//! * [`train`] — the UBM recipe: global-stats init → binary splitting →
//!   diagonal EM → full-covariance EM.
//! * [`select`] — top-K selection + posterior pruning/renormalization
//!   (the CPU reference of the accelerated `align_topk` graph).
//! * [`batch`] — the batched GEMM-shaped CPU aligner that
//!   [`select_posteriors`] routes through, in f64 or mixed-precision
//!   f32 ([`AlignPrecision`]); the per-frame scalar path survives as
//!   [`select_posteriors_scalar`], the equivalence oracle.

mod batch;
mod diag;
mod full;
mod select;
mod train;

pub use batch::{AlignPrecision, AlignScratch, BatchAligner, PackedDiag, PackedDiagF32};
pub use diag::DiagGmm;
pub use full::FullGmm;
pub use select::{
    prune_posteriors, select_posteriors, select_posteriors_scalar, top_k_indices, top_k_into,
};
pub use train::{train_ubm, UbmPair};

pub(crate) const LOG_2PI: f64 = 1.8378770664093453;
