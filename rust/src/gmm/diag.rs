//! Diagonal-covariance GMM: pre-selection model + diagonal EM.

use crate::io::Serialize;
use crate::linalg::Mat;

use super::LOG_2PI;

/// Diagonal-covariance GMM.
#[derive(Debug, Clone)]
pub struct DiagGmm {
    /// Mixture weights (C), sum to 1.
    pub weights: Vec<f64>,
    /// Means (C × F).
    pub means: Mat,
    /// Diagonal variances (C × F).
    pub vars: Mat,
}

impl DiagGmm {
    /// Number of components.
    pub fn num_components(&self) -> usize {
        self.weights.len()
    }

    /// Feature dim.
    pub fn dim(&self) -> usize {
        self.means.cols()
    }

    /// Per-component log-likelihoods of one frame (length C), including
    /// log-weights — i.e. log(w_c · N(x | m_c, diag v_c)).
    pub fn log_likes(&self, x: &[f64], out: &mut [f64]) {
        let c_n = self.num_components();
        let dim = self.dim();
        debug_assert_eq!(out.len(), c_n);
        for c in 0..c_n {
            let m = self.means.row(c);
            let v = self.vars.row(c);
            let mut ll = -0.5 * dim as f64 * LOG_2PI + self.weights[c].max(1e-300).ln();
            for j in 0..dim {
                let d = x[j] - m[j];
                ll -= 0.5 * (v[j].ln() + d * d / v[j]);
            }
            out[c] = ll;
        }
    }

    /// Total log-likelihood of one frame: logsumexp over components.
    pub fn frame_log_like(&self, x: &[f64]) -> f64 {
        let mut ll = vec![0.0; self.num_components()];
        self.log_likes(x, &mut ll);
        log_sum_exp(&ll)
    }

    /// One EM iteration over frames (rows of `data`); returns the mean
    /// frame log-likelihood *before* the update (standard EM reporting).
    /// Parallelized over frame chunks (UBM setup dominated experiment
    /// wall time single-threaded — EXPERIMENTS.md §Perf).
    pub fn em_step(&mut self, data: &Mat, var_floor: f64) -> f64 {
        let c_n = self.num_components();
        let dim = self.dim();
        let t_len = data.rows();
        let workers = crate::exec::default_workers();
        let chunk = t_len.div_ceil(workers).max(1);
        let n_chunks = t_len.div_ceil(chunk);

        struct Partial {
            n: Vec<f64>,
            f: Mat,
            s: Mat,
            ll: f64,
        }
        let partials = crate::exec::map_parallel(n_chunks, workers, |k| {
            let mut ll_buf = vec![0.0; c_n];
            let mut p = Partial {
                n: vec![0.0; c_n],
                f: Mat::zeros(c_n, dim),
                s: Mat::zeros(c_n, dim),
                ll: 0.0,
            };
            for t in k * chunk..((k + 1) * chunk).min(t_len) {
                let x = data.row(t);
                self.log_likes(x, &mut ll_buf);
                let lse = log_sum_exp(&ll_buf);
                p.ll += lse;
                for c in 0..c_n {
                    let gamma = (ll_buf[c] - lse).exp();
                    if gamma < 1e-12 {
                        continue;
                    }
                    p.n[c] += gamma;
                    let fr = p.f.row_mut(c);
                    let sr = p.s.row_mut(c);
                    for j in 0..dim {
                        fr[j] += gamma * x[j];
                        sr[j] += gamma * x[j] * x[j];
                    }
                }
            }
            p
        });
        let mut acc_n = vec![0.0; c_n];
        let mut acc_f = Mat::zeros(c_n, dim);
        let mut acc_s = Mat::zeros(c_n, dim);
        let mut total_ll = 0.0;
        for p in partials {
            for (a, b) in acc_n.iter_mut().zip(&p.n) {
                *a += b;
            }
            acc_f.add_scaled(1.0, &p.f);
            acc_s.add_scaled(1.0, &p.s);
            total_ll += p.ll;
        }
        let total_n: f64 = acc_n.iter().sum();
        for c in 0..c_n {
            if acc_n[c] < 1e-8 {
                continue; // keep dead components untouched
            }
            self.weights[c] = acc_n[c] / total_n;
            for j in 0..dim {
                let mean = acc_f.get(c, j) / acc_n[c];
                let var = (acc_s.get(c, j) / acc_n[c] - mean * mean).max(var_floor);
                self.means.set(c, j, mean);
                self.vars.set(c, j, var);
            }
        }
        total_ll / t_len as f64
    }
}

impl Serialize for DiagGmm {
    fn write(&self, w: &mut crate::io::BinWriter) -> anyhow::Result<()> {
        self.weights.write(w)?;
        self.means.write(w)?;
        self.vars.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> anyhow::Result<Self> {
        Ok(Self { weights: Vec::<f64>::read(r)?, means: Mat::read(r)?, vars: Mat::read(r)? })
    }
}

/// Numerically-stable logsumexp.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn two_component() -> DiagGmm {
        DiagGmm {
            weights: vec![0.4, 0.6],
            means: Mat::from_rows(&[&[0.0, 0.0], &[3.0, 3.0]]),
            vars: Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]),
        }
    }

    #[test]
    fn loglikes_match_hand_formula() {
        let g = two_component();
        let mut ll = [0.0; 2];
        g.log_likes(&[0.0, 0.0], &mut ll);
        let want0 = 0.4f64.ln() - LOG_2PI; // at the mean of comp 0
        assert!((ll[0] - want0).abs() < 1e-12, "{} vs {want0}", ll[0]);
        let want1 = 0.6f64.ln() - LOG_2PI - 0.5 * 18.0;
        assert!((ll[1] - want1).abs() < 1e-12);
    }

    #[test]
    fn log_sum_exp_stable() {
        assert!((log_sum_exp(&[1000.0, 1000.0]) - (1000.0 + 2.0f64.ln())).abs() < 1e-9);
        assert_eq!(log_sum_exp(&[f64::NEG_INFINITY, f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }

    #[test]
    fn em_increases_likelihood() {
        let mut rng = Rng::seed(21);
        // two clear clusters
        let data = Mat::from_fn(400, 2, |t, _| {
            if t % 2 == 0 {
                rng.normal()
            } else {
                4.0 + rng.normal()
            }
        });
        let mut g = DiagGmm {
            weights: vec![0.5, 0.5],
            means: Mat::from_rows(&[&[0.5, 0.5], &[3.0, 3.0]]),
            vars: Mat::from_rows(&[&[2.0, 2.0], &[2.0, 2.0]]),
        };
        let mut prev = f64::NEG_INFINITY;
        for _ in 0..5 {
            let ll = g.em_step(&data, 1e-4);
            assert!(ll >= prev - 1e-9, "EM decreased: {prev} → {ll}");
            prev = ll;
        }
        // variances floored
        for c in 0..2 {
            for j in 0..2 {
                assert!(g.vars.get(c, j) >= 1e-4);
            }
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let g = two_component();
        let dir = std::env::temp_dir().join("ivtv_gmm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("diag.bin");
        crate::io::save(&g, &p).unwrap();
        let back: DiagGmm = crate::io::load(&p).unwrap();
        assert_eq!(back.weights, g.weights);
        assert!(back.means.approx_eq(&g.means, 0.0));
    }
}
