//! Frame alignment: top-K Gaussian selection + posterior pruning.
//!
//! This is the CPU reference of the accelerated `align_topk` graph and
//! follows Kaldi/paper §4.2 exactly:
//!
//! 1. diagonal-covariance UBM scores all C components; keep the top-K
//!    (paper: K = 20);
//! 2. the full-covariance UBM re-scores only the selected components;
//! 3. posteriors are softmax over the selected components, entries
//!    below `min_post` (paper: 0.025) are discarded, and the survivors
//!    are linearly rescaled to sum to one.

use crate::io::Posting;
use crate::linalg::Mat;

use super::diag::log_sum_exp;
use super::{DiagGmm, FullGmm};

/// Indices of the K largest entries of `xs`, descending by value
/// (ties broken toward the lower index, matching a stable full sort).
/// Generic over the score scalar so the f32 alignment path selects
/// straight off its f32 score block without a widening copy.
pub fn top_k_indices<T: PartialOrd + Copy>(xs: &[T], k: usize) -> Vec<u32> {
    let mut out = Vec::new();
    top_k_into(xs, k, &mut out);
    out
}

/// [`top_k_indices`] into a reusable buffer — the per-frame hot path of
/// the batched aligner allocates nothing.
///
/// Uses a fixed-size binary min-heap held in `out` itself: build is
/// O(K), each of the remaining C−K elements costs O(1) when it loses to
/// the current K-th best and O(log K) when it displaces it. The old
/// insertion-shift selection degenerated to O(C·K) shifts per frame on
/// ascending input (every element displaced the tail); the heap's worst
/// case is O(C log K).
pub fn top_k_into<T: PartialOrd + Copy>(xs: &[T], k: usize, out: &mut Vec<u32>) {
    let k = k.min(xs.len());
    out.clear();
    if k == 0 {
        return;
    }
    out.extend(0..k as u32);
    for i in (0..k / 2).rev() {
        sift_down(out, xs, i);
    }
    for i in k..xs.len() {
        // strict `>` keeps the earliest index among boundary ties,
        // matching a stable descending sort
        if xs[i] > xs[out[0] as usize] {
            out[0] = i as u32;
            sift_down(out, xs, 0);
        }
    }
    out.sort_unstable_by(|&a, &b| {
        xs[b as usize].partial_cmp(&xs[a as usize]).unwrap().then(a.cmp(&b))
    });
}

/// Heap ordering: among equal values the *higher* index ranks lower,
/// so it sits at the root and is evicted first — keeping the earliest
/// indices among ties, exactly like a stable descending sort (relevant
/// when mixture splitting clones components bit-exactly).
#[inline]
fn heap_less<T: PartialOrd + Copy>(xs: &[T], a: u32, b: u32) -> bool {
    let (xa, xb) = (xs[a as usize], xs[b as usize]);
    xa < xb || (xa == xb && a > b)
}

/// Restore the min-heap property under `heap[i]` (keyed by `xs`).
fn sift_down<T: PartialOrd + Copy>(heap: &mut [u32], xs: &[T], mut i: usize) {
    loop {
        let l = 2 * i + 1;
        if l >= heap.len() {
            break;
        }
        let mut m = if heap_less(xs, heap[l], heap[i]) { l } else { i };
        let r = l + 1;
        if r < heap.len() && heap_less(xs, heap[r], heap[m]) {
            m = r;
        }
        if m == i {
            break;
        }
        heap.swap(i, m);
        i = m;
    }
}

/// Softmax over selected log-likes, prune `< min_post`, renormalize.
/// Returns (index, posterior) pairs — the archive representation.
pub fn prune_posteriors(select: &[u32], log_likes: &[f64], min_post: f64) -> Vec<Posting> {
    debug_assert_eq!(select.len(), log_likes.len());
    let lse = log_sum_exp(log_likes);
    let mut kept: Vec<Posting> = select
        .iter()
        .zip(log_likes)
        .filter_map(|(&idx, &ll)| {
            let post = (ll - lse).exp();
            (post >= min_post).then_some(Posting { idx, post: post as f32 })
        })
        .collect();
    if kept.is_empty() {
        // degenerate frame: keep the single best component
        let best = select
            .iter()
            .zip(log_likes)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(&idx, _)| idx)
            .unwrap();
        return vec![Posting { idx: best, post: 1.0 }];
    }
    let total: f32 = kept.iter().map(|p| p.post).sum();
    for p in &mut kept {
        p.post /= total;
    }
    kept
}

/// Full two-stage alignment of one utterance (frames × F): diag top-K →
/// full-cov refinement → pruning. Thin wrapper over the batched
/// GEMM-shaped aligner ([`super::BatchAligner`]), so every caller and
/// test of this entry point exercises the batched kernel.
pub fn select_posteriors(
    diag: &DiagGmm,
    full: &FullGmm,
    feats: &Mat,
    top_k: usize,
    min_post: f64,
) -> Vec<Vec<Posting>> {
    super::BatchAligner::new(diag, full, top_k, min_post).align_utterance(feats)
}

/// The per-frame scalar reference: one `diag.log_likes` pass per frame.
/// Kept as the equivalence oracle for the batched aligner and as the
/// bench baseline — not a hot path.
pub fn select_posteriors_scalar(
    diag: &DiagGmm,
    full: &FullGmm,
    feats: &Mat,
    top_k: usize,
    min_post: f64,
) -> Vec<Vec<Posting>> {
    let c_n = diag.num_components();
    let mut ll_diag = vec![0.0; c_n];
    let mut out = Vec::with_capacity(feats.rows());
    let mut ll_sel = vec![0.0; top_k.min(c_n)];
    for t in 0..feats.rows() {
        let x = feats.row(t);
        diag.log_likes(x, &mut ll_diag);
        let sel = top_k_indices(&ll_diag, top_k);
        ll_sel.resize(sel.len(), 0.0);
        full.log_likes_select(x, &sel, &mut ll_sel);
        out.push(prune_posteriors(&sel, &ll_sel, min_post));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, gen_dim};

    #[test]
    fn top_k_finds_largest() {
        let xs = [0.1, 5.0, -2.0, 3.0, 4.0];
        let mut got = top_k_indices(&xs, 3);
        got.sort();
        assert_eq!(got, vec![1, 3, 4]);
    }

    #[test]
    fn top_k_sorted_ascending_input() {
        // the old insertion-shift selection degenerated on this shape;
        // the heap must stay correct (and fast) here
        let xs: Vec<f64> = (0..500).map(|i| i as f64).collect();
        let got = top_k_indices(&xs, 20);
        let want: Vec<u32> = (480..500).rev().map(|i| i as u32).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn top_k_returns_descending_order() {
        let xs = [0.1, 5.0, -2.0, 3.0, 4.0, 5.0];
        // descending by value; tie at 5.0 keeps the lower index first
        assert_eq!(top_k_indices(&xs, 4), vec![1, 5, 4, 3]);
    }

    #[test]
    fn top_k_boundary_tie_evicts_highest_index() {
        // ties straddling the K boundary must keep the earliest index,
        // matching a stable descending sort — including when the tied
        // entry is *evicted* from the heap, not just never inserted
        let xs = [5.0, 5.0, 6.0];
        assert_eq!(top_k_indices(&xs, 2), vec![2, 0]);
        let xs2 = [5.0, 3.0, 5.0, 6.0];
        assert_eq!(top_k_indices(&xs2, 2), vec![3, 0]);
    }

    #[test]
    fn top_k_handles_k_ge_len() {
        let xs = [2.0, 1.0];
        let mut got = top_k_indices(&xs, 5);
        got.sort();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn prop_top_k_matches_sort() {
        forall(
            505,
            64,
            |rng| {
                let n = gen_dim(rng, 1, 200);
                let k = gen_dim(rng, 1, n);
                let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (xs, k)
            },
            |(xs, k)| {
                let mut got = top_k_indices(xs, *k);
                got.sort();
                let mut order: Vec<usize> = (0..xs.len()).collect();
                order.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
                let mut want: Vec<u32> = order[..*k].iter().map(|&i| i as u32).collect();
                want.sort();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got:?}, want {want:?}"))
                }
            },
        );
    }

    #[test]
    fn pruned_posteriors_sum_to_one() {
        let select = [3u32, 7, 9];
        let ll = [0.0, -1.0, -8.0]; // third gets pruned at 0.025
        let posts = prune_posteriors(&select, &ll, 0.025);
        let total: f32 = posts.iter().map(|p| p.post).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(posts.iter().all(|p| p.post >= 0.025));
        assert_eq!(posts.len(), 2);
        assert_eq!(posts[0].idx, 3);
    }

    #[test]
    fn degenerate_frame_keeps_best() {
        // all posteriors below threshold is impossible after softmax
        // (they sum to 1), but equal tiny values with huge min_post is:
        let posts = prune_posteriors(&[1, 2, 3, 4], &[0.0, 0.0, 0.0, 0.0], 0.9);
        assert_eq!(posts.len(), 1);
        assert_eq!(posts[0].post, 1.0);
    }

    #[test]
    fn prop_pruning_invariants() {
        forall(
            606,
            64,
            |rng| {
                let n = gen_dim(rng, 1, 30);
                let ll: Vec<f64> = (0..n).map(|_| 4.0 * rng.normal()).collect();
                let sel: Vec<u32> = (0..n as u32).collect();
                (sel, ll)
            },
            |(sel, ll)| {
                let posts = prune_posteriors(sel, ll, 0.025);
                let total: f64 = posts.iter().map(|p| p.post as f64).sum();
                if (total - 1.0).abs() > 1e-5 {
                    return Err(format!("sum {total}"));
                }
                if posts.is_empty() {
                    return Err("empty posting".into());
                }
                Ok(())
            },
        );
    }
}
