//! Timing / throughput metrics: real-time factors and stage reports.
//!
//! The paper's §4.2 headline numbers are *real-time factors* (alignment
//! 3000× RT, extraction 10 000× RT) and a training speed-up vs the CPU
//! baseline. Synthetic utterances have no audio clock, so we adopt the
//! front-end's nominal frame rate (100 frames/s, the standard 10 ms
//! hop the paper's MFCC config implies) to convert frames to seconds.

use std::time::Instant;

/// Nominal frame hop (seconds) — 10 ms like the Kaldi MFCC config.
pub const FRAME_HOP_S: f64 = 0.01;

/// Convert a frame count to nominal audio seconds.
pub fn frames_to_audio_seconds(frames: usize) -> f64 {
    frames as f64 * FRAME_HOP_S
}

/// Real-time factor: processed audio seconds per wall second.
pub fn rt_factor(frames: usize, wall_s: f64) -> f64 {
    if wall_s <= 0.0 {
        return f64::INFINITY;
    }
    frames_to_audio_seconds(frames) / wall_s
}

/// Simple scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// One row of a stage report (printed by examples / benches).
#[derive(Debug, Clone)]
pub struct StageReport {
    pub stage: String,
    pub wall_s: f64,
    pub items: usize,
    pub item_name: String,
    /// Optional real-time factor (alignment/extraction stages).
    pub rt: Option<f64>,
}

impl StageReport {
    pub fn new(stage: &str, wall_s: f64, items: usize, item_name: &str) -> Self {
        Self { stage: stage.into(), wall_s, items, item_name: item_name.into(), rt: None }
    }

    pub fn with_rt(mut self, frames: usize) -> Self {
        self.rt = Some(rt_factor(frames, self.wall_s));
        self
    }

    /// items / second.
    pub fn throughput(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::INFINITY;
        }
        self.items as f64 / self.wall_s
    }
}

/// Render stage reports as a markdown table (EXPERIMENTS.md format).
pub fn markdown_table(rows: &[StageReport]) -> String {
    let mut s = String::from("| stage | wall (s) | items | items/s | ×RT |\n|---|---|---|---|---|\n");
    for r in rows {
        let rt = r.rt.map(|x| format!("{x:.0}")).unwrap_or_else(|| "—".into());
        s.push_str(&format!(
            "| {} | {:.3} | {} {} | {:.1} | {} |\n",
            r.stage,
            r.wall_s,
            r.items,
            r.item_name,
            r.throughput(),
            rt
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rt_factor_math() {
        // 100 000 frames = 1000 s of audio; processed in 2 s → 500× RT
        assert!((rt_factor(100_000, 2.0) - 500.0).abs() < 1e-9);
        assert_eq!(rt_factor(10, 0.0), f64::INFINITY);
    }

    #[test]
    fn report_table_renders() {
        let rows = vec![
            StageReport::new("align", 2.0, 100_000, "frames").with_rt(100_000),
            StageReport::new("mstep", 0.5, 64, "components"),
        ];
        let md = markdown_table(&rows);
        assert!(md.contains("| align |"));
        assert!(md.contains("500"));
        assert!(md.contains("| — |") || md.contains(" — |"));
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }
}
