//! Timing / throughput metrics: real-time factors, stage reports, and
//! the serving-path latency histograms.
//!
//! The paper's §4.2 headline numbers are *real-time factors* (alignment
//! 3000× RT, extraction 10 000× RT) and a training speed-up vs the CPU
//! baseline. Synthetic utterances have no audio clock, so we adopt the
//! front-end's nominal frame rate (100 frames/s, the standard 10 ms
//! hop the paper's MFCC config implies) to convert frames to seconds.
//!
//! [`LatencyHistogram`] backs the online serving subsystem
//! ([`crate::serve`]): per-request latencies are recorded lock-free
//! into log-spaced buckets and summarized as p50/p95/p99.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Nominal frame hop (seconds) — 10 ms like the Kaldi MFCC config.
pub const FRAME_HOP_S: f64 = 0.01;

/// Convert a frame count to nominal audio seconds.
pub fn frames_to_audio_seconds(frames: usize) -> f64 {
    frames as f64 * FRAME_HOP_S
}

/// Real-time factor: processed audio seconds per wall second.
pub fn rt_factor(frames: usize, wall_s: f64) -> f64 {
    if wall_s <= 0.0 {
        return f64::INFINITY;
    }
    frames_to_audio_seconds(frames) / wall_s
}

/// Simple scoped stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// One row of a stage report (printed by examples / benches).
#[derive(Debug, Clone)]
pub struct StageReport {
    pub stage: String,
    pub wall_s: f64,
    pub items: usize,
    pub item_name: String,
    /// Optional real-time factor (alignment/extraction stages).
    pub rt: Option<f64>,
}

impl StageReport {
    pub fn new(stage: &str, wall_s: f64, items: usize, item_name: &str) -> Self {
        Self { stage: stage.into(), wall_s, items, item_name: item_name.into(), rt: None }
    }

    pub fn with_rt(mut self, frames: usize) -> Self {
        self.rt = Some(rt_factor(frames, self.wall_s));
        self
    }

    /// items / second.
    pub fn throughput(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::INFINITY;
        }
        self.items as f64 / self.wall_s
    }
}

/// Render stage reports as a markdown table (EXPERIMENTS.md format).
pub fn markdown_table(rows: &[StageReport]) -> String {
    let mut s = String::from("| stage | wall (s) | items | items/s | ×RT |\n|---|---|---|---|---|\n");
    for r in rows {
        let rt = r.rt.map(|x| format!("{x:.0}")).unwrap_or_else(|| "—".into());
        s.push_str(&format!(
            "| {} | {:.3} | {} {} | {:.1} | {} |\n",
            r.stage,
            r.wall_s,
            r.items,
            r.item_name,
            r.throughput(),
            rt
        ));
    }
    s
}

// ---------------------- serving latency histogram ----------------------

/// Buckets per octave (factor-of-two span) of the latency histogram:
/// 8 sub-buckets give ≤ ~9 % relative quantile error.
const LAT_BUCKETS_PER_OCTAVE: usize = 8;
/// Lower edge of bucket 0 (1 µs — anything faster lands in bucket 0).
const LAT_MIN_S: f64 = 1e-6;
/// Bucket count: 28 octaves above 1 µs ≈ 268 s ceiling.
const LAT_N_BUCKETS: usize = 28 * LAT_BUCKETS_PER_OCTAVE;

/// Concurrent log-spaced latency histogram: `record` is a single atomic
/// add per bucket (plus count/sum/max upkeep), so request threads never
/// contend on a lock; quantiles are read-side walks over the buckets.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
    invalid: AtomicU64,
}

/// Point-in-time summary of a [`LatencyHistogram`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    /// Rejected observations (non-finite or negative) — a nonzero value
    /// means a caller is timing with a broken clock, not that requests
    /// were instantaneous.
    pub invalid: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..LAT_N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            invalid: AtomicU64::new(0),
        }
    }

    fn bucket_index(seconds: f64) -> usize {
        if seconds <= LAT_MIN_S {
            return 0;
        }
        let octaves = (seconds / LAT_MIN_S).log2();
        ((octaves * LAT_BUCKETS_PER_OCTAVE as f64) as usize).min(LAT_N_BUCKETS - 1)
    }

    /// Upper edge of bucket `i` in seconds (quantiles report this, i.e.
    /// a conservative upper bound of the true quantile).
    fn bucket_upper_s(i: usize) -> f64 {
        LAT_MIN_S * 2f64.powf((i + 1) as f64 / LAT_BUCKETS_PER_OCTAVE as f64)
    }

    /// Record one observation given as a [`std::time::Duration`] (the
    /// request-path callers all hold an `Instant::elapsed()`).
    pub fn record_duration(&self, elapsed: std::time::Duration) {
        self.record(elapsed.as_secs_f64());
    }

    /// Record one observation (seconds). Non-finite or negative values
    /// are counted in a dedicated `invalid` counter instead of being
    /// clamped into bucket 0, where they would silently drag down every
    /// quantile.
    pub fn record(&self, seconds: f64) {
        if !(seconds.is_finite() && seconds >= 0.0) {
            self.invalid.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.buckets[Self::bucket_index(seconds)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let ns = (seconds * 1e9) as u64;
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Number of rejected (non-finite / negative) observations.
    pub fn invalid(&self) -> u64 {
        self.invalid.load(Ordering::Relaxed)
    }

    /// Quantile `q ∈ [0, 1]` as the upper edge of the covering bucket
    /// (0.0 when empty). The total is taken from one snapshot of the
    /// buckets themselves (not the separate `count` atomic), so a read
    /// that races concurrent `record`s stays internally consistent
    /// instead of falling through to the top-bucket sentinel.
    pub fn quantile(&self, q: f64) -> f64 {
        let snapshot: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = snapshot.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &b) in snapshot.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return Self::bucket_upper_s(i);
            }
        }
        Self::bucket_upper_s(LAT_N_BUCKETS - 1)
    }

    /// p50/p95/p99 + mean/max snapshot.
    pub fn summary(&self) -> LatencySummary {
        let count = self.count();
        let mean_s = if count == 0 {
            0.0
        } else {
            self.total_ns.load(Ordering::Relaxed) as f64 * 1e-9 / count as f64
        };
        LatencySummary {
            count,
            invalid: self.invalid(),
            mean_s,
            p50_s: self.quantile(0.50),
            p95_s: self.quantile(0.95),
            p99_s: self.quantile(0.99),
            max_s: self.max_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

// ---------------------- queue depth gauge ----------------------

/// Concurrent depth gauge for bounded queues: each enqueue records the
/// post-push depth, and the summary exposes the max and the mean of the
/// recorded samples — the "how close to `queue_cap` does admission
/// control run" statistic of the serving report. Lock-free like
/// [`LatencyHistogram`]: three relaxed atomics per record.
/// In addition to the lifetime stats, a second set of atomics tracks a
/// *window* since the last [`DepthGauge::take_window`] call, so a
/// long-running process can report recent queue pressure instead of a
/// lifetime average that stops moving after the first million samples.
#[derive(Debug, Default)]
pub struct DepthGauge {
    max: AtomicU64,
    sum: AtomicU64,
    samples: AtomicU64,
    win_max: AtomicU64,
    win_sum: AtomicU64,
    win_samples: AtomicU64,
}

/// Point-in-time summary of a [`DepthGauge`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DepthSummary {
    /// Recorded samples (enqueues, for a queue gauge).
    pub samples: u64,
    /// Largest recorded depth.
    pub max: u64,
    /// Mean recorded depth (0.0 when empty).
    pub mean: f64,
}

impl DepthGauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observed depth.
    pub fn record(&self, depth: u64) {
        self.max.fetch_max(depth, Ordering::Relaxed);
        self.sum.fetch_add(depth, Ordering::Relaxed);
        self.samples.fetch_add(1, Ordering::Relaxed);
        self.win_max.fetch_max(depth, Ordering::Relaxed);
        self.win_sum.fetch_add(depth, Ordering::Relaxed);
        self.win_samples.fetch_add(1, Ordering::Relaxed);
    }

    /// Lifetime max / mean snapshot.
    pub fn summary(&self) -> DepthSummary {
        let samples = self.samples.load(Ordering::Relaxed);
        let mean = if samples == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / samples as f64
        };
        DepthSummary { samples, max: self.max.load(Ordering::Relaxed), mean }
    }

    /// Stats since the previous `take_window` call, resetting the
    /// window — back-to-back exports see disjoint intervals. The three
    /// swaps are independent, so a record racing an export may split
    /// its fields across two windows; that skews one export's mean by
    /// at most one sample, which is fine for a monitoring read.
    pub fn take_window(&self) -> DepthSummary {
        let samples = self.win_samples.swap(0, Ordering::Relaxed);
        let sum = self.win_sum.swap(0, Ordering::Relaxed);
        let max = self.win_max.swap(0, Ordering::Relaxed);
        let mean = if samples == 0 { 0.0 } else { sum as f64 / samples as f64 };
        DepthSummary { samples, max, mean }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rt_factor_math() {
        // 100 000 frames = 1000 s of audio; processed in 2 s → 500× RT
        assert!((rt_factor(100_000, 2.0) - 500.0).abs() < 1e-9);
        assert_eq!(rt_factor(10, 0.0), f64::INFINITY);
    }

    #[test]
    fn report_table_renders() {
        let rows = vec![
            StageReport::new("align", 2.0, 100_000, "frames").with_rt(100_000),
            StageReport::new("mstep", 0.5, 64, "components"),
        ];
        let md = markdown_table(&rows);
        assert!(md.contains("| align |"));
        assert!(md.contains("500"));
        assert!(md.contains("| — |") || md.contains(" — |"));
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }

    #[test]
    fn histogram_quantiles_bracket_known_values() {
        let h = LatencyHistogram::new();
        // 90 fast (1 ms) + 10 slow (100 ms) observations
        for _ in 0..90 {
            h.record(1e-3);
        }
        for _ in 0..10 {
            h.record(0.1);
        }
        assert_eq!(h.count(), 100);
        let s = h.summary();
        // bucket resolution is 2^(1/8) ≈ 1.09×: quantiles are upper
        // bounds within ~10 % of the true value
        assert!(s.p50_s >= 1e-3 && s.p50_s < 1.2e-3, "p50 {}", s.p50_s);
        assert!(s.p95_s >= 0.1 && s.p95_s < 0.12, "p95 {}", s.p95_s);
        assert!(s.p99_s >= 0.1 && s.p99_s < 0.12, "p99 {}", s.p99_s);
        assert!((s.max_s - 0.1).abs() < 1e-6);
        let want_mean = (90.0 * 1e-3 + 10.0 * 0.1) / 100.0;
        assert!((s.mean_s - want_mean).abs() < 1e-6, "mean {}", s.mean_s);
    }

    #[test]
    fn histogram_empty_and_extremes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.summary().count, 0);
        // zero and huge values are in-range (clamped to edge buckets);
        // negative / non-finite ones land in `invalid`, not bucket 0
        h.record(0.0);
        h.record(-1.0);
        h.record(1e6);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 2);
        assert_eq!(h.invalid(), 3);
        assert!(h.quantile(1.0) > 0.0);
        let s = h.summary();
        assert_eq!(s.count, 2);
        assert_eq!(s.invalid, 3);
        // the Duration convenience records like the f64 path
        h.record_duration(std::time::Duration::from_millis(2));
        assert_eq!(h.count(), 3);
        assert_eq!(h.invalid(), 3);
    }

    #[test]
    fn depth_gauge_tracks_max_and_mean() {
        let g = DepthGauge::new();
        assert_eq!(g.summary(), DepthSummary { samples: 0, max: 0, mean: 0.0 });
        for d in [1, 4, 2, 1] {
            g.record(d);
        }
        let s = g.summary();
        assert_eq!(s.samples, 4);
        assert_eq!(s.max, 4);
        assert!((s.mean - 2.0).abs() < 1e-12, "mean {}", s.mean);
    }

    #[test]
    fn depth_gauge_window_resets_on_read() {
        let g = DepthGauge::new();
        for d in [1, 4, 2, 1] {
            g.record(d);
        }
        let w = g.take_window();
        assert_eq!(w.samples, 4);
        assert_eq!(w.max, 4);
        assert!((w.mean - 2.0).abs() < 1e-12);
        // the read reset the window; lifetime stats are untouched
        assert_eq!(g.take_window(), DepthSummary { samples: 0, max: 0, mean: 0.0 });
        assert_eq!(g.summary().samples, 4);
        assert_eq!(g.summary().max, 4);
        // new samples start a fresh window with its own (lower) max
        g.record(2);
        let w = g.take_window();
        assert_eq!(w.samples, 1);
        assert_eq!(w.max, 2);
        assert_eq!(g.summary().max, 4, "lifetime max still reflects the old peak");
    }

    #[test]
    fn histogram_concurrent_records_all_counted() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = std::sync::Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..250 {
                    h.record(1e-4 * (1 + (t + i) % 7) as f64);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 1000);
    }
}
