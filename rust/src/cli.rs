//! Command-line launcher (no external arg-parsing crates are available
//! offline, so this module is the substrate: a small subcommand + flag
//! parser with help text).

mod args;
mod commands;

pub use args::Args;

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main() -> i32 {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some((cmd, rest)) = argv.split_first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "synth" => commands::synth(&args),
        "train-ubm" => commands::train_ubm(&args),
        "align" => commands::align(&args),
        "train" => commands::train(&args),
        "extract" => commands::extract(&args),
        "backend" => commands::backend(&args),
        "eval" => commands::eval(&args),
        "pipeline" => commands::pipeline(&args),
        "bundle" => commands::bundle(&args),
        "verify" => commands::verify(&args),
        "serve-bench" => commands::serve_bench(&args),
        "cluster-bench" => commands::cluster_bench(&args),
        "replay" => commands::replay(&args),
        "chaos-bench" => commands::chaos_bench(&args),
        "registry-recover" => commands::registry_recover(&args),
        "registry-bench" => commands::registry_bench(&args),
        "stats" => commands::stats(&args),
        "smoke" => commands::smoke(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}` (try `ivector-tv help`)"),
    }
}

fn print_help() {
    println!(
        "\
ivector-tv — GPU-accelerated total-variability i-vector stack
             (Vestman et al., Interspeech 2019 reproduction)

USAGE: ivector-tv <COMMAND> [--flag value ...]

COMMANDS:
  synth      generate the synthetic corpus          (--config, --out-dir)
  train-ubm  train diagonal+full UBM                (--config, --data-dir)
  align      compute frame posteriors (accelerated) (--config, --data-dir)
  train      train the i-vector extractor           (--config, --variant,
             --iters, --realign-every, --seed, --accel|--cpu-ref)
  extract    extract i-vectors with a trained model (--config, --model)
  backend    train LDA + PLDA on extracted vectors  (--config)
  eval       score trials, report EER/minDCF        (--config)
  pipeline   synth → ubm → align → train → extract → backend → eval
             → bundle
  bundle     pack UBM+TVM+backend into work/bundle.bin for serving
  verify     online enroll/verify traffic vs a bundle (--work, --config,
             --speakers, --enroll-utts, --trials, --concurrency,
             --save-registry PATH, --registry DIR for a durable
             WAL-backed speaker store — see `[registry]` in the config)
  serve-bench  sustained verify load, micro-batched vs unbatched;
             writes BENCH_2.json + an observability snapshot
             (--requests, --concurrency, --speakers, --enroll-utts,
             --work | tiny in-process bundle, --out, --obs-out,
             --batched-only); --streaming replays chunk-fed sessions
             with early-exit thresholds vs a one-shot baseline and
             writes BENCH_8.json instead (--chunk-frames,
             --accept-score, --reject-score — unset thresholds are
             calibrated from oracle probe trials); --capture-out PATH
             records the load into a flight-recorder corpus (implies
             --batched-only; sampling via the [capture] config section)
  cluster-bench  1-vs-N replica scaling under a saturating load;
             writes BENCH_5.json + an observability snapshot
             (--replicas, --route, --max-failovers,
             --swap-mid-run, --stall-replica K, --live-enroll-every,
             --requests, --concurrency, --speakers, --enroll-utts,
             --work | tiny in-process bundle, --out, --obs-out);
             --capture-out PATH records the N-replica run's routed
             requests (failover hops included) into a capture corpus
  replay     re-issue a captured corpus against a fresh engine and
             verify it reproduces what production recorded: same
             bundle → every verify score within --tolerance (1e-10)
             and every outcome class equal, else nonzero exit; writes
             BENCH_10.json with capture-on/off overhead + per-stage
             latency drift (--capture PATH, --work | same-seed tiny
             bundle, --seed, --max-speed, --tolerance, --out,
             --obs-out)
  chaos-bench  deterministic self-healing drill: scripted replica
             stall + WAL poisoning mid-load; the faulty replica must
             quarantine, rebuild, and return to serving, the registry
             must degrade read-only and repair, and zero acked
             enrollments may be lost — non-zero exit otherwise; writes
             BENCH_9.json + an observability snapshot (--replicas,
             --faulty-replica, --stall-at, --wal-fault-at, --tick-ms,
             --settle-ms, --requests, --concurrency, --speakers,
             --enroll-utts, --live-enroll-every, --out, --obs-out)
  registry-recover  open a durable registry dir, report what recovery
             found (snapshot/replayed/torn tail), optionally compact
             (--dir PATH, --shards, --sync, --compact-every, --compact)
  registry-bench  crash/recovery drill: enroll synthetic speakers
             through the WAL, kill persistence mid-stream, recover, and
             audit for lost enrollments; writes BENCH_6.json
             (--speakers, --dim, --shards, --sync, --compact-every,
             --crash-at, --dir, --out)
  stats      read an observability snapshot (counters, per-stage
             latency histograms, slow traces) written by the bench
             commands' --obs-out; --check validates the schema and
             the canonical metric set, exiting nonzero on drift
             (--snapshot PATH, default OBS_SNAPSHOT.json);
             --diff OLD.json compares OLD against --snapshot —
             counters as deltas, histograms as p50/p95/p99 drift
  smoke      compile+run an HLO artifact with zero inputs (--hlo PATH)

Flags not listed above: --artifacts DIR (default ./artifacts),
--work DIR (default ./work), --quiet. See configs/*.toml for the full
config schema."
    );
}
