//! L3 coordinator — the paper's training system.
//!
//! * [`align`] — frame-alignment rounds (CPU reference and accelerated
//!   paths) + Baum-Welch statistics over the corpus.
//! * [`trainer`] — the five-step EM schedule of §3.2 with optional
//!   in-training realignment, pipelined CPU loaders feeding the device
//!   (the paper's Fig. 1), per-iteration diagnostics.
//! * [`ensemble`] — multi-seed ensemble runs (the paper averages five
//!   random restarts for every curve).
//! * [`stages`] — CLI stage implementations (synth → ubm → align →
//!   train → extract → backend → eval).

pub mod align;
pub mod ensemble;
pub mod stages;
pub mod trainer;

pub use align::{
    align_archive_accel, align_archive_cpu, align_archive_cpu_prec, align_archive_cpu_scalar,
    stats_from_posts, GlobalRawStats,
};
pub use trainer::{run_alignment, train_tvm, train_tvm_with_stats, ComputePath, IterCtx, IterStats, TrainSetup};
