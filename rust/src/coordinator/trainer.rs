//! The EM training schedule (paper §3.2's five-step loop):
//!
//! 1. frame alignment + Baum-Welch statistics with the current UBM;
//! 2. E-step (device batches via pipelined CPU loaders, or the batched
//!    GEMM-shaped CPU path);
//! 3. M-step: T update, optional Σ update;
//! 4. optional minimum-divergence re-estimation;
//! 5. if realignment is scheduled: push the updated bias means back
//!    into the UBM and recompute alignments next iteration.

use anyhow::Result;

use crate::config::Config;
use crate::exec::{default_workers, map_parallel, pipeline};
use crate::gmm::{DiagGmm, FullGmm};
use crate::io::FeatArchive;
use crate::ivector::{
    estep_batch_cpu, min_divergence, mstep, AccelTvm, EstepAccum, EstepWorkspace, Formulation,
    GlobalSecondOrder, TrainVariant, TvModel, UttStats,
};
use crate::metrics::Stopwatch;
use crate::stats::BwStats;

use super::align::{
    align_archive_accel, align_archive_cpu_prec, stats_from_posts, ArchivePosts, GlobalRawStats,
};

/// Which compute path executes the hot loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputePath {
    /// Pure-rust scalar reference (the "Kaldi CPU baseline" analogue).
    CpuRef,
    /// XLA/PJRT device graphs fed by pipelined CPU loaders (the
    /// paper's GPU path analogue).
    Accel,
}

/// Everything the trainer needs.
pub struct TrainSetup<'a> {
    pub cfg: &'a Config,
    /// Extractor-training utterances.
    pub feats: &'a FeatArchive,
    /// UBM pair; the full model's means move when realignment is on.
    pub diag: DiagGmm,
    pub full: FullGmm,
}

/// Snapshot handed to the per-iteration callback (EER harness).
pub struct IterCtx<'a> {
    pub iter: usize,
    pub model: &'a TvModel,
    pub diag: &'a DiagGmm,
    pub full: &'a FullGmm,
    /// True when this iteration recomputed the frame alignments.
    pub realigned: bool,
}

/// Per-iteration diagnostics.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: usize,
    pub align_s: f64,
    pub estep_s: f64,
    pub mstep_s: f64,
    pub wall_s: f64,
    /// Mean squared change in T (convergence signal).
    pub t_delta: f64,
    /// Pipeline consumer utilization (accel path only).
    pub device_util: Option<f64>,
    /// EER from the callback, when it chose to evaluate.
    pub eer_pct: Option<f64>,
}

/// Train a total-variability model. `per_iter` runs after every
/// iteration and may return an EER to record (pass `|_| None` to skip).
pub fn train_tvm(
    setup: &mut TrainSetup,
    variant: TrainVariant,
    iters: usize,
    seed: u64,
    path: ComputePath,
    accel: Option<&mut AccelTvm>,
    per_iter: &mut dyn FnMut(IterCtx) -> Option<f64>,
) -> Result<(TvModel, Vec<IterStats>)> {
    train_tvm_with_stats(setup, variant, iters, seed, path, accel, None, per_iter)
}

/// [`train_tvm`] with optionally pre-computed initial alignment
/// statistics (valid only while the UBM is unchanged — ensemble runs
/// over the same UBM share one alignment round this way).
#[allow(clippy::too_many_arguments)]
pub fn train_tvm_with_stats(
    setup: &mut TrainSetup,
    variant: TrainVariant,
    iters: usize,
    seed: u64,
    path: ComputePath,
    accel: Option<&mut AccelTvm>,
    initial_stats: Option<(Vec<BwStats>, GlobalRawStats)>,
    per_iter: &mut dyn FnMut(IterCtx) -> Option<f64>,
) -> Result<(TvModel, Vec<IterStats>)> {
    let cfg = setup.cfg;
    let c_n = cfg.ubm.components;
    let workers = default_workers();
    let mut accel = accel;
    if path == ComputePath::Accel {
        anyhow::ensure!(accel.is_some(), "accel path requires an AccelTvm");
    }

    let mut model = TvModel::init(
        variant.formulation,
        &setup.full,
        cfg.tvm.rank,
        cfg.tvm.prior_offset,
        seed,
    );

    // step 1 (initial): alignment + statistics (or the shared cache)
    let sw = Stopwatch::start();
    let (mut per_utt, mut global) = match initial_stats {
        Some(stats) => stats,
        None => run_alignment(setup, path, accel.as_deref(), workers)?,
    };
    let mut align_s = sw.elapsed_s();

    let mut history = Vec::with_capacity(iters);
    let mut last_h_bar: Option<Vec<f64>> = None;

    for iter in 0..iters {
        let iter_sw = Stopwatch::start();
        let mut realigned = false;

        // step 5 of the *previous* iteration: realignment
        if let Some(every) = variant.realign_every {
            if iter > 0 && iter % every == 0 {
                let sw = Stopwatch::start();
                apply_realignment(setup, &mut model, last_h_bar.as_deref())?;
                let (pu, gl) = run_alignment(setup, path, accel.as_deref(), workers)?;
                per_utt = pu;
                global = gl;
                align_s = sw.elapsed_s();
                realigned = true;
            } else if iter > 0 {
                align_s = 0.0;
            }
        } else if iter > 0 {
            align_s = 0.0;
        }

        // step 2: E-step
        let sw = Stopwatch::start();
        let (acc, device_util) = match path {
            ComputePath::CpuRef => {
                (estep_cpu(&model, &per_utt, workers, cfg.tvm.batch_utts), None)
            }
            ComputePath::Accel => {
                let a = accel.as_deref_mut().expect("checked above");
                let (acc, util) = estep_accel(&model, &per_utt, a, cfg.tvm.batch_utts, workers)?;
                (acc, Some(util))
            }
        };
        let estep_s = sw.elapsed_s();
        last_h_bar = Some(acc.h.iter().map(|&x| x / acc.count.max(1.0)).collect());

        // step 3: M-step (+ optional Σ update)
        let sw = Stopwatch::start();
        let second = variant.sigma_update.then(|| GlobalSecondOrder {
            s: match variant.formulation {
                Formulation::Standard => global.centered_second_order(&model.means),
                Formulation::Augmented => global.s.clone(),
            },
            n: global.n.clone(),
        });
        let t_delta = mstep(&mut model, &acc, second.as_ref(), cfg.ubm.var_floor);

        // step 4: minimum divergence
        if variant.min_divergence {
            min_divergence(&mut model, &acc);
        }
        let mstep_s = sw.elapsed_s();

        let eer = per_iter(IterCtx {
            iter,
            model: &model,
            diag: &setup.diag,
            full: &setup.full,
            realigned,
        });

        history.push(IterStats {
            iter,
            align_s: if iter == 0 || realigned { align_s } else { 0.0 },
            estep_s,
            mstep_s,
            wall_s: iter_sw.elapsed_s(),
            t_delta,
            device_util,
            eer_pct: eer,
        });
        let _ = c_n;
    }

    Ok((model, history))
}

/// Alignment + statistics with the current UBM pair.
pub fn run_alignment(
    setup: &TrainSetup,
    path: ComputePath,
    accel: Option<&AccelTvm>,
    workers: usize,
) -> Result<(Vec<BwStats>, GlobalRawStats)> {
    let cfg = setup.cfg;
    let posts: ArchivePosts = match path {
        // scoring precision comes from `[align] precision`; the
        // Baum-Welch statistics accumulated below are f64 either way
        ComputePath::CpuRef => align_archive_cpu_prec(
            &setup.diag,
            &setup.full,
            setup.feats,
            cfg.tvm.top_k,
            cfg.tvm.min_post,
            workers,
            cfg.align.precision,
        ),
        ComputePath::Accel => {
            align_archive_accel(accel.expect("accel set"), &setup.diag, &setup.full, setup.feats)?
        }
    };
    Ok(stats_from_posts(setup.feats, &posts, cfg.ubm.components, workers))
}

/// Push the model's bias means into the UBM (paper §3.2 / §5).
fn apply_realignment(
    setup: &mut TrainSetup,
    model: &mut TvModel,
    last_h_bar: Option<&[f64]>,
) -> Result<()> {
    if model.formulation == Formulation::Standard {
        // §5: m_c ← m_c + T_c h̄ (works "less well with Σ updates", as
        // the paper notes — kept for completeness)
        if let Some(h) = last_h_bar {
            let c_n = model.num_components();
            let mut means = model.means.clone();
            for c in 0..c_n {
                let shift = model.t[c].matvec(h);
                for (j, s) in shift.iter().enumerate() {
                    *means.get_mut(c, j) += s;
                }
            }
            model.means = means;
        }
    }
    let new_means = model.bias_means();
    setup.diag.means = new_means.clone();
    setup.full.set_means(new_means)?;
    // keep the standard model's centering means in sync with the UBM
    if model.formulation == Formulation::Standard {
        model.means = setup.full.means.clone();
    }
    Ok(())
}

/// Batched CPU E-step: parallel chunks, each worker streaming
/// `batch_utts`-sized batches through [`estep_batch_cpu`] with a
/// reusable workspace — structurally identical to the accel path's
/// batch loop, merged accumulators at the end.
fn estep_cpu(
    model: &TvModel,
    per_utt: &[BwStats],
    workers: usize,
    batch_utts: usize,
) -> EstepAccum {
    let consts = model.precompute_consts();
    let (c_n, f_dim, r) = (model.num_components(), model.feat_dim(), model.rank());
    let chunk = per_utt.len().div_ceil(workers.max(1)).max(1);
    let n_chunks = per_utt.len().div_ceil(chunk);
    let bu = batch_utts.max(1);
    let partials = map_parallel(n_chunks, workers, |k| {
        let mut acc = EstepAccum::zeros(c_n, f_dim, r);
        let mut ws = EstepWorkspace::new(r, bu);
        let slice = &per_utt[k * chunk..((k + 1) * chunk).min(per_utt.len())];
        for batch in slice.chunks(bu) {
            // formulation adaptation (centering) per batch, like the
            // accel path's loader stage
            let stats: Vec<UttStats> =
                batch.iter().map(|bw| UttStats::from_bw(bw, model)).collect();
            let refs: Vec<&UttStats> = stats.iter().collect();
            estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));
        }
        acc
    });
    let mut total = EstepAccum::zeros(c_n, f_dim, r);
    for p in &partials {
        total.merge(p);
    }
    total
}

/// Accelerated E-step: CPU loader threads adapt/pack batches, the
/// device drains them (paper Fig. 1). Returns (accum, device util).
fn estep_accel(
    model: &TvModel,
    per_utt: &[BwStats],
    accel: &mut AccelTvm,
    batch_utts: usize,
    workers: usize,
) -> Result<(EstepAccum, f64)> {
    accel.set_model(model)?;
    let bu = batch_utts.min(accel.dims.bu);
    let n_batches = per_utt.len().div_ceil(bu);
    let (c_n, f_dim, r) = (model.num_components(), model.feat_dim(), model.rank());

    let mut total = EstepAccum::zeros(c_n, f_dim, r);
    let mut err: Option<anyhow::Error> = None;
    let accel_ref = &*accel;
    let (stats, wall) = pipeline(
        n_batches,
        workers,
        workers * 2,
        |k| {
            // loader: formulation adaptation (centering) on CPU
            per_utt[k * bu..((k + 1) * bu).min(per_utt.len())]
                .iter()
                .map(|bw| UttStats::from_bw(bw, model))
                .collect::<Vec<_>>()
        },
        |_k, batch| {
            if err.is_some() {
                return;
            }
            let refs: Vec<&UttStats> = batch.iter().collect();
            match accel_ref.estep_batch(&refs) {
                Ok((acc, _phi)) => total.merge(&acc),
                Err(e) => err = Some(e),
            }
        },
    );
    if let Some(e) = err {
        return Err(e);
    }
    Ok((total, stats.consumer_utilization(wall)))
}

#[cfg(test)]
mod tests {
    use super::super::align::tests::tiny_setup;
    use super::*;
    use crate::config::Config;

    fn tiny_config() -> Config {
        let mut cfg = Config::default_scaled();
        cfg.ubm.components = 8;
        cfg.tvm.rank = 6;
        cfg.tvm.top_k = 5;
        cfg.tvm.batch_utts = 4;
        cfg
    }

    #[test]
    fn cpu_training_runs_and_converges() {
        let cfg = tiny_config();
        let (arch, ubm) = tiny_setup();
        let mut setup = TrainSetup { cfg: &cfg, feats: &arch, diag: ubm.diag, full: ubm.full };
        let variant = TrainVariant {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: true,
            realign_every: None,
        };
        let (model, hist) = train_tvm(
            &mut setup,
            variant,
            5,
            42,
            ComputePath::CpuRef,
            None,
            &mut |_| None,
        )
        .unwrap();
        assert_eq!(hist.len(), 5);
        // T change shrinks as EM converges
        assert!(
            hist.last().unwrap().t_delta < hist[0].t_delta,
            "{:?}",
            hist.iter().map(|h| h.t_delta).collect::<Vec<_>>()
        );
        assert_eq!(model.rank(), 6);
        // prior offset survives min-div with the right structure
        assert!(model.prior_mean[0] > 0.0);
        assert!(model.prior_mean[1..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cpu_training_runs_with_f32_alignment() {
        // end-to-end precision selection: the trainer's alignment pass
        // honours `[align] precision = "f32"` and EM still converges
        let mut cfg = tiny_config();
        cfg.align.precision = crate::gmm::AlignPrecision::F32;
        let (arch, ubm) = tiny_setup();
        let mut setup = TrainSetup { cfg: &cfg, feats: &arch, diag: ubm.diag, full: ubm.full };
        let variant = TrainVariant {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: false,
            realign_every: None,
        };
        let (model, hist) =
            train_tvm(&mut setup, variant, 4, 42, ComputePath::CpuRef, None, &mut |_| None)
                .unwrap();
        assert_eq!(hist.len(), 4);
        assert!(hist.iter().all(|h| h.t_delta.is_finite()));
        assert!(
            hist.last().unwrap().t_delta < hist[0].t_delta,
            "{:?}",
            hist.iter().map(|h| h.t_delta).collect::<Vec<_>>()
        );
        assert!(model.t[0].as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn realignment_updates_ubm_means() {
        let cfg = tiny_config();
        let (arch, ubm) = tiny_setup();
        let before = ubm.full.means.clone();
        let mut setup = TrainSetup { cfg: &cfg, feats: &arch, diag: ubm.diag, full: ubm.full };
        let variant = TrainVariant {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: false,
            realign_every: Some(2),
        };
        let mut realign_iters = Vec::new();
        train_tvm(&mut setup, variant, 5, 7, ComputePath::CpuRef, None, &mut |ctx| {
            if ctx.realigned {
                realign_iters.push(ctx.iter);
            }
            None
        })
        .unwrap();
        assert_eq!(realign_iters, vec![2, 4]);
        assert!(
            !setup.full.means.approx_eq(&before, 1e-9),
            "realignment must move the UBM means"
        );
    }

    #[test]
    fn callback_receives_every_iteration() {
        let cfg = tiny_config();
        let (arch, ubm) = tiny_setup();
        let mut setup = TrainSetup { cfg: &cfg, feats: &arch, diag: ubm.diag, full: ubm.full };
        let variant = TrainVariant {
            formulation: Formulation::Standard,
            min_divergence: false,
            sigma_update: false,
            realign_every: None,
        };
        let mut seen = Vec::new();
        let (_, hist) = train_tvm(&mut setup, variant, 3, 1, ComputePath::CpuRef, None, &mut |ctx| {
            seen.push(ctx.iter);
            Some(42.0)
        })
        .unwrap();
        assert_eq!(seen, vec![0, 1, 2]);
        assert!(hist.iter().all(|h| h.eer_pct == Some(42.0)));
    }

    #[test]
    fn different_seeds_give_different_models() {
        let cfg = tiny_config();
        let (arch, ubm) = tiny_setup();
        let variant = TrainVariant {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: false,
            realign_every: None,
        };
        let run = |seed| {
            let (arch2, ubm2) = (&arch, (ubm.diag.clone(), ubm.full.clone()));
            let mut setup =
                TrainSetup { cfg: &cfg, feats: arch2, diag: ubm2.0, full: ubm2.1 };
            train_tvm(&mut setup, variant, 2, seed, ComputePath::CpuRef, None, &mut |_| None)
                .unwrap()
                .0
        };
        let m1 = run(1);
        let m2 = run(2);
        assert!(!m1.t[0].approx_eq(&m2.t[0], 1e-6), "seeds must differ");
        // but the same seed reproduces exactly
        let m1b = run(1);
        assert!(m1.t[0].approx_eq(&m1b.t[0], 0.0));
    }
}
