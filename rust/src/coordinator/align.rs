//! Alignment rounds + Baum-Welch statistics over an archive.
//!
//! Two paths compute identical pruned posteriors:
//! * CPU — [`crate::gmm::BatchAligner`] scoring frame blocks as one
//!   matrix product, parallel over utterance chunks (the per-frame
//!   scalar oracle survives as [`align_archive_cpu_scalar`]);
//! * accelerated — frames from *all* utterances are packed densely into
//!   BF-sized device blocks (crossing utterance boundaries, so no
//!   padding waste) and streamed through the `align_topk` graph.

use anyhow::Result;

use crate::exec::map_parallel;
use crate::gmm::{select_posteriors_scalar, AlignPrecision, DiagGmm, FullGmm};
use crate::io::{FeatArchive, Posting};
use crate::ivector::AccelTvm;
use crate::linalg::Mat;
use crate::stats::BwStats;

/// Per-utterance posting lists for a whole archive.
pub type ArchivePosts = Vec<Vec<Vec<Posting>>>;

/// Globally-accumulated raw statistics (for Σ updates and centering).
#[derive(Debug, Clone)]
pub struct GlobalRawStats {
    /// Σ_u n_c(u).
    pub n: Vec<f64>,
    /// Σ_u f_c(u) raw (C × F).
    pub f: Mat,
    /// Σ_u S_c(u) raw, C matrices of F × F.
    pub s: Vec<Mat>,
}

impl GlobalRawStats {
    /// Centered second-order stats around `means` (standard
    /// formulation): `S̃ = S − m f_totᵀ − f_tot mᵀ + n_tot m mᵀ`.
    pub fn centered_second_order(&self, means: &Mat) -> Vec<Mat> {
        let c_n = self.n.len();
        let dim = self.f.cols();
        (0..c_n)
            .map(|c| {
                let m = means.row(c);
                let ft = self.f.row(c);
                let nc = self.n[c];
                let mut sc = self.s[c].clone();
                for i in 0..dim {
                    for j in 0..dim {
                        let v = sc.get(i, j) - m[i] * ft[j] - ft[i] * m[j] + nc * m[i] * m[j];
                        sc.set(i, j, v);
                    }
                }
                sc
            })
            .collect()
    }
}

/// CPU alignment of a whole archive through the batched GEMM-shaped
/// f64 aligner (see [`align_archive_cpu_prec`] for precision
/// selection), parallel over utterance chunks.
pub fn align_archive_cpu(
    diag: &DiagGmm,
    full: &FullGmm,
    archive: &FeatArchive,
    top_k: usize,
    min_post: f64,
    workers: usize,
) -> ArchivePosts {
    align_archive_cpu_prec(diag, full, archive, top_k, min_post, workers, AlignPrecision::F64)
}

/// CPU alignment of a whole archive at an explicit scoring precision
/// (`[align] precision`), parallel over utterance chunks: each worker
/// packs the UBM weights and allocates its scratch once per chunk, not
/// per utterance. The f32 path scores and selects single-precision;
/// rescoring and posteriors stay f64 (see [`crate::gmm::batch`]).
#[allow(clippy::too_many_arguments)]
pub fn align_archive_cpu_prec(
    diag: &DiagGmm,
    full: &FullGmm,
    archive: &FeatArchive,
    top_k: usize,
    min_post: f64,
    workers: usize,
    precision: AlignPrecision,
) -> ArchivePosts {
    let n = archive.utts.len();
    let chunk = n.div_ceil(workers.max(1)).max(1);
    let n_chunks = n.div_ceil(chunk);
    let chunks = map_parallel(n_chunks, workers, |k| {
        let mut aligner =
            crate::gmm::BatchAligner::with_precision(diag, full, top_k, min_post, precision);
        archive.utts[k * chunk..((k + 1) * chunk).min(n)]
            .iter()
            .map(|u| aligner.align_utterance(&u.feats))
            .collect::<Vec<_>>()
    });
    chunks.into_iter().flatten().collect()
}

/// The per-frame scalar CPU path — the equivalence oracle and bench
/// baseline for [`align_archive_cpu`].
pub fn align_archive_cpu_scalar(
    diag: &DiagGmm,
    full: &FullGmm,
    archive: &FeatArchive,
    top_k: usize,
    min_post: f64,
    workers: usize,
) -> ArchivePosts {
    map_parallel(archive.utts.len(), workers, |i| {
        select_posteriors_scalar(diag, full, &archive.utts[i].feats, top_k, min_post)
    })
}

/// Accelerated alignment: dense frame packing across utterances.
pub fn align_archive_accel(
    accel: &AccelTvm,
    diag: &DiagGmm,
    full: &FullGmm,
    archive: &FeatArchive,
) -> Result<ArchivePosts> {
    let dims = accel.dims;
    let aligner = crate::ivector::accel::AccelAligner::new(accel.runtime(), dims, diag, full)?;
    let f_dim = archive.dim();
    let total: usize = archive.total_frames();

    // pack every frame of every utterance into BF-sized blocks
    let mut out: ArchivePosts = archive.utts.iter().map(|u| Vec::with_capacity(u.feats.rows())).collect();
    let mut block = Mat::zeros(dims.bf, f_dim);
    let mut owners: Vec<usize> = Vec::with_capacity(dims.bf); // utt index per row
    let mut filled = 0usize;
    let flush = |block: &Mat, owners: &[usize], filled: usize, out: &mut ArchivePosts| -> Result<()> {
        if filled == 0 {
            return Ok(());
        }
        let posts = aligner.align_block(block, filled)?;
        for (row, frame_posts) in posts.into_iter().enumerate() {
            out[owners[row]].push(frame_posts);
        }
        Ok(())
    };

    for (ui, u) in archive.utts.iter().enumerate() {
        for t in 0..u.feats.rows() {
            block.row_mut(filled).copy_from_slice(u.feats.row(t));
            owners.push(ui);
            filled += 1;
            if filled == dims.bf {
                flush(&block, &owners, filled, &mut out)?;
                filled = 0;
                owners.clear();
            }
        }
    }
    flush(&block, &owners, filled, &mut out)?;
    debug_assert_eq!(out.iter().map(|u| u.len()).sum::<usize>(), total);
    Ok(out)
}

/// Raw per-utterance first-order stats + global accumulators from
/// aligned posteriors (parallel over utterances). This is the CPU side
/// of the paper's pipeline ("Baum-Welch statistics … computed in CPU").
pub fn stats_from_posts(
    archive: &FeatArchive,
    posts: &ArchivePosts,
    n_components: usize,
    workers: usize,
) -> (Vec<BwStats>, GlobalRawStats) {
    let per_utt: Vec<BwStats> = map_parallel(archive.utts.len(), workers, |i| {
        BwStats::accumulate(&archive.utts[i].feats, &posts[i], n_components, true)
    });
    let dim = archive.dim();
    let mut global = GlobalRawStats {
        n: vec![0.0; n_components],
        f: Mat::zeros(n_components, dim),
        s: vec![Mat::zeros(dim, dim); n_components],
    };
    let mut light = Vec::with_capacity(per_utt.len());
    for st in per_utt {
        for (a, &b) in global.n.iter_mut().zip(&st.n) {
            *a += b;
        }
        global.f.add_scaled(1.0, &st.f);
        if let Some(s) = &st.s {
            for (g, u) in global.s.iter_mut().zip(s) {
                g.add_scaled(1.0, u);
            }
        }
        // keep only the first-order stats per utterance (second-order
        // lives in the global accumulator — Kaldi does the same)
        light.push(BwStats { n: st.n, f: st.f, s: None });
    }
    (light, global)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::frontend::synth::generate_corpus;
    use crate::gmm::{train_ubm, UbmPair};

    pub(crate) fn tiny_setup() -> (FeatArchive, UbmPair) {
        let cfg = CorpusConfig {
            n_train_speakers: 5,
            utts_per_train_speaker: 3,
            n_eval_speakers: 2,
            utts_per_eval_speaker: 2,
            min_frames: 50,
            max_frames: 80,
            base_dim: 4,
            true_components: 6,
            speaker_rank: 4,
            speaker_scale: 0.4,
            channel_rank: 2,
            channel_scale: 0.15,
            stay_prob: 0.85,
            silence_frac: 0.1,
            seed: 99,
        };
        let corpus = generate_corpus(&cfg).unwrap();
        let ubm_cfg = crate::config::UbmConfig {
            components: 8,
            diag_em_iters: 3,
            full_em_iters: 2,
            train_frames: 3000,
            var_floor: 1e-3,
        };
        let (pair, _) = train_ubm(&corpus.train, &ubm_cfg, 1).unwrap();
        (corpus.train, pair)
    }

    #[test]
    fn batched_archive_alignment_matches_scalar() {
        let (arch, ubm) = tiny_setup();
        let batched = align_archive_cpu(&ubm.diag, &ubm.full, &arch, 5, 0.025, 4);
        let scalar = align_archive_cpu_scalar(&ubm.diag, &ubm.full, &arch, 5, 0.025, 4);
        assert_eq!(batched.len(), scalar.len());
        for (ub, us) in batched.iter().zip(&scalar) {
            assert_eq!(ub.len(), us.len());
            for (fb, fs) in ub.iter().zip(us) {
                assert_eq!(fb.len(), fs.len(), "posting counts differ");
                for (pb, ps) in fb.iter().zip(fs) {
                    assert_eq!(pb.idx, ps.idx);
                    assert!((pb.post - ps.post).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn f32_archive_alignment_matches_f64_within_tolerance() {
        // trainer-path acceptance: the f32 archive pass produces the
        // same statistics space as f64 — identical posting structure up
        // to boundary ties, values within f32 tolerance
        let (arch, ubm) = tiny_setup();
        let f64_posts = align_archive_cpu_prec(
            &ubm.diag,
            &ubm.full,
            &arch,
            5,
            0.025,
            4,
            AlignPrecision::F64,
        );
        let f32_posts = align_archive_cpu_prec(
            &ubm.diag,
            &ubm.full,
            &arch,
            5,
            0.025,
            4,
            AlignPrecision::F32,
        );
        assert_eq!(f64_posts.len(), f32_posts.len());
        let mut mismatched_frames = 0usize;
        let mut total_frames = 0usize;
        for (ua, ub) in f64_posts.iter().zip(&f32_posts) {
            assert_eq!(ua.len(), ub.len());
            for (fa, fb) in ua.iter().zip(ub) {
                total_frames += 1;
                let same_sel = fa.len() == fb.len()
                    && fa.iter().zip(fb).all(|(p, q)| p.idx == q.idx);
                if !same_sel {
                    // a boundary tie swapped the selected set — rare
                    mismatched_frames += 1;
                    continue;
                }
                for (p, q) in fa.iter().zip(fb) {
                    assert!((p.post - q.post).abs() <= 1e-4, "{} vs {}", p.post, q.post);
                }
            }
        }
        assert!(
            mismatched_frames * 100 <= total_frames,
            "boundary swaps must be rare: {mismatched_frames}/{total_frames}"
        );
    }

    #[test]
    fn cpu_alignment_covers_all_frames() {
        let (arch, ubm) = tiny_setup();
        let posts = align_archive_cpu(&ubm.diag, &ubm.full, &arch, 5, 0.025, 4);
        assert_eq!(posts.len(), arch.utts.len());
        for (u, p) in arch.utts.iter().zip(&posts) {
            assert_eq!(p.len(), u.feats.rows());
            for frame in p {
                assert!(!frame.is_empty());
                let total: f32 = frame.iter().map(|x| x.post).sum();
                assert!((total - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn stats_totals_match_frame_count() {
        let (arch, ubm) = tiny_setup();
        let posts = align_archive_cpu(&ubm.diag, &ubm.full, &arch, 5, 0.025, 4);
        let (per_utt, global) = stats_from_posts(&arch, &posts, 8, 4);
        assert_eq!(per_utt.len(), arch.utts.len());
        let total_frames: f64 = arch.utts.iter().map(|u| u.feats.rows() as f64).sum();
        let total_n: f64 = global.n.iter().sum();
        assert!((total_n - total_frames).abs() < 1e-6 * total_frames);
        // per-utt stats sum to global
        let mut n_sum = 0.0;
        for st in &per_utt {
            n_sum += st.total_count();
            assert!(st.s.is_none(), "per-utt second order must be dropped");
        }
        assert!((n_sum - total_n).abs() < 1e-6 * total_n);
    }

    #[test]
    fn centered_second_order_is_psd_like() {
        let (arch, ubm) = tiny_setup();
        let posts = align_archive_cpu(&ubm.diag, &ubm.full, &arch, 5, 0.025, 4);
        let (_per_utt, global) = stats_from_posts(&arch, &posts, 8, 4);
        let centered = global.centered_second_order(&ubm.full.means);
        for (c, sc) in centered.iter().enumerate() {
            if global.n[c] < 1.0 {
                continue;
            }
            // diagonal of a centered scatter must be non-negative
            for i in 0..sc.rows() {
                assert!(sc.get(i, i) > -1e-6, "S̃[{c}][{i}][{i}] = {}", sc.get(i, i));
            }
        }
    }
}
