//! CLI stage implementations: each command reads/writes the work dir
//! so stages compose like a Kaldi recipe (`synth → train-ubm → align →
//! train → extract → backend → eval`), and `pipeline` chains them
//! in-process.

use anyhow::{Context, Result};

use crate::backend::{Backend, BackendOpts};
use crate::cli::Args;
use crate::config::Config;
use crate::exec::default_workers;
use crate::frontend::synth::generate_corpus;
use crate::gmm::{DiagGmm, FullGmm};
use crate::io::{load, save, FeatArchive, PostArchive, Serialize, UttPosts};
use crate::ivector::{
    extract_cpu, AccelTvm, Formulation, TrainVariant, TvModel, UttStats,
};
use crate::linalg::Mat;
use crate::metrics::{rt_factor, Stopwatch};
use crate::trials::{det_metrics, generate_trials};

use super::align::{align_archive_accel, align_archive_cpu_prec, stats_from_posts};
use super::trainer::{train_tvm, ComputePath, TrainSetup};

fn load_config(args: &Args) -> Result<Config> {
    match args.get("config") {
        Some(path) => Config::load(&path),
        None => Ok(Config::default_scaled()),
    }
}

fn work_dir(args: &Args) -> String {
    args.get_or("work", "./work")
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "./artifacts")
}

/// `synth`: generate the corpus archives.
pub fn synth(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    args.finish()?;
    let sw = Stopwatch::start();
    let corpus = generate_corpus(&cfg.corpus)?;
    corpus.train.save(format!("{work}/train.feats"))?;
    corpus.eval.save(format!("{work}/eval.feats"))?;
    println!(
        "synth: {} train utts ({} frames), {} eval utts ({} frames) in {:.1}s -> {work}/",
        corpus.train.utts.len(),
        corpus.train.total_frames(),
        corpus.eval.utts.len(),
        corpus.eval.total_frames(),
        sw.elapsed_s()
    );
    Ok(())
}

/// `train-ubm`: diagonal + full UBM.
pub fn train_ubm_stage(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    args.finish()?;
    let train = FeatArchive::load(format!("{work}/train.feats"))
        .context("run `ivector-tv synth` first")?;
    let sw = Stopwatch::start();
    let (pair, lls) = crate::gmm::train_ubm(&train, &cfg.ubm, cfg.corpus.seed)?;
    save(&pair.diag, format!("{work}/ubm.diag"))?;
    save(&pair.full, format!("{work}/ubm.full"))?;
    println!(
        "train-ubm: C={} in {:.1}s (diag EM ll: {:.3} -> {:.3})",
        cfg.ubm.components,
        sw.elapsed_s(),
        lls.first().unwrap_or(&f64::NAN),
        lls.last().unwrap_or(&f64::NAN)
    );
    Ok(())
}

/// `align`: frame posteriors for the train archive.
pub fn align(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    let arts = artifacts_dir(args);
    let cpu_ref = args.switch("cpu-ref");
    args.finish()?;
    let train = FeatArchive::load(format!("{work}/train.feats"))?;
    let diag: DiagGmm = load(format!("{work}/ubm.diag"))?;
    let full: FullGmm = load(format!("{work}/ubm.full"))?;

    let sw = Stopwatch::start();
    let posts = if cpu_ref {
        align_archive_cpu_prec(
            &diag,
            &full,
            &train,
            cfg.tvm.top_k,
            cfg.tvm.min_post,
            default_workers(),
            cfg.align.precision,
        )
    } else {
        let accel = AccelTvm::new(&arts)?.with_alignment()?;
        align_archive_accel(&accel, &diag, &full, &train)?
    };
    let wall = sw.elapsed_s();
    let frames = train.total_frames();
    let archive = PostArchive {
        utts: train
            .utts
            .iter()
            .zip(posts)
            .map(|(u, frames)| UttPosts { utt_id: u.utt_id.clone(), frames })
            .collect(),
    };
    let avg: f64 = archive.utts.iter().map(|u| u.avg_postings()).sum::<f64>()
        / archive.utts.len().max(1) as f64;
    archive.save(format!("{work}/train.posts"))?;
    println!(
        "align[{}]: {frames} frames in {wall:.2}s = {:.0}x real time, {:.2} postings/frame",
        if cpu_ref { format!("cpu-ref/{}", cfg.align.precision) } else { "accel".into() },
        rt_factor(frames, wall),
        avg
    );
    Ok(())
}

fn variant_from_args(args: &Args) -> Result<TrainVariant> {
    let formulation = match args.get_or("variant", "aug").as_str() {
        "std" | "standard" => Formulation::Standard,
        "aug" | "augmented" => Formulation::Augmented,
        other => anyhow::bail!("--variant must be std|aug, got `{other}`"),
    };
    let realign = args.get_parse_or("realign-every", 0usize)?;
    Ok(TrainVariant {
        formulation,
        min_divergence: formulation == Formulation::Augmented || args.switch("mindiv"),
        sigma_update: args.switch("sigma"),
        realign_every: (realign > 0).then_some(realign),
    })
}

/// `train`: train the i-vector extractor.
pub fn train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    let arts = artifacts_dir(args);
    let variant = variant_from_args(args)?;
    let iters = args.get_parse_or("iters", cfg.tvm.iters)?;
    let seed = args.get_parse_or("seed", 42u64)?;
    let cpu_ref = args.switch("cpu-ref");
    args.finish()?;

    let train_arch = FeatArchive::load(format!("{work}/train.feats"))?;
    let diag: DiagGmm = load(format!("{work}/ubm.diag"))?;
    let full: FullGmm = load(format!("{work}/ubm.full"))?;
    let mut setup = TrainSetup { cfg: &cfg, feats: &train_arch, diag, full };

    let sw = Stopwatch::start();
    let (path, mut accel) = if cpu_ref {
        (ComputePath::CpuRef, None)
    } else {
        (ComputePath::Accel, Some(AccelTvm::new(&arts)?.with_alignment()?))
    };
    let (model, hist) = train_tvm(
        &mut setup,
        variant,
        iters,
        seed,
        path,
        accel.as_mut(),
        &mut |_| None,
    )?;
    save(&model, format!("{work}/tvm.bin"))?;
    // persist the (possibly realigned) UBM alongside the model — the
    // paper uses the *updated* UBM at test time
    save(&setup.diag, format!("{work}/ubm_final.diag"))?;
    save(&setup.full, format!("{work}/ubm_final.full"))?;
    let estep_total: f64 = hist.iter().map(|h| h.estep_s).sum();
    println!(
        "train[{}|{}]: variant={} iters={iters} seed={seed} in {:.1}s (estep {:.1}s, final tΔ {:.2e})",
        if cpu_ref { "cpu-ref" } else { "accel" },
        variant.id(),
        variant.id(),
        sw.elapsed_s(),
        estep_total,
        hist.last().map(|h| h.t_delta).unwrap_or(f64::NAN),
    );
    Ok(())
}

/// i-vector file: ids + speaker labels + row matrix.
pub struct IvecSet {
    pub utt_ids: Vec<String>,
    pub spk_ids: Vec<String>,
    pub vectors: Mat,
}

impl Serialize for IvecSet {
    fn write(&self, w: &mut crate::io::BinWriter) -> Result<()> {
        w.write_u64(self.utt_ids.len() as u64)?;
        for (u, s) in self.utt_ids.iter().zip(&self.spk_ids) {
            w.write_string(u)?;
            w.write_string(s)?;
        }
        self.vectors.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> Result<Self> {
        let n = r.read_u64()? as usize;
        let mut utt_ids = Vec::with_capacity(n);
        let mut spk_ids = Vec::with_capacity(n);
        for _ in 0..n {
            utt_ids.push(r.read_string()?);
            spk_ids.push(r.read_string()?);
        }
        Ok(Self { utt_ids, spk_ids, vectors: Mat::read(r)? })
    }
}

fn extract_set(
    cfg: &Config,
    model: &TvModel,
    diag: &DiagGmm,
    full: &FullGmm,
    arch: &FeatArchive,
) -> IvecSet {
    let workers = default_workers();
    let posts = align_archive_cpu_prec(
        diag,
        full,
        arch,
        cfg.tvm.top_k,
        cfg.tvm.min_post,
        workers,
        cfg.align.precision,
    );
    let (bw, _) = stats_from_posts(arch, &posts, cfg.ubm.components, workers);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, model)).collect();
    IvecSet {
        utt_ids: arch.utts.iter().map(|u| u.utt_id.clone()).collect(),
        spk_ids: arch.utts.iter().map(|u| u.spk_id.clone()).collect(),
        vectors: extract_cpu(model, &utts, workers),
    }
}

/// `extract`: i-vectors for train (backend) and eval sets.
pub fn extract(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    args.finish()?;
    let model: TvModel = load(format!("{work}/tvm.bin"))?;
    let diag: DiagGmm = load(format!("{work}/ubm_final.diag"))?;
    let full: FullGmm = load(format!("{work}/ubm_final.full"))?;
    let sw = Stopwatch::start();
    for (name, file) in [("train", "train.feats"), ("eval", "eval.feats")] {
        let arch = FeatArchive::load(format!("{work}/{file}"))?;
        let set = extract_set(&cfg, &model, &diag, &full, &arch);
        save(&set, format!("{work}/{name}.ivecs"))?;
        println!(
            "extract: {} {} i-vectors (dim {})",
            set.vectors.rows(),
            name,
            set.vectors.cols()
        );
    }
    println!("extract done in {:.1}s", sw.elapsed_s());
    Ok(())
}

/// `backend`: train LDA + PLDA on the train i-vectors.
pub fn backend(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    let whiten = args.switch("whiten");
    args.finish()?;
    let set: IvecSet = load(format!("{work}/train.ivecs"))?;
    let spk = dense_labels(&set.spk_ids);
    let be = Backend::train(
        &set.vectors,
        &spk,
        &BackendOpts { lda_dim: cfg.backend.lda_dim, plda_iters: cfg.backend.plda_iters, whiten },
    )?;
    save(&be, format!("{work}/backend.bin"))?;
    println!("backend: LDA {}→{}, PLDA {} iters", set.vectors.cols(), cfg.backend.lda_dim, cfg.backend.plda_iters);
    Ok(())
}

/// `eval`: score the trial list, print EER/minDCF.
pub fn eval(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    args.finish()?;
    let set: IvecSet = load(format!("{work}/eval.ivecs"))?;
    let be: Backend = load(format!("{work}/backend.bin"))?;
    let spk = dense_labels(&set.spk_ids);
    let trials = generate_trials(&spk, cfg.trials.n_trials, cfg.trials.seed);
    let proj = be.project(&set.vectors);
    let scores = be.score(&proj, &proj);
    let scored: Vec<(f64, bool)> =
        trials.iter().map(|t| (scores.get(t.enroll, t.test), t.target)).collect();
    let m = det_metrics(&scored);
    println!(
        "eval: {} trials -> EER {:.2}%  minDCF(0.01) {:.3}  minDCF(0.001) {:.3}",
        trials.len(),
        m.eer_pct,
        m.min_dcf_01,
        m.min_dcf_001
    );
    Ok(())
}

/// `bundle`: assemble the serving [`crate::serve::ModelBundle`] from
/// the per-stage artifacts and write `work/bundle.bin` — the single
/// file the serving commands (`verify`, `serve-bench`) hot-load.
pub fn bundle(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let work = work_dir(args);
    args.finish()?;
    let bundle = crate::serve::ModelBundle::from_work_dir(&work, &cfg)?;
    save(&bundle, format!("{work}/bundle.bin"))?;
    println!(
        "bundle: C={} F={} R={} (+LDA/PLDA backend) -> {work}/bundle.bin",
        bundle.tvm.num_components(),
        bundle.tvm.feat_dim(),
        bundle.tvm.rank()
    );
    Ok(())
}

/// `pipeline`: all stages end-to-end in one process (plus the serving
/// bundle, so a finished pipeline is immediately servable).
pub fn pipeline(args: &Args) -> Result<()> {
    synth(args)?;
    train_ubm_stage(args)?;
    align(args)?;
    train(args)?;
    extract(args)?;
    backend(args)?;
    eval(args)?;
    bundle(args)
}

/// Re-export used by `cli::commands`.
pub use train_ubm_stage as train_ubm;

/// Map speaker ids to dense 0-based labels in first-seen order (the
/// layout `Backend::train`/PLDA expect). Shared with the serving bench
/// harness.
pub fn dense_labels(spk_ids: &[String]) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    spk_ids
        .iter()
        .map(|s| {
            let next = map.len();
            *map.entry(s.clone()).or_insert(next)
        })
        .collect()
}

// ------------------------- backend serialization -------------------------

impl Serialize for Backend {
    fn write(&self, w: &mut crate::io::BinWriter) -> Result<()> {
        self.centering.mean.write(w)?;
        match &self.whitening {
            Some(wh) => {
                w.write_u32(1)?;
                wh.p.write(w)?;
            }
            None => w.write_u32(0)?,
        }
        self.lda.w.write(w)?;
        self.plda.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> Result<Self> {
        let mean = Vec::<f64>::read(r)?;
        let whitening = if r.read_u32()? == 1 {
            Some(crate::backend::Whitening { p: Mat::read(r)? })
        } else {
            None
        };
        let lda = crate::backend::Lda { w: Mat::read(r)? };
        let plda = crate::backend::Plda::read(r)?;
        Ok(Self { centering: crate::backend::Centering { mean }, whitening, lda, plda })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_labels_stable() {
        let ids: Vec<String> = ["a", "b", "a", "c"].iter().map(|s| s.to_string()).collect();
        assert_eq!(dense_labels(&ids), vec![0, 1, 0, 2]);
    }

    #[test]
    fn ivecset_roundtrip() {
        let dir = std::env::temp_dir().join("ivtv_stage_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("set.ivecs");
        let set = IvecSet {
            utt_ids: vec!["u0".into(), "u1".into()],
            spk_ids: vec!["s0".into(), "s0".into()],
            vectors: Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]),
        };
        save(&set, &p).unwrap();
        let back: IvecSet = load(&p).unwrap();
        assert_eq!(back.utt_ids, set.utt_ids);
        assert!(back.vectors.approx_eq(&set.vectors, 0.0));
    }
}
