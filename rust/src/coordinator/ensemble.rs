//! Ensemble experiment harness: the paper reports every curve as the
//! average of five random restarts; this module runs (variant × seed)
//! grids and evaluates EER per training iteration.

use anyhow::Result;

use crate::backend::{Backend, BackendOpts};
use crate::config::Config;
use crate::exec::default_workers;
use crate::gmm::{DiagGmm, FullGmm};
use crate::io::FeatArchive;
use crate::ivector::{extract_cpu, AccelTvm, TrainVariant, TvModel, UttStats};
use crate::stats::BwStats;
use crate::trials::{det_metrics, generate_trials, Trial};

use super::align::{align_archive_cpu_prec, stats_from_posts, GlobalRawStats};
use super::trainer::{train_tvm_with_stats, ComputePath, IterCtx, IterStats, TrainSetup};

/// Evaluation harness: extracts i-vectors for the backend-training and
/// eval sets, trains the LDA/PLDA backend, scores the trial list, and
/// returns pooled EER. Alignments are cached and recomputed only when
/// the trainer realigned (the paper's "updated UBM is used in the
/// testing phase").
pub struct EvalHarness<'a> {
    cfg: &'a Config,
    backend_train: &'a FeatArchive,
    eval: &'a FeatArchive,
    trials: Vec<Trial>,
    eval_spk: Vec<usize>,
    backend_spk: Vec<usize>,
    // cached stats (invalidated on realignment)
    cache: Option<(Vec<BwStats>, Vec<BwStats>)>,
}

/// Alignment products shared across ensemble runs over one fixed UBM:
/// trainer-side per-utterance stats + eval-harness stats.
#[derive(Clone)]
pub struct SharedAlignment {
    pub train_stats: (Vec<BwStats>, GlobalRawStats),
    pub harness_stats: (Vec<BwStats>, Vec<BwStats>),
}

impl<'a> EvalHarness<'a> {
    pub fn new(cfg: &'a Config, backend_train: &'a FeatArchive, eval: &'a FeatArchive) -> Self {
        let eval_spk = speaker_indices(eval);
        let backend_spk = speaker_indices(backend_train);
        let trials = generate_trials(&eval_spk, cfg.trials.n_trials, cfg.trials.seed);
        Self { cfg, backend_train, eval, trials, eval_spk, backend_spk, cache: None }
    }

    /// Seed the alignment cache (shared across ensemble runs).
    pub fn set_cache(&mut self, cache: (Vec<BwStats>, Vec<BwStats>)) {
        self.cache = Some(cache);
    }

    /// EER (%) for the current model/UBM state. `whiten` should be set
    /// when the variant skipped min-div (paper §4.1).
    pub fn eer(&mut self, ctx: &IterCtx, whiten: bool) -> Result<f64> {
        let workers = default_workers();
        if ctx.realigned {
            self.cache = None;
        }
        if self.cache.is_none() {
            let stats_of = |arch: &FeatArchive| {
                let posts = align_archive_cpu_prec(
                    ctx.diag,
                    ctx.full,
                    arch,
                    self.cfg.tvm.top_k,
                    self.cfg.tvm.min_post,
                    workers,
                    self.cfg.align.precision,
                );
                stats_from_posts(arch, &posts, self.cfg.ubm.components, workers).0
            };
            self.cache = Some((stats_of(self.backend_train), stats_of(self.eval)));
        }
        let (bt_stats, ev_stats) = self.cache.as_ref().unwrap();

        let to_utt = |bw: &BwStats| UttStats::from_bw(bw, ctx.model);
        let bt_utts: Vec<UttStats> = bt_stats.iter().map(to_utt).collect();
        let ev_utts: Vec<UttStats> = ev_stats.iter().map(to_utt).collect();
        let bt_iv = extract_cpu(ctx.model, &bt_utts, workers);
        let ev_iv = extract_cpu(ctx.model, &ev_utts, workers);

        let backend = Backend::train(
            &bt_iv,
            &self.backend_spk,
            &BackendOpts {
                lda_dim: self.cfg.backend.lda_dim,
                plda_iters: self.cfg.backend.plda_iters,
                whiten,
            },
        )?;
        let proj = backend.project(&ev_iv);
        let scores = backend.score(&proj, &proj);
        let scored: Vec<(f64, bool)> = self
            .trials
            .iter()
            .map(|t| (scores.get(t.enroll, t.test), t.target))
            .collect();
        Ok(det_metrics(&scored).eer_pct)
    }

    /// The trial list (exposed for examples that report counts).
    pub fn trial_count(&self) -> usize {
        self.trials.len()
    }

    /// Eval speaker labels per utterance row.
    pub fn eval_speakers(&self) -> &[usize] {
        &self.eval_spk
    }
}

/// Map utterances to dense speaker indices.
pub fn speaker_indices(arch: &FeatArchive) -> Vec<usize> {
    let mut map = std::collections::HashMap::new();
    arch.utts
        .iter()
        .map(|u| {
            let next = map.len();
            *map.entry(u.spk_id.clone()).or_insert(next)
        })
        .collect()
}

/// One (variant, seed) training run with per-iteration EER tracking.
#[derive(Debug, Clone)]
pub struct RunCurve {
    pub variant_id: String,
    pub seed: u64,
    pub eer_by_iter: Vec<f64>,
    pub iter_stats: Vec<IterStats>,
}

/// Train one variant with one seed, evaluating EER after every
/// iteration. `eval_every` thins the (expensive) EER evaluation.
#[allow(clippy::too_many_arguments)]
pub fn run_curve(
    cfg: &Config,
    train: &FeatArchive,
    eval: &FeatArchive,
    diag: &DiagGmm,
    full: &FullGmm,
    variant: TrainVariant,
    iters: usize,
    seed: u64,
    eval_every: usize,
    path: ComputePath,
    accel: Option<&mut AccelTvm>,
) -> Result<(TvModel, RunCurve)> {
    run_curve_shared(cfg, train, eval, diag, full, variant, iters, seed, eval_every, path, accel, None)
}

/// [`run_curve`] with alignments shared across runs (fig2-style
/// ensembles over one fixed UBM — a large wall-time win on this
/// single-core testbed; see EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
pub fn run_curve_shared(
    cfg: &Config,
    train: &FeatArchive,
    eval: &FeatArchive,
    diag: &DiagGmm,
    full: &FullGmm,
    variant: TrainVariant,
    iters: usize,
    seed: u64,
    eval_every: usize,
    path: ComputePath,
    accel: Option<&mut AccelTvm>,
    shared: Option<&SharedAlignment>,
) -> Result<(TvModel, RunCurve)> {
    let mut setup =
        TrainSetup { cfg, feats: train, diag: diag.clone(), full: full.clone() };
    let mut harness = EvalHarness::new(cfg, train, eval);
    if let Some(sh) = shared {
        harness.set_cache(sh.harness_stats.clone());
    }
    let whiten = !variant.min_divergence;
    let mut eers = Vec::new();
    let (model, hist) = train_tvm_with_stats(
        &mut setup,
        variant,
        iters,
        seed,
        path,
        accel,
        shared.map(|sh| sh.train_stats.clone()),
        &mut |ctx| {
            if (ctx.iter + 1) % eval_every == 0 || ctx.iter + 1 == iters {
                let eer = harness.eer(&ctx, whiten).expect("eval harness");
                eers.push(eer);
                Some(eer)
            } else {
                None
            }
        },
    )?;
    Ok((
        model,
        RunCurve {
            variant_id: variant.id(),
            seed,
            eer_by_iter: eers,
            iter_stats: hist,
        },
    ))
}

/// Average curves across seeds (the paper's five-run ensembles).
pub fn mean_curve(curves: &[RunCurve]) -> Vec<f64> {
    if curves.is_empty() {
        return Vec::new();
    }
    let len = curves.iter().map(|c| c.eer_by_iter.len()).min().unwrap_or(0);
    (0..len)
        .map(|i| curves.iter().map(|c| c.eer_by_iter[i]).sum::<f64>() / curves.len() as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speaker_indices_dense_and_stable() {
        use crate::io::Utterance;
        use crate::linalg::Mat;
        let arch = FeatArchive {
            utts: vec![
                Utterance { utt_id: "a0".into(), spk_id: "a".into(), feats: Mat::zeros(1, 2) },
                Utterance { utt_id: "b0".into(), spk_id: "b".into(), feats: Mat::zeros(1, 2) },
                Utterance { utt_id: "a1".into(), spk_id: "a".into(), feats: Mat::zeros(1, 2) },
            ],
        };
        assert_eq!(speaker_indices(&arch), vec![0, 1, 0]);
    }

    #[test]
    fn mean_curve_averages() {
        let mk = |eers: Vec<f64>| RunCurve {
            variant_id: "x".into(),
            seed: 0,
            eer_by_iter: eers,
            iter_stats: vec![],
        };
        let m = mean_curve(&[mk(vec![4.0, 2.0]), mk(vec![6.0, 4.0, 9.0])]);
        assert_eq!(m, vec![5.0, 3.0]); // truncates to shortest
        assert!(mean_curve(&[]).is_empty());
    }
}
