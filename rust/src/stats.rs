//! Baum-Welch sufficient statistics (paper §2, notation n_c, f_c, S_c).
//!
//! Computed on CPU worker threads — the paper does the same ("The
//! Baum-Welch statistics used in i-vector extractor training are
//! computed in CPU"): statistics give a fixed-size representation per
//! utterance, which is what the device E-step batches over.
//!
//! The two formulations differ in centering: the standard formulation
//! centers first/second-order stats around the UBM means; the augmented
//! (Kaldi) formulation consumes them raw (paper §2, "centered … for the
//! standard formulation and *not* centered for the augmented").

use crate::io::Posting;
use crate::linalg::Mat;

/// Per-utterance Baum-Welch statistics over C components, dim F.
#[derive(Debug, Clone)]
pub struct BwStats {
    /// Occupancies n_c, length C.
    pub n: Vec<f64>,
    /// First-order stats f_c, C × F.
    pub f: Mat,
    /// Second-order stats S_c (only accumulated when requested — the
    /// Σ-update needs them, extraction does not). `S[c]` is F × F.
    pub s: Option<Vec<Mat>>,
}

impl BwStats {
    /// Accumulate stats for one utterance from its frames (T × F) and
    /// pruned posteriors (`posts[t]` lists surviving components).
    pub fn accumulate(
        feats: &Mat,
        posts: &[Vec<Posting>],
        n_components: usize,
        second_order: bool,
    ) -> Self {
        assert_eq!(feats.rows(), posts.len(), "frames/posteriors mismatch");
        let dim = feats.cols();
        let mut n = vec![0.0; n_components];
        let mut f = Mat::zeros(n_components, dim);
        let mut s = if second_order {
            Some(vec![Mat::zeros(dim, dim); n_components])
        } else {
            None
        };
        for (t, frame_posts) in posts.iter().enumerate() {
            let x = feats.row(t);
            for p in frame_posts {
                let c = p.idx as usize;
                debug_assert!(c < n_components);
                let gamma = p.post as f64;
                n[c] += gamma;
                let f_row = f.row_mut(c);
                for (j, &xj) in x.iter().enumerate() {
                    f_row[j] += gamma * xj;
                }
                if let Some(s) = &mut s {
                    let sc = &mut s[c];
                    for i in 0..dim {
                        let gx = gamma * x[i];
                        if gx == 0.0 {
                            continue;
                        }
                        let row = sc.row_mut(i);
                        for (j, &xj) in x.iter().enumerate().skip(i) {
                            row[j] += gx * xj;
                        }
                    }
                }
            }
        }
        // mirror the upper triangles
        if let Some(s) = &mut s {
            for sc in s.iter_mut() {
                for i in 0..dim {
                    for j in 0..i {
                        let v = sc.get(j, i);
                        sc.set(i, j, v);
                    }
                }
            }
        }
        Self { n, f, s }
    }

    /// Center around per-component means (standard formulation):
    /// `f̃_c = f_c − n_c m_c`, `S̃_c = S_c − m_c f_cᵀ − f_c m_cᵀ + n_c m_c m_cᵀ`.
    pub fn center(&self, means: &Mat) -> Self {
        let (c_n, dim) = (self.n.len(), self.f.cols());
        assert_eq!((means.rows(), means.cols()), (c_n, dim));
        let mut f = self.f.clone();
        for c in 0..c_n {
            let nc = self.n[c];
            let m = means.row(c);
            let row = f.row_mut(c);
            for j in 0..dim {
                row[j] -= nc * m[j];
            }
        }
        let s = self.s.as_ref().map(|s_raw| {
            (0..c_n)
                .map(|c| {
                    let mut sc = s_raw[c].clone();
                    let m = means.row(c);
                    let fr = self.f.row(c);
                    let nc = self.n[c];
                    for i in 0..dim {
                        for j in 0..dim {
                            let v = sc.get(i, j) - m[i] * fr[j] - fr[i] * m[j] + nc * m[i] * m[j];
                            sc.set(i, j, v);
                        }
                    }
                    sc
                })
                .collect()
        });
        Self { n: self.n.clone(), f, s }
    }

    /// Total occupancy Σ_c n_c (≈ VAD-surviving frame count).
    pub fn total_count(&self) -> f64 {
        self.n.iter().sum()
    }

    /// Merge another utterance's stats into a global accumulator.
    pub fn merge(&mut self, other: &BwStats) {
        assert_eq!(self.n.len(), other.n.len());
        for (a, b) in self.n.iter_mut().zip(&other.n) {
            *a += b;
        }
        self.f.add_scaled(1.0, &other.f);
        match (&mut self.s, &other.s) {
            (Some(a), Some(b)) => {
                for (x, y) in a.iter_mut().zip(b) {
                    x.add_scaled(1.0, y);
                }
            }
            (None, None) => {}
            _ => panic!("merging stats with mismatched second-order presence"),
        }
    }

    /// Empty accumulator.
    pub fn zeros(n_components: usize, dim: usize, second_order: bool) -> Self {
        Self {
            n: vec![0.0; n_components],
            f: Mat::zeros(n_components, dim),
            s: second_order.then(|| vec![Mat::zeros(dim, dim); n_components]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> (Mat, Vec<Vec<Posting>>) {
        // 3 frames, dim 2, 2 components
        let feats = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let posts = vec![
            vec![Posting { idx: 0, post: 1.0 }],
            vec![Posting { idx: 0, post: 0.5 }, Posting { idx: 1, post: 0.5 }],
            vec![Posting { idx: 1, post: 1.0 }],
        ];
        (feats, posts)
    }

    #[test]
    fn occupancy_and_first_order() {
        let (feats, posts) = demo();
        let st = BwStats::accumulate(&feats, &posts, 2, false);
        assert!((st.n[0] - 1.5).abs() < 1e-12);
        assert!((st.n[1] - 1.5).abs() < 1e-12);
        // f_0 = 1.0*[1,2] + 0.5*[3,4] = [2.5, 4]
        assert!((st.f.get(0, 0) - 2.5).abs() < 1e-12);
        assert!((st.f.get(0, 1) - 4.0).abs() < 1e-12);
        // f_1 = 0.5*[3,4] + 1.0*[5,6] = [6.5, 8]
        assert!((st.f.get(1, 0) - 6.5).abs() < 1e-12);
        assert!((st.total_count() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn second_order_symmetric_and_correct() {
        let (feats, posts) = demo();
        let st = BwStats::accumulate(&feats, &posts, 2, true);
        let s0 = &st.s.as_ref().unwrap()[0];
        // S_0 = 1*[1,2]ᵀ[1,2] + 0.5*[3,4]ᵀ[3,4]
        assert!((s0.get(0, 0) - (1.0 + 4.5)).abs() < 1e-12);
        assert!((s0.get(0, 1) - (2.0 + 6.0)).abs() < 1e-12);
        assert_eq!(s0.get(0, 1), s0.get(1, 0));
    }

    #[test]
    fn centering_zeroes_mean_matched_stats() {
        // single component whose mean equals the weighted frame mean →
        // centered f must vanish.
        let feats = Mat::from_rows(&[&[2.0, 0.0], &[4.0, 2.0]]);
        let posts = vec![
            vec![Posting { idx: 0, post: 1.0 }],
            vec![Posting { idx: 0, post: 1.0 }],
        ];
        let st = BwStats::accumulate(&feats, &posts, 1, true);
        let means = Mat::from_rows(&[&[3.0, 1.0]]);
        let c = st.center(&means);
        assert!(c.f.max_abs() < 1e-12);
        // centered S = Σ (x-m)(x-m)ᵀ = [[1,1],[1,1]] * 2
        let s0 = &c.s.as_ref().unwrap()[0];
        assert!((s0.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((s0.get(1, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let (feats, posts) = demo();
        let st = BwStats::accumulate(&feats, &posts, 2, true);
        let mut acc = BwStats::zeros(2, 2, true);
        acc.merge(&st);
        acc.merge(&st);
        assert!((acc.n[0] - 3.0).abs() < 1e-12);
        assert!((acc.f.get(1, 1) - 16.0).abs() < 1e-12);
    }
}
