//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the crate touches the accelerator, and the
//! only place that depends on the `xla` crate — which is why the whole
//! device path sits behind the `accel` cargo feature. The default
//! (CPU-only) build swaps in [`stub`]: an API-identical runtime whose
//! constructor returns an error, so every downstream consumer
//! (`AccelTvm`, the accelerated aligner, the CLI `smoke` command)
//! compiles unchanged and degrades to a clear runtime message in
//! network-less / toolchain-less environments.
//!
//! With `--features accel`: the compile path (`python/compile/aot.py`)
//! lowers each L2 jax graph to HLO *text* (jax >= 0.5 serialized protos
//! use 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids), and this module compiles those artifacts
//! once per process and executes them from the hot path.
//!
//! All device IO is `f32`/`i32`; the f64 model math in [`crate::linalg`]
//! converts at this boundary.

mod tensor;

pub use tensor::Tensor;

#[cfg(feature = "accel")]
mod pjrt;
#[cfg(feature = "accel")]
pub use pjrt::{smoke_run, Graph, Runtime};

#[cfg(not(feature = "accel"))]
mod stub;
#[cfg(not(feature = "accel"))]
pub use stub::{smoke_run, Graph, Runtime};
