//! CPU-only stand-in for the PJRT runtime (default build, no `accel`).
//!
//! API-identical to [`super::pjrt`] so that every accelerated code path
//! compiles without the `xla` dependency. [`Runtime::cpu`] is the single
//! entry point and it returns an error, so the types are uninhabited
//! (they hold [`std::convert::Infallible`]) and the remaining methods
//! are statically unreachable — no panics, no `unimplemented!`.

use std::convert::Infallible;
use std::path::Path;

use anyhow::{bail, Result};

use super::Tensor;

const NO_ACCEL_MSG: &str = "this binary was built without the `accel` cargo feature — \
     the XLA/PJRT device path is unavailable; rebuild with \
     `cargo build --release --features accel` (requires the `xla` crate \
     and a prebuilt xla_extension), or use the CPU paths (`--cpu-ref`)";

/// Uninhabited stand-in for the PJRT client wrapper.
pub struct Runtime {
    never: Infallible,
    /// Accumulated device-execution wall time (API parity with the
    /// accel runtime; never observable because `Runtime` cannot be
    /// constructed in this build).
    pub device_time: std::cell::Cell<f64>,
}

/// Uninhabited stand-in for a compiled HLO graph.
pub struct Graph {
    never: Infallible,
}

impl Runtime {
    /// Always fails: the device path is compiled out.
    pub fn cpu(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(NO_ACCEL_MSG)
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn load(&mut self, _name: &str) -> Result<&Graph> {
        match self.never {}
    }

    pub fn graph(&self, _name: &str) -> Result<&Graph> {
        match self.never {}
    }

    pub fn load_path(&mut self, _name: &str, _path: impl AsRef<Path>) -> Result<&Graph> {
        match self.never {}
    }
}

impl Graph {
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match self.never {}
    }

    pub fn run_refs(&self, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        match self.never {}
    }

    pub fn name(&self) -> &str {
        match self.never {}
    }
}

/// `ivector-tv smoke` without the device path: a clear error.
pub fn smoke_run(_path: &str, _input_specs: &[(Vec<usize>, &str)]) -> Result<Vec<Tensor>> {
    bail!(NO_ACCEL_MSG)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::cpu(".").unwrap_err();
        assert!(err.to_string().contains("accel"), "{err}");
        let err = smoke_run("x.hlo.txt", &[]).unwrap_err();
        assert!(err.to_string().contains("accel"), "{err}");
    }
}
