//! Host-side tensor: the IO type at the rust ⇄ PJRT boundary.
//!
//! Row-major, shape-tagged, `f32` or `i32` payload — exactly what the L2
//! graphs consume/produce. Model math lives in [`crate::linalg`] (f64);
//! conversion happens here at the device boundary.

#[cfg(feature = "accel")]
use anyhow::anyhow;
use anyhow::{bail, Result};

/// Element payload of a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor (row-major) with shape metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// f32 tensor from data + shape. Panics if sizes mismatch (programmer error).
    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "tensor data/shape mismatch: {} vs {:?}",
            data.len(),
            shape
        );
        Self { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    /// i32 tensor from data + shape.
    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    /// Zero-filled f32 tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Self::from_f32(vec![0.0; shape.iter().product()], shape)
    }

    /// Zero-filled i32 tensor.
    pub fn zeros_i32(shape: &[usize]) -> Self {
        Self::from_i32(vec![0; shape.iter().product()], shape)
    }

    /// f32 tensor from f64 slice (the linalg → device conversion,
    /// through the crate-wide narrowing helper shared with the f32
    /// alignment pack).
    pub fn from_f64(data: &[f64], shape: &[usize]) -> Self {
        Self::from_f32(crate::linalg::f32::narrow(data), shape)
    }

    /// Shape (row-major dims).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow f32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    /// Borrow i32 payload.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// Copy payload to f64 (the device → linalg conversion).
    pub fn to_f64(&self) -> Result<Vec<f64>> {
        Ok(crate::linalg::f32::widen(self.as_f32()?))
    }

    /// Convert to an XLA literal for device upload.
    #[cfg(feature = "accel")]
    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).map_err(|e| anyhow!("literal reshape {:?}: {e:?}", self.shape))
    }

    /// Build from an XLA literal fetched off device.
    #[cfg(feature = "accel")]
    pub(crate) fn from_literal(lit: xla::Literal) -> Result<Self> {
        let array_shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let shape: Vec<usize> = array_shape.dims().iter().map(|&d| d as usize).collect();
        let data = match array_shape.primitive_type() {
            xla::PrimitiveType::F32 => {
                TensorData::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?)
            }
            xla::PrimitiveType::S32 => {
                TensorData::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?)
            }
            // 64-bit outputs appear if a graph was lowered with x64 enabled —
            // that is a build-path bug; surface it clearly.
            other => bail!("unsupported device output type {other:?} (graphs must be f32/i32)"),
        };
        Ok(Self { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_shape() {
        let t = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn f64_conversion() {
        let t = Tensor::from_f64(&[1.5, -2.5], &[2]);
        assert_eq!(t.as_f32().unwrap(), &[1.5f32, -2.5f32]);
        assert_eq!(t.to_f64().unwrap(), vec![1.5, -2.5]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(vec![1.0], &[2, 2]);
    }

    #[test]
    fn zeros_i32() {
        let t = Tensor::zeros_i32(&[3, 2]);
        assert_eq!(t.as_i32().unwrap(), &[0; 6]);
    }
}
