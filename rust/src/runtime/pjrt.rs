//! The real PJRT-backed runtime (`--features accel`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::Tensor;

/// A process-wide PJRT client plus the set of compiled graph executables.
pub struct Runtime {
    client: xla::PjRtClient,
    graphs: HashMap<String, Graph>,
    artifacts_dir: PathBuf,
    /// Accumulated device-execution wall time, for the speed report.
    pub device_time: std::cell::Cell<f64>,
}

/// One compiled HLO graph (one `artifacts/<name>.hlo.txt`).
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Runtime {
    /// Create a CPU PJRT client. (The paper used a Titan V GPU; on this
    /// testbed the accelerator is the XLA CPU backend — see DESIGN.md
    /// substitution table.)
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            graphs: HashMap::new(),
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            device_time: std::cell::Cell::new(0.0),
        })
    }

    /// Platform string, e.g. "cpu" — used by the speed report.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `artifacts/<name>.hlo.txt`, caching the executable.
    pub fn load(&mut self, name: &str) -> Result<&Graph> {
        if !self.graphs.contains_key(name) {
            let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
            let graph = Graph::compile_file(&self.client, name, &path)?;
            self.graphs.insert(name.to_string(), graph);
        }
        Ok(&self.graphs[name])
    }

    /// Get an already-loaded graph.
    pub fn graph(&self, name: &str) -> Result<&Graph> {
        self.graphs.get(name).ok_or_else(|| anyhow!("graph `{name}` not loaded"))
    }

    /// Load + compile a graph from an explicit path (diagnostics, tests).
    pub fn load_path(&mut self, name: &str, path: impl AsRef<Path>) -> Result<&Graph> {
        let graph = Graph::compile_file(&self.client, name, path.as_ref())?;
        self.graphs.insert(name.to_string(), graph);
        Ok(&self.graphs[name])
    }
}

impl Graph {
    fn compile_file(client: &xla::PjRtClient, name: &str, path: &Path) -> Result<Self> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))
        .with_context(|| format!("did you run `make artifacts`? missing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compile `{name}`: {e:?}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        eprintln!("[runtime] compiled graph `{name}` in {ms:.0} ms");
        Ok(Self { exe, name: name.to_string() })
    }

    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// All L2 graphs are lowered with `return_tuple=True`, so the single
    /// device result is always a tuple literal — we decompose it into one
    /// [`Tensor`] per graph output.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// [`Graph::run`] over borrowed tensors — the hot-path variant.
    /// Streaming callers mix per-batch inputs with large per-iteration
    /// constants (packed weights, TᵀΣ⁻¹ tensors); borrowing lets them
    /// pass the constants without cloning the buffers on every batch.
    pub fn run_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute `{}`: {e:?}", self.name))?;
        let mut out = result
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("execute `{}`: empty result", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch `{}`: {e:?}", self.name))?;
        let parts =
            out.decompose_tuple().map_err(|e| anyhow!("decompose `{}`: {e:?}", self.name))?;
        parts.into_iter().map(Tensor::from_literal).collect()
    }

    /// Graph name (artifact stem).
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Quick diagnostic used by `ivector-tv smoke`: compile an HLO file and
/// run it with zero-filled inputs of the given shapes.
pub fn smoke_run(path: &str, input_specs: &[(Vec<usize>, &str)]) -> Result<Vec<Tensor>> {
    let mut rt = Runtime::cpu(".")?;
    let graph = rt.load_path("smoke", path)?;
    let inputs: Vec<Tensor> = input_specs
        .iter()
        .map(|(shape, ty)| match *ty {
            "f32" => Ok(Tensor::zeros(shape)),
            "i32" => Ok(Tensor::zeros_i32(shape)),
            other => bail!("unsupported smoke input type {other}"),
        })
        .collect::<Result<_>>()?;
    graph.run(&inputs)
}
