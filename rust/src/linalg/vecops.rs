//! Small vector kernels shared across the crate.

use super::Mat;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Normalize to unit length in place; returns the original norm.
/// Zero vectors are left untouched.
pub fn normalize(a: &mut [f64]) -> f64 {
    let n = norm2(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
    n
}

/// Scale in place.
#[inline]
pub fn scale_in_place(a: &mut [f64], alpha: f64) {
    for x in a.iter_mut() {
        *x *= alpha;
    }
}

/// Outer product `a bᵀ`.
pub fn outer(a: &[f64], b: &[f64]) -> Mat {
    let mut m = Mat::zeros(a.len(), b.len());
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0.0 {
            continue;
        }
        let row = m.row_mut(i);
        for (j, &bj) in b.iter().enumerate() {
            row[j] = ai * bj;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        axpy(2.0, &a, &mut b);
        assert_eq!(b, [6.0, 9.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn normalize_unit() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-15);
        assert!((norm2(&v) - 1.0).abs() < 1e-15);
        let mut z = vec![0.0, 0.0];
        assert_eq!(normalize(&mut z), 0.0);
    }

    #[test]
    fn outer_shape_values() {
        let m = outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert_eq!(m.get(1, 2), 10.0);
    }
}
