//! Single-precision kernel layer for the mixed-precision alignment
//! path.
//!
//! The hot alignment GEMM (`[x; x²] · Wᵀ`) is memory-bandwidth- and
//! SIMD-lane-bound: in f64 half the vector lanes sit idle and every
//! cache line carries half as many elements. This module provides the
//! f32 mirror of the few [`super::Mat`] kernels that GEMM needs —
//! [`MatF32`] with packed [`MatF32::matmul_nt_into`] /
//! [`MatF32::matvec_into`] — written as 8-wide unrolled loops that
//! auto-vectorize on stable rustc. The `simd` cargo feature (nightly)
//! swaps the inner dot kernel for explicit `std::simd` lanes.
//!
//! Model math stays f64 ([`super::Mat`]); f32 is only for score-shaped
//! work whose consumers re-derive exact quantities downstream (top-K
//! selection feeding an f64 rescoring pass, device uploads). The
//! f64 ⇄ f32 boundary crossings all go through [`narrow`] / [`widen`]
//! so the crate has exactly one conversion idiom.

/// Unroll width of the scalar kernels; matches the `std::simd` lane
/// count used under the `simd` feature, so both paths sum partial
/// products in the same 8-accumulator order.
const LANES: usize = 8;

/// Shared-dimension panel for [`MatF32::matmul_nt_into`] (same role as
/// the f64 kernel's `NT_QB`; f32 halves the bytes per element, so the
/// panel covers twice the logical width per cache byte).
const NT_QB: usize = 512;

/// Narrow an f64 slice to f32 — the single widening/narrowing idiom
/// shared by the device-tensor boundary
/// ([`crate::runtime::Tensor::from_f64`]) and the f32 alignment pack
/// ([`crate::gmm::PackedDiagF32`]).
#[inline]
pub fn narrow(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

/// Widen an f32 slice to f64 (the inverse boundary crossing).
#[inline]
pub fn widen(xs: &[f32]) -> Vec<f64> {
    xs.iter().map(|&x| x as f64).collect()
}

/// f32 dot product, 8-wide. The scalar build keeps 8 independent
/// accumulators so rustc can vectorize without reassociating a single
/// serial chain; the `simd` build uses explicit `std::simd` lanes with
/// the same reduction order, so the two builds agree bit-for-bit.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let main = a.len() - a.len() % LANES;
    let (a8, a_tail) = a.split_at(main);
    let (b8, b_tail) = b.split_at(main);
    let mut acc = lane_sums(a8, b8);
    // pairwise lane reduction (what `reduce_sum` lowers to)
    for step in [4, 2, 1] {
        for l in 0..step {
            acc[l] += acc[l + step];
        }
    }
    let mut s = acc[0];
    for (x, y) in a_tail.iter().zip(b_tail) {
        s += x * y;
    }
    s
}

/// Per-lane partial sums over the 8-aligned prefix (scalar build).
#[cfg(not(feature = "simd"))]
#[inline]
fn lane_sums(a8: &[f32], b8: &[f32]) -> [f32; LANES] {
    let mut acc = [0.0f32; LANES];
    for (ca, cb) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    acc
}

/// Per-lane partial sums over the 8-aligned prefix (`std::simd` build).
#[cfg(feature = "simd")]
#[inline]
fn lane_sums(a8: &[f32], b8: &[f32]) -> [f32; LANES] {
    use std::simd::f32x8;
    let mut acc = f32x8::splat(0.0);
    for (ca, cb) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        acc += f32x8::from_slice(ca) * f32x8::from_slice(cb);
    }
    acc.to_array()
}

/// Dense row-major f32 matrix — the alignment-scoring mirror of
/// [`super::Mat`], deliberately minimal: only what the f32 GEMM path
/// consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatF32 {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From an owned buffer (row-major).
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "MatF32::from_vec size mismatch");
        Self { rows, cols, data }
    }

    /// Narrow an f64 matrix (row-major copy through [`narrow`]).
    pub fn from_mat(m: &super::Mat) -> Self {
        Self::from_vec(narrow(m.as_slice()), m.rows(), m.cols())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Fill every element with `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// `out = self · otherᵀ` into a caller-owned buffer, shared
    /// dimension panel-blocked like the f64 kernel: the panel of
    /// `other` rows is re-read from cache, not memory, across the
    /// `self` row sweep, and every dot runs 8 lanes wide.
    pub fn matmul_nt_into(&self, other: &MatF32, out: &mut MatF32) {
        assert_eq!((out.rows, out.cols), (self.rows, other.rows), "matmul_nt_into out dims");
        out.fill(0.0);
        self.matmul_nt_acc_rows(self.rows, other, out);
    }

    /// The panel-blocked accumulation core of [`MatF32::matmul_nt_into`]:
    /// `out[i] += self[i] · otherᵀ` for the first `n_rows` rows, on top
    /// of whatever `out` already holds. Exposed so the alignment score
    /// kernel — which pre-initializes each output row with
    /// per-component constants and scores only the filled prefix of its
    /// block buffer — shares this blocking structure instead of
    /// duplicating it.
    pub fn matmul_nt_acc_rows(&self, n_rows: usize, other: &MatF32, out: &mut MatF32) {
        assert_eq!(self.cols, other.cols, "matmul_nt dims");
        assert_eq!(out.cols, other.rows, "matmul_nt out cols");
        assert!(n_rows <= self.rows && n_rows <= out.rows, "matmul_nt row prefix");
        let q = self.cols;
        let p = other.rows;
        for qb in (0..q).step_by(NT_QB) {
            let qe = (qb + NT_QB).min(q);
            for i in 0..n_rows {
                let a_seg = &self.data[i * q + qb..i * q + qe];
                let out_row = &mut out.data[i * p..(i + 1) * p];
                for (j, o) in out_row.iter_mut().enumerate() {
                    *o += dot_f32(a_seg, &other.data[j * q + qb..j * q + qe]);
                }
            }
        }
    }

    /// Matrix–vector product into a caller-owned buffer.
    pub fn matvec_into(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(self.cols, v.len(), "matvec dims");
        assert_eq!(out.len(), self.rows, "matvec out dims");
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot_f32(self.row(i), v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::Mat;
    use super::*;
    use crate::proptest::{forall, gen_dim, gen_mat};

    #[test]
    fn narrow_widen_roundtrip() {
        let xs = [1.5, -2.25, 0.0, 1e10, -3.5e-4];
        let n = narrow(&xs);
        assert_eq!(n, vec![1.5f32, -2.25, 0.0, 1e10, -3.5e-4]);
        // every value above is exactly representable in f32
        assert_eq!(widen(&n), xs.to_vec());
    }

    #[test]
    fn dot_handles_unroll_boundaries() {
        // lengths straddling the 8-lane unroll: 0..=9, 16, 17
        for len in (0..=9).chain([16, 17]) {
            let a: Vec<f32> = (0..len).map(|i| 0.5 * i as f32 - 1.0).collect();
            let b: Vec<f32> = (0..len).map(|i| 0.25 * i as f32 + 2.0).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot_f32(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn prop_dot_matches_f64_dot() {
        forall(
            1907,
            48,
            |rng| {
                let n = gen_dim(rng, 1, 300);
                let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (a, b)
            },
            |(a, b)| {
                let got = dot_f32(&narrow(a), &narrow(b)) as f64;
                let want = crate::linalg::dot(a, b);
                // f32 relative accuracy over a ~300-term sum
                let scale: f64 = a.iter().zip(b).map(|(x, y)| (x * y).abs()).sum();
                if (got - want).abs() <= 1e-5 * (1.0 + scale) {
                    Ok(())
                } else {
                    Err(format!("{got} vs {want} (scale {scale})"))
                }
            },
        );
    }

    #[test]
    fn prop_matmul_nt_into_matches_f64_kernel() {
        forall(
            2008,
            24,
            |rng| {
                let m = gen_dim(rng, 1, 20);
                let q = gen_dim(rng, 1, 700); // straddles NT_QB and the unroll
                let p = gen_dim(rng, 1, 20);
                let a = gen_mat(rng, m, q, 1.0);
                let b = gen_mat(rng, p, q, 1.0);
                (a, b)
            },
            |(a, b)| {
                let (a32, b32) = (MatF32::from_mat(a), MatF32::from_mat(b));
                let mut out = MatF32::zeros(a.rows(), b.rows());
                a32.matmul_nt_into(&b32, &mut out);
                let want = a.matmul_nt(b);
                let scale = 1.0 + want.max_abs() + a.cols() as f64;
                for i in 0..want.rows() {
                    for j in 0..want.cols() {
                        let (g, w) = (out.get(i, j) as f64, want.get(i, j));
                        if (g - w).abs() > 1e-5 * scale {
                            return Err(format!("({i},{j}): {g} vs {w}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn acc_rows_accumulates_over_a_row_prefix() {
        // the score-kernel contract: accumulate on top of preloaded
        // output rows, touch only the first n_rows
        let a = MatF32::from_mat(&Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64));
        let b = MatF32::from_mat(&Mat::from_fn(2, 5, |i, j| (i + j) as f64 * 0.5));
        let mut out = MatF32::zeros(3, 2);
        out.fill(1.0);
        a.matmul_nt_acc_rows(2, &b, &mut out);
        for i in 0..2 {
            for j in 0..2 {
                let want = 1.0 + dot_f32(a.row(i), b.row(j));
                assert_eq!(out.get(i, j), want, "({i},{j})");
            }
        }
        assert_eq!(out.row(2), &[1.0f32, 1.0][..], "rows past the prefix must be untouched");
    }

    #[test]
    fn matvec_into_matches_f64_matvec() {
        let a = Mat::from_fn(5, 19, |i, j| (i * 19 + j) as f64 * 0.37 - 3.0);
        let v: Vec<f64> = (0..19).map(|j| 0.21 * j as f64 - 1.0).collect();
        let a32 = MatF32::from_mat(&a);
        let mut out = vec![0.0f32; 5];
        a32.matvec_into(&narrow(&v), &mut out);
        for (g, w) in out.iter().zip(a.matvec(&v)) {
            assert!((*g as f64 - w).abs() <= 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }
}
