//! Packed storage for symmetric matrices.
//!
//! The E-step precision build `L = I + Σ_c n_c · TᵀΣ⁻¹T|_c` touches C
//! full R×R matrices per utterance even though each is symmetric.
//! Packing the upper triangles into rows of a `(C × R(R+1)/2)` matrix
//! turns the whole sum into a single `(R(R+1)/2 × C) · n` GEMV over
//! contiguous memory — half the flops and none of the strided reads of
//! C separate full-matrix axpys.
//!
//! Layout: row-major upper triangle, `packed[idx(i, j)] = M[i][j]` for
//! `j ≥ i`, with `idx(i, j) = i·n − i(i−1)/2 + (j − i)`.

use super::Mat;

/// Packed length of an `n × n` symmetric matrix: `n(n+1)/2`.
#[inline]
pub fn sym_packed_len(n: usize) -> usize {
    n * (n + 1) / 2
}

/// Pack the upper triangle of a symmetric matrix into `out`
/// (length [`sym_packed_len`]). Only the upper triangle is read, so
/// exact symmetry of `m` is the caller's contract.
pub fn sym_pack_into(m: &Mat, out: &mut [f64]) {
    let n = m.rows();
    assert_eq!(m.cols(), n, "sym_pack needs a square matrix");
    assert_eq!(out.len(), sym_packed_len(n), "sym_pack out length");
    let mut idx = 0;
    for i in 0..n {
        let row = m.row(i);
        out[idx..idx + (n - i)].copy_from_slice(&row[i..]);
        idx += n - i;
    }
}

/// Pack the upper triangle into a fresh buffer.
pub fn sym_pack(m: &Mat) -> Vec<f64> {
    let mut out = vec![0.0; sym_packed_len(m.rows())];
    sym_pack_into(m, &mut out);
    out
}

/// Unpack into `out = I + M` — the precision-matrix assembly of the
/// E-step (`L = I + Σ n_c M_c` after the packed weighted sum).
pub fn sym_unpack_eye_into(packed: &[f64], out: &mut Mat) {
    let n = out.rows();
    assert_eq!(out.cols(), n, "sym_unpack needs a square out");
    assert_eq!(packed.len(), sym_packed_len(n), "sym_unpack packed length");
    let mut idx = 0;
    for i in 0..n {
        for j in i..n {
            let mut v = packed[idx];
            idx += 1;
            if i == j {
                v += 1.0;
            }
            out.set(i, j, v);
            out.set(j, i, v);
        }
    }
}

/// `out = Σ_c w[c] · packed_rows[c]` — the single GEMV that replaces C
/// full-matrix axpys when accumulating weighted symmetric matrices.
/// `packed_rows` is `(C × n(n+1)/2)`; zero weights are skipped so the
/// result matches the sparse per-component reference loop exactly.
pub fn sym_weighted_sum(packed_rows: &Mat, w: &[f64], out: &mut [f64]) {
    assert_eq!(packed_rows.rows(), w.len(), "sym_weighted_sum weight length");
    assert_eq!(packed_rows.cols(), out.len(), "sym_weighted_sum out length");
    out.fill(0.0);
    for (c, &wc) in w.iter().enumerate() {
        if wc != 0.0 {
            super::axpy(wc, packed_rows.row(c), out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, gen_dim, gen_spd};

    #[test]
    fn pack_roundtrip_adds_identity() {
        let m = Mat::from_rows(&[&[2.0, 0.5, -1.0], &[0.5, 3.0, 0.25], &[-1.0, 0.25, 4.0]]);
        let packed = sym_pack(&m);
        assert_eq!(packed.len(), 6);
        let mut back = Mat::zeros(3, 3);
        sym_unpack_eye_into(&packed, &mut back);
        let mut want = m.clone();
        for i in 0..3 {
            *want.get_mut(i, i) += 1.0;
        }
        assert!(back.approx_eq(&want, 0.0));
    }

    #[test]
    fn prop_weighted_sum_matches_full_axpys() {
        forall(
            909,
            48,
            |rng| {
                let n = gen_dim(rng, 1, 10);
                let c = gen_dim(rng, 1, 8);
                let mats: Vec<Mat> = (0..c)
                    .map(|_| {
                        let mut m = gen_spd(rng, n, 0.1);
                        m.symmetrize();
                        m
                    })
                    .collect();
                // include an exact zero weight to exercise the skip
                let mut w: Vec<f64> = (0..c).map(|_| rng.uniform_in(0.0, 5.0)).collect();
                w[0] = 0.0;
                (mats, w)
            },
            |(mats, w)| {
                let n = mats[0].rows();
                let p = sym_packed_len(n);
                let mut rows = Mat::zeros(mats.len(), p);
                for (c, m) in mats.iter().enumerate() {
                    sym_pack_into(m, rows.row_mut(c));
                }
                let mut packed = vec![0.0; p];
                sym_weighted_sum(&rows, w, &mut packed);
                let mut got = Mat::zeros(n, n);
                sym_unpack_eye_into(&packed, &mut got);
                // reference: I + Σ w_c M_c with full-matrix axpys
                let mut want = Mat::eye(n);
                for (m, &wc) in mats.iter().zip(w) {
                    if wc != 0.0 {
                        want.add_scaled(wc, m);
                    }
                }
                // not bit-exact: the reference folds the identity in
                // before the sum, the packed path after it
                if got.approx_eq(&want, 1e-12 * (1.0 + want.max_abs())) {
                    Ok(())
                } else {
                    Err(format!("deviates by {}", got.sub(&want).max_abs()))
                }
            },
        );
    }
}
