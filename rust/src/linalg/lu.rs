//! LU decomposition with partial pivoting — general (non-SPD) solves,
//! used for inverting the minimum-divergence transform `P₁ = Λ^{-½}Qᵀ`
//! and other non-symmetric systems.

use anyhow::{bail, Result};

use super::Mat;

/// Packed LU factorization with row pivots.
#[derive(Debug, Clone)]
pub struct Lu {
    lu: Mat,
    piv: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factorize `A = P L U`.
    pub fn new(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols(), "lu needs a square matrix");
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut pmax = lu.get(k, k).abs();
            for i in (k + 1)..n {
                let v = lu.get(i, k).abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax < 1e-300 {
                bail!("singular matrix at pivot {k}");
            }
            if p != k {
                for j in 0..n {
                    let t = lu.get(k, j);
                    lu.set(k, j, lu.get(p, j));
                    lu.set(p, j, t);
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu.get(k, k);
            for i in (k + 1)..n {
                let m = lu.get(i, k) / pivot;
                lu.set(i, k, m);
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu.get(i, j) - m * lu.get(k, j);
                        lu.set(i, j, v);
                    }
                }
            }
        }
        Ok(Self { lu, piv, sign })
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward L (unit diagonal)
        for i in 1..n {
            for k in 0..i {
                x[i] -= self.lu.get(i, k) * x[k];
            }
        }
        // backward U
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= self.lu.get(i, k) * x[k];
            }
            x[i] /= self.lu.get(i, i);
        }
        x
    }

    /// Solve `A X = B`.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut x = Mat::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            x.set_col(j, &self.solve_vec(&b.col(j)));
        }
        x
    }

    /// `A⁻¹`.
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.lu.rows()))
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows() {
            d *= self.lu.get(i, i);
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn solve_roundtrip() {
        let mut rng = Rng::seed(2);
        let a = Mat::from_fn(7, 7, |_, _| rng.normal());
        let b: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let x = Lu::new(&a).unwrap().solve_vec(&b);
        let ax = a.matvec(&x);
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-8, "{l} vs {r}");
        }
    }

    #[test]
    fn inverse_identity() {
        let mut rng = Rng::seed(4);
        let a = Mat::from_fn(6, 6, |_, _| rng.normal());
        let inv = Lu::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).approx_eq(&Mat::eye(6), 1e-8));
    }

    #[test]
    fn det_known() {
        let a = Mat::from_rows(&[&[2.0, 0.0], &[1.0, 3.0]]);
        assert!((Lu::new(&a).unwrap().det() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn singular_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::new(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = Lu::new(&a).unwrap().solve_vec(&[3.0, 5.0]);
        assert!((x[0] - 5.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }
}
