//! Cyclic Jacobi eigendecomposition for symmetric matrices — used by the
//! minimum-divergence whitening `G = QΛQᵀ` (paper §3.1), LDA, and PLDA.

use super::Mat;

/// Symmetric eigendecomposition `A = Q Λ Qᵀ`.
#[derive(Debug, Clone)]
pub struct EigH {
    /// Eigenvalues, ascending.
    pub values: Vec<f64>,
    /// Eigenvectors as columns of `Q` (same order as `values`).
    pub vectors: Mat,
}

/// Cyclic Jacobi with threshold sweeps. Converges quadratically; for the
/// R ≤ a-few-hundred matrices in this codebase it is exact to ~1e-12.
pub fn jacobi_eigh(a: &Mat) -> EigH {
    assert_eq!(a.rows(), a.cols(), "eigh needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut q = Mat::eye(n);

    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.fro_norm()) {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apq = m.get(p, r);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(r, r);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,r of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, r);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, r, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(r, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(r, k, s * mpk + c * mqk);
                }
                // accumulate rotations into q
                for k in 0..n {
                    let qkp = q.get(k, p);
                    let qkq = q.get(k, r);
                    q.set(k, p, c * qkp - s * qkq);
                    q.set(k, r, s * qkp + c * qkq);
                }
            }
        }
    }

    // extract + sort ascending
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m.get(i, i)).collect();
    order.sort_by(|&i, &j| diag[i].partial_cmp(&diag[j]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vectors.set(i, new_j, q.get(i, old_j));
        }
    }
    EigH { values, vectors }
}

impl EigH {
    /// Reconstruct `Q Λ Qᵀ` (tests / diagnostics).
    pub fn reconstruct(&self) -> Mat {
        let n = self.values.len();
        let mut ql = self.vectors.clone();
        for j in 0..n {
            for i in 0..n {
                *ql.get_mut(i, j) *= self.values[j];
            }
        }
        ql.matmul_nt(&self.vectors)
    }

    /// Whitening transform `P₁ = Λ^{-½} Qᵀ` of the (SPD) decomposed
    /// matrix, flooring eigenvalues at `floor` (paper §3.1).
    pub fn whitener(&self, floor: f64) -> Mat {
        let n = self.values.len();
        let mut p = self.vectors.t();
        for i in 0..n {
            let lam = self.values[i].max(floor);
            let s = 1.0 / lam.sqrt();
            for j in 0..n {
                *p.get_mut(i, j) *= s;
            }
        }
        p
    }

    /// Inverse of the whitening transform: `P₁⁻¹ = Q Λ^{½}`.
    pub fn whitener_inv(&self, floor: f64) -> Mat {
        let n = self.values.len();
        let mut qi = self.vectors.clone();
        for j in 0..n {
            let s = self.values[j].max(floor).sqrt();
            for i in 0..n {
                *qi.get_mut(i, j) *= s;
            }
        }
        qi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        a
    }

    #[test]
    fn reconstructs() {
        let mut rng = Rng::seed(13);
        let a = random_sym(10, &mut rng);
        let e = jacobi_eigh(&a);
        assert!(e.reconstruct().approx_eq(&a, 1e-10));
    }

    #[test]
    fn eigenvalues_sorted_and_orthonormal() {
        let mut rng = Rng::seed(17);
        let a = random_sym(8, &mut rng);
        let e = jacobi_eigh(&a);
        for w in e.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
        let qtq = e.vectors.matmul_tn(&e.vectors);
        assert!(qtq.approx_eq(&Mat::eye(8), 1e-10));
    }

    #[test]
    fn known_2x2() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let e = jacobi_eigh(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn whitener_whitens() {
        let mut rng = Rng::seed(23);
        let m = Mat::from_fn(6, 6, |_, _| rng.normal());
        let mut g = m.matmul_nt(&m);
        for i in 0..6 {
            *g.get_mut(i, i) += 1.0;
        }
        let e = jacobi_eigh(&g);
        let p1 = e.whitener(1e-12);
        // P1 G P1ᵀ = I
        let w = p1.matmul(&g).matmul_nt(&p1);
        assert!(w.approx_eq(&Mat::eye(6), 1e-9));
        // P1 · P1⁻¹ = I
        let id = p1.matmul(&e.whitener_inv(1e-12));
        assert!(id.approx_eq(&Mat::eye(6), 1e-9));
    }

    #[test]
    fn diagonal_matrix_fast_path() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let e = jacobi_eigh(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-14);
        assert!((e.values[2] - 3.0).abs() < 1e-14);
    }
}
