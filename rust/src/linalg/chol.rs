//! Cholesky decomposition and SPD solves — the workhorse behind the
//! E-step precision solve `L(u) φ = rhs`, covariance inversion, and
//! PLDA/LDA whitening.
//!
//! Two factorization paths:
//!
//! * [`factor_in_place`] — the blocked right-looking kernel: factors a
//!   caller-owned buffer with panel-dot trailing updates, so hot loops
//!   (the batched E-step solves one R×R system per utterance) allocate
//!   nothing. [`CholRef`] wraps such a buffer with the solve kernels,
//!   which read only the lower triangle — the junk the in-place factor
//!   leaves above the diagonal is never touched.
//! * [`Cholesky::new_scalar`] — the unblocked scalar reference, kept as
//!   the equivalence oracle for the blocked path.

use anyhow::{bail, Result};

use super::Mat;

/// Panel width of the blocked right-looking factorization: the column
/// panel whose trailing update dominates the flops. `CHOL_NB × CHOL_NB`
/// f64s (~32 KiB) keep the diagonal block L1-resident while the panel
/// rows stream through the dot-product update.
const CHOL_NB: usize = 64;

/// Blocked right-looking Cholesky factorization, in place: on success
/// the lower triangle (diagonal included) of `a` holds `L` with
/// `A = L Lᵀ`. The strictly-upper triangle is left untouched (solvers
/// via [`CholRef`] never read it). On failure `a` is partially
/// overwritten — callers that retry (e.g. with a ridge) must rebuild it.
///
/// Same math as the scalar reference with a different accumulation
/// grouping (per-panel trailing updates), so the factors agree to
/// floating-point rounding, not bit-exactly.
pub fn factor_in_place(a: &mut Mat) -> Result<()> {
    let n = a.rows();
    assert_eq!(a.cols(), n, "cholesky needs a square matrix");
    for kb in (0..n).step_by(CHOL_NB) {
        let ke = (kb + CHOL_NB).min(n);
        // 1. factor the diagonal block (scalar, within the panel);
        //    contributions of columns < kb were already subtracted by
        //    earlier trailing updates.
        for j in kb..ke {
            let s = {
                let d = a.as_slice();
                super::dot(&d[j * n + kb..j * n + j], &d[j * n + kb..j * n + j])
            };
            let djj = a.get(j, j) - s;
            if djj <= 0.0 || !djj.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d = {djj:.3e})");
            }
            let djj = djj.sqrt();
            a.set(j, j, djj);
            for i in (j + 1)..ke {
                let s = {
                    let d = a.as_slice();
                    super::dot(&d[i * n + kb..i * n + j], &d[j * n + kb..j * n + j])
                };
                let v = (a.get(i, j) - s) / djj;
                a.set(i, j, v);
            }
        }
        // 2. panel solve: rows below the block against L11ᵀ.
        for i in ke..n {
            for j in kb..ke {
                let s = {
                    let d = a.as_slice();
                    super::dot(&d[i * n + kb..i * n + j], &d[j * n + kb..j * n + j])
                };
                let v = (a.get(i, j) - s) / a.get(j, j);
                a.set(i, j, v);
            }
        }
        // 3. trailing update of the lower triangle:
        //    A22 −= L21 L21ᵀ, one panel-dot per (i, j).
        for i in ke..n {
            for j in ke..=i {
                let s = {
                    let d = a.as_slice();
                    super::dot(&d[i * n + kb..i * n + ke], &d[j * n + kb..j * n + ke])
                };
                *a.get_mut(i, j) -= s;
            }
        }
    }
    Ok(())
}

/// Zero the strictly-upper triangle an in-place factorization leaves as
/// junk, so an owned factor is a proper lower-triangular matrix.
fn zero_upper(l: &mut Mat) {
    let n = l.rows();
    for i in 0..n {
        for j in (i + 1)..n {
            l.set(i, j, 0.0);
        }
    }
}

/// [`factor_in_place`] with the standard ridge-escalation retry: on a
/// failed factorization, `rebuild` must restore the original matrix
/// into the (clobbered) buffer, the next ridge of the ladder is added
/// to the diagonal, and the factorization retries. Returns the ridge
/// that succeeded (0.0 on first try) — the single policy shared by
/// [`Cholesky::new_regularized`] and the allocation-free E-step path.
pub fn factor_in_place_regularized(a: &mut Mat, mut rebuild: impl FnMut(&mut Mat)) -> f64 {
    let scale = a.trace().abs().max(1e-10) / a.rows().max(1) as f64;
    let mut ridge = 0.0;
    loop {
        if factor_in_place(a).is_ok() {
            return ridge;
        }
        ridge = if ridge == 0.0 { scale * 1e-10 } else { ridge * 10.0 };
        assert!(ridge.is_finite(), "regularization diverged");
        rebuild(a);
        for i in 0..a.rows() {
            *a.get_mut(i, i) += ridge;
        }
    }
}

/// Borrowed lower-triangular Cholesky factor over a caller-owned buffer
/// (typically one factored by [`factor_in_place`]). Only the lower
/// triangle is read, so the buffer's upper triangle may hold leftovers.
#[derive(Debug, Clone, Copy)]
pub struct CholRef<'a> {
    l: &'a Mat,
}

impl<'a> CholRef<'a> {
    /// Wrap a factored buffer.
    pub fn new(l: &'a Mat) -> Self {
        debug_assert_eq!(l.rows(), l.cols(), "cholesky factor must be square");
        Self { l }
    }

    /// Solve `A x = b` in place (no allocation) — the hot-path variant
    /// used by the batched E-step workspaces.
    pub fn solve_vec_in_place(&self, y: &mut [f64]) {
        let n = self.l.rows();
        assert_eq!(y.len(), n);
        // forward: L y = b
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l.get(i, k) * y[k];
            }
            y[i] /= self.l.get(i, i);
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                y[i] -= self.l.get(k, i) * y[k];
            }
            y[i] /= self.l.get(i, i);
        }
    }

    /// `out = A⁻¹` into a caller-owned buffer, solving per unit column
    /// with one reused scratch vector (the workspace-friendly variant).
    pub fn inverse_into(&self, out: &mut Mat) {
        let n = self.l.rows();
        assert_eq!((out.rows(), out.cols()), (n, n), "inverse_into out dims");
        let mut col = vec![0.0; n];
        for j in 0..n {
            col.fill(0.0);
            col[j] = 1.0;
            self.solve_vec_in_place(&mut col);
            out.set_col(j, &col);
        }
        out.symmetrize();
    }

    /// `log |A|`.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Solve `L z = v` (forward substitution only) — used for whitening
    /// with the covariance factor: `z = L⁻¹ v`.
    pub fn forward_solve_vec(&self, v: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(v.len(), n);
        let mut z = v.to_vec();
        for i in 0..n {
            for k in 0..i {
                z[i] -= self.l.get(i, k) * z[k];
            }
            z[i] /= self.l.get(i, i);
        }
        z
    }
}

/// Lower-triangular Cholesky factor of an SPD matrix: `A = L Lᵀ`
/// (owned-buffer API; allocation-free callers use [`factor_in_place`] +
/// [`CholRef`] directly).
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorize (blocked right-looking path). Fails (rather than
    /// silently regularizing) when `A` is not positive definite —
    /// callers that want flooring do it explicitly via
    /// [`Cholesky::new_regularized`].
    pub fn new(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let mut l = a.clone();
        factor_in_place(&mut l)?;
        zero_upper(&mut l);
        Ok(Self { l })
    }

    /// The unblocked scalar factorization — the equivalence oracle and
    /// bench baseline for the blocked [`factor_in_place`] path.
    pub fn new_scalar(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            let mut d = a.get(j, j);
            for k in 0..j {
                d -= l.get(j, k) * l.get(j, k);
            }
            if d <= 0.0 || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d = {d:.3e})");
            }
            let dj = d.sqrt();
            l.set(j, j, dj);
            for i in (j + 1)..n {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s / dj);
            }
        }
        Ok(Self { l })
    }

    /// Factorize with a diagonal ridge added until the factorization
    /// succeeds (used on accumulated covariances that may be rank
    /// deficient early in EM). Returns the factor and the ridge used.
    pub fn new_regularized(a: &Mat) -> (Self, f64) {
        let mut l = a.clone();
        let ridge = factor_in_place_regularized(&mut l, |buf| {
            buf.as_mut_slice().copy_from_slice(a.as_slice())
        });
        zero_upper(&mut l);
        (Self { l }, ridge)
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Borrow as the allocation-free solver view.
    pub fn view(&self) -> CholRef<'_> {
        CholRef::new(&self.l)
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut y = b.to_vec();
        self.solve_vec_in_place(&mut y);
        y
    }

    /// Solve `A x = b` in place (no allocation).
    pub fn solve_vec_in_place(&self, y: &mut [f64]) {
        self.view().solve_vec_in_place(y)
    }

    /// Solve `A X = B` column-block right-hand side.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut x = Mat::zeros(n, b.cols());
        // Solve per column (column extraction cost is negligible at our sizes).
        for j in 0..b.cols() {
            let col = self.solve_vec(&b.col(j));
            x.set_col(j, &col);
        }
        x
    }

    /// `A⁻¹` (SPD inverse).
    pub fn inverse(&self) -> Mat {
        let mut inv = Mat::zeros(self.l.rows(), self.l.rows());
        self.inverse_into(&mut inv);
        inv
    }

    /// `out = A⁻¹` into a caller-owned buffer.
    pub fn inverse_into(&self, out: &mut Mat) {
        self.view().inverse_into(out)
    }

    /// `log |A|`.
    pub fn logdet(&self) -> f64 {
        self.view().logdet()
    }

    /// Solve `L z = v` (forward substitution only).
    pub fn forward_solve_vec(&self, v: &[f64]) -> Vec<f64> {
        self.view().forward_solve_vec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let m = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = m.matmul_nt(&m);
        for i in 0..n {
            *a.get_mut(i, i) += n as f64;
        }
        a
    }

    #[test]
    fn chol_reconstructs() {
        let mut rng = Rng::seed(7);
        let a = random_spd(8, &mut rng);
        let c = Cholesky::new(&a).unwrap();
        let rec = c.l().matmul_nt(c.l());
        assert!(rec.approx_eq(&a, 1e-9), "max diff {}", rec.sub(&a).max_abs());
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::seed(3);
        let a = random_spd(6, &mut rng);
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let x = Cholesky::new(&a).unwrap().solve_vec(&b);
        let ax = a.matvec(&x);
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut rng = Rng::seed(11);
        let a = random_spd(5, &mut rng);
        let inv = Cholesky::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).approx_eq(&Mat::eye(5), 1e-9));
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let ld = Cholesky::new(&a).unwrap().logdet();
        assert!((ld - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig -1, 3
        assert!(Cholesky::new(&a).is_err());
        assert!(Cholesky::new_scalar(&a).is_err());
        let mut b = a.clone();
        assert!(factor_in_place(&mut b).is_err());
    }

    #[test]
    fn regularized_recovers() {
        // singular matrix: rank 1
        let a = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let (c, ridge) = Cholesky::new_regularized(&a);
        assert!(ridge > 0.0);
        assert_eq!(c.l().rows(), 2);
    }

    #[test]
    fn solve_mat_matches_vec() {
        let mut rng = Rng::seed(5);
        let a = random_spd(4, &mut rng);
        let b = Mat::from_fn(4, 3, |_, _| rng.normal());
        let c = Cholesky::new(&a).unwrap();
        let x = c.solve_mat(&b);
        for j in 0..3 {
            let xj = c.solve_vec(&b.col(j));
            for i in 0..4 {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn prop_blocked_factor_matches_scalar() {
        // dims straddle CHOL_NB so interior and ragged panels are both
        // exercised; the blocked factor groups the trailing-update sums
        // per panel, so the match is to rounding, not bit-exact.
        crate::proptest::forall(
            1313,
            24,
            |rng| {
                let n = crate::proptest::gen_dim(rng, 1, 150);
                random_spd(n, rng)
            },
            |a| {
                let blocked = Cholesky::new(a).map_err(|e| e.to_string())?;
                let scalar = Cholesky::new_scalar(a).map_err(|e| e.to_string())?;
                let tol = 1e-11 * (1.0 + scalar.l().max_abs());
                if blocked.l().approx_eq(scalar.l(), tol) {
                    Ok(())
                } else {
                    Err(format!(
                        "blocked factor deviates by {}",
                        blocked.l().sub(scalar.l()).max_abs()
                    ))
                }
            },
        );
    }

    #[test]
    fn prop_in_place_factor_solves_with_junk_upper() {
        // factor_in_place leaves the upper triangle untouched; CholRef
        // must still solve correctly over that buffer.
        crate::proptest::forall(
            1414,
            24,
            |rng| {
                let n = crate::proptest::gen_dim(rng, 1, 90);
                let a = random_spd(n, rng);
                let b: Vec<f64> = rng.normal_vec(n);
                (a, b)
            },
            |(a, b)| {
                let mut f = a.clone();
                factor_in_place(&mut f).map_err(|e| e.to_string())?;
                let mut x = b.clone();
                CholRef::new(&f).solve_vec_in_place(&mut x);
                let ax = a.matvec(&x);
                for (l, r) in ax.iter().zip(b) {
                    crate::proptest::close(*l, *r, 1e-7, "A x = b residual")?;
                }
                Ok(())
            },
        );
    }
}
