//! Cholesky decomposition and SPD solves — the workhorse behind the
//! E-step precision solve `L(u) φ = rhs`, covariance inversion, and
//! PLDA/LDA whitening.

use anyhow::{bail, Result};

use super::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix: `A = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorize. Fails (rather than silently regularizing) when `A` is
    /// not positive definite — callers that want flooring do it
    /// explicitly via [`Cholesky::new_regularized`].
    pub fn new(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            let mut d = a.get(j, j);
            for k in 0..j {
                d -= l.get(j, k) * l.get(j, k);
            }
            if d <= 0.0 || !d.is_finite() {
                bail!("matrix not positive definite at pivot {j} (d = {d:.3e})");
            }
            let dj = d.sqrt();
            l.set(j, j, dj);
            for i in (j + 1)..n {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s / dj);
            }
        }
        Ok(Self { l })
    }

    /// Factorize with a diagonal ridge added until the factorization
    /// succeeds (used on accumulated covariances that may be rank
    /// deficient early in EM). Returns the factor and the ridge used.
    pub fn new_regularized(a: &Mat) -> (Self, f64) {
        let mut ridge = 0.0;
        let scale = a.trace().abs().max(1e-10) / a.rows() as f64;
        loop {
            let mut m = a.clone();
            if ridge > 0.0 {
                for i in 0..m.rows() {
                    *m.get_mut(i, i) += ridge;
                }
            }
            if let Ok(c) = Self::new(&m) {
                return (c, ridge);
            }
            ridge = if ridge == 0.0 { scale * 1e-10 } else { ridge * 10.0 };
            assert!(ridge.is_finite(), "regularization diverged");
        }
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut y = b.to_vec();
        self.solve_vec_in_place(&mut y);
        y
    }

    /// Solve `A x = b` in place (no allocation) — the hot-path variant
    /// used by the batched E-step workspaces.
    pub fn solve_vec_in_place(&self, y: &mut [f64]) {
        let n = self.l.rows();
        assert_eq!(y.len(), n);
        // forward: L y = b
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l.get(i, k) * y[k];
            }
            y[i] /= self.l.get(i, i);
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                y[i] -= self.l.get(k, i) * y[k];
            }
            y[i] /= self.l.get(i, i);
        }
    }

    /// Solve `A X = B` column-block right-hand side.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut x = Mat::zeros(n, b.cols());
        // Solve per column (column extraction cost is negligible at our sizes).
        for j in 0..b.cols() {
            let col = self.solve_vec(&b.col(j));
            x.set_col(j, &col);
        }
        x
    }

    /// `A⁻¹` (SPD inverse).
    pub fn inverse(&self) -> Mat {
        let mut inv = Mat::zeros(self.l.rows(), self.l.rows());
        self.inverse_into(&mut inv);
        inv
    }

    /// `out = A⁻¹` into a caller-owned buffer, solving per unit column
    /// with one reused scratch vector (the workspace-friendly variant).
    pub fn inverse_into(&self, out: &mut Mat) {
        let n = self.l.rows();
        assert_eq!((out.rows(), out.cols()), (n, n), "inverse_into out dims");
        let mut col = vec![0.0; n];
        for j in 0..n {
            col.fill(0.0);
            col[j] = 1.0;
            self.solve_vec_in_place(&mut col);
            out.set_col(j, &col);
        }
        out.symmetrize();
    }

    /// `log |A|`.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Solve `L z = v` (forward substitution only) — used for whitening
    /// with the covariance factor: `z = L⁻¹ v`.
    pub fn forward_solve_vec(&self, v: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(v.len(), n);
        let mut z = v.to_vec();
        for i in 0..n {
            for k in 0..i {
                z[i] -= self.l.get(i, k) * z[k];
            }
            z[i] /= self.l.get(i, i);
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let m = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = m.matmul_nt(&m);
        for i in 0..n {
            *a.get_mut(i, i) += n as f64;
        }
        a
    }

    #[test]
    fn chol_reconstructs() {
        let mut rng = Rng::seed(7);
        let a = random_spd(8, &mut rng);
        let c = Cholesky::new(&a).unwrap();
        let rec = c.l().matmul_nt(c.l());
        assert!(rec.approx_eq(&a, 1e-9), "max diff {}", rec.sub(&a).max_abs());
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::seed(3);
        let a = random_spd(6, &mut rng);
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let x = Cholesky::new(&a).unwrap().solve_vec(&b);
        let ax = a.matvec(&x);
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut rng = Rng::seed(11);
        let a = random_spd(5, &mut rng);
        let inv = Cholesky::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).approx_eq(&Mat::eye(5), 1e-9));
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let ld = Cholesky::new(&a).unwrap().logdet();
        assert!((ld - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig -1, 3
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn regularized_recovers() {
        // singular matrix: rank 1
        let a = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let (c, ridge) = Cholesky::new_regularized(&a);
        assert!(ridge > 0.0);
        assert_eq!(c.l().rows(), 2);
    }

    #[test]
    fn solve_mat_matches_vec() {
        let mut rng = Rng::seed(5);
        let a = random_spd(4, &mut rng);
        let b = Mat::from_fn(4, 3, |_, _| rng.normal());
        let c = Cholesky::new(&a).unwrap();
        let x = c.solve_mat(&b);
        for j in 0..3 {
            let xj = c.solve_vec(&b.col(j));
            for i in 0..4 {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-12);
            }
        }
    }
}
