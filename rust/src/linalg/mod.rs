//! Dense linear algebra substrate (f64, row-major).
//!
//! No linear-algebra crates are available in the offline build, so the
//! model math (EM updates, minimum-divergence whitening, Householder
//! reflections, LDA/PLDA) runs on this hand-written kernel set:
//! [`Mat`] plus Cholesky / LU solves and a Jacobi symmetric
//! eigendecomposition. Model math is f64 throughout; the [`mod@f32`]
//! submodule holds the single-precision mirror kernels ([`MatF32`])
//! used by the mixed-precision alignment scoring path and the
//! [`crate::runtime`] device boundary, with [`f32::narrow`] /
//! [`f32::widen`] as the one sanctioned conversion idiom.

mod mat;
mod chol;
mod lu;
mod eig;
pub mod f32;
mod sympack;
mod vecops;

pub use chol::{factor_in_place, factor_in_place_regularized, CholRef, Cholesky};
pub use eig::{jacobi_eigh, EigH};
pub use lu::Lu;
pub use mat::Mat;
pub use self::f32::{dot_f32, MatF32};
pub use sympack::{sym_pack, sym_pack_into, sym_packed_len, sym_unpack_eye_into, sym_weighted_sum};
pub use vecops::{axpy, dot, norm2, normalize, outer, scale_in_place};

/// Householder reflection `P = I - 2 a aᵀ` applied to a matrix from the
/// left: `P · M`, without materializing `P` (paper eq. 8).
pub fn householder_apply_left(a: &[f64], m: &Mat) -> Mat {
    assert_eq!(a.len(), m.rows());
    // P M = M - 2 a (aᵀ M)
    let mut atm = vec![0.0; m.cols()];
    for i in 0..m.rows() {
        let ai = a[i];
        if ai != 0.0 {
            let row = m.row(i);
            for (j, &mij) in row.iter().enumerate() {
                atm[j] += ai * mij;
            }
        }
    }
    let mut out = m.clone();
    for i in 0..m.rows() {
        let c = 2.0 * a[i];
        let row = out.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= c * atm[j];
        }
    }
    out
}

/// Householder reflection applied to a vector: `P v = v - 2 a (aᵀ v)`.
pub fn householder_apply_vec(a: &[f64], v: &[f64]) -> Vec<f64> {
    let av = dot(a, v);
    v.iter().zip(a).map(|(&vi, &ai)| vi - 2.0 * ai * av).collect()
}

/// The Householder direction of paper eqs. (10)–(11): given the whitened
/// mean direction `h_tilde` (unit length), returns the unit vector `a`
/// such that `(I - 2aaᵀ) h_tilde = ±e₁`.
pub fn householder_direction(h_tilde: &[f64]) -> Vec<f64> {
    let r = h_tilde.len();
    // alpha = 1/sqrt(2(1 - h~[1])), beta = -alpha   (paper eq. 11)
    let h1 = h_tilde[0];
    if (1.0 - h1).abs() < 1e-12 {
        // h_tilde is already e1: any reflection fixing e1 works; use a = 0
        // (caller treats zero vector as the identity reflection).
        return vec![0.0; r];
    }
    let alpha = 1.0 / (2.0 * (1.0 - h1)).sqrt();
    let beta = -alpha;
    let mut a: Vec<f64> = h_tilde.iter().map(|&x| alpha * x).collect();
    a[0] += beta;
    // normalize defensively (analytically already unit length)
    normalize(&mut a);
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn householder_maps_h_to_e1() {
        let h = [0.6, 0.0, 0.8];
        let a = householder_direction(&h);
        let r = householder_apply_vec(&a, &h);
        assert!((r[0].abs() - 1.0).abs() < 1e-12, "{r:?}");
        assert!(r[1].abs() < 1e-12 && r[2].abs() < 1e-12, "{r:?}");
    }

    #[test]
    fn householder_is_involution() {
        let h = {
            let mut v = vec![0.3, -0.5, 0.2, 0.7];
            normalize(&mut v);
            v
        };
        let a = householder_direction(&h);
        let once = householder_apply_vec(&a, &[1.0, 2.0, 3.0, 4.0]);
        let twice = householder_apply_vec(&a, &once);
        for (x, y) in twice.iter().zip([1.0, 2.0, 3.0, 4.0]) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn householder_identity_case() {
        let h = [1.0, 0.0, 0.0];
        let a = householder_direction(&h);
        assert!(a.iter().all(|&x| x == 0.0));
        let v = householder_apply_vec(&a, &[3.0, 1.0, -2.0]);
        assert_eq!(v, vec![3.0, 1.0, -2.0]);
    }

    #[test]
    fn householder_left_matches_explicit() {
        let h = {
            let mut v = vec![0.3, -0.5, 0.2];
            normalize(&mut v);
            v
        };
        let a = householder_direction(&h);
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        // explicit P
        let mut p = Mat::eye(3);
        for i in 0..3 {
            for j in 0..3 {
                *p.get_mut(i, j) -= 2.0 * a[i] * a[j];
            }
        }
        let want = p.matmul(&m);
        let got = householder_apply_left(&a, &m);
        assert!(want.approx_eq(&got, 1e-12));
    }
}
