//! The dense row-major f64 matrix type.

/// Dense row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// From an owned buffer (row-major).
    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec size mismatch");
        Self { rows, cols, data }
    }

    /// From row slices (tests / small fixtures).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build elementwise.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m.data[i * d.len() + i] = x;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        *self.get_mut(i, j) = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self.set(i, j, x);
        }
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix product `self · other` (ikj loop order, cache-friendly).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * n..(k + 1) * n];
                for j in 0..n {
                    out_row[j] += aik * b_row[j];
                }
            }
        }
        out
    }

    /// `self · otherᵀ` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt dims");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                out.data[i * other.rows + j] = super::dot(a_row, other.row(j));
            }
        }
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn dims");
        let mut out = Mat::zeros(self.cols, other.cols);
        let n = other.cols;
        for k in 0..self.rows {
            let a_row = self.row(k);
            let b_row = other.row(k);
            for (i, &aki) in a_row.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    out_row[j] += aki * b_row[j];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec dims");
        (0..self.rows).map(|i| super::dot(self.row(i), v)).collect()
    }

    /// `selfᵀ · v`.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t dims");
        let mut out = vec![0.0; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            for (j, &mij) in self.row(i).iter().enumerate() {
                out[j] += vi * mij;
            }
        }
        out
    }

    /// Elementwise `self += alpha * other`.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat::from_vec(data, self.rows, self.cols)
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat::from_vec(data, self.rows, self.cols)
    }

    /// Force exact symmetry: `(M + Mᵀ)/2` in place (square only).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, m);
                self.set(j, i, m);
            }
        }
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a - b| comparison.
    pub fn approx_eq(&self, other: &Mat, tol: f64) -> bool {
        (self.rows, self.cols) == (other.rows, other.cols)
            && self.data.iter().zip(&other.data).all(|(a, b)| (a - b).abs() <= tol)
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!(a.matmul(&Mat::eye(2)).approx_eq(&a, 0.0));
        assert!(Mat::eye(2).matmul(&a).approx_eq(&a, 0.0));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(&[&[7.0, 8.0], &[9.0, 10.0], &[11.0, 12.0]]);
        let c = a.matmul(&b);
        assert!(c.approx_eq(&Mat::from_rows(&[&[58.0, 64.0], &[139.0, 154.0]]), 1e-12));
    }

    #[test]
    fn matmul_variants_agree() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.37 - 1.0);
        let b = Mat::from_fn(3, 5, |i, j| (i as f64 - j as f64) * 0.21);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_nt(&b.t());
        let c2 = a.t().matmul_tn(&b);
        assert!(c0.approx_eq(&c1, 1e-12));
        assert!(c0.approx_eq(&c2, 1e-12));
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f64);
        let v = [1.0, -1.0, 2.0, 0.5];
        let got = a.matvec(&v);
        let vm = Mat::from_vec(v.to_vec(), 4, 1);
        let want = a.matmul(&vm);
        for i in 0..3 {
            assert!((got[i] - want.get(i, 0)).abs() < 1e-12);
        }
        let got_t = a.matvec_t(&[1.0, 2.0, 3.0]);
        let want_t = a.t().matvec(&[1.0, 2.0, 3.0]);
        for (x, y) in got_t.iter().zip(&want_t) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 7 + j) as f64);
        assert!(a.t().t().approx_eq(&a, 0.0));
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        a.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
        }
    }
}
