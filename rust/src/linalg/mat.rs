//! The dense row-major f64 matrix type.

/// k-panel height for the blocked matmul: the panel of `B` rows kept
/// hot while streaming the output.
const MM_KB: usize = 64;
/// j-panel width for the blocked matmul: `MM_KB × MM_JB` f64s ≈ 128 KiB
/// of `B`, sized to stay resident in L2 across the `i` sweep.
const MM_JB: usize = 256;
/// Shared-dimension panel for the dot-product-shaped kernels
/// (`matmul_nt_into`): bounds the slice of every `other` row touched
/// per pass so the whole row panel fits in cache.
const NT_QB: usize = 512;

/// Dense row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// From an owned buffer (row-major).
    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec size mismatch");
        Self { rows, cols, data }
    }

    /// From row slices (tests / small fixtures).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build elementwise.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m.data[i * d.len() + i] = x;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        *self.get_mut(i, j) = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for (i, &x) in v.iter().enumerate() {
            self.set(i, j, x);
        }
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix product `self · other` — cache-blocked i-k-j kernel.
    ///
    /// Panels of `other` (`MM_KB` rows × `MM_JB` cols) are swept over
    /// every output row, so each panel is loaded from memory once per
    /// `i` sweep instead of once per scalar. For every output element
    /// the k-contributions are still added in ascending order, so the
    /// result is bit-identical to [`Mat::matmul_naive`].
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        let kk = self.cols;
        for kb in (0..kk).step_by(MM_KB) {
            let ke = (kb + MM_KB).min(kk);
            for jb in (0..n).step_by(MM_JB) {
                let je = (jb + MM_JB).min(n);
                for i in 0..self.rows {
                    let a_row = &self.data[i * kk..(i + 1) * kk];
                    let out_row = &mut out.data[i * n + jb..i * n + je];
                    for k in kb..ke {
                        let aik = a_row[k];
                        if aik == 0.0 {
                            continue;
                        }
                        let b_seg = &other.data[k * n + jb..k * n + je];
                        for (o, &b) in out_row.iter_mut().zip(b_seg) {
                            *o += aik * b;
                        }
                    }
                }
            }
        }
        out
    }

    /// The unblocked ikj reference kernel — kept as the equivalence
    /// oracle for the blocked [`Mat::matmul`] and as a bench baseline.
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Mat::zeros(self.rows, other.cols);
        let n = other.cols;
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (k, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * n..(k + 1) * n];
                for j in 0..n {
                    out_row[j] += aik * b_row[j];
                }
            }
        }
        out
    }

    /// `self · otherᵀ` without materializing the transpose.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.rows);
        self.matmul_nt_into(other, &mut out);
        out
    }

    /// `out = self · otherᵀ` into a caller-owned buffer (no allocation)
    /// with the shared dimension processed in cache-sized panels: the
    /// panel of `other` rows is re-read from cache, not memory, across
    /// the `self` row sweep.
    pub fn matmul_nt_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.cols, "matmul_nt dims");
        assert_eq!((out.rows, out.cols), (self.rows, other.rows), "matmul_nt_into out dims");
        out.fill(0.0);
        let q = self.cols;
        let p = other.rows;
        for qb in (0..q).step_by(NT_QB) {
            let qe = (qb + NT_QB).min(q);
            for i in 0..self.rows {
                let a_seg = &self.data[i * q + qb..i * q + qe];
                let out_row = &mut out.data[i * p..(i + 1) * p];
                for (j, o) in out_row.iter_mut().enumerate() {
                    *o += super::dot(a_seg, &other.data[j * q + qb..j * q + qe]);
                }
            }
        }
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn dims");
        let mut out = Mat::zeros(self.cols, other.cols);
        let n = other.cols;
        for k in 0..self.rows {
            let a_row = self.row(k);
            let b_row = other.row(k);
            for (i, &aki) in a_row.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    out_row[j] += aki * b_row[j];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec dims");
        (0..self.rows).map(|i| super::dot(self.row(i), v)).collect()
    }

    /// `selfᵀ · v`.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t dims");
        let mut out = vec![0.0; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            for (j, &mij) in self.row(i).iter().enumerate() {
                out[j] += vi * mij;
            }
        }
        out
    }

    /// Matrix–vector product into a caller-owned buffer (no allocation).
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(self.cols, v.len(), "matvec dims");
        assert_eq!(out.len(), self.rows, "matvec out dims");
        for (i, o) in out.iter_mut().enumerate() {
            *o = super::dot(self.row(i), v);
        }
    }

    /// Rank-1 update `self += alpha · a bᵀ` in place — the ger/syr-style
    /// kernel that replaces `outer()` temporaries in the E-step
    /// accumulators (pass `a == b` for the symmetric `φφᵀ` case).
    pub fn rank1_update(&mut self, alpha: f64, a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), self.rows, "rank1 a dim");
        assert_eq!(b.len(), self.cols, "rank1 b dim");
        for (i, &ai) in a.iter().enumerate() {
            let w = alpha * ai;
            if w == 0.0 {
                continue;
            }
            let row = self.row_mut(i);
            for (r, &bj) in row.iter_mut().zip(b) {
                *r += w * bj;
            }
        }
    }

    /// Fill every element with `v`.
    pub fn fill(&mut self, v: f64) {
        self.data.fill(v);
    }

    /// Elementwise `self += alpha * other`.
    pub fn add_scaled(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat::from_vec(data, self.rows, self.cols)
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat::from_vec(data, self.rows, self.cols)
    }

    /// Force exact symmetry: `(M + Mᵀ)/2` in place (square only).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, m);
                self.set(j, i, m);
            }
        }
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.get(i, i)).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a - b| comparison.
    pub fn approx_eq(&self, other: &Mat, tol: f64) -> bool {
        (self.rows, self.cols) == (other.rows, other.cols)
            && self.data.iter().zip(&other.data).all(|(a, b)| (a - b).abs() <= tol)
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!(a.matmul(&Mat::eye(2)).approx_eq(&a, 0.0));
        assert!(Mat::eye(2).matmul(&a).approx_eq(&a, 0.0));
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Mat::from_rows(&[&[7.0, 8.0], &[9.0, 10.0], &[11.0, 12.0]]);
        let c = a.matmul(&b);
        assert!(c.approx_eq(&Mat::from_rows(&[&[58.0, 64.0], &[139.0, 154.0]]), 1e-12));
    }

    #[test]
    fn matmul_variants_agree() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.37 - 1.0);
        let b = Mat::from_fn(3, 5, |i, j| (i as f64 - j as f64) * 0.21);
        let c0 = a.matmul(&b);
        let c1 = a.matmul_nt(&b.t());
        let c2 = a.t().matmul_tn(&b);
        assert!(c0.approx_eq(&c1, 1e-12));
        assert!(c0.approx_eq(&c2, 1e-12));
    }

    #[test]
    fn matvec_agrees_with_matmul() {
        let a = Mat::from_fn(3, 4, |i, j| (i + j) as f64);
        let v = [1.0, -1.0, 2.0, 0.5];
        let got = a.matvec(&v);
        let vm = Mat::from_vec(v.to_vec(), 4, 1);
        let want = a.matmul(&vm);
        for i in 0..3 {
            assert!((got[i] - want.get(i, 0)).abs() < 1e-12);
        }
        let got_t = a.matvec_t(&[1.0, 2.0, 3.0]);
        let want_t = a.t().matvec(&[1.0, 2.0, 3.0]);
        for (x, y) in got_t.iter().zip(&want_t) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn prop_blocked_matmul_matches_naive() {
        // dims straddle the panel sizes so the blocked kernel exercises
        // both interior and ragged panels; the k-order is preserved per
        // output element, so the match is exact, not approximate.
        crate::proptest::forall(
            707,
            24,
            |rng| {
                let m = crate::proptest::gen_dim(rng, 1, 90);
                let k = crate::proptest::gen_dim(rng, 1, 150);
                let n = crate::proptest::gen_dim(rng, 1, 300);
                let a = crate::proptest::gen_mat(rng, m, k, 1.0);
                let b = crate::proptest::gen_mat(rng, k, n, 1.0);
                (a, b)
            },
            |(a, b)| {
                let blocked = a.matmul(b);
                let naive = a.matmul_naive(b);
                if blocked.approx_eq(&naive, 0.0) {
                    Ok(())
                } else {
                    Err(format!("blocked deviates by {}", blocked.sub(&naive).max_abs()))
                }
            },
        );
    }

    #[test]
    fn prop_matmul_nt_into_matches_matmul() {
        crate::proptest::forall(
            808,
            24,
            |rng| {
                let m = crate::proptest::gen_dim(rng, 1, 20);
                let q = crate::proptest::gen_dim(rng, 1, 700); // straddles NT_QB
                let p = crate::proptest::gen_dim(rng, 1, 20);
                let a = crate::proptest::gen_mat(rng, m, q, 1.0);
                let b = crate::proptest::gen_mat(rng, p, q, 1.0);
                (a, b)
            },
            |(a, b)| {
                let mut out = Mat::zeros(a.rows(), b.rows());
                a.matmul_nt_into(b, &mut out);
                let want = a.matmul(&b.t());
                if out.approx_eq(&want, 1e-9 * (1.0 + want.max_abs())) {
                    Ok(())
                } else {
                    Err(format!("deviates by {}", out.sub(&want).max_abs()))
                }
            },
        );
    }

    #[test]
    fn rank1_update_matches_outer() {
        let a = [1.0, 0.0, -2.0];
        let b = [3.0, 4.0];
        let mut m = Mat::from_fn(3, 2, |i, j| (i + j) as f64);
        let mut want = m.clone();
        want.add_scaled(0.5, &crate::linalg::outer(&a, &b));
        m.rank1_update(0.5, &a, &b);
        assert!(m.approx_eq(&want, 1e-15));
    }

    #[test]
    fn matvec_into_matches_matvec() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.7 - 1.0);
        let v = [0.5, -1.0, 2.0];
        let mut out = [0.0; 4];
        a.matvec_into(&v, &mut out);
        assert_eq!(out.to_vec(), a.matvec(&v));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 7 + j) as f64);
        assert!(a.t().t().approx_eq(&a, 0.0));
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let mut a = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        a.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(a.get(i, j), a.get(j, i));
            }
        }
    }
}
