//! Tiny `--flag value` / `--switch` parser (clap is unavailable offline).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed flag map with typed accessors and unknown-flag detection at
/// access time (commands declare what they read; leftovers are reported
/// by [`Args::finish`]).
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `--key value` pairs and bare `--switch`es.
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument `{tok}`");
            };
            if name.is_empty() {
                bail!("bare `--` is not supported");
            }
            // --key=value form
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
                i += 1;
                continue;
            }
            // --key value form (value must not look like a flag)
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                switches.push(name.to_string());
                i += 1;
            }
        }
        Ok(Self { flags, switches, consumed: Default::default() })
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<String> {
        self.get(key).ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Typed flag with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|e| anyhow!("--{key} `{s}`: {e}")),
        }
    }

    /// Bare switch presence (e.g. `--cpu-ref`).
    pub fn switch(&self, key: &str) -> bool {
        self.mark(key);
        self.switches.iter().any(|s| s == key)
    }

    /// Error on any flag the command never read (catches typos).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !consumed.iter().any(|c| c == *k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flag(s): {}", unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_switches() {
        let a = Args::parse(&sv(&["--iters", "25", "--cpu-ref", "--seed=7"])).unwrap();
        assert_eq!(a.get_parse_or("iters", 0usize).unwrap(), 25);
        assert_eq!(a.get_parse_or("seed", 0u64).unwrap(), 7);
        assert!(a.switch("cpu-ref"));
        assert!(!a.switch("accel"));
        a.finish().unwrap();
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["train"])).is_err());
    }

    #[test]
    fn unknown_flags_reported() {
        let a = Args::parse(&sv(&["--oops", "1"])).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn require_missing() {
        let a = Args::parse(&sv(&[])).unwrap();
        assert!(a.require("config").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[])).unwrap();
        assert_eq!(a.get_or("work", "./work"), "./work");
        assert_eq!(a.get_parse_or("batch", 256usize).unwrap(), 256);
    }
}
