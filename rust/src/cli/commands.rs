//! CLI command implementations. Each command is a thin wrapper over the
//! library: parse flags → load config → call into the pipeline stages.

use anyhow::Result;

use super::Args;

/// `smoke --hlo PATH [--inputs 2x3:f32,4:i32]` — compile + run an HLO
/// artifact with zero-filled inputs; prints output shapes. Diagnostic for
/// the AOT bridge.
pub fn smoke(args: &Args) -> Result<()> {
    let path = args.require("hlo")?;
    let spec_str = args.get_or("inputs", "");
    args.finish()?;
    let specs: Vec<(Vec<usize>, &str)> = spec_str
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            let (dims, ty) = s.split_once(':').unwrap_or((s, "f32"));
            let shape = dims
                .split('x')
                .filter(|d| !d.is_empty())
                .map(|d| d.parse().expect("bad dim"))
                .collect();
            (shape, if ty == "i32" { "i32" } else { "f32" })
        })
        .collect();
    let outs = crate::runtime::smoke_run(&path, &specs)?;
    for (i, t) in outs.iter().enumerate() {
        println!("output[{i}]: shape={:?}", t.shape());
    }
    println!("smoke OK ({} outputs)", outs.len());
    Ok(())
}

/// `synth` — generate the synthetic corpus (features + speaker labels).
pub fn synth(args: &Args) -> Result<()> {
    crate::coordinator::stages::synth(args)
}

/// `train-ubm` — train the diagonal + full-covariance UBM.
pub fn train_ubm(args: &Args) -> Result<()> {
    crate::coordinator::stages::train_ubm(args)
}

/// `align` — compute pruned frame posteriors for the corpus.
pub fn align(args: &Args) -> Result<()> {
    crate::coordinator::stages::align(args)
}

/// `train` — train the i-vector extractor (one variant / seed).
pub fn train(args: &Args) -> Result<()> {
    crate::coordinator::stages::train(args)
}

/// `extract` — extract i-vectors for a dataset with a trained model.
pub fn extract(args: &Args) -> Result<()> {
    crate::coordinator::stages::extract(args)
}

/// `backend` — train the LDA+PLDA backend.
pub fn backend(args: &Args) -> Result<()> {
    crate::coordinator::stages::backend(args)
}

/// `eval` — score the trial list and print EER / minDCF.
pub fn eval(args: &Args) -> Result<()> {
    crate::coordinator::stages::eval(args)
}

/// `pipeline` — run every stage end-to-end.
pub fn pipeline(args: &Args) -> Result<()> {
    crate::coordinator::stages::pipeline(args)
}
