//! CLI command implementations. Each command is a thin wrapper over the
//! library: parse flags → load config → call into the pipeline stages
//! (or, for the serving commands, into [`crate::serve`]).

use anyhow::Result;

use crate::config::Config;
use crate::frontend::synth::TrafficGen;
use crate::metrics::Stopwatch;
use crate::serve::bench::{
    run_batched_vs_unbatched, run_verify_load, tiny_serve_config, train_tiny_bundle,
    write_bench2_json, ServeBenchOpts, ServeBenchReport,
};
use crate::serve::{Engine, ModelBundle};

use super::Args;

/// `smoke --hlo PATH [--inputs 2x3:f32,4:i32]` — compile + run an HLO
/// artifact with zero-filled inputs; prints output shapes. Diagnostic for
/// the AOT bridge.
pub fn smoke(args: &Args) -> Result<()> {
    let path = args.require("hlo")?;
    let spec_str = args.get_or("inputs", "");
    args.finish()?;
    let specs: Vec<(Vec<usize>, &str)> = spec_str
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            let (dims, ty) = s.split_once(':').unwrap_or((s, "f32"));
            let shape = dims
                .split('x')
                .filter(|d| !d.is_empty())
                .map(|d| d.parse().expect("bad dim"))
                .collect();
            (shape, if ty == "i32" { "i32" } else { "f32" })
        })
        .collect();
    let outs = crate::runtime::smoke_run(&path, &specs)?;
    for (i, t) in outs.iter().enumerate() {
        println!("output[{i}]: shape={:?}", t.shape());
    }
    println!("smoke OK ({} outputs)", outs.len());
    Ok(())
}

/// `synth` — generate the synthetic corpus (features + speaker labels).
pub fn synth(args: &Args) -> Result<()> {
    crate::coordinator::stages::synth(args)
}

/// `train-ubm` — train the diagonal + full-covariance UBM.
pub fn train_ubm(args: &Args) -> Result<()> {
    crate::coordinator::stages::train_ubm(args)
}

/// `align` — compute pruned frame posteriors for the corpus.
pub fn align(args: &Args) -> Result<()> {
    crate::coordinator::stages::align(args)
}

/// `train` — train the i-vector extractor (one variant / seed).
pub fn train(args: &Args) -> Result<()> {
    crate::coordinator::stages::train(args)
}

/// `extract` — extract i-vectors for a dataset with a trained model.
pub fn extract(args: &Args) -> Result<()> {
    crate::coordinator::stages::extract(args)
}

/// `backend` — train the LDA+PLDA backend.
pub fn backend(args: &Args) -> Result<()> {
    crate::coordinator::stages::backend(args)
}

/// `eval` — score the trial list and print EER / minDCF.
pub fn eval(args: &Args) -> Result<()> {
    crate::coordinator::stages::eval(args)
}

/// `pipeline` — run every stage end-to-end.
pub fn pipeline(args: &Args) -> Result<()> {
    crate::coordinator::stages::pipeline(args)
}

/// `bundle` — assemble the serving model bundle from stage artifacts.
pub fn bundle(args: &Args) -> Result<()> {
    crate::coordinator::stages::bundle(args)
}

fn print_load_report(name: &str, r: &ServeBenchReport) {
    println!(
        "{name}: {}/{} requests completed @ {} clients in {:.2}s = {:.0} req/s | \
         p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms | mean batch {:.2} | \
         shed {} timeout {} | queue depth max {} mean {:.1} | \
         score target {:.2} vs impostor {:.2}",
        r.completed_requests,
        r.requests,
        r.concurrency,
        r.wall_s,
        r.throughput_rps,
        r.verify.p50_s * 1e3,
        r.verify.p95_s * 1e3,
        r.verify.p99_s * 1e3,
        r.mean_batch,
        r.shed_requests,
        r.timed_out_requests,
        r.queue_depth_max,
        r.queue_depth_mean,
        r.target_mean,
        r.impostor_mean,
    );
}

/// `verify` — enroll/verify synthetic traffic against a trained bundle
/// through the serving engine (the online counterpart of `eval`).
pub fn verify(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => Config::load(&path)?,
        None => Config::default_scaled(),
    };
    let work = args.get_or("work", "./work");
    let speakers = args.get_parse_or("speakers", 4usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 3usize)?;
    let trials = args.get_parse_or("trials", 64usize)?;
    let concurrency = args.get_parse_or("concurrency", 4usize)?;
    let seed = args.get_parse_or("seed", 7u64)?;
    let save_registry = args.get("save-registry");
    args.finish()?;

    let bundle = ModelBundle::load_auto(&work, &cfg)?;
    let engine = Engine::new(bundle, &cfg.serve)?;
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed);
    let report = run_verify_load(
        &engine,
        &traffic,
        &ServeBenchOpts { speakers, enroll_utts, requests: trials, concurrency },
    )?;
    print_load_report("verify", &report);
    if let Some(path) = save_registry {
        engine.registry().save(&path)?;
        println!("registry: {} speakers -> {path}", engine.registry().len());
    }
    Ok(())
}

/// `serve-bench` — sustained verify load against an engine (trained
/// tiny bundle in-process, or a `--work` dir's bundle), micro-batching
/// on vs off; writes the `BENCH_2.json` serving report plus the
/// `BENCH_4.json` f32-vs-f64 alignment kernel comparison.
/// `--precision {f32,f64}` overrides `[align] precision` so the two
/// alignment paths can be A/B'd under the same load harness (all
/// shed/timeout/queue-depth counters stay in the report).
pub fn serve_bench(args: &Args) -> Result<()> {
    let work = args.get("work");
    // precedence: explicit --config; else the default pipeline config
    // when loading a --work bundle (matching how it was trained); else
    // the tiny config for the in-process bundle
    let mut cfg = match (args.get("config"), &work) {
        (Some(path), _) => Config::load(&path)?,
        (None, Some(_)) => Config::default_scaled(),
        (None, None) => tiny_serve_config(),
    };
    let requests = args.get_parse_or("requests", 1500usize)?;
    let concurrency = args.get_parse_or("concurrency", 8usize)?;
    let speakers = args.get_parse_or("speakers", 8usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 2usize)?;
    let seed = args.get_parse_or("seed", 42u64)?;
    let out = args.get_or("out", "BENCH_2.json");
    let bench4_out = args.get_or("bench4-out", "BENCH_4.json");
    let batched_only = args.switch("batched-only");
    if let Some(p) = args.get("precision") {
        let p = crate::gmm::AlignPrecision::parse(&p)?;
        cfg.align.precision = p;
        cfg.serve.precision = p;
    }
    args.finish()?;

    let sw = Stopwatch::start();
    let bundle = match &work {
        Some(w) => ModelBundle::load_auto(w, &cfg)?,
        None => {
            println!("serve-bench: no --work given — training a tiny in-process bundle");
            train_tiny_bundle(&cfg, seed)?
        }
    };
    println!(
        "bundle ready in {:.1}s (C={} F={} R={}, align precision {})",
        sw.elapsed_s(),
        bundle.tvm.num_components(),
        bundle.tvm.feat_dim(),
        bundle.tvm.rank(),
        cfg.serve.precision,
    );
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed ^ 0xBEEF);

    // kernel-level f32-vs-f64 alignment comparison on this bundle's UBM
    // (same harness run as the load replay) → BENCH_4.json
    {
        let sample = traffic.utterance(0, 0);
        let n = 1024;
        let frames = crate::linalg::Mat::from_fn(n, sample.cols(), |t, j| {
            sample.get(t % sample.rows(), j)
        });
        let pb = crate::bench_util::bench_align_precision(
            &bundle.diag,
            &bundle.full,
            &frames,
            bundle.top_k,
            bundle.min_post,
            1,
            3,
        );
        println!(
            "-> alignment {:.0} frames/s f32 vs {:.0} f64 ({:.2}x)",
            pb.frames_per_s_f32(),
            pb.frames_per_s_f64(),
            pb.f32_speedup(),
        );
        crate::bench_util::write_bench4_json(&bench4_out, &pb)?;
        println!("wrote {bench4_out}");
    }

    let opts = ServeBenchOpts { speakers, enroll_utts, requests, concurrency };

    let mut reports: Vec<(&str, ServeBenchReport)> = Vec::new();
    if batched_only {
        let engine = Engine::new(bundle, &cfg.serve)?;
        let report = run_verify_load(&engine, &traffic, &opts)?;
        print_load_report("serve-bench[batched]", &report);
        reports.push(("batched", report));
    } else {
        let (batched, unbatched) = run_batched_vs_unbatched(bundle, &cfg.serve, &traffic, &opts)?;
        print_load_report("serve-bench[batched]", &batched);
        print_load_report("serve-bench[unbatched]", &unbatched);
        reports.push(("batched", batched));
        reports.push(("unbatched", unbatched));
    }
    let refs: Vec<(&str, &ServeBenchReport)> =
        reports.iter().map(|(name, r)| (*name, r)).collect();
    write_bench2_json(&out, &refs)?;
    println!("wrote {out}");
    Ok(())
}
