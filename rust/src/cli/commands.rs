//! CLI command implementations. Each command is a thin wrapper over the
//! library: parse flags → load config → call into the pipeline stages
//! (or, for the serving commands, into [`crate::serve`]).

use std::sync::Arc;

use anyhow::Result;

use crate::bench_util::{latency_drift_row, write_bench_json, LatencyTriple};
use crate::config::{Config, WalSync};
use crate::frontend::synth::TrafficGen;
use crate::metrics::{LatencySummary, Stopwatch};
use crate::obs::{Json, ObsRegistry, RenderFormat};
use crate::serve::capture::{
    replay_corpus, run_capture_overhead, CaptureLog, CaptureSummary, Recorder, RecorderOptions,
    ReplayOptions,
};
use crate::serve::bench::{
    run_batched_vs_unbatched, run_streaming_vs_oneshot, run_verify_load, tiny_serve_config,
    train_tiny_bundle, write_bench2_json, write_bench8_json, ServeBenchOpts, ServeBenchReport,
    StreamBenchOpts, StreamBenchReport,
};
use crate::serve::cluster::bench::{
    cluster_bench_config, run_cluster_load, saturation_serve_config, write_bench5_json,
    ClusterBenchOpts, ClusterBenchReport,
};
use crate::serve::cluster::chaos::{
    chaos_health_config, chaos_serve_config, poisoning_storage, run_chaos_drill,
    write_bench9_json, ChaosOpts,
};
use crate::serve::registry::bench::{
    run_registry_bench, write_bench6_json, RegistryBenchOpts,
};
use crate::serve::registry::{FileStorage, MemStorage, RegistryStorage};
use crate::serve::{
    Dispatcher, DurableRegistry, DurableRegistryOptions, Engine, ModelBundle, Registry,
};

use super::Args;

/// `smoke --hlo PATH [--inputs 2x3:f32,4:i32]` — compile + run an HLO
/// artifact with zero-filled inputs; prints output shapes. Diagnostic for
/// the AOT bridge.
pub fn smoke(args: &Args) -> Result<()> {
    let path = args.require("hlo")?;
    let spec_str = args.get_or("inputs", "");
    args.finish()?;
    let specs: Vec<(Vec<usize>, &str)> = spec_str
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            let (dims, ty) = s.split_once(':').unwrap_or((s, "f32"));
            let shape = dims
                .split('x')
                .filter(|d| !d.is_empty())
                .map(|d| d.parse().expect("bad dim"))
                .collect();
            (shape, if ty == "i32" { "i32" } else { "f32" })
        })
        .collect();
    let outs = crate::runtime::smoke_run(&path, &specs)?;
    for (i, t) in outs.iter().enumerate() {
        println!("output[{i}]: shape={:?}", t.shape());
    }
    println!("smoke OK ({} outputs)", outs.len());
    Ok(())
}

/// `synth` — generate the synthetic corpus (features + speaker labels).
pub fn synth(args: &Args) -> Result<()> {
    crate::coordinator::stages::synth(args)
}

/// `train-ubm` — train the diagonal + full-covariance UBM.
pub fn train_ubm(args: &Args) -> Result<()> {
    crate::coordinator::stages::train_ubm(args)
}

/// `align` — compute pruned frame posteriors for the corpus.
pub fn align(args: &Args) -> Result<()> {
    crate::coordinator::stages::align(args)
}

/// `train` — train the i-vector extractor (one variant / seed).
pub fn train(args: &Args) -> Result<()> {
    crate::coordinator::stages::train(args)
}

/// `extract` — extract i-vectors for a dataset with a trained model.
pub fn extract(args: &Args) -> Result<()> {
    crate::coordinator::stages::extract(args)
}

/// `backend` — train the LDA+PLDA backend.
pub fn backend(args: &Args) -> Result<()> {
    crate::coordinator::stages::backend(args)
}

/// `eval` — score the trial list and print EER / minDCF.
pub fn eval(args: &Args) -> Result<()> {
    crate::coordinator::stages::eval(args)
}

/// `pipeline` — run every stage end-to-end.
pub fn pipeline(args: &Args) -> Result<()> {
    crate::coordinator::stages::pipeline(args)
}

/// `bundle` — assemble the serving model bundle from stage artifacts.
pub fn bundle(args: &Args) -> Result<()> {
    crate::coordinator::stages::bundle(args)
}

fn print_load_report(name: &str, r: &ServeBenchReport) {
    println!(
        "{name}: {}/{} requests completed @ {} clients in {:.2}s = {:.0} req/s | \
         p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms | mean batch {:.2} | \
         shed {} timeout {} | queue depth max {} mean {:.1} | \
         wal {} compactions {} torn {} | \
         score target {:.2} vs impostor {:.2}",
        r.completed_requests,
        r.requests,
        r.concurrency,
        r.wall_s,
        r.throughput_rps,
        r.verify.p50_s * 1e3,
        r.verify.p95_s * 1e3,
        r.verify.p99_s * 1e3,
        r.mean_batch,
        r.shed_requests,
        r.timed_out_requests,
        r.queue_depth_max,
        r.queue_depth_mean,
        r.wal_appends,
        r.compactions,
        r.torn_tail,
        r.target_mean,
        r.impostor_mean,
    );
}

fn print_stream_report(name: &str, r: &StreamBenchReport) {
    println!(
        "{name}: {}/{} sessions decided @ {} clients in {:.2}s = {:.0} decisions/s | \
         frames/decision {:.1} of {:.1} offered ({:.0}% early exits) | \
         thresholds accept {:.2} reject {:.2} | evicted {} shed {} rejected {} | \
         score target {:.2} vs impostor {:.2}",
        r.decided,
        r.requests,
        r.concurrency,
        r.wall_s,
        r.decisions_per_s,
        r.mean_frames_per_decision,
        r.mean_frames_available,
        r.early_exit_rate * 100.0,
        r.accept_score,
        r.reject_score,
        r.evictions,
        r.shed,
        r.rejected,
        r.target_mean,
        r.impostor_mean,
    );
}

/// One aligned row per stage with traffic — the per-stage latency
/// breakdown every serving command prints under its headline.
fn print_stage_rows(stages: &[(&'static str, LatencySummary)]) {
    for (stage, s) in stages {
        if s.count > 0 {
            println!(
                "  stage {stage:<16} n {:>7}  p50 {:>9.3} ms  p95 {:>9.3} ms  \
                 p99 {:>9.3} ms  max {:>9.3} ms",
                s.count,
                s.p50_s * 1e3,
                s.p95_s * 1e3,
                s.p99_s * 1e3,
                s.max_s * 1e3,
            );
        }
    }
}

/// Export the observability registry as the JSON snapshot `stats`
/// reads (`--obs-out` on the serving bench commands).
fn write_obs_snapshot(path: &str, obs: &ObsRegistry) -> Result<()> {
    std::fs::write(path, obs.render(RenderFormat::Json))
        .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// Report what a closed capture session amounted to. A write failure
/// fails the run — a silently truncated corpus must not gate CI — and
/// drops are printed, never hidden (they mean the corpus under-samples
/// the traffic, which a `policy = "all"` replay needs to know).
fn finish_capture(
    path: &str,
    policy: crate::config::SamplePolicy,
    summary: &CaptureSummary,
) -> Result<()> {
    if let Some(err) = &summary.write_error {
        anyhow::bail!("capture {path}: write failed after {} records: {err}", summary.records);
    }
    println!(
        "capture: {} records ({} bytes) -> {path} [policy {policy}]{}",
        summary.records,
        summary.bytes,
        if summary.dropped > 0 {
            format!("  dropped {} on queue overflow", summary.dropped)
        } else {
            String::new()
        },
    );
    Ok(())
}

/// `verify` — enroll/verify synthetic traffic against a trained bundle
/// through the serving engine (the online counterpart of `eval`).
/// `--registry DIR` (or `[registry] path` in the config) puts the
/// speaker store on the durable WAL-backed backend: enrollments survive
/// a crash and are recovered on the next run.
pub fn verify(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => Config::load(&path)?,
        None => Config::default_scaled(),
    };
    let work = args.get_or("work", "./work");
    let speakers = args.get_parse_or("speakers", 4usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 3usize)?;
    let trials = args.get_parse_or("trials", 64usize)?;
    let concurrency = args.get_parse_or("concurrency", 4usize)?;
    let seed = args.get_parse_or("seed", 7u64)?;
    let save_registry = args.get("save-registry");
    let registry_dir = args.get("registry").or_else(|| cfg.registry.path.clone());
    args.finish()?;

    let bundle = ModelBundle::load_auto(&work, &cfg)?;
    let obs = Arc::new(ObsRegistry::new(&cfg.obs));
    let engine = match &registry_dir {
        Some(dir) => {
            let dopts =
                DurableRegistryOptions::from_config(&cfg.registry, cfg.serve.registry_shards);
            let durable = DurableRegistry::open_obs(dir, &dopts, Some(Arc::clone(&obs)))?;
            let rec = durable.recovery();
            println!(
                "registry: durable at {dir} — recovered {} speakers \
                 (snapshot seq {}, {} WAL records replayed{}) in {:.3}s",
                rec.speakers,
                rec.snapshot_seq,
                rec.replayed,
                if rec.torn_tail { ", torn tail truncated" } else { "" },
                rec.wall_s,
            );
            Engine::with_registry_obs(bundle, &cfg.serve, durable.handle(), Arc::clone(&obs))?
        }
        None => Engine::with_registry_obs(
            bundle,
            &cfg.serve,
            Arc::new(Registry::new(cfg.serve.registry_shards)),
            Arc::clone(&obs),
        )?,
    };
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed);
    let report = run_verify_load(
        &engine,
        &traffic,
        &ServeBenchOpts { speakers, enroll_utts, requests: trials, concurrency },
    )?;
    print_load_report("verify", &report);
    print_stage_rows(&obs.stage_summaries());
    if let Some(path) = save_registry {
        engine.registry().save(&path)?;
        println!("registry: {} speakers -> {path}", engine.registry().len());
    }
    Ok(())
}

/// `serve-bench` — sustained verify load against an engine (trained
/// tiny bundle in-process, or a `--work` dir's bundle), micro-batching
/// on vs off; writes the `BENCH_2.json` serving report plus the
/// `BENCH_4.json` f32-vs-f64 alignment kernel comparison.
/// `--precision {f32,f64}` overrides `[align] precision` so the two
/// alignment paths can be A/B'd under the same load harness (all
/// shed/timeout/queue-depth counters stay in the report).
///
/// `--streaming` switches to chunk-fed verification sessions with
/// early-exit thresholds (calibrated from oracle probes unless
/// `--accept-score`/`--reject-score` pin them; `--chunk-frames` sets
/// the feed granularity) next to a one-shot baseline over the same
/// trial plan, and writes `BENCH_8.json` instead.
pub fn serve_bench(args: &Args) -> Result<()> {
    let work = args.get("work");
    // precedence: explicit --config; else the default pipeline config
    // when loading a --work bundle (matching how it was trained); else
    // the tiny config for the in-process bundle
    let mut cfg = match (args.get("config"), &work) {
        (Some(path), _) => Config::load(&path)?,
        (None, Some(_)) => Config::default_scaled(),
        (None, None) => tiny_serve_config(),
    };
    let requests = args.get_parse_or("requests", 1500usize)?;
    let concurrency = args.get_parse_or("concurrency", 8usize)?;
    let speakers = args.get_parse_or("speakers", 8usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 2usize)?;
    let seed = args.get_parse_or("seed", 42u64)?;
    let streaming = args.switch("streaming");
    let chunk_frames = args.get_parse_or("chunk-frames", 20usize)?;
    let accept_score = args
        .get("accept-score")
        .map(|s| s.parse::<f64>())
        .transpose()
        .map_err(|e| anyhow::anyhow!("bad --accept-score: {e}"))?;
    let reject_score = args
        .get("reject-score")
        .map(|s| s.parse::<f64>())
        .transpose()
        .map_err(|e| anyhow::anyhow!("bad --reject-score: {e}"))?;
    let out = args.get_or("out", if streaming { "BENCH_8.json" } else { "BENCH_2.json" });
    let bench4_out = args.get_or("bench4-out", "BENCH_4.json");
    let obs_out = args.get_or("obs-out", "OBS_SNAPSHOT.json");
    let mut batched_only = args.switch("batched-only");
    let capture_out = args.get("capture-out");
    if let Some(p) = args.get("precision") {
        let p = crate::gmm::AlignPrecision::parse(&p)?;
        cfg.align.precision = p;
        cfg.serve.precision = p;
    }
    args.finish()?;
    if capture_out.is_some() {
        anyhow::ensure!(
            cfg.capture.enabled,
            "--capture-out given but [capture] enabled = false — refusing to write an \
             empty corpus"
        );
        anyhow::ensure!(!streaming, "--capture-out records one-shot requests, not sessions");
        if !batched_only {
            // a replay corpus must hold each request exactly once — the
            // batched-vs-unbatched A/B would record the load twice
            println!("serve-bench: --capture-out implies --batched-only");
            batched_only = true;
        }
    }

    let sw = Stopwatch::start();
    let bundle = match &work {
        Some(w) => ModelBundle::load_auto(w, &cfg)?,
        None => {
            println!("serve-bench: no --work given — training a tiny in-process bundle");
            train_tiny_bundle(&cfg, seed)?
        }
    };
    println!(
        "bundle ready in {:.1}s (C={} F={} R={}, align precision {})",
        sw.elapsed_s(),
        bundle.tvm.num_components(),
        bundle.tvm.feat_dim(),
        bundle.tvm.rank(),
        cfg.serve.precision,
    );
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed ^ 0xBEEF);

    if streaming {
        let sopts = StreamBenchOpts {
            speakers,
            enroll_utts,
            requests,
            concurrency,
            chunk_frames,
            accept_score,
            reject_score,
        };
        let (stream, oneshot, obs) =
            run_streaming_vs_oneshot(bundle, &cfg.serve, &cfg.obs, &traffic, &sopts)?;
        print_stream_report("serve-bench[streaming]", &stream);
        print_load_report("serve-bench[oneshot]", &oneshot);
        print_stage_rows(&stream.stages);
        write_bench8_json(&out, &stream, &oneshot)?;
        println!("wrote {out}");
        write_obs_snapshot(&obs_out, &obs)?;
        return Ok(());
    }

    // kernel-level f32-vs-f64 alignment comparison on this bundle's UBM
    // (same harness run as the load replay) → BENCH_4.json
    {
        let sample = traffic.utterance(0, 0);
        let n = 1024;
        let frames = crate::linalg::Mat::from_fn(n, sample.cols(), |t, j| {
            sample.get(t % sample.rows(), j)
        });
        let pb = crate::bench_util::bench_align_precision(
            &bundle.diag,
            &bundle.full,
            &frames,
            bundle.top_k,
            bundle.min_post,
            1,
            3,
        );
        println!(
            "-> alignment {:.0} frames/s f32 vs {:.0} f64 ({:.2}x)",
            pb.frames_per_s_f32(),
            pb.frames_per_s_f64(),
            pb.f32_speedup(),
        );
        crate::bench_util::write_bench4_json(&bench4_out, &pb)?;
        println!("wrote {bench4_out}");
    }

    let opts = ServeBenchOpts { speakers, enroll_utts, requests, concurrency };

    let mut reports: Vec<(&str, ServeBenchReport)> = Vec::new();
    let obs = if batched_only {
        let obs = Arc::new(ObsRegistry::new(&cfg.obs));
        let bundle_fp = bundle.fingerprint();
        let engine = Engine::with_registry_obs(
            bundle,
            &cfg.serve,
            Arc::new(Registry::new(cfg.serve.registry_shards)),
            Arc::clone(&obs),
        )?;
        let recorder = match &capture_out {
            Some(path) => {
                let log = CaptureLog::create_at_path(path, bundle_fp)?;
                let rec = Recorder::new(log, &RecorderOptions::from_config(&cfg), &obs);
                engine.set_recorder(Some(Arc::clone(&rec)));
                Some(rec)
            }
            None => None,
        };
        let report = run_verify_load(&engine, &traffic, &opts)?;
        if let (Some(rec), Some(path)) = (&recorder, &capture_out) {
            engine.set_recorder(None);
            finish_capture(path, cfg.capture.policy, &rec.close())?;
        }
        print_load_report("serve-bench[batched]", &report);
        reports.push(("batched", report));
        obs
    } else {
        let (batched, unbatched, obs) =
            run_batched_vs_unbatched(bundle, &cfg.serve, &cfg.obs, &traffic, &opts)?;
        print_load_report("serve-bench[batched]", &batched);
        print_load_report("serve-bench[unbatched]", &unbatched);
        reports.push(("batched", batched));
        reports.push(("unbatched", unbatched));
        obs
    };
    print_stage_rows(&reports[0].1.stages);
    let refs: Vec<(&str, &ServeBenchReport)> =
        reports.iter().map(|(name, r)| (*name, r)).collect();
    write_bench2_json(&out, &refs)?;
    println!("wrote {out}");
    write_obs_snapshot(&obs_out, &obs)?;
    Ok(())
}

fn print_cluster_report(name: &str, r: &ClusterBenchReport) {
    println!(
        "{name}: {} replicas ({}) | {}/{} requests completed in {:.2}s = {:.0} req/s | \
         p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms | \
         failovers {} exhausted {} | engine shed {} timeouts {} | swaps {} | \
         enrollments acked {} lost {} | wal {} compactions {} torn {} | \
         score target {:.2} vs impostor {:.2}",
        r.replicas,
        r.route,
        r.completed,
        r.requests,
        r.wall_s,
        r.throughput_rps,
        r.verify.p50_s * 1e3,
        r.verify.p95_s * 1e3,
        r.verify.p99_s * 1e3,
        r.failovers,
        r.exhausted,
        r.engine_shed,
        r.engine_timeouts,
        r.swaps,
        r.acked_enrollments,
        r.lost_enrollments,
        r.wal_appends,
        r.compactions,
        r.torn_tail,
        r.target_mean,
        r.impostor_mean,
    );
}

/// `cluster-bench` — the 1-vs-N replica scaling run behind
/// `BENCH_5.json`: replay the same saturating verify load against a
/// single-replica dispatcher and an N-replica one (same bundle, same
/// traffic), with live enrollments riding along. `--swap-mid-run`
/// rolls an identical-bundle swap through the cluster a third of the
/// way in (the report's `lost_enrollments` must stay 0);
/// `--stall-replica K` freezes one replica's workers for the load
/// phase (the run must still complete, sheds failing over). Without an
/// explicit `--config` the engines run the deliberately-saturating
/// shape of [`saturation_serve_config`] over the compute-heavy
/// [`cluster_bench_config`] bundle so the scaling headline measures
/// the dispatcher, not an idle queue. `--replicas` is clamped to ≥ 2 —
/// the bench *is* the 1-vs-N comparison, so N must exceed the
/// baseline.
pub fn cluster_bench(args: &Args) -> Result<()> {
    let work = args.get("work");
    let explicit_cfg = args.get("config");
    let mut cfg = match (&explicit_cfg, &work) {
        (Some(path), _) => Config::load(path)?,
        (None, Some(_)) => Config::default_scaled(),
        (None, None) => cluster_bench_config(),
    };
    let requests = args.get_parse_or("requests", 1200usize)?;
    let concurrency = args.get_parse_or("concurrency", 8usize)?;
    let speakers = args.get_parse_or("speakers", 8usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 2usize)?;
    let live_enroll_every = args.get_parse_or("live-enroll-every", 16usize)?;
    let seed = args.get_parse_or("seed", 42u64)?;
    let replicas = args.get_parse_or("replicas", cfg.cluster.replicas.max(2))?.max(2);
    if let Some(route) = args.get("route") {
        cfg.cluster.route = crate::config::RoutePolicy::parse(&route)?;
    }
    cfg.cluster.max_failovers =
        args.get_parse_or("max-failovers", cfg.cluster.max_failovers)?;
    let swap_mid_run = args.switch("swap-mid-run");
    let stall_replica = args
        .get("stall-replica")
        .map(|s| {
            s.parse::<usize>().map_err(|e| anyhow::anyhow!("--stall-replica `{s}`: {e}"))
        })
        .transpose()?;
    let out = args.get_or("out", "BENCH_5.json");
    let obs_out = args.get_or("obs-out", "OBS_SNAPSHOT.json");
    let capture_out = args.get("capture-out");
    args.finish()?;
    // fail the flag combination now — not after the multi-minute
    // baseline run has already been paid for
    if let Some(id) = stall_replica {
        anyhow::ensure!(
            id < replicas,
            "--stall-replica {id} out of range (cluster run has {replicas} replicas)"
        );
    }
    anyhow::ensure!(
        capture_out.is_none() || cfg.capture.enabled,
        "--capture-out given but [capture] enabled = false — refusing to write an empty corpus"
    );

    if explicit_cfg.is_none() {
        cfg.serve = saturation_serve_config(&cfg.serve);
        println!(
            "cluster-bench: saturating engine shape (workers {}, queue_cap {}, \
             flush {} µs, submit {} ms) — pass --config to override",
            cfg.serve.workers, cfg.serve.queue_cap, cfg.serve.flush_us, cfg.serve.submit_timeout_ms,
        );
    }

    let sw = Stopwatch::start();
    let bundle = match &work {
        Some(w) => ModelBundle::load_auto(w, &cfg)?,
        None => {
            println!("cluster-bench: no --work given — training a tiny in-process bundle");
            train_tiny_bundle(&cfg, seed)?
        }
    };
    println!(
        "bundle ready in {:.1}s (C={} F={} R={})",
        sw.elapsed_s(),
        bundle.tvm.num_components(),
        bundle.tvm.feat_dim(),
        bundle.tvm.rank(),
    );
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed ^ 0xC1A5);
    let base_opts = ClusterBenchOpts {
        speakers,
        enroll_utts,
        requests,
        concurrency,
        live_enroll_every,
        stall_replica: None,
    };

    // baseline: the same load against a single replica (no stall, no
    // swap — the clean denominator of the scaling ratio)
    let mut single = cfg.cluster.clone();
    single.replicas = 1;
    let d1 = Dispatcher::with_registry_obs(
        bundle.clone(),
        &cfg.serve,
        &single,
        Arc::new(Registry::new(cfg.serve.registry_shards)),
        Arc::new(ObsRegistry::new(&cfg.obs)),
    )?;
    let r1 = run_cluster_load(&d1, &traffic, &base_opts, None)?;
    print_cluster_report("cluster-bench[1 replica]", &r1);
    drop(d1);

    // the cluster run, with the optional degraded-replica and
    // rolling-swap drills — on its own obs registry so the exported
    // snapshot measures this run, not the baseline
    let mut multi = cfg.cluster.clone();
    multi.replicas = replicas;
    let dn = Dispatcher::with_registry_obs(
        bundle.clone(),
        &cfg.serve,
        &multi,
        Arc::new(Registry::new(cfg.serve.registry_shards)),
        Arc::new(ObsRegistry::new(&cfg.obs)),
    )?;
    // capture rides the N-replica run only (the scaling headline): one
    // corpus, each routed request recorded once with its failover hops
    let recorder = match &capture_out {
        Some(path) => {
            let log = CaptureLog::create_at_path(path, bundle.fingerprint())?;
            let rec = Recorder::new(log, &RecorderOptions::from_config(&cfg), dn.obs());
            dn.set_recorder(Some(Arc::clone(&rec)));
            Some(rec)
        }
        None => None,
    };
    let opts = ClusterBenchOpts { stall_replica, ..base_opts };
    let rn = run_cluster_load(&dn, &traffic, &opts, swap_mid_run.then_some(&bundle))?;
    if let (Some(rec), Some(path)) = (&recorder, &capture_out) {
        dn.set_recorder(None);
        finish_capture(path, cfg.capture.policy, &rec.close())?;
    }
    print_cluster_report(&format!("cluster-bench[{replicas} replicas]"), &rn);
    print_stage_rows(&rn.stages);
    if r1.throughput_rps > 0.0 {
        println!(
            "-> completed-throughput scaling: {:.2}x ({}-replica {:.0} req/s vs 1-replica {:.0})",
            rn.throughput_rps / r1.throughput_rps,
            replicas,
            rn.throughput_rps,
            r1.throughput_rps,
        );
    }

    write_bench5_json(
        &out,
        &[
            ("replicas_1".to_string(), &r1),
            (format!("replicas_{replicas}"), &rn),
        ],
    )?;
    println!("wrote {out}");
    write_obs_snapshot(&obs_out, dn.obs())?;
    Ok(())
}

/// `replay` — re-issue a captured corpus (`--capture`, written by
/// `serve-bench`/`cluster-bench` `--capture-out`) through a fresh
/// engine and hold the answers to what production recorded. Against
/// the same bundle (same `--work` dir, or the same-seed tiny
/// in-process bundle) every recorded verify score must reproduce to
/// `--tolerance` (default 1e-10) and every outcome class must match —
/// any mismatch exits nonzero, which is what makes this a CI gate and
/// not a smoke test. Under a *different* bundle only outcome classes
/// are compared (scores from different total-variability spaces are
/// incomparable). Also measures capture-on vs capture-off throughput
/// on the same corpus and the per-stage captured-vs-replayed latency
/// drift, and writes the whole comparison to `BENCH_10.json`.
/// `--max-speed` drops the original inter-arrival spacing.
pub fn replay(args: &Args) -> Result<()> {
    let capture = args.require("capture")?;
    let work = args.get("work");
    let mut cfg = match (args.get("config"), &work) {
        (Some(path), _) => Config::load(&path)?,
        (None, Some(_)) => Config::default_scaled(),
        (None, None) => tiny_serve_config(),
    };
    let seed = args.get_parse_or("seed", 42u64)?;
    let max_speed = args.switch("max-speed");
    let tolerance = args.get_parse_or("tolerance", 1e-10f64)?;
    let out = args.get_or("out", "BENCH_10.json");
    let obs_out = args.get("obs-out");
    if let Some(p) = args.get("precision") {
        let p = crate::gmm::AlignPrecision::parse(&p)?;
        cfg.align.precision = p;
        cfg.serve.precision = p;
    }
    args.finish()?;

    let corpus = CaptureLog::load_path(&capture)?;
    println!(
        "replay: {} records from {capture} (bundle fingerprint {:016x}{})",
        corpus.records.len(),
        corpus.fingerprint,
        if corpus.torn_tail { ", torn tail truncated" } else { "" },
    );

    let sw = Stopwatch::start();
    let bundle = match &work {
        Some(w) => ModelBundle::load_auto(w, &cfg)?,
        None => {
            // the same deterministic training serve-bench uses: the
            // same seed reproduces the same bundle, fingerprint and all
            println!("replay: no --work given — training the tiny in-process bundle (seed {seed})");
            train_tiny_bundle(&cfg, seed)?
        }
    };
    println!("bundle ready in {:.1}s", sw.elapsed_s());

    // a fresh engine on a fresh obs registry: the corpus carries its
    // own enrollments, and the stage histograms must measure only the
    // replay
    let obs = Arc::new(ObsRegistry::new(&cfg.obs));
    let engine = Engine::with_registry_obs(
        bundle,
        &cfg.serve,
        Arc::new(Registry::new(cfg.serve.registry_shards)),
        Arc::clone(&obs),
    )?;

    let report = replay_corpus(&corpus, &engine, &ReplayOptions { max_speed, tolerance })?;
    if !report.fingerprint_match {
        println!(
            "replay: serving bundle differs from the corpus's — outcome classes compared, \
             scores not checked"
        );
    }
    println!(
        "replay: {}/{} re-issued in {:.2}s ({}) | {} scores checked, max delta {:.3e} | \
         outcomes ok {} shed {} timeout {} failed {}",
        report.replayed,
        report.total,
        report.wall_s,
        if max_speed { "max speed" } else { "original inter-arrival timing" },
        report.score_checked,
        report.max_score_delta,
        report.replayed_outcomes[0],
        report.replayed_outcomes[1],
        report.replayed_outcomes[2],
        report.replayed_outcomes[3],
    );
    for d in &report.stage_drift {
        println!(
            "  {}",
            latency_drift_row(
                d.stage.as_str(),
                &LatencyTriple::from_summary(&d.captured),
                &LatencyTriple::from_summary(&d.replayed),
            )
        );
    }

    // after the verification pass (re-enrollment keeps profile means
    // intact but would inflate the counts the pass above checked)
    let overhead = run_capture_overhead(&corpus, &engine)?;
    println!(
        "-> capture overhead: {:.0} req/s off vs {:.0} on ({:+.2}%) | \
         {} records captured, {} dropped",
        overhead.off_rps(),
        overhead.on_rps(),
        overhead.overhead_pct,
        overhead.captured_records,
        overhead.capture_dropped,
    );

    write_bench_json(
        &out,
        10,
        &[
            ("replay", report.json_fragment()),
            ("stage_drift", report.drift_json()),
            ("capture_overhead", overhead.json_fragment()),
        ],
    )?;
    println!("wrote {out}");
    if let Some(path) = obs_out {
        write_obs_snapshot(&path, &obs)?;
    }
    anyhow::ensure!(
        report.mismatches() == 0,
        "replay found {} mismatch(es) ({} score, {} outcome) — the serving path no longer \
         reproduces the captured corpus",
        report.mismatches(),
        report.score_mismatches,
        report.outcome_mismatches,
    );
    println!(
        "replay OK: outcome classes match; {} scores reproduced within {tolerance:e}",
        report.score_checked,
    );
    Ok(())
}

/// `chaos-bench` — the deterministic self-healing drill behind
/// `BENCH_9.json`: replay a verify load (live enrollments riding
/// along) against an N-replica cluster over a WAL-backed registry,
/// with two scripted faults — at `--stall-at` attempted requests one
/// replica's workers freeze (and are never thawed: the supervisor's
/// quarantine → rebuild → probe cycle is the only cure), and at
/// `--wal-fault-at` durable mutations the registry storage fails an
/// append plus its rollback, poisoning the WAL into degraded
/// read-only mode until the supervisor repairs it. The run **fails**
/// (non-zero exit) on any hard error, any acked-but-lost enrollment,
/// or if either incident is not healed by run end — this command is a
/// CI gate, not just a report. Without an explicit `--config` the
/// engines run the deliberately-fragile [`chaos_serve_config`] /
/// [`chaos_health_config`] shape so the whole incident fits in
/// seconds.
pub fn chaos_bench(args: &Args) -> Result<()> {
    let work = args.get("work");
    let explicit_cfg = args.get("config");
    // the tiny corpus, not cluster-bench's compute-heavy rank-64 one:
    // the drill's 250 ms request deadline must be generous for a
    // *healthy* replica, so the only deadline-blowing replica is the
    // scripted stalled one — otherwise healthy replicas would feed
    // their own fault budgets and the incident would not be scripted
    let mut cfg = match (&explicit_cfg, &work) {
        (Some(path), _) => Config::load(path)?,
        (None, Some(_)) => Config::default_scaled(),
        (None, None) => tiny_serve_config(),
    };
    let requests = args.get_parse_or("requests", 600usize)?;
    let concurrency = args.get_parse_or("concurrency", 8usize)?;
    let speakers = args.get_parse_or("speakers", 6usize)?;
    let enroll_utts = args.get_parse_or("enroll-utts", 2usize)?;
    let live_enroll_every = args.get_parse_or("live-enroll-every", 8usize)?;
    let seed = args.get_parse_or("seed", 42u64)?;
    let replicas = args.get_parse_or("replicas", cfg.cluster.replicas.max(2))?.max(2);
    let faulty_replica = args.get_parse_or("faulty-replica", 0usize)?;
    let stall_at = args.get_parse_or("stall-at", (requests / 6).max(1))?;
    // default: the WAL fault lands a few live enrollments past the
    // deterministic up-front batch
    let up_front = (speakers * enroll_utts.max(1)) as u64;
    let wal_fault_at = args.get_parse_or("wal-fault-at", up_front + 4)?;
    let tick_ms = args.get_parse_or("tick-ms", 5u64)?;
    let settle_ms = args.get_parse_or("settle-ms", 15_000u64)?;
    let out = args.get_or("out", "BENCH_9.json");
    let obs_out = args.get_or("obs-out", "OBS_SNAPSHOT.json");
    args.finish()?;
    anyhow::ensure!(
        faulty_replica < replicas,
        "--faulty-replica {faulty_replica} out of range (cluster has {replicas} replicas)"
    );

    if explicit_cfg.is_none() {
        cfg.serve = chaos_serve_config(&cfg.serve);
        cfg.cluster.health = chaos_health_config();
        println!(
            "chaos-bench: fragile engine shape (workers {}, queue_cap {}, request \
             deadline {} ms; fault budget {}, cooldown {} ms) — pass --config to override",
            cfg.serve.workers,
            cfg.serve.queue_cap,
            cfg.serve.request_timeout_ms,
            cfg.cluster.health.fault_budget,
            cfg.cluster.health.cooldown_ms,
        );
    }
    cfg.cluster.replicas = replicas;

    let sw = Stopwatch::start();
    let bundle = match &work {
        Some(w) => ModelBundle::load_auto(w, &cfg)?,
        None => {
            println!("chaos-bench: no --work given — training a tiny in-process bundle");
            train_tiny_bundle(&cfg, seed)?
        }
    };
    println!(
        "bundle ready in {:.1}s (C={} F={} R={})",
        sw.elapsed_s(),
        bundle.tvm.num_components(),
        bundle.tvm.feat_dim(),
        bundle.tvm.rank(),
    );
    let traffic = TrafficGen::new(&cfg.corpus, speakers, seed ^ 0xC4A0);

    let obs = Arc::new(ObsRegistry::new(&cfg.obs));
    let store = MemStorage::new();
    let durable = DurableRegistry::with_storage_obs(
        Box::new(poisoning_storage(&store, wal_fault_at)),
        &DurableRegistryOptions {
            shards: cfg.serve.registry_shards,
            wal: true,
            sync: WalSync::Always,
            compact_every: 0,
        },
        Some(obs.clone()),
    )?;
    let d = Dispatcher::with_registry_obs(
        bundle,
        &cfg.serve,
        &cfg.cluster,
        durable.handle(),
        obs,
    )?;

    let opts = ChaosOpts {
        speakers,
        enroll_utts,
        requests,
        concurrency,
        live_enroll_every,
        faulty_replica,
        stall_at,
        tick_ms,
        settle_ms,
    };
    println!(
        "chaos-bench: {replicas} replicas, {requests} requests x{concurrency} — \
         stalling replica {faulty_replica} at request {stall_at}, poisoning the WAL \
         at mutation {wal_fault_at}"
    );
    let report = run_chaos_drill(&d, &traffic, &opts)?;

    println!(
        "chaos-bench: {} completed, {} shed/timed out, {} enrolls refused in degraded \
         mode over {:.2}s",
        report.completed, report.rejected, report.degraded_enrolls, report.wall_s,
    );
    println!(
        "  replica incident: quarantined +{:.3}s, serving again +{:.3}s \
         (quarantines {}, probes {}, self-heals {}, failovers {})",
        report.time_to_quarantine_s,
        report.time_to_recover_s,
        report.quarantines,
        report.probes,
        report.self_heals,
        report.failovers,
    );
    println!(
        "  registry incident: WAL poisoned={} repaired={} (repair took {:.3}s)",
        report.registry_poisoned, report.registry_repaired, report.time_to_repair_wal_s,
    );
    println!(
        "  verify p99: {:.1} ms inside the incident window vs {:.1} ms steady-state",
        report.incident_p99_ms, report.steady_p99_ms,
    );
    println!(
        "  audit: {} acked enrollments, {} lost",
        report.acked_enrollments, report.lost_enrollments,
    );

    // the gates: this command exists to fail CI when self-healing breaks
    anyhow::ensure!(
        report.lost_enrollments == 0,
        "AUDIT FAILED: {} acked enrollments missing from the registry",
        report.lost_enrollments
    );
    anyhow::ensure!(
        report.quarantines >= 1 && report.self_heals >= 1 && report.replica_restored,
        "faulty replica was not quarantined and restored: {report:?}"
    );
    anyhow::ensure!(
        report.registry_poisoned && report.registry_repaired,
        "WAL incident did not complete its degrade/repair cycle: {report:?}"
    );

    write_bench9_json(&out, &report)?;
    println!("wrote {out}");
    write_obs_snapshot(&obs_out, d.obs())?;
    Ok(())
}

fn parse_sync(args: &Args, default: WalSync) -> Result<WalSync> {
    match args.get("sync") {
        Some(s) => WalSync::parse(&s),
        None => Ok(default),
    }
}

/// `registry-recover` — open a durable registry directory, run
/// recovery (snapshot + WAL replay, torn-tail truncation), and report
/// what was found. `--compact` then folds the replayed WAL into a
/// fresh snapshot, so the next open replays nothing. Exits nonzero on
/// mid-log corruption — recovery refuses to guess past it.
pub fn registry_recover(args: &Args) -> Result<()> {
    let dir = args.require("dir")?;
    let shards = args.get_parse_or("shards", 16usize)?;
    let sync = parse_sync(args, WalSync::Always)?;
    let compact_every = args.get_parse_or("compact-every", 10_000u64)?;
    let do_compact = args.switch("compact");
    args.finish()?;

    let opts = DurableRegistryOptions { shards, wal: true, sync, compact_every };
    let reg = DurableRegistry::open(&dir, &opts)?;
    let rec = reg.recovery();
    println!(
        "registry-recover: {dir}\n\
         snapshot: {} (covers WAL seq {})\n\
         replayed: {} WAL records ({} already in the snapshot, skipped)\n\
         torn tail: {}\n\
         state: {} speakers, {} enrollments, recovered in {:.3}s",
        if rec.snapshot_loaded { "loaded" } else { "none" },
        rec.snapshot_seq,
        rec.replayed,
        rec.skipped,
        if rec.torn_tail { "yes — truncated" } else { "no" },
        rec.speakers,
        rec.enrollments,
        rec.wall_s,
    );
    if do_compact {
        reg.compact()?;
        println!("compacted: WAL folded into the snapshot");
    }
    Ok(())
}

/// `registry-bench` — the crash/recovery drill behind `BENCH_6.json`:
/// enroll `--speakers` synthetic speakers through the WAL on the real
/// file backend, kill persistence mid-append at `--crash-at` via the
/// deterministic fault injector, reopen, and audit every acknowledged
/// enrollment. A single lost acknowledgment fails the run — that is
/// the guarantee the durable registry exists to keep.
pub fn registry_bench(args: &Args) -> Result<()> {
    let speakers = args.get_parse_or("speakers", 100_000usize)?;
    let dim = args.get_parse_or("dim", 64usize)?;
    let shards = args.get_parse_or("shards", 16usize)?;
    let sync = parse_sync(args, WalSync::Always)?;
    let compact_every = args.get_parse_or("compact-every", 20_000u64)?;
    let crash_at = args.get_parse_or("crash-at", speakers / 2)?;
    let dir = args.get_or("dir", "./work/registry-bench");
    let out = args.get_or("out", "BENCH_6.json");
    args.finish()?;

    // the drill needs empty persistent state: a survivor from a prior
    // run would replay into the audit and corrupt the counts
    if std::path::Path::new(&dir).exists() {
        std::fs::remove_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("wipe bench dir {dir}: {e}"))?;
    }
    let opts = RegistryBenchOpts { speakers, dim, shards, sync, compact_every, crash_at };
    println!(
        "registry-bench: {speakers} speakers (dim {dim}), sync {}, \
         compact every {compact_every}, crash at enrollment {crash_at} — {dir}",
        opts.sync,
    );
    let dir_for_factory = dir.clone();
    let obs = Arc::new(ObsRegistry::default());
    let report = run_registry_bench(
        &opts,
        move || Ok(Box::new(FileStorage::open(&dir_for_factory)?) as Box<dyn RegistryStorage>),
        Some(Arc::clone(&obs)),
    )?;
    println!(
        "enroll: {:.0}/s volatile vs {:.0}/s durable ({:.2}x fsync overhead, sync {})",
        report.mem_enroll_rps, report.wal_enroll_rps, report.fsync_overhead_x, report.wal_sync,
    );
    println!(
        "crash: {} acked, {} recovered, {} lost | torn tail {} | \
         {} replayed over {} compactions | recovery {:.3}s",
        report.acked,
        report.recovered,
        report.lost,
        report.torn_tail,
        report.replayed,
        report.compactions,
        report.recovery_s,
    );
    print_stage_rows(&report.wal_stages);
    write_bench6_json(&out, &report)?;
    println!("wrote {out}");
    anyhow::ensure!(
        report.lost == 0,
        "{} acknowledged enrollments lost after recovery — the durability guarantee is broken",
        report.lost
    );
    Ok(())
}

/// `stats --snapshot PATH [--check]` — read an observability snapshot
/// written by `serve-bench`/`cluster-bench --obs-out` and print its
/// counters, gauges, histograms, and slow traces. `--check` first runs
/// full validation (schema version, every canonical metric including
/// every per-stage series, well-formed values and traces) and fails
/// the process on any malformation — the CI gate on exporter drift.
pub fn stats(args: &Args) -> Result<()> {
    let path = args.get_or("snapshot", "OBS_SNAPSHOT.json");
    let check = args.switch("check");
    let diff = args.get("diff");
    args.finish()?;

    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("read snapshot {path}: {e}"))?;
    if check {
        crate::obs::validate_snapshot(&text)
            .map_err(|e| anyhow::anyhow!("snapshot {path} failed validation: {e:#}"))?;
        println!("stats: {path} valid (schema v1, all canonical metrics present)");
    }
    let doc = crate::obs::parse_json(&text)
        .map_err(|e| anyhow::anyhow!("snapshot {path}: {e:#}"))?;

    let metrics = doc
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("snapshot {path}: missing `metrics` object"))?;
    let num = |m: &Json, key: &str| m.get(key).and_then(Json::as_num).unwrap_or(0.0);

    // `--diff OLD.json` compares an older snapshot against `--snapshot`
    // (the newer one): counters as deltas, histograms as p50/p95/p99
    // drift through the same helper the replayer's BENCH_10.json uses.
    if let Some(old_path) = diff {
        let old_text = std::fs::read_to_string(&old_path)
            .map_err(|e| anyhow::anyhow!("read snapshot {old_path}: {e}"))?;
        let old_doc = crate::obs::parse_json(&old_text)
            .map_err(|e| anyhow::anyhow!("snapshot {old_path}: {e:#}"))?;
        let old_metrics = old_doc
            .get("metrics")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("snapshot {old_path}: missing `metrics` object"))?;
        let triple = |m: &Json| LatencyTriple {
            p50_ms: num(m, "p50_s") * 1e3,
            p95_ms: num(m, "p95_s") * 1e3,
            p99_ms: num(m, "p99_s") * 1e3,
        };
        println!("diff: {old_path} → {path}");
        for (key, m) in metrics {
            let old_m = old_metrics.iter().find(|(k, _)| k == key).map(|(_, v)| v);
            match m.get("type").and_then(Json::as_str).unwrap_or("?") {
                "counter" => {
                    let old_v = old_m.map_or(0.0, |o| num(o, "value"));
                    let new_v = num(m, "value");
                    if new_v != old_v || old_m.is_none() {
                        println!(
                            "  {key:<52} {old_v:>12.0} → {new_v:>12.0}  ({:+.0}){}",
                            new_v - old_v,
                            if old_m.is_none() { "  [new series]" } else { "" },
                        );
                    }
                }
                "histogram" => {
                    let old_n = old_m.map_or(0.0, |o| num(o, "count"));
                    if num(m, "count") > 0.0 || old_n > 0.0 {
                        println!(
                            "  {}",
                            latency_drift_row(
                                key,
                                &old_m.map(triple).unwrap_or(LatencyTriple {
                                    p50_ms: 0.0,
                                    p95_ms: 0.0,
                                    p99_ms: 0.0,
                                }),
                                &triple(m),
                            )
                        );
                    }
                }
                "gauge" => {
                    let old_v = old_m.map_or(0.0, |o| num(o, "mean"));
                    let new_v = num(m, "mean");
                    if new_v != old_v {
                        println!("  {key:<52} mean {old_v:>8.2} → {new_v:>8.2}");
                    }
                }
                _ => {}
            }
        }
        for (key, _) in old_metrics {
            if !metrics.iter().any(|(k, _)| k == key) {
                println!("  {key:<52} [series removed]");
            }
        }
        return Ok(());
    }

    println!("{path}: {} metric series", metrics.len());
    for (key, m) in metrics {
        match m.get("type").and_then(Json::as_str).unwrap_or("?") {
            "counter" => println!("  {key:<64} {:>12.0}", num(m, "value")),
            "gauge" => println!(
                "  {key:<64} max {:>6.0}  mean {:>8.2}  (window max {:.0} mean {:.2})",
                num(m, "max"),
                num(m, "mean"),
                num(m, "window_max"),
                num(m, "window_mean"),
            ),
            "histogram" => println!(
                "  {key:<64} n {:>7.0}  p50 {:>9.3} ms  p99 {:>9.3} ms  max {:>9.3} ms{}",
                num(m, "count"),
                num(m, "p50_s") * 1e3,
                num(m, "p99_s") * 1e3,
                num(m, "max_s") * 1e3,
                if num(m, "invalid") > 0.0 {
                    format!("  [invalid {}]", num(m, "invalid"))
                } else {
                    String::new()
                },
            ),
            other => println!("  {key:<64} (unknown type `{other}`)"),
        }
    }

    let traces = doc.get("slow_traces").and_then(Json::as_arr).unwrap_or(&[]);
    println!("{} slow traces", traces.len());
    for t in traces {
        let hops = t
            .get("hops")
            .and_then(Json::as_arr)
            .map(|h| {
                h.iter()
                    .filter_map(Json::as_num)
                    .map(|r| format!("{r:.0}"))
                    .collect::<Vec<_>>()
                    .join("→")
            })
            .unwrap_or_default();
        let stage_sum: f64 = t
            .get("stages_ms")
            .and_then(Json::as_obj)
            .map(|s| s.iter().filter_map(|(_, v)| v.as_num()).sum())
            .unwrap_or(0.0);
        println!(
            "  trace {:>5.0}  {:>9.3} ms total ({stage_sum:.3} ms in stages)  {}  \
             failovers {:.0}  hops [{hops}]",
            num(t, "id"),
            num(t, "total_ms"),
            t.get("outcome").and_then(Json::as_str).unwrap_or("?"),
            num(t, "failovers"),
        );
    }
    Ok(())
}
