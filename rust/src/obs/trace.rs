//! Per-request stage tracing: a request ID minted at admission and a
//! shared trace object that rides the request through the dispatcher,
//! engine, micro-batcher, and durable registry.
//!
//! Propagation is by a thread-local *current trace* (the dispatcher or
//! engine installs it with [`enter`] for the duration of the request
//! closure) plus an explicit `Arc` captured into the micro-batch `Job`
//! at submit time — worker threads attribute queue-wait and E-step time
//! to the right request without any signature changes on the hot path.
//! Stage timings are relaxed atomics, so a worker can still be writing
//! an E-step span while the requester finalizes the trace: the record
//! snapshots whatever has landed, which is exactly the time the caller
//! observed.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::serve::ServeError;

use super::{Stage, N_STAGES};

thread_local! {
    static CURRENT: RefCell<Option<Arc<RequestTrace>>> = RefCell::new(None);
}

/// One in-flight request's trace: per-stage accumulated nanoseconds,
/// the replicas it touched, and its failover count.
#[derive(Debug)]
pub struct RequestTrace {
    /// Request ID minted at admission (unique per [`super::ObsRegistry`]).
    pub id: u64,
    pub(super) start_ns: u64,
    stage_ns: [AtomicU64; N_STAGES],
    hops: Mutex<Vec<usize>>,
    failovers: AtomicU64,
}

impl RequestTrace {
    pub(super) fn new(id: u64, start_ns: u64) -> Self {
        Self {
            id,
            start_ns,
            stage_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            hops: Mutex::new(Vec::new()),
            failovers: AtomicU64::new(0),
        }
    }

    /// Accumulate `ns` into a stage (a stage can fire more than once per
    /// request — e.g. align re-runs on a failover hop).
    pub fn add_stage(&self, stage: Stage, ns: u64) {
        self.stage_ns[stage.index()].fetch_add(ns, Ordering::Relaxed);
    }

    /// Nanoseconds accumulated in `stage` so far.
    pub fn stage_ns(&self, stage: Stage) -> u64 {
        self.stage_ns[stage.index()].load(Ordering::Relaxed)
    }

    /// Record an attempt on replica `id` (in attempt order; a failover
    /// leaves both the failed and the rescuing replica in the list).
    pub fn add_hop(&self, replica: usize) {
        self.hops.lock().unwrap_or_else(|p| p.into_inner()).push(replica);
    }

    /// Count one failover retry.
    pub fn record_failover(&self) {
        self.failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// Failover retries so far.
    pub fn failovers(&self) -> u64 {
        self.failovers.load(Ordering::Relaxed)
    }

    pub(super) fn to_record(&self, total_ns: u64, outcome: TraceOutcome) -> TraceRecord {
        TraceRecord {
            id: self.id,
            total_ns,
            stage_ns: std::array::from_fn(|i| self.stage_ns[i].load(Ordering::Relaxed)),
            hops: self.hops.lock().unwrap_or_else(|p| p.into_inner()).clone(),
            failovers: self.failovers(),
            outcome,
        }
    }
}

/// How a traced request ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOutcome {
    /// Completed with a result.
    Ok,
    /// Rejected without entering a queue (`Overloaded` / `ShuttingDown`).
    Shed,
    /// Admitted but missed its response deadline.
    Timeout,
    /// Hard failure (worker panic, validation error, ...).
    Failed,
}

impl TraceOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Ok => "ok",
            Self::Shed => "shed",
            Self::Timeout => "timeout",
            Self::Failed => "failed",
        }
    }

    /// Classify a request result via the typed [`ServeError`] surface.
    pub fn of<T>(r: &anyhow::Result<T>) -> Self {
        match r {
            Ok(_) => Self::Ok,
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(ServeError::Overloaded { .. })
                | Some(ServeError::ShuttingDown)
                | Some(ServeError::SessionLimit { .. }) => Self::Shed,
                Some(ServeError::Timeout { .. }) => Self::Timeout,
                _ => Self::Failed,
            },
        }
    }
}

/// A completed trace as frozen into the slow-trace ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRecord {
    pub id: u64,
    /// End-to-end nanoseconds from mint to completion.
    pub total_ns: u64,
    /// Per-stage accumulated nanoseconds (indexed by [`Stage::index`]).
    pub stage_ns: [u64; N_STAGES],
    /// Replica ids in attempt order (empty for a standalone engine).
    pub hops: Vec<usize>,
    pub failovers: u64,
    pub outcome: TraceOutcome,
}

impl TraceRecord {
    /// Sum of all stage timings — always ≤ `total_ns` for a request
    /// whose stages are disjoint sub-intervals of its lifetime.
    pub fn stage_sum_ns(&self) -> u64 {
        self.stage_ns.iter().sum()
    }
}

/// The thread's current trace, if a request scope is installed.
pub fn current() -> Option<Arc<RequestTrace>> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Install `trace` as the thread's current trace until the returned
/// scope drops (restores whatever was current before — scopes nest).
pub fn enter(trace: Arc<RequestTrace>) -> TraceScope {
    let prev = CURRENT.with(|c| c.replace(Some(trace)));
    TraceScope { prev }
}

/// Accumulate `ns` into the current trace's `stage`, if one is
/// installed — the hook layers without a registry handle (the durable
/// registry's WAL spans) use to stay attributable.
pub fn add_current_stage(stage: Stage, ns: u64) {
    CURRENT.with(|c| {
        if let Some(t) = c.borrow().as_ref() {
            t.add_stage(stage, ns);
        }
    });
}

/// Guard restoring the previously-current trace on drop.
#[must_use = "dropping the scope immediately uninstalls the trace"]
pub struct TraceScope {
    prev: Option<Arc<RequestTrace>>,
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scopes_nest_and_restore() {
        assert!(current().is_none());
        let a = Arc::new(RequestTrace::new(1, 0));
        let b = Arc::new(RequestTrace::new(2, 0));
        {
            let _sa = enter(Arc::clone(&a));
            assert_eq!(current().unwrap().id, 1);
            {
                let _sb = enter(Arc::clone(&b));
                assert_eq!(current().unwrap().id, 2);
                add_current_stage(Stage::Align, 50);
            }
            assert_eq!(current().unwrap().id, 1);
        }
        assert!(current().is_none());
        assert_eq!(b.stage_ns(Stage::Align), 50);
        assert_eq!(a.stage_ns(Stage::Align), 0);
    }

    #[test]
    fn record_snapshots_stages_hops_failovers() {
        let t = RequestTrace::new(7, 100);
        t.add_stage(Stage::AdmitWait, 10);
        t.add_stage(Stage::EstepBatch, 30);
        t.add_stage(Stage::EstepBatch, 5);
        t.add_hop(0);
        t.add_hop(2);
        t.record_failover();
        let r = t.to_record(100, TraceOutcome::Ok);
        assert_eq!(r.id, 7);
        assert_eq!(r.stage_ns[Stage::AdmitWait.index()], 10);
        assert_eq!(r.stage_ns[Stage::EstepBatch.index()], 35);
        assert_eq!(r.hops, vec![0, 2]);
        assert_eq!(r.failovers, 1);
        assert_eq!(r.stage_sum_ns(), 45);
        assert!(r.stage_sum_ns() <= r.total_ns);
    }

    #[test]
    fn outcome_classification() {
        use std::time::Duration;
        let ok: anyhow::Result<u32> = Ok(1);
        assert_eq!(TraceOutcome::of(&ok), TraceOutcome::Ok);
        let shed: anyhow::Result<u32> =
            Err(ServeError::Overloaded { waited: Duration::ZERO }.into());
        assert_eq!(TraceOutcome::of(&shed), TraceOutcome::Shed);
        let drain: anyhow::Result<u32> = Err(ServeError::ShuttingDown.into());
        assert_eq!(TraceOutcome::of(&drain), TraceOutcome::Shed);
        let to: anyhow::Result<u32> = Err(ServeError::Timeout { waited: Duration::ZERO }.into());
        assert_eq!(TraceOutcome::of(&to), TraceOutcome::Timeout);
        let session_shed: anyhow::Result<u32> =
            Err(ServeError::SessionLimit { live: 8 }.into());
        assert_eq!(TraceOutcome::of(&session_shed), TraceOutcome::Shed);
        let gone: anyhow::Result<u32> = Err(ServeError::SessionExpired.into());
        assert_eq!(TraceOutcome::of(&gone), TraceOutcome::Failed);
        let hard: anyhow::Result<u32> = Err(anyhow::anyhow!("boom"));
        assert_eq!(TraceOutcome::of(&hard), TraceOutcome::Failed);
        assert_eq!(TraceOutcome::Timeout.as_str(), "timeout");
    }
}
