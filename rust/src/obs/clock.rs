//! Time source behind every span and trace: a process-monotonic
//! nanosecond clock with a mockable variant.
//!
//! The observability layer never reads wall-clock time — everything is
//! nanoseconds since a process-global epoch, so durations subtract
//! cleanly across threads. Tests swap in [`Clock::mock`] and advance an
//! atomic by hand, which is what makes span timings deterministic
//! (satellite: injectable mock clock).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Anchor for the real clock: fixed at first use, shared process-wide
/// so `now_ns` values from different registries are comparable.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Monotonic nanosecond clock. `Real` reads the process epoch;
/// `Mock` reads an atomic the test owns.
#[derive(Debug, Clone)]
pub enum Clock {
    /// Nanoseconds since the process-global epoch (`Instant`-backed).
    Real,
    /// Test clock: `now_ns` is whatever the shared atomic holds.
    Mock(Arc<AtomicU64>),
}

impl Clock {
    /// A mock clock plus the handle that advances it.
    pub fn mock() -> (Self, Arc<AtomicU64>) {
        let t = Arc::new(AtomicU64::new(0));
        (Self::Mock(Arc::clone(&t)), t)
    }

    /// Current time in nanoseconds since the (real or mock) epoch.
    pub fn now_ns(&self) -> u64 {
        match self {
            Self::Real => EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64,
            Self::Mock(t) => t.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic() {
        let c = Clock::Real;
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn mock_clock_reads_the_atomic() {
        let (c, t) = Clock::mock();
        assert_eq!(c.now_ns(), 0);
        t.store(5_000_000, Ordering::Relaxed);
        assert_eq!(c.now_ns(), 5_000_000);
    }
}
