//! Exporters: Prometheus-style text exposition and a JSON snapshot,
//! plus the dependency-free JSON parser/validator behind the `stats`
//! CLI command (`--check` fails on a malformed snapshot or a missing
//! canonical metric name — the guard against silent metric-rename
//! drift).

use anyhow::{bail, ensure, Context, Result};

use crate::metrics::LatencySummary;

use super::trace::TraceRecord;
use super::{MetricSnapshot, SnapshotValue, Stage, STAGE_METRIC};

/// Metric names every serving engine registers — present in any
/// `serve-bench`/`cluster-bench` snapshot regardless of configuration.
/// `stats --check` (and the CI obs job through it) fails if one is
/// missing, so a rename has to touch this list to land.
pub const CANONICAL_METRICS: &[&str] = &[
    STAGE_METRIC,
    "serve_extract_latency_seconds",
    "serve_enroll_latency_seconds",
    "serve_verify_latency_seconds",
    "serve_batches_total",
    "serve_batched_requests_total",
    "serve_shed_total",
    "serve_timeouts_total",
    "serve_expired_jobs_total",
    "serve_queue_depth",
    // appended after the gauge so the earlier indices stay stable
    "serve_worker_panics_total",
    "serve_sessions_opened_total",
    "serve_session_early_exits_total",
    "serve_session_evictions_total",
    "serve_session_shed_total",
];

/// Metric names every cluster dispatcher registers on top of the
/// engine canon: routing/failover counters, the self-healing
/// supervisor counters, and the per-replica health gauge. Enforced by
/// [`validate_snapshot`] only when the snapshot *is* a cluster
/// snapshot — detected by the presence of `cluster_routed_total` — so
/// single-engine `serve-bench` snapshots stay valid unchanged.
pub const CANONICAL_CLUSTER_METRICS: &[&str] = &[
    "cluster_routed_total",
    "cluster_failovers_total",
    "cluster_exhausted_total",
    "cluster_swaps_total",
    "cluster_quarantines_total",
    "cluster_probes_total",
    "cluster_self_heals_total",
    "cluster_replica_health",
];

/// Metric names a capture-enabled run registers on top of the engine
/// canon. Same sentinel trick as the cluster canon: enforced only when
/// the snapshot *is* a capture snapshot — detected by the presence of
/// `capture_records_total` — so capture-less snapshots stay valid
/// unchanged. (`replay_mismatches_total` is registered by the replayer
/// and shape-validated like any other series, but not required here: a
/// capture session and a replay session are different runs.)
pub const CANONICAL_CAPTURE_METRICS: &[&str] = &[
    "capture_records_total",
    "capture_bytes_total",
    "capture_dropped_total",
];

fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// `{k="v",...}` with an optional extra pair appended; empty labels
/// (and no extra) render as no braces at all.
fn label_str(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut s = String::from("{");
    let mut first = true;
    for (k, v) in labels {
        if !first {
            s.push(',');
        }
        first = false;
        s.push_str(&format!("{k}=\"{v}\""));
    }
    if let Some((k, v)) = extra {
        if !first {
            s.push(',');
        }
        s.push_str(&format!("{k}=\"{v}\""));
    }
    s.push('}');
    s
}

/// Prometheus text exposition of a registry snapshot. Histograms render
/// summary-style (`quantile` labels + `_count`/`_sum`/`_max`/
/// `_invalid`), gauges as lifetime + windowed derived series.
pub fn render_prometheus(metrics: &[MetricSnapshot]) -> String {
    let mut out = String::new();
    let mut prev_name = "";
    for m in metrics {
        let labels = &m.labels;
        match &m.value {
            SnapshotValue::Counter(v) => {
                if m.name != prev_name {
                    out.push_str(&format!("# TYPE {} counter\n", m.name));
                }
                out.push_str(&format!("{}{} {v}\n", m.name, label_str(labels, None)));
            }
            SnapshotValue::Gauge { lifetime, window } => {
                if m.name != prev_name {
                    out.push_str(&format!("# TYPE {} gauge\n", m.name));
                }
                let ls = label_str(labels, None);
                out.push_str(&format!("{}_max{ls} {}\n", m.name, lifetime.max));
                out.push_str(&format!("{}_mean{ls} {}\n", m.name, fmt_num(lifetime.mean)));
                out.push_str(&format!("{}_samples{ls} {}\n", m.name, lifetime.samples));
                out.push_str(&format!("{}_window_max{ls} {}\n", m.name, window.max));
                out.push_str(&format!("{}_window_mean{ls} {}\n", m.name, fmt_num(window.mean)));
                out.push_str(&format!("{}_window_samples{ls} {}\n", m.name, window.samples));
            }
            SnapshotValue::Histogram(s) => {
                if m.name != prev_name {
                    out.push_str(&format!("# TYPE {} summary\n", m.name));
                }
                for (q, v) in
                    [("0.5", s.p50_s), ("0.95", s.p95_s), ("0.99", s.p99_s)]
                {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        m.name,
                        label_str(labels, Some(("quantile", q))),
                        fmt_num(v)
                    ));
                }
                let ls = label_str(labels, None);
                out.push_str(&format!("{}_count{ls} {}\n", m.name, s.count));
                out.push_str(&format!(
                    "{}_sum{ls} {}\n",
                    m.name,
                    fmt_num(s.mean_s * s.count as f64)
                ));
                out.push_str(&format!("{}_max{ls} {}\n", m.name, fmt_num(s.max_s)));
                out.push_str(&format!("{}_invalid{ls} {}\n", m.name, s.invalid));
            }
        }
        prev_name = &m.name;
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON snapshot of a registry: `schema_version`, every instrument
/// keyed by its canonical `name{labels}` string, and the slow-trace
/// ring's contents.
pub fn render_json(metrics: &[MetricSnapshot], traces: &[TraceRecord]) -> String {
    let mut out = String::from("{\n  \"schema_version\": 1,\n  \"metrics\": {\n");
    for (i, m) in metrics.iter().enumerate() {
        let body = match &m.value {
            SnapshotValue::Counter(v) => format!("{{\"type\": \"counter\", \"value\": {v}}}"),
            SnapshotValue::Gauge { lifetime, window } => format!(
                "{{\"type\": \"gauge\", \"max\": {}, \"mean\": {}, \"samples\": {}, \
                 \"window_max\": {}, \"window_mean\": {}, \"window_samples\": {}}}",
                lifetime.max,
                fmt_num(lifetime.mean),
                lifetime.samples,
                window.max,
                fmt_num(window.mean),
                window.samples,
            ),
            SnapshotValue::Histogram(s) => format!(
                "{{\"type\": \"histogram\", \"count\": {}, \"invalid\": {}, \
                 \"mean_s\": {}, \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \"max_s\": {}}}",
                s.count,
                s.invalid,
                fmt_num(s.mean_s),
                fmt_num(s.p50_s),
                fmt_num(s.p95_s),
                fmt_num(s.p99_s),
                fmt_num(s.max_s),
            ),
        };
        out.push_str(&format!("    \"{}\": {body}", json_escape(&m.key)));
        out.push_str(if i + 1 < metrics.len() { ",\n" } else { "\n" });
    }
    out.push_str("  },\n  \"slow_traces\": [\n");
    for (i, t) in traces.iter().enumerate() {
        let mut stages = String::new();
        for (j, stage) in Stage::ALL.iter().enumerate() {
            if j > 0 {
                stages.push_str(", ");
            }
            stages.push_str(&format!(
                "\"{}\": {}",
                stage.as_str(),
                fmt_num(t.stage_ns[j] as f64 / 1e6)
            ));
        }
        let hops =
            t.hops.iter().map(|h| h.to_string()).collect::<Vec<_>>().join(", ");
        out.push_str(&format!(
            "    {{\"id\": {}, \"total_ms\": {}, \"outcome\": \"{}\", \"failovers\": {}, \
             \"hops\": [{hops}], \"stages_ms\": {{{stages}}}}}",
            t.id,
            fmt_num(t.total_ns as f64 / 1e6),
            t.outcome.as_str(),
        ));
        out.push_str(if i + 1 < traces.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// One [`LatencySummary`] as a millisecond-unit JSON object — the
/// shared fragment behind the bench reports' per-stage breakdowns.
pub fn latency_summary_json(s: &LatencySummary) -> String {
    format!(
        "{{\"count\": {}, \"invalid\": {}, \"mean_ms\": {:.4}, \"p50_ms\": {:.4}, \
         \"p95_ms\": {:.4}, \"p99_ms\": {:.4}, \"max_ms\": {:.4}}}",
        s.count,
        s.invalid,
        s.mean_s * 1e3,
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.p99_s * 1e3,
        s.max_s * 1e3,
    )
}

/// A parsed JSON value (dependency-free subset parser: objects keep
/// insertion order, all numbers are `f64`).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member lookup on an object; `None` for missing key or non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Self::Obj(m) => m.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Self::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Self::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_num(&self) -> Option<f64> {
        match self {
            Self::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Self::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.i))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        let got = self.peek()?;
        ensure!(got == c, "expected `{}` at byte {}, got `{}`", c as char, self.i, got as char);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number token");
        let n: f64 = tok
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number `{tok}` at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| anyhow::anyhow!("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            ensure!(self.i + 4 <= self.b.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow::anyhow!("bad \\u escape `{hex}`"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("unknown escape `\\{}`", other as char),
                    }
                }
                c => {
                    // re-walk multi-byte UTF-8 sequences intact
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let mut end = self.i;
                        while end < self.b.len() && (self.b[end] & 0xC0) == 0x80 {
                            end += 1;
                        }
                        out.push_str(
                            std::str::from_utf8(&self.b[start..end])
                                .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                        );
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected `,` or `]` at byte {}, got `{}`", self.i, other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            // last-wins duplicate keys would let one metric series
            // silently shadow another in a snapshot — reject instead
            ensure!(
                !out.iter().any(|(k, _): &(String, Json)| *k == key),
                "duplicate object key `{key}` at byte {}",
                self.i
            );
            self.expect(b':')?;
            out.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!("expected `,` or `}}` at byte {}, got `{}`", self.i, other as char),
            }
        }
    }
}

/// Parse a JSON document (the subset the exporters emit, which is all
/// of JSON minus exotic number forms).
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    ensure!(p.i == p.b.len(), "trailing bytes after JSON value at byte {}", p.i);
    Ok(v)
}

fn require_num(obj: &Json, key: &str, what: &str) -> Result<f64> {
    obj.get(key)
        .and_then(Json::as_num)
        .with_context(|| format!("{what}: missing numeric field `{key}`"))
}

/// Validate an `ObsRegistry` JSON snapshot: schema version, every
/// canonical metric name present (every per-stage series included),
/// well-formed per-type fields, and a well-formed slow-trace list.
///
/// Metric-level problems accumulate: one failing run reports *every*
/// missing canonical name and malformed series in a single pass, not
/// just the first — chasing a rename sweep one `--check` cycle at a
/// time was the motivating papercut. Structural problems (not JSON, no
/// `metrics` object) still fail immediately; there is nothing left to
/// accumulate over.
pub fn validate_snapshot(text: &str) -> Result<()> {
    let doc = parse_json(text).context("snapshot is not valid JSON")?;
    let version = require_num(&doc, "schema_version", "snapshot")?;
    ensure!(version == 1.0, "unsupported snapshot schema_version {version}");
    let metrics = doc
        .get("metrics")
        .and_then(Json::as_obj)
        .context("snapshot: missing `metrics` object")?;

    let mut problems: Vec<String> = Vec::new();
    let present = |name: &str| {
        let prefixed = format!("{name}{{");
        metrics.iter().any(|(k, _)| k == name || k.starts_with(&prefixed))
    };
    for name in CANONICAL_METRICS {
        if !present(name) {
            problems.push(format!("canonical metric `{name}` missing from snapshot"));
        }
    }
    // a cluster snapshot — the dispatcher's routing counter is the
    // sentinel — must also carry the full cluster canon, including the
    // self-healing counters and the per-replica health gauge
    if present("cluster_routed_total") {
        for name in CANONICAL_CLUSTER_METRICS {
            if !present(name) {
                problems.push(format!(
                    "cluster canonical metric `{name}` missing from snapshot"
                ));
            }
        }
    }
    // same trick for capture: the record counter is the sentinel, so
    // capture-less snapshots stay valid while a capture-enabled run
    // must export its whole counter set
    if present("capture_records_total") {
        for name in CANONICAL_CAPTURE_METRICS {
            if !present(name) {
                problems.push(format!(
                    "capture canonical metric `{name}` missing from snapshot"
                ));
            }
        }
    }
    for stage in Stage::ALL {
        let key = format!("{STAGE_METRIC}{{stage=\"{}\"}}", stage.as_str());
        if !metrics.iter().any(|(k, _)| *k == key) {
            problems.push(format!("stage series `{key}` missing from snapshot"));
        }
    }
    for (key, m) in metrics {
        let fields: &[&str] = match m.get("type").and_then(Json::as_str) {
            Some("counter") => &["value"],
            Some("gauge") => &["max", "mean", "samples", "window_max", "window_mean"],
            Some("histogram") => {
                &["count", "invalid", "mean_s", "p50_s", "p95_s", "p99_s", "max_s"]
            }
            Some(other) => {
                problems.push(format!("metric `{key}`: unknown type `{other}`"));
                continue;
            }
            None => {
                problems.push(format!("metric `{key}`: missing `type`"));
                continue;
            }
        };
        for f in fields {
            if let Err(e) = require_num(m, f, key) {
                problems.push(format!("{e:#}"));
            }
        }
    }
    if !problems.is_empty() {
        bail!(
            "snapshot failed validation with {} problem(s):\n  - {}",
            problems.len(),
            problems.join("\n  - ")
        );
    }

    let traces = doc
        .get("slow_traces")
        .and_then(Json::as_arr)
        .context("snapshot: missing `slow_traces` array")?;
    for t in traces {
        require_num(t, "id", "slow trace")?;
        require_num(t, "total_ms", "slow trace")?;
        t.get("outcome").and_then(Json::as_str).context("slow trace: missing `outcome`")?;
        t.get("hops").and_then(Json::as_arr).context("slow trace: missing `hops`")?;
        let stages = t
            .get("stages_ms")
            .and_then(Json::as_obj)
            .context("slow trace: missing `stages_ms`")?;
        for stage in Stage::ALL {
            ensure!(
                stages.iter().any(|(k, _)| k == stage.as_str()),
                "slow trace: missing stage `{}`",
                stage.as_str()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_parser_round_trips_the_basics() {
        let v = parse_json(
            r#"{"a": 1, "b": -2.5e-2, "s": "x\"y\\z\nw", "t": true, "n": null,
                "arr": [1, 2, {"k": "v"}], "empty": {}, "ea": []}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_num(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_num(), Some(-0.025));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\"y\\z\nw"));
        assert_eq!(v.get("t"), Some(&Json::Bool(true)));
        assert_eq!(v.get("n"), Some(&Json::Null));
        let arr = v.get("arr").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("k").unwrap().as_str(), Some("v"));
        assert_eq!(v.get("empty").unwrap().as_obj().unwrap().len(), 0);
        assert_eq!(v.get("ea").unwrap().as_arr().unwrap().len(), 0);

        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("{} trailing").is_err());
        assert!(parse_json("{\"u\": \"caf\\u00e9 ünïcode\"}").is_ok());
    }

    /// Satellite regression: the subset parser used to accept duplicate
    /// object keys (last-wins). A duplicated metric key must now be a
    /// typed parse error at every nesting depth.
    #[test]
    fn json_parser_rejects_duplicate_object_keys() {
        let err = parse_json("{\"a\": 1, \"a\": 2}").unwrap_err();
        assert!(err.to_string().contains("duplicate object key `a`"), "{err:#}");
        // nested objects are checked too
        let err = parse_json("{\"m\": {\"x\": 1, \"x\": 1}}").unwrap_err();
        assert!(err.to_string().contains("duplicate object key `x`"), "{err:#}");
        // distinct keys and duplicate *values* remain fine
        assert!(parse_json("{\"a\": 1, \"b\": 1, \"c\": {\"a\": 1}}").is_ok());
        // validate_snapshot surfaces the same typed error
        let err = validate_snapshot(
            "{\"schema_version\": 1, \"schema_version\": 1, \"metrics\": {}, \"slow_traces\": []}",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("duplicate object key"), "{err:#}");
    }

    /// Satellite: one failing `--check` reports every problem at once —
    /// all missing canonical names and every malformed series — instead
    /// of surfacing them one re-run at a time.
    #[test]
    fn validator_reports_all_problems_in_one_pass() {
        let err = validate_snapshot(
            "{\"schema_version\": 1, \
              \"metrics\": {\"oddball\": {\"type\": \"teapot\"}}, \
              \"slow_traces\": []}",
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        // every engine canonical metric is reported missing...
        for name in CANONICAL_METRICS {
            assert!(msg.contains(name), "missing `{name}` not reported: {msg}");
        }
        // ...alongside the unknown-type series, in the same error
        assert!(msg.contains("unknown type `teapot`"), "{msg}");
        let n = CANONICAL_METRICS.len() + Stage::ALL.len() + 1;
        assert!(msg.contains(&format!("{n} problem(s)")), "{msg}");
    }

    /// Satellite: the capture canon rides the `capture_records_total`
    /// sentinel exactly like the cluster canon rides
    /// `cluster_routed_total` — capture-less snapshots stay valid.
    #[test]
    fn capture_sentinel_gates_the_capture_canon() {
        let obs = super::super::ObsRegistry::default();
        for name in &CANONICAL_METRICS[1..4] {
            obs.histogram(name, &[("engine", "0")]);
        }
        for name in CANONICAL_METRICS[4..9].iter().chain(&CANONICAL_METRICS[10..]) {
            obs.counter(name, &[("engine", "0")]);
        }
        obs.gauge("serve_queue_depth", &[("engine", "0")]);
        // capture-less: valid without any capture series
        validate_snapshot(&obs.render(super::super::RenderFormat::Json)).unwrap();

        // the sentinel alone makes the rest of the capture canon
        // required — and both gaps are reported in one pass
        obs.counter("capture_records_total", &[]);
        let err =
            validate_snapshot(&obs.render(super::super::RenderFormat::Json)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("capture canonical metric `capture_bytes_total`"), "{msg}");
        assert!(msg.contains("capture canonical metric `capture_dropped_total`"), "{msg}");

        obs.counter("capture_bytes_total", &[]);
        obs.counter("capture_dropped_total", &[]);
        validate_snapshot(&obs.render(super::super::RenderFormat::Json)).unwrap();
    }

    #[test]
    fn escaped_metric_keys_survive_the_round_trip() {
        let key = "serve_stage_latency_seconds{stage=\"align\"}";
        let doc = format!("{{\"{}\": 1}}", json_escape(key));
        let v = parse_json(&doc).unwrap();
        assert_eq!(v.as_obj().unwrap()[0].0, key);
    }
}
