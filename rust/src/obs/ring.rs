//! Slow-trace ring buffer: the last N completed request traces that
//! exceeded the configured threshold, readable without stopping
//! traffic.
//!
//! Writers claim a globally-ordered sequence ticket with one
//! `fetch_add` (wait-free — no writer ever spins on another), then
//! publish into `slot = (seq - 1) % capacity` under that slot's own
//! short critical section. Two writers only ever contend when their
//! tickets are exactly `capacity` apart (a full wrap); the
//! newest-ticket-wins guard keeps a stalled old writer from clobbering
//! a newer record, so a snapshot is always the highest-seq record each
//! slot has seen — no lost traces, no torn reads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::trace::TraceRecord;

type Slot = Mutex<(u64, Option<TraceRecord>)>;

/// Fixed-capacity last-N ring of completed slow traces.
#[derive(Debug)]
pub struct TraceRing {
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl TraceRing {
    /// A ring keeping the last `capacity.max(1)` records.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            head: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Mutex::new((0, None))).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total records ever pushed (the high-water sequence number).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Publish a record. Returns its sequence number (1-based).
    pub fn push(&self, rec: TraceRecord) -> u64 {
        let seq = self.head.fetch_add(1, Ordering::Relaxed) + 1;
        let slot = &self.slots[((seq - 1) % self.slots.len() as u64) as usize];
        let mut g = slot.lock().unwrap_or_else(|p| p.into_inner());
        // newest ticket wins: a writer delayed a full wrap behind must
        // not overwrite the fresher record already published here
        if seq > g.0 {
            *g = (seq, Some(rec));
        }
        seq
    }

    /// Every live record with its sequence number, oldest first.
    /// Locks one slot at a time — concurrent pushes keep flowing.
    pub fn snapshot(&self) -> Vec<(u64, TraceRecord)> {
        let mut out: Vec<(u64, TraceRecord)> = self
            .slots
            .iter()
            .filter_map(|s| {
                let g = s.lock().unwrap_or_else(|p| p.into_inner());
                g.1.as_ref().map(|r| (g.0, r.clone()))
            })
            .collect();
        out.sort_by_key(|(seq, _)| *seq);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::trace::TraceOutcome;
    use super::super::N_STAGES;
    use super::*;

    /// A record whose contents are a pure function of `id` — any torn
    /// or mixed write shows up as an internal inconsistency.
    fn rec(id: u64) -> TraceRecord {
        TraceRecord {
            id,
            total_ns: id * 1000,
            stage_ns: std::array::from_fn(|i| id * 10 + i as u64),
            hops: vec![(id % 3) as usize],
            failovers: id % 2,
            outcome: TraceOutcome::Ok,
        }
    }

    fn assert_consistent(r: &TraceRecord) {
        assert_eq!(r.total_ns, r.id * 1000, "torn total for id {}", r.id);
        for (i, &s) in r.stage_ns.iter().enumerate() {
            assert_eq!(s, r.id * 10 + i as u64, "torn stage {i} for id {}", r.id);
        }
        assert_eq!(r.hops, vec![(r.id % 3) as usize], "torn hops for id {}", r.id);
        assert_eq!(r.failovers, r.id % 2, "torn failovers for id {}", r.id);
    }

    #[test]
    fn keeps_the_last_n_in_order() {
        let ring = TraceRing::new(4);
        for id in 1..=10u64 {
            ring.push(rec(id));
        }
        assert_eq!(ring.pushed(), 10);
        let snap = ring.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10]);
        for (seq, r) in &snap {
            assert_eq!(r.id, *seq); // ids were pushed in seq order
            assert_consistent(r);
        }
    }

    #[test]
    fn capacity_floor_is_one() {
        let ring = TraceRing::new(0);
        assert_eq!(ring.capacity(), 1);
        ring.push(rec(1));
        ring.push(rec(2));
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1.id, 2);
    }

    /// Satellite: 4 writers hammering one ring through many wraps — the
    /// snapshot must hold exactly the last-capacity sequence window,
    /// every record internally consistent (no lost or torn traces).
    #[test]
    fn four_writer_contention_loses_and_tears_nothing() {
        const WRITERS: u64 = 4;
        const PER_WRITER: u64 = 200;
        const CAP: usize = 64;
        let ring = TraceRing::new(CAP);
        std::thread::scope(|scope| {
            for w in 0..WRITERS {
                let ring = &ring;
                scope.spawn(move || {
                    for k in 0..PER_WRITER {
                        ring.push(rec(w * PER_WRITER + k + 1));
                    }
                });
            }
        });
        let total = WRITERS * PER_WRITER;
        assert_eq!(ring.pushed(), total);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), CAP, "every slot holds a record after {total} pushes");
        let seqs: Vec<u64> = snap.iter().map(|(s, _)| *s).collect();
        let want: Vec<u64> = (total - CAP as u64 + 1..=total).collect();
        assert_eq!(seqs, want, "snapshot must be exactly the newest {CAP} tickets");
        for (_, r) in &snap {
            assert_consistent(r);
        }
    }
}
