//! Unified observability layer: metric registry, per-request stage
//! tracing, slow-trace ring buffer, and exportable snapshots.
//!
//! Everything here is dependency-free and rides the lock-free
//! primitives in [`crate::metrics`] ([`LatencyHistogram`],
//! [`DepthGauge`]):
//!
//! - **[`ObsRegistry`]** — named, labeled counters/gauges/histograms
//!   with one canonical name per counter in the system. Instruments
//!   are cumulative; interval views come from diffing snapshots (plus
//!   the gauge's built-in window), so there is no `snapshot_and_reset`
//!   race to lose increments to.
//! - **Stage tracing** — a request ID minted at admission rides the
//!   request through `Dispatcher` → `Engine` → `MicroBatcher` →
//!   `DurableRegistry`; span timers decompose p99 into the nine
//!   [`Stage`]s (admit-wait, align, queue-wait, estep-batch,
//!   backend-project, wal-append, wal-fsync, session-feed,
//!   session-score).
//! - **[`TraceRing`]** — the last N completed traces over a
//!   configurable threshold, readable without stopping traffic.
//! - **Exporters** — [`ObsRegistry::render`] emits Prometheus text or
//!   a JSON snapshot (the `stats` CLI consumes the latter; the future
//!   TCP front-end can serve either verbatim).
//!
//! The per-engine instruments carry an `engine="<instance>"` label and
//! are deregistered when the engine drops, so a rolling swap replaces
//! a replica's series instead of leaking a stale generation into every
//! future export.

mod clock;
mod export;
mod ring;
mod trace;

pub use clock::Clock;
pub use export::{
    latency_summary_json, parse_json, validate_snapshot, Json, CANONICAL_CAPTURE_METRICS,
    CANONICAL_CLUSTER_METRICS, CANONICAL_METRICS,
};
pub use ring::TraceRing;
pub use trace::{
    add_current_stage, current, enter, RequestTrace, TraceOutcome, TraceRecord, TraceScope,
};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ObsConfig;
use crate::metrics::{DepthGauge, DepthSummary, LatencyHistogram, LatencySummary};

/// Canonical name of the per-stage request latency series (labeled
/// `stage="<name>"`).
pub const STAGE_METRIC: &str = "serve_stage_latency_seconds";

/// The named request-path stages every trace decomposes into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Waiting for micro-batch queue space at admission.
    AdmitWait,
    /// Frame alignment + Baum-Welch statistics on the request thread.
    Align,
    /// Admitted job waiting in the queue for a worker to pick it up.
    QueueWait,
    /// The batched E-step dispatch the request rode in.
    EstepBatch,
    /// LDA/PLDA projection + scoring of the extracted i-vector.
    BackendProject,
    /// Registry WAL record append.
    WalAppend,
    /// Registry WAL fsync.
    WalFsync,
    /// Streaming session: chunk alignment + stat absorption on feed.
    SessionFeed,
    /// Streaming session: partial-stat finalize + batched score.
    SessionScore,
}

/// Number of [`Stage`] variants (the length of every per-stage array).
pub const N_STAGES: usize = 9;

impl Stage {
    pub const ALL: [Stage; N_STAGES] = [
        Stage::AdmitWait,
        Stage::Align,
        Stage::QueueWait,
        Stage::EstepBatch,
        Stage::BackendProject,
        Stage::WalAppend,
        Stage::WalFsync,
        Stage::SessionFeed,
        Stage::SessionScore,
    ];

    /// The snake_case label value (`stage="<this>"`).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::AdmitWait => "admit_wait",
            Self::Align => "align",
            Self::QueueWait => "queue_wait",
            Self::EstepBatch => "estep_batch",
            Self::BackendProject => "backend_project",
            Self::WalAppend => "wal_append",
            Self::WalFsync => "wal_fsync",
            Self::SessionFeed => "session_feed",
            Self::SessionScore => "session_score",
        }
    }

    /// Index into per-stage arrays (declaration order).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Handle onto a registered monotonic counter. Cheap to clone; all
/// clones share the one atomic the registry exports.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Instrument {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<DepthGauge>),
    Histogram(Arc<LatencyHistogram>),
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    kind: Instrument,
}

/// One instrument's state as frozen by [`ObsRegistry::snapshot`].
pub struct MetricSnapshot {
    /// Canonical `name{label="value",...}` key.
    pub key: String,
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: SnapshotValue,
}

/// The typed payload of a [`MetricSnapshot`].
pub enum SnapshotValue {
    Counter(u64),
    /// Lifetime plus windowed-since-last-snapshot gauge stats (reading
    /// the window resets it — interval-delta semantics).
    Gauge { lifetime: DepthSummary, window: DepthSummary },
    Histogram(LatencySummary),
}

/// Export format selector for [`ObsRegistry::render`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RenderFormat {
    Prometheus,
    Json,
}

fn key_of(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut s = String::from(name);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{k}=\"{v}\""));
    }
    s.push('}');
    s
}

/// The metric registry + trace machinery one serving process (or one
/// engine/dispatcher under test) shares.
pub struct ObsRegistry {
    enabled: bool,
    clock: Clock,
    trace_threshold_ns: u64,
    instruments: Mutex<BTreeMap<String, Entry>>,
    stage_lat: [Arc<LatencyHistogram>; N_STAGES],
    ring: TraceRing,
    next_request_id: AtomicU64,
    next_instance_id: AtomicU64,
}

impl std::fmt::Debug for ObsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsRegistry")
            .field("enabled", &self.enabled)
            .field("trace_threshold_ns", &self.trace_threshold_ns)
            .field("ring_capacity", &self.ring.capacity())
            .finish_non_exhaustive()
    }
}

impl Default for ObsRegistry {
    fn default() -> Self {
        Self::new(&ObsConfig::default())
    }
}

impl ObsRegistry {
    pub fn new(cfg: &ObsConfig) -> Self {
        Self::with_clock(cfg, Clock::Real)
    }

    /// Registry on an explicit clock — tests inject [`Clock::mock`] for
    /// deterministic span timings.
    pub fn with_clock(cfg: &ObsConfig, clock: Clock) -> Self {
        let mut map = BTreeMap::new();
        let stage_lat = Stage::ALL.map(|s| {
            let h = Arc::new(LatencyHistogram::new());
            let labels = [("stage", s.as_str())];
            map.insert(
                key_of(STAGE_METRIC, &labels),
                Entry {
                    name: STAGE_METRIC.to_string(),
                    labels: vec![("stage".to_string(), s.as_str().to_string())],
                    kind: Instrument::Histogram(Arc::clone(&h)),
                },
            );
            h
        });
        Self {
            enabled: cfg.enabled,
            clock,
            trace_threshold_ns: (cfg.trace_threshold_ms.max(0.0) * 1e6) as u64,
            instruments: Mutex::new(map),
            stage_lat,
            ring: TraceRing::new(cfg.trace_ring),
            next_request_id: AtomicU64::new(0),
            next_instance_id: AtomicU64::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Monotonic per-registry instance id — engines take one to build
    /// their `engine="<id>"` label, so a swapped-in replacement never
    /// collides with the series of the engine it retired.
    pub fn next_instance(&self) -> u64 {
        self.next_instance_id.fetch_add(1, Ordering::Relaxed)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Entry>> {
        self.instruments.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Get-or-create a named counter. Re-requesting the same
    /// name+labels returns a handle onto the same atomic.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let key = key_of(name, labels);
        let mut m = self.lock();
        if let Some(Entry { kind: Instrument::Counter(c), .. }) = m.get(&key) {
            return Counter(Arc::clone(c));
        }
        let c = Arc::new(AtomicU64::new(0));
        m.insert(key, self.entry(name, labels, Instrument::Counter(Arc::clone(&c))));
        Counter(c)
    }

    /// Get-or-create a named depth gauge.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<DepthGauge> {
        let key = key_of(name, labels);
        let mut m = self.lock();
        if let Some(Entry { kind: Instrument::Gauge(g), .. }) = m.get(&key) {
            return Arc::clone(g);
        }
        let g = Arc::new(DepthGauge::new());
        m.insert(key, self.entry(name, labels, Instrument::Gauge(Arc::clone(&g))));
        g
    }

    /// Get-or-create a named latency histogram.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<LatencyHistogram> {
        let key = key_of(name, labels);
        let mut m = self.lock();
        if let Some(Entry { kind: Instrument::Histogram(h), .. }) = m.get(&key) {
            return Arc::clone(h);
        }
        let h = Arc::new(LatencyHistogram::new());
        m.insert(key, self.entry(name, labels, Instrument::Histogram(Arc::clone(&h))));
        h
    }

    fn entry(&self, name: &str, labels: &[(&str, &str)], kind: Instrument) -> Entry {
        Entry {
            name: name.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            kind,
        }
    }

    /// Drop every instrument carrying `label="value"` — how a retiring
    /// engine removes its per-instance series from future exports.
    pub fn remove_label(&self, label: &str, value: &str) {
        self.lock().retain(|_, e| !e.labels.iter().any(|(k, v)| k == label && v == value));
    }

    /// Record `ns` into a stage's latency histogram (no trace
    /// attribution — callers with a trace use [`ObsRegistry::span`] or
    /// add to the trace themselves).
    pub fn observe_stage_ns(&self, stage: Stage, ns: u64) {
        if self.enabled {
            self.stage_lat[stage.index()].record(ns as f64 / 1e9);
        }
    }

    /// `(name, summary)` for all nine stage histograms, declaration
    /// order — the bench reports' per-stage breakdown.
    pub fn stage_summaries(&self) -> Vec<(&'static str, LatencySummary)> {
        Stage::ALL
            .iter()
            .map(|s| (s.as_str(), self.stage_lat[s.index()].summary()))
            .collect()
    }

    /// Start a span over `stage`: on drop it records into the stage
    /// histogram and (when a request scope is installed on this
    /// thread) into the current trace.
    pub fn span(&self, stage: Stage) -> Span<'_> {
        if !self.enabled {
            return Span { obs: self, stage, start_ns: 0, trace: None, active: false };
        }
        Span {
            obs: self,
            stage,
            start_ns: self.clock.now_ns(),
            trace: trace::current(),
            active: true,
        }
    }

    /// Mint a new request trace (None when tracing is disabled). The
    /// caller installs it with [`enter`] and finalizes it with
    /// [`ObsRegistry::complete`].
    pub fn mint(&self) -> Option<Arc<RequestTrace>> {
        if !self.enabled {
            return None;
        }
        let id = self.next_request_id.fetch_add(1, Ordering::Relaxed) + 1;
        Some(Arc::new(RequestTrace::new(id, self.clock.now_ns())))
    }

    /// Finalize a minted trace: compute its end-to-end time and, if it
    /// met the slow-trace threshold, freeze it into the ring.
    pub fn complete(&self, trace: &Arc<RequestTrace>, outcome: TraceOutcome) {
        let total_ns = self.clock.now_ns().saturating_sub(trace.start_ns);
        if total_ns >= self.trace_threshold_ns {
            self.ring.push(trace.to_record(total_ns, outcome));
        }
    }

    /// The slow-trace ring's live contents, oldest first.
    pub fn slow_traces(&self) -> Vec<TraceRecord> {
        self.ring.snapshot().into_iter().map(|(_, r)| r).collect()
    }

    /// Freeze every instrument, sorted by canonical key. Gauge windows
    /// reset on read (interval-delta semantics), so back-to-back
    /// snapshots see disjoint windows.
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        self.lock()
            .iter()
            .map(|(key, e)| MetricSnapshot {
                key: key.clone(),
                name: e.name.clone(),
                labels: e.labels.clone(),
                value: match &e.kind {
                    Instrument::Counter(c) => {
                        SnapshotValue::Counter(c.load(Ordering::Relaxed))
                    }
                    Instrument::Gauge(g) => SnapshotValue::Gauge {
                        lifetime: g.summary(),
                        window: g.take_window(),
                    },
                    Instrument::Histogram(h) => SnapshotValue::Histogram(h.summary()),
                },
            })
            .collect()
    }

    /// Render the full registry state — Prometheus text exposition or
    /// the JSON snapshot (which also carries the slow-trace ring).
    pub fn render(&self, format: RenderFormat) -> String {
        let metrics = self.snapshot();
        match format {
            RenderFormat::Prometheus => export::render_prometheus(&metrics),
            RenderFormat::Json => export::render_json(&metrics, &self.slow_traces()),
        }
    }
}

/// Live span timer from [`ObsRegistry::span`]; records on drop.
#[must_use = "a span records its stage time when dropped"]
pub struct Span<'a> {
    obs: &'a ObsRegistry,
    stage: Stage,
    start_ns: u64,
    trace: Option<Arc<RequestTrace>>,
    active: bool,
}

impl Span<'_> {
    /// End the span now (sugar over drop for explicit call sites).
    pub fn finish(self) {}
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let ns = self.obs.clock.now_ns().saturating_sub(self.start_ns);
        self.obs.observe_stage_ns(self.stage, ns);
        if let Some(t) = &self.trace {
            t.add_stage(self.stage, ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_registry(threshold_ms: f64, ring: usize) -> (ObsRegistry, Arc<AtomicU64>) {
        let (clock, t) = Clock::mock();
        let cfg = ObsConfig { enabled: true, trace_threshold_ms: threshold_ms, trace_ring: ring };
        (ObsRegistry::with_clock(&cfg, clock), t)
    }

    /// Satellite: deterministic span timing through the injectable mock
    /// clock — the span's measured time is exactly the mock advance,
    /// landing in both the stage histogram and the current trace.
    #[test]
    fn mock_clock_spans_are_deterministic() {
        let (obs, t) = mock_registry(0.0, 8);
        let trace = obs.mint().expect("tracing enabled");
        let scope = enter(Arc::clone(&trace));

        let span = obs.span(Stage::Align);
        t.fetch_add(5_000_000, Ordering::Relaxed); // +5 ms
        span.finish();

        let span = obs.span(Stage::EstepBatch);
        t.fetch_add(2_000_000, Ordering::Relaxed); // +2 ms
        drop(span);

        assert_eq!(trace.stage_ns(Stage::Align), 5_000_000);
        assert_eq!(trace.stage_ns(Stage::EstepBatch), 2_000_000);
        drop(scope);

        t.fetch_add(1_000_000, Ordering::Relaxed); // +1 ms outside any stage
        obs.complete(&trace, TraceOutcome::Ok);
        let traces = obs.slow_traces();
        assert_eq!(traces.len(), 1);
        let r = &traces[0];
        assert_eq!(r.id, trace.id);
        assert_eq!(r.total_ns, 8_000_000);
        assert_eq!(r.stage_sum_ns(), 7_000_000);
        assert!(r.stage_sum_ns() <= r.total_ns);
        assert_eq!(r.outcome, TraceOutcome::Ok);

        let stages = obs.stage_summaries();
        let align = stages.iter().find(|(n, _)| *n == "align").unwrap().1;
        assert_eq!(align.count, 1);
        // log-bucket quantile: the upper edge of the covering bucket
        assert!(align.p50_s >= 0.005 && align.p50_s < 0.006, "{}", align.p50_s);
        let estep = stages.iter().find(|(n, _)| *n == "estep_batch").unwrap().1;
        assert_eq!(estep.count, 1);
        assert!((estep.mean_s - 0.002).abs() < 1e-9);
    }

    #[test]
    fn threshold_filters_fast_traces_out_of_the_ring() {
        let (obs, t) = mock_registry(3.0, 8);
        // 1 ms trace: below the 3 ms threshold
        let fast = obs.mint().unwrap();
        t.fetch_add(1_000_000, Ordering::Relaxed);
        obs.complete(&fast, TraceOutcome::Ok);
        assert!(obs.slow_traces().is_empty());
        // 4 ms trace: recorded
        let slow = obs.mint().unwrap();
        t.fetch_add(4_000_000, Ordering::Relaxed);
        obs.complete(&slow, TraceOutcome::Timeout);
        let traces = obs.slow_traces();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].id, slow.id);
        assert_eq!(traces[0].outcome, TraceOutcome::Timeout);
        assert!(slow.id > fast.id, "request ids are minted monotonically");
    }

    #[test]
    fn disabled_registry_is_inert() {
        let cfg = ObsConfig { enabled: false, ..ObsConfig::default() };
        let obs = ObsRegistry::new(&cfg);
        assert!(obs.mint().is_none());
        obs.span(Stage::Align).finish();
        obs.observe_stage_ns(Stage::Align, 1_000_000);
        assert_eq!(obs.stage_summaries()[Stage::Align.index()].1.count, 0);
    }

    #[test]
    fn instruments_are_shared_by_name_and_removed_by_label() {
        let obs = ObsRegistry::default();
        let a = obs.counter("serve_shed_total", &[("engine", "0")]);
        let b = obs.counter("serve_shed_total", &[("engine", "0")]);
        a.add(2);
        b.inc();
        assert_eq!(a.get(), 3, "same name+labels shares one atomic");
        let other = obs.counter("serve_shed_total", &[("engine", "1")]);
        assert_eq!(other.get(), 0, "different labels are a different series");
        let h = obs.histogram("serve_extract_latency_seconds", &[("engine", "0")]);
        h.record(0.001);
        let g = obs.gauge("serve_queue_depth", &[("engine", "0")]);
        g.record(4);

        let keys: Vec<String> = obs.snapshot().into_iter().map(|m| m.key).collect();
        assert!(keys.contains(&"serve_shed_total{engine=\"0\"}".to_string()));
        assert!(keys.contains(&"serve_extract_latency_seconds{engine=\"0\"}".to_string()));

        obs.remove_label("engine", "0");
        let keys: Vec<String> = obs.snapshot().into_iter().map(|m| m.key).collect();
        assert!(!keys.iter().any(|k| k.contains("engine=\"0\"")), "{keys:?}");
        assert!(keys.contains(&"serve_shed_total{engine=\"1\"}".to_string()));
        // the per-stage series are construction-registered and stay
        assert_eq!(keys.iter().filter(|k| k.starts_with(STAGE_METRIC)).count(), N_STAGES);
    }

    /// Satellite: exposition-format golden test — Prometheus text and
    /// the JSON snapshot round-trip through the bundled parser and
    /// validator.
    #[test]
    fn exposition_golden_round_trip() {
        let (obs, t) = mock_registry(0.0, 8);
        // one instrument of each kind, with known values
        for name in [
            "serve_extract_latency_seconds",
            "serve_enroll_latency_seconds",
            "serve_verify_latency_seconds",
        ] {
            let h = obs.histogram(name, &[("engine", "0")]);
            h.record(0.002);
            h.record(f64::NAN); // lands in `invalid`, not bucket 0
        }
        for name in [
            "serve_batches_total",
            "serve_batched_requests_total",
            "serve_shed_total",
            "serve_timeouts_total",
            "serve_expired_jobs_total",
        ] {
            obs.counter(name, &[("engine", "0")]).add(3);
        }
        let g = obs.gauge("serve_queue_depth", &[("engine", "0")]);
        g.record(2);
        g.record(6);
        let trace = obs.mint().unwrap();
        trace.add_stage(Stage::Align, 2_000_000);
        trace.add_hop(0);
        trace.add_hop(1);
        trace.record_failover();
        obs.span(Stage::Align).finish();
        t.fetch_add(2_500_000, Ordering::Relaxed);
        obs.complete(&trace, TraceOutcome::Ok);

        let prom = obs.render(RenderFormat::Prometheus);
        assert!(prom.contains("# TYPE serve_shed_total counter"), "{prom}");
        assert!(prom.contains("serve_shed_total{engine=\"0\"} 3"), "{prom}");
        assert!(prom.contains("# TYPE serve_queue_depth gauge"), "{prom}");
        assert!(prom.contains("serve_queue_depth_max{engine=\"0\"} 6"), "{prom}");
        assert!(prom.contains("serve_queue_depth_window_max{engine=\"0\"} 6"), "{prom}");
        assert!(prom.contains("# TYPE serve_extract_latency_seconds summary"), "{prom}");
        assert!(
            prom.contains("serve_extract_latency_seconds{engine=\"0\",quantile=\"0.5\"}"),
            "{prom}"
        );
        assert!(prom.contains("serve_extract_latency_seconds_count{engine=\"0\"} 1"), "{prom}");
        assert!(prom.contains("serve_extract_latency_seconds_invalid{engine=\"0\"} 1"), "{prom}");
        assert!(
            prom.contains(&format!("{STAGE_METRIC}{{stage=\"align\",quantile=\"0.99\"}}")),
            "{prom}"
        );

        let json = obs.render(RenderFormat::Json);
        validate_snapshot(&json).expect("snapshot validates");
        let doc = parse_json(&json).unwrap();
        let metrics = doc.get("metrics").unwrap();
        let shed = metrics.get("serve_shed_total{engine=\"0\"}").unwrap();
        assert_eq!(shed.get("value").unwrap().as_num(), Some(3.0));
        let align = metrics
            .get(&format!("{STAGE_METRIC}{{stage=\"align\"}}"))
            .unwrap();
        assert_eq!(align.get("count").unwrap().as_num(), Some(1.0));
        let traces = doc.get("slow_traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        assert_eq!(traces[0].get("failovers").unwrap().as_num(), Some(1.0));
        let hops = traces[0].get("hops").unwrap().as_arr().unwrap();
        assert_eq!(hops.len(), 2, "both replica hops survive the export");
        assert_eq!(
            traces[0].get("stages_ms").unwrap().get("align").unwrap().as_num(),
            Some(2.0)
        );

        // the gauge window reset on the first snapshot: a second export
        // with no new samples shows an empty window, intact lifetime
        let json2 = obs.render(RenderFormat::Json);
        let doc2 = parse_json(&json2).unwrap();
        let depth = doc2.get("metrics").unwrap().get("serve_queue_depth{engine=\"0\"}").unwrap();
        assert_eq!(depth.get("window_samples").unwrap().as_num(), Some(0.0));
        assert_eq!(depth.get("max").unwrap().as_num(), Some(6.0));
    }

    #[test]
    fn validator_rejects_malformed_and_renamed() {
        assert!(validate_snapshot("not json").is_err());
        assert!(validate_snapshot("{}").is_err());
        // a full valid snapshot minus one canonical metric must fail
        let obs = ObsRegistry::default();
        let json = obs.render(RenderFormat::Json);
        // bare registry lacks the engine-level canonical metrics
        let err = validate_snapshot(&json).unwrap_err();
        assert!(err.to_string().contains("canonical metric"), "{err:#}");
        // with the engine set registered it validates... (the counters
        // straddle the queue-depth gauge at index 9, hence two slices)
        for name in CANONICAL_METRICS[4..9].iter().chain(&CANONICAL_METRICS[10..]) {
            obs.counter(name, &[("engine", "0")]);
        }
        for name in &CANONICAL_METRICS[1..4] {
            obs.histogram(name, &[("engine", "0")]);
        }
        obs.gauge("serve_queue_depth", &[("engine", "0")]);
        validate_snapshot(&obs.render(RenderFormat::Json)).unwrap();
        // ...and a rename breaks it again
        let renamed = obs
            .render(RenderFormat::Json)
            .replace("serve_shed_total", "serve_load_shed_total");
        let err = validate_snapshot(&renamed).unwrap_err();
        assert!(err.to_string().contains("serve_shed_total"), "{err:#}");
    }

    #[test]
    fn validator_enforces_cluster_canon_only_on_cluster_snapshots() {
        // an engine-only snapshot needs no cluster metrics at all
        let obs = ObsRegistry::default();
        for name in CANONICAL_METRICS[4..9].iter().chain(&CANONICAL_METRICS[10..]) {
            obs.counter(name, &[("engine", "0")]);
        }
        for name in &CANONICAL_METRICS[1..4] {
            obs.histogram(name, &[("engine", "0")]);
        }
        obs.gauge("serve_queue_depth", &[("engine", "0")]);
        validate_snapshot(&obs.render(RenderFormat::Json)).unwrap();

        // the routing-counter sentinel alone flips the snapshot into a
        // cluster snapshot — the rest of the cluster canon (including
        // the self-healing counters) becomes required
        obs.counter("cluster_routed_total", &[]);
        let err = validate_snapshot(&obs.render(RenderFormat::Json)).unwrap_err();
        assert!(err.to_string().contains("cluster canonical metric"), "{err:#}");

        // the full canon — with the health gauge labeled per replica,
        // as the dispatcher registers it — validates
        for name in &CANONICAL_CLUSTER_METRICS[1..7] {
            obs.counter(name, &[]);
        }
        obs.gauge("cluster_replica_health", &[("replica", "0")]);
        validate_snapshot(&obs.render(RenderFormat::Json)).unwrap();

        // a renamed supervisor counter breaks it again
        let renamed = obs
            .render(RenderFormat::Json)
            .replace("cluster_self_heals_total", "cluster_heals_total");
        let err = validate_snapshot(&renamed).unwrap_err();
        assert!(err.to_string().contains("cluster_self_heals_total"), "{err:#}");
    }
}
