//! Property-testing substrate (the proptest crate is unavailable
//! offline). Seeded case generation with failure reporting: on a
//! failing case the runner reports the case seed so the exact input is
//! reproducible with `forall_seeded`.

use crate::linalg::Mat;
use crate::rng::Rng;

/// Number of cases per property (kept modest; these run in `cargo test`).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` generated inputs. `gen` builds a case from a
/// per-case rng; `prop` returns `Err(reason)` on violation.
///
/// Panics with the failing case seed on the first violation.
pub fn forall<T, G, P>(base_seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case as u64);
        let mut rng = Rng::seed(case_seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {reason}\ninput: {input:?}"
            );
        }
    }
}

/// Re-run a single case by seed (debugging a failure from [`forall`]).
pub fn forall_seeded<T, G, P>(case_seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::seed(case_seed);
    let input = gen(&mut rng);
    if let Err(reason) = prop(&input) {
        panic!("property failed (seed {case_seed:#x}): {reason}\ninput: {input:?}");
    }
}

/// Generator: dimension in [lo, hi].
pub fn gen_dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Generator: random matrix with entries ~ scale·N(0,1).
pub fn gen_mat(rng: &mut Rng, rows: usize, cols: usize, scale: f64) -> Mat {
    Mat::from_fn(rows, cols, |_, _| scale * rng.normal())
}

/// Generator: random SPD matrix `M Mᵀ + ridge·I`.
pub fn gen_spd(rng: &mut Rng, n: usize, ridge: f64) -> Mat {
    let m = gen_mat(rng, n, n, 1.0);
    let mut a = m.matmul_nt(&m);
    for i in 0..n {
        *a.get_mut(i, i) += ridge;
    }
    a
}

/// Generator: probability vector of length n (Dirichlet(1)).
pub fn gen_simplex(rng: &mut Rng, n: usize) -> Vec<f64> {
    rng.dirichlet(1.0, n)
}

/// Helper: assert two f64s are close, producing a property error.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{jacobi_eigh, Cholesky, Lu};

    #[test]
    fn prop_chol_solves_spd() {
        forall(
            101,
            DEFAULT_CASES,
            |rng| {
                let n = gen_dim(rng, 1, 12);
                let a = gen_spd(rng, n, n as f64);
                let b: Vec<f64> = rng.normal_vec(n);
                (a, b)
            },
            |(a, b)| {
                let x = Cholesky::new(a).map_err(|e| e.to_string())?.solve_vec(b);
                let ax = a.matvec(&x);
                for (l, r) in ax.iter().zip(b) {
                    close(*l, *r, 1e-8, "A x = b residual")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_lu_inverse() {
        forall(
            202,
            DEFAULT_CASES,
            |rng| {
                let n = gen_dim(rng, 1, 10);
                // shifted to keep condition number sane
                let mut m = gen_mat(rng, n, n, 1.0);
                for i in 0..n {
                    *m.get_mut(i, i) += 4.0;
                }
                m
            },
            |a| {
                let inv = Lu::new(a).map_err(|e| e.to_string())?.inverse();
                let id = a.matmul(&inv);
                if id.approx_eq(&Mat::eye(a.rows()), 1e-7) {
                    Ok(())
                } else {
                    Err(format!("A·A⁻¹ deviates by {}", id.sub(&Mat::eye(a.rows())).max_abs()))
                }
            },
        );
    }

    #[test]
    fn prop_eigh_reconstructs_and_orthonormal() {
        forall(
            303,
            32,
            |rng| {
                let n = gen_dim(rng, 2, 10);
                let mut a = gen_mat(rng, n, n, 2.0);
                a.symmetrize();
                a
            },
            |a| {
                let e = jacobi_eigh(a);
                if !e.reconstruct().approx_eq(a, 1e-9) {
                    return Err("QΛQᵀ ≠ A".into());
                }
                let qtq = e.vectors.matmul_tn(&e.vectors);
                if !qtq.approx_eq(&Mat::eye(a.rows()), 1e-9) {
                    return Err("Q not orthonormal".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_simplex_sums_to_one() {
        forall(
            404,
            DEFAULT_CASES,
            |rng| {
                let n = gen_dim(rng, 1, 30);
                gen_simplex(rng, n)
            },
            |p| close(p.iter().sum::<f64>(), 1.0, 1e-10, "simplex sum"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(1, 4, |rng| rng.uniform(), |&u| if u < 2.0 { Err("forced".into()) } else { Ok(()) });
    }
}
