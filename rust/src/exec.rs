//! Threaded execution substrate — the paper's Fig. 1 pipeline.
//!
//! The paper keeps its GPU saturated by running multiple CPU data
//! loaders in parallel with device execution. This module provides that
//! shape with std threads + bounded channels (tokio is unavailable
//! offline, and the workload is CPU/compute bound anyway):
//!
//! * [`map_parallel`] — order-preserving parallel map over items
//!   (used for Baum-Welch statistics, per-utterance CPU work).
//! * [`Pipeline`] — producers push prepared batches into a bounded
//!   queue; a single consumer (the device executor) drains it. Producer
//!   and consumer busy-times are tracked so benchmarks can report
//!   pipeline efficiency.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

/// Order-preserving parallel map: applies `f` to every item index using
/// `workers` threads and returns outputs in input order.
pub fn map_parallel<T, F>(n_items: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n_items == 0 {
        return Vec::new();
    }
    let workers = workers.min(n_items);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n_items).map(|_| None).collect();
    let out_ptr = SendPtr(out.as_mut_ptr());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let out_ptr = out_ptr;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let v = f(i);
                // SAFETY: each index i is claimed exactly once via the
                // atomic counter, so no two threads write the same slot,
                // and the scope keeps `out` alive.
                unsafe { out_ptr.write(i, Some(v)) };
            });
        }
    });

    out.into_iter().map(|v| v.expect("worker completed")).collect()
}

/// Raw-pointer wrapper that is Send/Sync by construction. A method (not
/// direct field access) is used at the write site so the 2021-edition
/// closure captures the wrapper, not the bare pointer field.
struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    /// SAFETY: caller guarantees exclusive access to slot `i` and that
    /// the allocation outlives the call.
    unsafe fn write(&self, i: usize, v: T) {
        *self.0.add(i) = v;
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        Self(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Busy-time accounting shared between the pipeline sides.
#[derive(Default)]
pub struct PipelineStats {
    producer_busy_ns: AtomicU64,
    consumer_busy_ns: AtomicU64,
    items: AtomicUsize,
}

impl PipelineStats {
    /// Seconds the producers spent computing (summed across threads).
    pub fn producer_busy(&self) -> f64 {
        self.producer_busy_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Seconds the consumer spent computing.
    pub fn consumer_busy(&self) -> f64 {
        self.consumer_busy_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Items that flowed through the pipeline.
    pub fn items(&self) -> usize {
        self.items.load(Ordering::Relaxed)
    }

    /// Consumer busy fraction of wall time — how well the loaders kept
    /// the device fed (the paper's "keep the GPU utilized all the time").
    pub fn consumer_utilization(&self, wall: f64) -> f64 {
        if wall <= 0.0 {
            return 0.0;
        }
        self.consumer_busy() / wall
    }
}

/// Producer/consumer pipeline over an indexed work list.
///
/// `n_producers` threads run `produce(index)` for every index in
/// `0..n_items` (dynamic scheduling), pushing into a bounded queue of
/// `queue_cap`; the calling thread runs `consume(index, item)` in
/// arbitrary arrival order. Returns the pipeline stats + wall seconds.
pub fn pipeline<T, P, C>(
    n_items: usize,
    n_producers: usize,
    queue_cap: usize,
    produce: P,
    mut consume: C,
) -> (Arc<PipelineStats>, f64)
where
    T: Send,
    P: Fn(usize) -> T + Sync,
    C: FnMut(usize, T),
{
    let stats = Arc::new(PipelineStats::default());
    let wall0 = Instant::now();
    if n_items == 0 {
        return (stats, 0.0);
    }
    let n_producers = n_producers.max(1).min(n_items);
    let (tx, rx): (SyncSender<(usize, T)>, Receiver<(usize, T)>) = sync_channel(queue_cap.max(1));
    let next = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..n_producers {
            let tx = tx.clone();
            let next = &next;
            let produce = &produce;
            let stats = Arc::clone(&stats);
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let t0 = Instant::now();
                let item = produce(i);
                stats
                    .producer_busy_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                if tx.send((i, item)).is_err() {
                    break; // consumer dropped — abort quietly
                }
            });
        }
        drop(tx); // close the channel once all producers finish

        for (i, item) in rx {
            let t0 = Instant::now();
            consume(i, item);
            stats
                .consumer_busy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            stats.items.fetch_add(1, Ordering::Relaxed);
        }
    });

    let wall = wall0.elapsed().as_secs_f64();
    (stats, wall)
}

/// Reasonable default worker count: physical parallelism minus one for
/// the consumer thread.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_parallel_preserves_order() {
        let out = map_parallel(100, 7, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_parallel_empty_and_single() {
        assert_eq!(map_parallel(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(map_parallel(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn pipeline_processes_everything() {
        let mut seen = vec![false; 50];
        let mut sum = 0usize;
        let (stats, _wall) = pipeline(
            50,
            4,
            8,
            |i| i * 2,
            |i, v| {
                assert_eq!(v, i * 2);
                seen[i] = true;
                sum += v;
            },
        );
        assert!(seen.iter().all(|&s| s));
        assert_eq!(sum, (0..50).map(|i| i * 2).sum::<usize>());
        assert_eq!(stats.items(), 50);
    }

    #[test]
    fn pipeline_overlaps_work() {
        // producers sleep; consumer is fast — wall should be well under
        // the serial sum of producer time.
        let per_item = std::time::Duration::from_millis(5);
        let (stats, wall) = pipeline(
            16,
            8,
            4,
            |_| std::thread::sleep(per_item),
            |_, _| {},
        );
        let serial = stats.producer_busy();
        assert!(wall < serial * 0.6, "wall {wall:.3}s vs serial {serial:.3}s");
    }

    #[test]
    fn pipeline_zero_items() {
        let (stats, _) = pipeline(0, 4, 4, |_| 0u8, |_, _| panic!("no items"));
        assert_eq!(stats.items(), 0);
    }
}
