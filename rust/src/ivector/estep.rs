//! E-step (paper §3 step 2, eqs. 3–4) — CPU reference path.
//!
//! Per utterance: posterior precision `L(u) = I + Σ_c n_c TᵀΣ⁻¹T|_c`,
//! posterior mean `φ(u) = L⁻¹(p + Σ_c TᵀΣ⁻¹ f_c)`, posterior
//! covariance `Φ(u) = L⁻¹`, accumulated into the M-step and
//! minimum-divergence sufficient statistics.

use crate::linalg::{outer, Cholesky, Mat};

use super::model::{Formulation, TvModel};

/// Per-utterance first-order statistics in the layout the extractor
/// consumes: occupancies + first-order stats (already centered for the
/// standard formulation — see [`UttStats::from_bw`]).
#[derive(Debug, Clone)]
pub struct UttStats {
    /// n_c (C).
    pub n: Vec<f64>,
    /// f_c (C × F).
    pub f: Mat,
}

impl UttStats {
    /// Adapt raw Baum-Welch stats to a formulation: the standard
    /// formulation centers around the model's bias means, the
    /// augmented consumes them raw (paper §2).
    pub fn from_bw(bw: &crate::stats::BwStats, model: &TvModel) -> Self {
        match model.formulation {
            Formulation::Standard => {
                let centered = bw.center(&model.means);
                Self { n: centered.n, f: centered.f }
            }
            Formulation::Augmented => Self { n: bw.n.clone(), f: bw.f.clone() },
        }
    }
}

/// Accumulators for the M-step + minimum divergence (paper eqs. 6–7).
#[derive(Debug, Clone)]
pub struct EstepAccum {
    /// A_c = Σ_u n_c(u) (Φ(u)+φφᵀ), C matrices of R × R.
    pub a: Vec<Mat>,
    /// B_c = Σ_u f_c(u) φ(u)ᵀ, C matrices of F × R.
    pub b: Vec<Mat>,
    /// Σ_u φ(u) (R).
    pub h: Vec<f64>,
    /// Σ_u (Φ(u)+φφᵀ) (R × R).
    pub hh: Mat,
    /// Number of utterances accumulated.
    pub count: f64,
}

impl EstepAccum {
    pub fn zeros(c: usize, f: usize, r: usize) -> Self {
        Self {
            a: vec![Mat::zeros(r, r); c],
            b: vec![Mat::zeros(f, r); c],
            h: vec![0.0; r],
            hh: Mat::zeros(r, r),
            count: 0.0,
        }
    }

    /// Merge a partial accumulator (parallel workers / device batches).
    pub fn merge(&mut self, other: &EstepAccum) {
        for (x, y) in self.a.iter_mut().zip(&other.a) {
            x.add_scaled(1.0, y);
        }
        for (x, y) in self.b.iter_mut().zip(&other.b) {
            x.add_scaled(1.0, y);
        }
        for (x, &y) in self.h.iter_mut().zip(&other.h) {
            *x += y;
        }
        self.hh.add_scaled(1.0, &other.hh);
        self.count += other.count;
    }
}

/// E-step for one utterance; returns φ and accumulates into `acc`.
///
/// `tt_si` / `tt_si_t` are the precomputed per-component constants from
/// [`TvModel::precompute`].
pub fn estep_utterance(
    stats: &UttStats,
    tt_si: &[Mat],
    tt_si_t: &[Mat],
    prior_mean: &[f64],
    acc: Option<&mut EstepAccum>,
) -> Vec<f64> {
    let r = prior_mean.len();
    let c_n = stats.n.len();

    // L = I + Σ_c n_c M_c
    let mut l_mat = Mat::eye(r);
    for c in 0..c_n {
        if stats.n[c] != 0.0 {
            l_mat.add_scaled(stats.n[c], &tt_si_t[c]);
        }
    }
    // rhs = p + Σ_c TᵀΣ⁻¹ f_c
    let mut rhs = prior_mean.to_vec();
    for c in 0..c_n {
        if stats.n[c] != 0.0 {
            let v = tt_si[c].matvec(stats.f.row(c));
            crate::linalg::axpy(1.0, &v, &mut rhs);
        }
    }
    let chol = Cholesky::new_regularized(&l_mat).0;
    let phi = chol.solve_vec(&rhs);

    if let Some(acc) = acc {
        let mut cov = chol.inverse(); // Φ
        // second moment Φ + φφᵀ
        let phi_outer = outer(&phi, &phi);
        cov.add_scaled(1.0, &phi_outer);
        for c in 0..c_n {
            if stats.n[c] != 0.0 {
                acc.a[c].add_scaled(stats.n[c], &cov);
                // B_c += f_c φᵀ
                acc.b[c].add_scaled(1.0, &outer(stats.f.row(c), &phi));
            }
        }
        crate::linalg::axpy(1.0, &phi, &mut acc.h);
        acc.hh.add_scaled(1.0, &cov);
        acc.count += 1.0;
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::*;
    use crate::rng::Rng;

    pub(crate) fn random_stats(c: usize, f: usize, rng: &mut Rng) -> UttStats {
        UttStats {
            n: (0..c).map(|_| rng.uniform_in(0.0, 30.0)).collect(),
            f: Mat::from_fn(c, f, |_, _| rng.normal() * 3.0),
        }
    }

    #[test]
    fn phi_solves_the_linear_system() {
        let ubm = tiny_ubm(4, 3, 1);
        let model = TvModel::init(Formulation::Augmented, &ubm, 5, 100.0, 2);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(3);
        let stats = random_stats(4, 3, &mut rng);
        let phi = estep_utterance(&stats, &tt_si, &tt_si_t, &model.prior_mean, None);

        // reconstruct L φ and compare to rhs
        let r = model.rank();
        let mut l_mat = Mat::eye(r);
        for c in 0..4 {
            l_mat.add_scaled(stats.n[c], &tt_si_t[c]);
        }
        let lphi = l_mat.matvec(&phi);
        let mut rhs = model.prior_mean.clone();
        for c in 0..4 {
            crate::linalg::axpy(1.0, &tt_si[c].matvec(stats.f.row(c)), &mut rhs);
        }
        for (a, b) in lphi.iter().zip(&rhs) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_stats_give_prior_mean() {
        let ubm = tiny_ubm(3, 2, 5);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 1);
        let (tt_si, tt_si_t) = model.precompute();
        let stats = UttStats { n: vec![0.0; 3], f: Mat::zeros(3, 2) };
        let phi = estep_utterance(&stats, &tt_si, &tt_si_t, &model.prior_mean, None);
        // L = I, rhs = p → φ = p
        for (a, b) in phi.iter().zip(&model.prior_mean) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn accumulators_match_manual_sums() {
        let ubm = tiny_ubm(3, 2, 7);
        let model = TvModel::init(Formulation::Standard, &ubm, 4, 100.0, 9);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(11);
        let s1 = random_stats(3, 2, &mut rng);
        let s2 = random_stats(3, 2, &mut rng);

        let mut acc = EstepAccum::zeros(3, 2, 4);
        let phi1 = estep_utterance(&s1, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        let phi2 = estep_utterance(&s2, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));

        assert_eq!(acc.count, 2.0);
        // h = φ1 + φ2
        for i in 0..4 {
            assert!((acc.h[i] - (phi1[i] + phi2[i])).abs() < 1e-10);
        }
        // B_c = f_c(1) φ1ᵀ + f_c(2) φ2ᵀ
        for c in 0..3 {
            let mut want = outer(s1.f.row(c), &phi1);
            want.add_scaled(1.0, &outer(s2.f.row(c), &phi2));
            assert!(acc.b[c].approx_eq(&want, 1e-10));
        }
    }

    #[test]
    fn merge_equals_joint_accumulation() {
        let ubm = tiny_ubm(3, 2, 13);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 2);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(17);
        let stats: Vec<UttStats> = (0..4).map(|_| random_stats(3, 2, &mut rng)).collect();

        let mut joint = EstepAccum::zeros(3, 2, 4);
        for s in &stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut joint));
        }
        let mut a1 = EstepAccum::zeros(3, 2, 4);
        let mut a2 = EstepAccum::zeros(3, 2, 4);
        for s in &stats[..2] {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut a1));
        }
        for s in &stats[2..] {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut a2));
        }
        a1.merge(&a2);
        assert_eq!(a1.count, joint.count);
        assert!(a1.hh.approx_eq(&joint.hh, 1e-10));
        for c in 0..3 {
            assert!(a1.a[c].approx_eq(&joint.a[c], 1e-10));
        }
    }

    #[test]
    fn centering_applied_only_for_standard() {
        let ubm = tiny_ubm(2, 2, 19);
        let std_model = TvModel::init(Formulation::Standard, &ubm, 3, 100.0, 1);
        let aug_model = TvModel::init(Formulation::Augmented, &ubm, 3, 100.0, 1);
        let bw = crate::stats::BwStats {
            n: vec![2.0, 1.0],
            f: Mat::from_rows(&[&[4.0, 2.0], &[1.0, 1.0]]),
            s: None,
        };
        let s_std = UttStats::from_bw(&bw, &std_model);
        let s_aug = UttStats::from_bw(&bw, &aug_model);
        // augmented = raw
        assert!(s_aug.f.approx_eq(&bw.f, 0.0));
        // standard = centered: f − n·m
        for c in 0..2 {
            for j in 0..2 {
                let want = bw.f.get(c, j) - bw.n[c] * ubm.means.get(c, j);
                assert!((s_std.f.get(c, j) - want).abs() < 1e-12);
            }
        }
    }
}
