//! E-step (paper §3 step 2, eqs. 3–4) — CPU paths.
//!
//! Per utterance: posterior precision `L(u) = I + Σ_c n_c TᵀΣ⁻¹T|_c`,
//! posterior mean `φ(u) = L⁻¹(p + Σ_c TᵀΣ⁻¹ f_c)`, posterior
//! covariance `Φ(u) = L⁻¹`, accumulated into the M-step and
//! minimum-divergence sufficient statistics.
//!
//! Two implementations share the math:
//!
//! * [`estep_utterance`] — the per-item scalar reference (one utterance,
//!   `outer()` temporaries), kept as the equivalence oracle;
//! * [`estep_batch_cpu`] — the batched GEMM-shaped kernel the trainer
//!   and extractor run: `Σ_c TᵀΣ⁻¹ f_c` for a whole utterance batch is
//!   one panel-blocked `(U × CF)·(CF × R)` product against
//!   [`EstepConsts::tt_si_flat`], `L` is assembled by a single packed
//!   GEMV over [`EstepConsts::tt_si_t_packed`] (mirroring the device
//!   graph's packed constants), and all accumulator updates are
//!   in-place rank-1 kernels with buffers owned by [`EstepWorkspace`].

use crate::linalg::{
    axpy, dot, factor_in_place_regularized, outer, sym_pack_into, sym_packed_len,
    sym_unpack_eye_into, sym_weighted_sum, CholRef, Cholesky, Mat,
};

use super::model::{Formulation, TvModel};

/// Per-utterance first-order statistics in the layout the extractor
/// consumes: occupancies + first-order stats (already centered for the
/// standard formulation — see [`UttStats::from_bw`]).
#[derive(Debug, Clone)]
pub struct UttStats {
    /// n_c (C).
    pub n: Vec<f64>,
    /// f_c (C × F).
    pub f: Mat,
}

impl UttStats {
    /// Adapt raw Baum-Welch stats to a formulation: the standard
    /// formulation centers around the model's bias means, the
    /// augmented consumes them raw (paper §2).
    pub fn from_bw(bw: &crate::stats::BwStats, model: &TvModel) -> Self {
        match model.formulation {
            Formulation::Standard => {
                let centered = bw.center(&model.means);
                Self { n: centered.n, f: centered.f }
            }
            Formulation::Augmented => Self { n: bw.n.clone(), f: bw.f.clone() },
        }
    }
}

/// Accumulators for the M-step + minimum divergence (paper eqs. 6–7).
#[derive(Debug, Clone)]
pub struct EstepAccum {
    /// A_c = Σ_u n_c(u) (Φ(u)+φφᵀ), C matrices of R × R.
    pub a: Vec<Mat>,
    /// B_c = Σ_u f_c(u) φ(u)ᵀ, C matrices of F × R.
    pub b: Vec<Mat>,
    /// Σ_u φ(u) (R).
    pub h: Vec<f64>,
    /// Σ_u (Φ(u)+φφᵀ) (R × R).
    pub hh: Mat,
    /// Number of utterances accumulated.
    pub count: f64,
}

impl EstepAccum {
    pub fn zeros(c: usize, f: usize, r: usize) -> Self {
        Self {
            a: vec![Mat::zeros(r, r); c],
            b: vec![Mat::zeros(f, r); c],
            h: vec![0.0; r],
            hh: Mat::zeros(r, r),
            count: 0.0,
        }
    }

    /// Merge a partial accumulator (parallel workers / device batches).
    pub fn merge(&mut self, other: &EstepAccum) {
        for (x, y) in self.a.iter_mut().zip(&other.a) {
            x.add_scaled(1.0, y);
        }
        for (x, y) in self.b.iter_mut().zip(&other.b) {
            x.add_scaled(1.0, y);
        }
        for (x, &y) in self.h.iter_mut().zip(&other.h) {
            *x += y;
        }
        self.hh.add_scaled(1.0, &other.hh);
        self.count += other.count;
    }
}

/// Per-iteration E-step constants in the batched (GEMM-friendly)
/// layout — the CPU mirror of what `AccelTvm::set_model` uploads.
/// Built once per EM iteration via [`TvModel::precompute_consts`].
#[derive(Debug, Clone)]
pub struct EstepConsts {
    /// Components C.
    pub c: usize,
    /// Feature dim F.
    pub f: usize,
    /// Rank R.
    pub r: usize,
    /// `(R × C·F)`: row i holds `[TᵀΣ⁻¹]_c[i, ·]` for ascending c —
    /// the flat layout that turns `Σ_c TᵀΣ⁻¹ f_c` into one GEMV
    /// against `vec(f)` (and a GEMM over an utterance batch).
    pub tt_si_flat: Mat,
    /// `(C × R(R+1)/2)`: packed upper triangles of `TᵀΣ⁻¹T|_c`, so
    /// `L − I = Σ_c n_c M_c` is a single packed GEMV.
    pub tt_si_t_packed: Mat,
    /// Prior mean p (R).
    pub prior_mean: Vec<f64>,
}

impl EstepConsts {
    /// Repack the per-component constants of [`TvModel::precompute`].
    pub fn from_parts(tt_si: &[Mat], tt_si_t: &[Mat], prior_mean: &[f64]) -> Self {
        let c_n = tt_si.len();
        let r = prior_mean.len();
        let f_dim = if c_n > 0 { tt_si[0].cols() } else { 0 };
        let mut flat = Mat::zeros(r, c_n * f_dim);
        for (c, m) in tt_si.iter().enumerate() {
            debug_assert_eq!((m.rows(), m.cols()), (r, f_dim));
            for i in 0..r {
                flat.row_mut(i)[c * f_dim..(c + 1) * f_dim].copy_from_slice(m.row(i));
            }
        }
        let mut packed = Mat::zeros(c_n, sym_packed_len(r));
        for (c, m) in tt_si_t.iter().enumerate() {
            sym_pack_into(m, packed.row_mut(c));
        }
        Self {
            c: c_n,
            f: f_dim,
            r,
            tt_si_flat: flat,
            tt_si_t_packed: packed,
            prior_mean: prior_mean.to_vec(),
        }
    }
}

/// Reusable scratch for [`estep_batch_cpu`]: one per worker thread, so
/// the batch loop allocates nothing but the returned φ matrix.
#[derive(Debug, Clone)]
pub struct EstepWorkspace {
    /// Right-hand sides `p + TᵀΣ⁻¹ vec(f)` (BU × R).
    rhs: Mat,
    /// Packed `L − I` accumulator (R(R+1)/2).
    l_packed: Vec<f64>,
    /// Assembled precision L (R × R).
    l_mat: Mat,
    /// Posterior second moment `Φ + φφᵀ` of the current utterance.
    cov: Mat,
    /// Batch capacity.
    bu: usize,
}

impl EstepWorkspace {
    pub fn new(r: usize, bu: usize) -> Self {
        Self {
            rhs: Mat::zeros(bu, r),
            l_packed: vec![0.0; sym_packed_len(r)],
            l_mat: Mat::zeros(r, r),
            cov: Mat::zeros(r, r),
            bu,
        }
    }

    /// Batch capacity this workspace was sized for.
    pub fn capacity(&self) -> usize {
        self.bu
    }
}

/// Shared-dimension panel width for the rhs GEMM: bounds the slice of
/// `tt_si_flat` touched per pass so the panel stays cache-resident
/// across the utterance sweep instead of re-streaming all R·C·F weights
/// per utterance.
const RHS_QB: usize = 256;

/// Batched E-step over a slice of utterances — the CPU structural twin
/// of `AccelTvm::estep_batch`. Returns the batch φ rows
/// (`batch.len() × R`) and, when `acc` is given, accumulates the
/// M-step/min-div statistics exactly like the per-item reference.
///
/// Matches [`estep_utterance`] to floating-point rounding (~1e-13
/// relative) with one caveat: the reference skips components with
/// `n_c = 0` in the rhs sum, while the GEMM cannot — so the two agree
/// only when `f_c = 0` whenever `n_c = 0`, which is guaranteed for
/// statistics accumulated from posteriors.
pub fn estep_batch_cpu(
    batch: &[&UttStats],
    consts: &EstepConsts,
    ws: &mut EstepWorkspace,
    mut acc: Option<&mut EstepAccum>,
) -> Mat {
    let (c_n, f_dim, r) = (consts.c, consts.f, consts.r);
    let u_n = batch.len();
    assert!(u_n <= ws.bu, "batch {} exceeds workspace capacity {}", u_n, ws.bu);
    let cf = c_n * f_dim;

    // rhs = p + TᵀΣ⁻¹ · vec(f): one panel-blocked GEMM over the batch;
    // each weight panel is read from memory once per batch, not once
    // per utterance.
    for u in 0..u_n {
        ws.rhs.row_mut(u).copy_from_slice(&consts.prior_mean);
    }
    for qb in (0..cf).step_by(RHS_QB) {
        let qe = (qb + RHS_QB).min(cf);
        for (u, st) in batch.iter().enumerate() {
            debug_assert_eq!(st.f.as_slice().len(), cf, "stats dims mismatch");
            let f_seg = &st.f.as_slice()[qb..qe];
            let rrow = ws.rhs.row_mut(u);
            for (i, rv) in rrow.iter_mut().enumerate() {
                *rv += dot(f_seg, &consts.tt_si_flat.row(i)[qb..qe]);
            }
        }
    }

    // per-utterance: packed L assembly, solve, in-place accumulation
    let mut phi_out = Mat::zeros(u_n, r);
    for (u, st) in batch.iter().enumerate() {
        debug_assert_eq!(st.n.len(), c_n, "stats dims mismatch");
        sym_weighted_sum(&consts.tt_si_t_packed, &st.n, &mut ws.l_packed);
        sym_unpack_eye_into(&ws.l_packed, &mut ws.l_mat);
        // blocked in-place factorization of the precision — no per-solve
        // allocation (the former `Cholesky::new_regularized` cloned an
        // R×R matrix per utterance). L is SPD by construction
        // (I + Σ n_c·PSD), so the ridge retry — which rebuilds the
        // clobbered buffer from the packed form — is a defensive rarity.
        factor_in_place_regularized(&mut ws.l_mat, |m| sym_unpack_eye_into(&ws.l_packed, m));
        let chol = CholRef::new(&ws.l_mat);
        let phi_row = phi_out.row_mut(u);
        phi_row.copy_from_slice(ws.rhs.row(u));
        chol.solve_vec_in_place(phi_row);

        if let Some(acc) = acc.as_deref_mut() {
            chol.inverse_into(&mut ws.cov); // Φ
            let phi_u = phi_out.row(u);
            ws.cov.rank1_update(1.0, phi_u, phi_u); // Φ + φφᵀ
            for c in 0..c_n {
                let w = st.n[c];
                if w != 0.0 {
                    acc.a[c].add_scaled(w, &ws.cov);
                    acc.b[c].rank1_update(1.0, st.f.row(c), phi_u);
                }
            }
            axpy(1.0, phi_u, &mut acc.h);
            acc.hh.add_scaled(1.0, &ws.cov);
            acc.count += 1.0;
        }
    }
    phi_out
}

/// E-step for one utterance; returns φ and accumulates into `acc`.
///
/// `tt_si` / `tt_si_t` are the precomputed per-component constants from
/// [`TvModel::precompute`].
pub fn estep_utterance(
    stats: &UttStats,
    tt_si: &[Mat],
    tt_si_t: &[Mat],
    prior_mean: &[f64],
    acc: Option<&mut EstepAccum>,
) -> Vec<f64> {
    let r = prior_mean.len();
    let c_n = stats.n.len();

    // L = I + Σ_c n_c M_c
    let mut l_mat = Mat::eye(r);
    for c in 0..c_n {
        if stats.n[c] != 0.0 {
            l_mat.add_scaled(stats.n[c], &tt_si_t[c]);
        }
    }
    // rhs = p + Σ_c TᵀΣ⁻¹ f_c
    let mut rhs = prior_mean.to_vec();
    for c in 0..c_n {
        if stats.n[c] != 0.0 {
            let v = tt_si[c].matvec(stats.f.row(c));
            crate::linalg::axpy(1.0, &v, &mut rhs);
        }
    }
    let chol = Cholesky::new_regularized(&l_mat).0;
    let phi = chol.solve_vec(&rhs);

    if let Some(acc) = acc {
        let mut cov = chol.inverse(); // Φ
        // second moment Φ + φφᵀ
        let phi_outer = outer(&phi, &phi);
        cov.add_scaled(1.0, &phi_outer);
        for c in 0..c_n {
            if stats.n[c] != 0.0 {
                acc.a[c].add_scaled(stats.n[c], &cov);
                // B_c += f_c φᵀ
                acc.b[c].add_scaled(1.0, &outer(stats.f.row(c), &phi));
            }
        }
        crate::linalg::axpy(1.0, &phi, &mut acc.h);
        acc.hh.add_scaled(1.0, &cov);
        acc.count += 1.0;
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::*;
    use crate::rng::Rng;

    pub(crate) fn random_stats(c: usize, f: usize, rng: &mut Rng) -> UttStats {
        UttStats {
            n: (0..c).map(|_| rng.uniform_in(0.0, 30.0)).collect(),
            f: Mat::from_fn(c, f, |_, _| rng.normal() * 3.0),
        }
    }

    #[test]
    fn phi_solves_the_linear_system() {
        let ubm = tiny_ubm(4, 3, 1);
        let model = TvModel::init(Formulation::Augmented, &ubm, 5, 100.0, 2);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(3);
        let stats = random_stats(4, 3, &mut rng);
        let phi = estep_utterance(&stats, &tt_si, &tt_si_t, &model.prior_mean, None);

        // reconstruct L φ and compare to rhs
        let r = model.rank();
        let mut l_mat = Mat::eye(r);
        for c in 0..4 {
            l_mat.add_scaled(stats.n[c], &tt_si_t[c]);
        }
        let lphi = l_mat.matvec(&phi);
        let mut rhs = model.prior_mean.clone();
        for c in 0..4 {
            crate::linalg::axpy(1.0, &tt_si[c].matvec(stats.f.row(c)), &mut rhs);
        }
        for (a, b) in lphi.iter().zip(&rhs) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_stats_give_prior_mean() {
        let ubm = tiny_ubm(3, 2, 5);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 1);
        let (tt_si, tt_si_t) = model.precompute();
        let stats = UttStats { n: vec![0.0; 3], f: Mat::zeros(3, 2) };
        let phi = estep_utterance(&stats, &tt_si, &tt_si_t, &model.prior_mean, None);
        // L = I, rhs = p → φ = p
        for (a, b) in phi.iter().zip(&model.prior_mean) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn accumulators_match_manual_sums() {
        let ubm = tiny_ubm(3, 2, 7);
        let model = TvModel::init(Formulation::Standard, &ubm, 4, 100.0, 9);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(11);
        let s1 = random_stats(3, 2, &mut rng);
        let s2 = random_stats(3, 2, &mut rng);

        let mut acc = EstepAccum::zeros(3, 2, 4);
        let phi1 = estep_utterance(&s1, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        let phi2 = estep_utterance(&s2, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));

        assert_eq!(acc.count, 2.0);
        // h = φ1 + φ2
        for i in 0..4 {
            assert!((acc.h[i] - (phi1[i] + phi2[i])).abs() < 1e-10);
        }
        // B_c = f_c(1) φ1ᵀ + f_c(2) φ2ᵀ
        for c in 0..3 {
            let mut want = outer(s1.f.row(c), &phi1);
            want.add_scaled(1.0, &outer(s2.f.row(c), &phi2));
            assert!(acc.b[c].approx_eq(&want, 1e-10));
        }
    }

    #[test]
    fn merge_equals_joint_accumulation() {
        let ubm = tiny_ubm(3, 2, 13);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 2);
        let (tt_si, tt_si_t) = model.precompute();
        let mut rng = Rng::seed(17);
        let stats: Vec<UttStats> = (0..4).map(|_| random_stats(3, 2, &mut rng)).collect();

        let mut joint = EstepAccum::zeros(3, 2, 4);
        for s in &stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut joint));
        }
        let mut a1 = EstepAccum::zeros(3, 2, 4);
        let mut a2 = EstepAccum::zeros(3, 2, 4);
        for s in &stats[..2] {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut a1));
        }
        for s in &stats[2..] {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut a2));
        }
        a1.merge(&a2);
        assert_eq!(a1.count, joint.count);
        assert!(a1.hh.approx_eq(&joint.hh, 1e-10));
        for c in 0..3 {
            assert!(a1.a[c].approx_eq(&joint.a[c], 1e-10));
        }
    }

    #[test]
    fn prop_batched_estep_matches_per_item_reference() {
        use crate::proptest::{forall, gen_dim};
        forall(
            7117,
            24,
            |rng| {
                let c = gen_dim(rng, 1, 6);
                let f = gen_dim(rng, 1, 4);
                let r = gen_dim(rng, 1, 6);
                let n_utts = gen_dim(rng, 1, 9);
                let ubm = tiny_ubm(c, f, rng.below(1 << 30) as u64 + 1);
                let model = TvModel::init(Formulation::Augmented, &ubm, r, 10.0, 5);
                // n > 0 everywhere: the reference skips n_c = 0 in the
                // rhs, the GEMM cannot (valid stats have f_c = 0 there)
                let stats: Vec<UttStats> = (0..n_utts)
                    .map(|_| UttStats {
                        n: (0..c).map(|_| rng.uniform_in(0.1, 30.0)).collect(),
                        f: Mat::from_fn(c, f, |_, _| 3.0 * rng.normal()),
                    })
                    .collect();
                (model, stats)
            },
            |(model, stats)| {
                let (c, f, r) =
                    (model.num_components(), model.feat_dim(), model.rank());
                let (tt_si, tt_si_t) = model.precompute();
                let mut ref_acc = EstepAccum::zeros(c, f, r);
                let mut ref_phi = Mat::zeros(stats.len(), r);
                for (u, s) in stats.iter().enumerate() {
                    let phi = estep_utterance(
                        s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut ref_acc),
                    );
                    ref_phi.row_mut(u).copy_from_slice(&phi);
                }

                let consts = model.precompute_consts();
                let mut ws = EstepWorkspace::new(r, stats.len());
                let refs: Vec<&UttStats> = stats.iter().collect();
                let mut acc = EstepAccum::zeros(c, f, r);
                let phi = estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));

                let tol = 1e-10 * (1.0 + ref_phi.max_abs());
                if !phi.approx_eq(&ref_phi, tol) {
                    return Err(format!(
                        "phi deviates by {}",
                        phi.sub(&ref_phi).max_abs()
                    ));
                }
                if acc.count != ref_acc.count {
                    return Err("count mismatch".into());
                }
                for ci in 0..c {
                    let ta = 1e-10 * (1.0 + ref_acc.a[ci].max_abs());
                    if !acc.a[ci].approx_eq(&ref_acc.a[ci], ta) {
                        return Err(format!(
                            "A[{ci}] deviates by {}",
                            acc.a[ci].sub(&ref_acc.a[ci]).max_abs()
                        ));
                    }
                    let tb = 1e-10 * (1.0 + ref_acc.b[ci].max_abs());
                    if !acc.b[ci].approx_eq(&ref_acc.b[ci], tb) {
                        return Err(format!(
                            "B[{ci}] deviates by {}",
                            acc.b[ci].sub(&ref_acc.b[ci]).max_abs()
                        ));
                    }
                }
                let th = 1e-10 * (1.0 + ref_acc.hh.max_abs());
                if !acc.hh.approx_eq(&ref_acc.hh, th) {
                    return Err("hh deviates".into());
                }
                for (x, y) in acc.h.iter().zip(&ref_acc.h) {
                    crate::proptest::close(*x, *y, 1e-10, "h")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn batched_estep_split_batches_match_single_batch() {
        // batching boundaries must not change the accumulated result
        let ubm = tiny_ubm(4, 3, 91);
        let model = TvModel::init(Formulation::Augmented, &ubm, 5, 10.0, 2);
        let mut rng = Rng::seed(23);
        let stats: Vec<UttStats> = (0..7).map(|_| random_stats(4, 3, &mut rng)).collect();
        let consts = model.precompute_consts();

        let refs: Vec<&UttStats> = stats.iter().collect();
        let mut ws = EstepWorkspace::new(5, 7);
        let mut joint = EstepAccum::zeros(4, 3, 5);
        estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut joint));

        let mut ws2 = EstepWorkspace::new(5, 4);
        let mut split = EstepAccum::zeros(4, 3, 5);
        for chunk in refs.chunks(4) {
            estep_batch_cpu(chunk, &consts, &mut ws2, Some(&mut split));
        }
        assert_eq!(split.count, joint.count);
        assert!(split.hh.approx_eq(&joint.hh, 1e-12));
        for c in 0..4 {
            assert!(split.a[c].approx_eq(&joint.a[c], 1e-12));
            assert!(split.b[c].approx_eq(&joint.b[c], 1e-12));
        }
    }

    #[test]
    fn batched_estep_zero_stats_give_prior_mean() {
        let ubm = tiny_ubm(3, 2, 5);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 1);
        let consts = model.precompute_consts();
        let stats = UttStats { n: vec![0.0; 3], f: Mat::zeros(3, 2) };
        let mut ws = EstepWorkspace::new(4, 1);
        let phi = estep_batch_cpu(&[&stats], &consts, &mut ws, None);
        for (a, b) in phi.row(0).iter().zip(&model.prior_mean) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn centering_applied_only_for_standard() {
        let ubm = tiny_ubm(2, 2, 19);
        let std_model = TvModel::init(Formulation::Standard, &ubm, 3, 100.0, 1);
        let aug_model = TvModel::init(Formulation::Augmented, &ubm, 3, 100.0, 1);
        let bw = crate::stats::BwStats {
            n: vec![2.0, 1.0],
            f: Mat::from_rows(&[&[4.0, 2.0], &[1.0, 1.0]]),
            s: None,
        };
        let s_std = UttStats::from_bw(&bw, &std_model);
        let s_aug = UttStats::from_bw(&bw, &aug_model);
        // augmented = raw
        assert!(s_aug.f.approx_eq(&bw.f, 0.0));
        // standard = centered: f − n·m
        for c in 0..2 {
            for j in 0..2 {
                let want = bw.f.get(c, j) - bw.n[c] * ubm.means.get(c, j);
                assert!((s_std.f.get(c, j) - want).abs() < 1e-12);
            }
        }
    }
}
