//! Accelerated (device) extractor path — the paper's GPU contribution.
//!
//! Mirrors the CPU reference exactly (same math, f32 on device):
//! `precompute` runs once per EM iteration, `estep`/`extract` stream
//! utterance batches. Batches are padded to the graph's static shape
//! and masked; integration tests assert CPU ≡ accel to f32 tolerance.

use anyhow::{bail, Context, Result};

use crate::config::Doc;
use crate::gmm::{DiagGmm, FullGmm};
use crate::io::Posting;
use crate::linalg::Mat;
use crate::runtime::{Runtime, Tensor};

use super::estep::{EstepAccum, UttStats};
use super::model::TvModel;

/// Static graph dimensions, read from `artifacts/manifest.toml`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphDims {
    pub c: usize,
    pub f: usize,
    pub r: usize,
    pub k: usize,
    pub bf: usize,
    pub bu: usize,
    pub d: usize,
    pub ne: usize,
    pub nt: usize,
}

impl GraphDims {
    /// Parse from the manifest emitted by `python -m compile.aot`.
    pub fn from_manifest(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let doc = Doc::load(&path).context("artifact manifest (run `make artifacts`)")?;
        Ok(Self {
            c: doc.get_usize("dims.C", 0)?,
            f: doc.get_usize("dims.F", 0)?,
            r: doc.get_usize("dims.R", 0)?,
            k: doc.get_usize("dims.K", 0)?,
            bf: doc.get_usize("dims.BF", 0)?,
            bu: doc.get_usize("dims.BU", 0)?,
            d: doc.get_usize("dims.D", 0)?,
            ne: doc.get_usize("dims.NE", 0)?,
            nt: doc.get_usize("dims.NT", 0)?,
        })
    }
}

/// Device-side TVM: owns the runtime, the compiled graphs, and the
/// per-iteration precomputed constants.
pub struct AccelTvm {
    rt: Runtime,
    pub dims: GraphDims,
    // per-iteration constants (set_model)
    tt_si: Option<Tensor>,   // (C, R, F)
    tt_si_t: Option<Tensor>, // (C, R, R)
    prior: Option<Tensor>,   // (R,)
}

impl AccelTvm {
    /// Load the manifest + the TVM graphs from `artifacts_dir`.
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let dims = GraphDims::from_manifest(format!("{artifacts_dir}/manifest.toml"))?;
        let mut rt = Runtime::cpu(artifacts_dir)?;
        rt.load("precompute")?;
        rt.load("estep")?;
        rt.load("extract")?;
        Ok(Self { rt, dims, tt_si: None, tt_si_t: None, prior: None })
    }

    /// Also load the alignment + UBM graphs (used by the aligner paths).
    pub fn with_alignment(mut self) -> Result<Self> {
        self.rt.load("align_topk")?;
        self.rt.load("ubm_acc")?;
        Ok(self)
    }

    /// Validate that a model matches the graph shapes.
    fn check_model(&self, model: &TvModel) -> Result<()> {
        if model.num_components() != self.dims.c
            || model.feat_dim() != self.dims.f
            || model.rank() != self.dims.r
        {
            bail!(
                "model dims (C={}, F={}, R={}) do not match artifacts (C={}, F={}, R={}) — \
                 re-run `make artifacts` after changing python/compile/dims.py",
                model.num_components(),
                model.feat_dim(),
                model.rank(),
                self.dims.c,
                self.dims.f,
                self.dims.r
            );
        }
        Ok(())
    }

    /// Run the `precompute` graph for the current model parameters.
    /// Must be called after every parameter update (per EM iteration).
    pub fn set_model(&mut self, model: &TvModel) -> Result<()> {
        self.check_model(model)?;
        let (c, f, r) = (self.dims.c, self.dims.f, self.dims.r);
        // pack T (C, F, R)
        let mut t_flat = Vec::with_capacity(c * f * r);
        for tc in &model.t {
            t_flat.extend(tc.as_slice().iter().map(|&x| x as f32));
        }
        // pack Σ⁻¹ (C, F, F)
        let inv = model.sigma_inverses();
        let mut si_flat = Vec::with_capacity(c * f * f);
        for ic in &inv {
            si_flat.extend(ic.as_slice().iter().map(|&x| x as f32));
        }
        let out = self.rt.graph("precompute")?.run(&[
            Tensor::from_f32(t_flat, &[c, f, r]),
            Tensor::from_f32(si_flat, &[c, f, f]),
        ])?;
        let prior: Vec<f32> = model.prior_mean.iter().map(|&x| x as f32).collect();
        self.tt_si = Some(out[0].clone());
        self.tt_si_t = Some(out[1].clone());
        self.prior = Some(Tensor::from_f32(prior, &[r]));
        Ok(())
    }

    fn pack_batch(&self, batch: &[&UttStats]) -> (Tensor, Tensor, Tensor) {
        let (c, f, bu) = (self.dims.c, self.dims.f, self.dims.bu);
        assert!(batch.len() <= bu, "batch {} exceeds BU {}", batch.len(), bu);
        let mut n = vec![0f32; bu * c];
        let mut fs = vec![0f32; bu * c * f];
        let mut mask = vec![0f32; bu];
        for (b, st) in batch.iter().enumerate() {
            debug_assert_eq!(st.n.len(), c);
            for ci in 0..c {
                n[b * c + ci] = st.n[ci] as f32;
            }
            for (k, &v) in st.f.as_slice().iter().enumerate() {
                fs[b * c * f + k] = v as f32;
            }
            mask[b] = 1.0;
        }
        (
            Tensor::from_f32(n, &[bu, c]),
            Tensor::from_f32(fs, &[bu, c, f]),
            Tensor::from_f32(mask, &[bu]),
        )
    }

    fn constants(&self) -> Result<(&Tensor, &Tensor, &Tensor)> {
        match (&self.tt_si, &self.tt_si_t, &self.prior) {
            (Some(a), Some(b), Some(p)) => Ok((a, b, p)),
            _ => bail!("AccelTvm::set_model must be called before estep/extract"),
        }
    }

    /// Run the E-step graph on one utterance batch (≤ BU) and return
    /// the partial accumulator plus the batch φ rows. The per-iteration
    /// constants set by [`AccelTvm::set_model`] are passed by reference,
    /// eliminating the per-batch `Tensor` buffer clones (the remaining
    /// per-batch host→Literal conversion is a runtime-API limit — see
    /// ROADMAP "device-resident constants").
    pub fn estep_batch(&self, batch: &[&UttStats]) -> Result<(EstepAccum, Mat)> {
        let (c, f, r) = (self.dims.c, self.dims.f, self.dims.r);
        let graph = self.rt.graph("estep")?;
        let (n_t, f_t, m_t) = self.pack_batch(batch);
        let (tt_si, tt_si_t, prior) = self.constants()?;
        let out = graph.run_refs(&[&n_t, &f_t, &m_t, tt_si, tt_si_t, prior])?;
        // unpack: acc_a (C,R,R), acc_b (C,F,R), acc_h (R), acc_hh (R,R),
        // count (), phi (BU, R)
        let mut acc = EstepAccum::zeros(c, f, r);
        let a = out[0].to_f64()?;
        for ci in 0..c {
            acc.a[ci] = Mat::from_vec(a[ci * r * r..(ci + 1) * r * r].to_vec(), r, r);
        }
        let b = out[1].to_f64()?;
        for ci in 0..c {
            acc.b[ci] = Mat::from_vec(b[ci * f * r..(ci + 1) * f * r].to_vec(), f, r);
        }
        acc.h = out[2].to_f64()?;
        acc.hh = Mat::from_vec(out[3].to_f64()?, r, r);
        acc.count = out[4].to_f64()?[0];

        let phi_all = out[5].to_f64()?;
        let mut phi = Mat::zeros(batch.len(), r);
        for (bi, row) in phi.as_mut_slice().chunks_exact_mut(r).enumerate() {
            row.copy_from_slice(&phi_all[bi * r..(bi + 1) * r]);
        }
        Ok((acc, phi))
    }

    /// Run the extraction graph on one batch; returns i-vectors
    /// (posterior means minus the prior mean), one row per input.
    pub fn extract_batch(&self, batch: &[&UttStats], prior_mean: &[f64]) -> Result<Mat> {
        let r = self.dims.r;
        let graph = self.rt.graph("extract")?;
        let (n_t, f_t, _m) = self.pack_batch(batch);
        let (tt_si, tt_si_t, prior) = self.constants()?;
        let out = graph.run_refs(&[&n_t, &f_t, tt_si, tt_si_t, prior])?;
        let phi_all = out[0].to_f64()?;
        let mut iv = Mat::zeros(batch.len(), r);
        for bi in 0..batch.len() {
            for j in 0..r {
                iv.set(bi, j, phi_all[bi * r + j] - prior_mean[j]);
            }
        }
        Ok(iv)
    }

    /// Borrow the runtime (aligner / scorer helpers share the client).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Mutable runtime access (loading extra graphs).
    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }
}

/// Pack diagonal-GMM parameters for the `align_topk` graph
/// (mirrors `kernels.loglikes.pack_diag_weights`).
pub fn pack_diag_params(g: &DiagGmm) -> (Tensor, Tensor) {
    let (c, f) = (g.num_components(), g.dim());
    let mut w = vec![0f32; c * 2 * f];
    let mut consts = vec![0f32; c];
    for ci in 0..c {
        let mut const_c = g.weights[ci].max(1e-300).ln() - 0.5 * f as f64 * crate::gmm::LOG_2PI;
        for j in 0..f {
            let v = g.vars.get(ci, j);
            let m = g.means.get(ci, j);
            let vinv = 1.0 / v;
            w[ci * 2 * f + j] = (m * vinv) as f32;
            w[ci * 2 * f + f + j] = (-0.5 * vinv) as f32;
            const_c -= 0.5 * (v.ln() + m * m * vinv);
        }
        consts[ci] = const_c as f32;
    }
    (Tensor::from_f32(w, &[c, 2 * f]), Tensor::from_f32(consts, &[c]))
}

/// Pack full-covariance GMM parameters for the `align_topk` /
/// `ubm_acc` graphs (mirrors `kernels.loglikes.pack_full_weights`).
/// Uses the FullGmm caches, so `consts` match the CPU path exactly.
pub fn pack_full_params(g: &FullGmm) -> (Tensor, Tensor) {
    let (c, f) = (g.num_components(), g.dim());
    let q = f + f * f;
    let mut w = vec![0f32; c * q];
    let mut consts = vec![0f32; c];
    for ci in 0..c {
        let inv = g.inv_cov(ci);
        let m = g.means.row(ci);
        let lin = inv.matvec(m); // Σ⁻¹ m
        for j in 0..f {
            w[ci * q + j] = lin[j] as f32;
        }
        for (k, &v) in inv.as_slice().iter().enumerate() {
            w[ci * q + f + k] = (-0.5 * v) as f32;
        }
        // const = log w − ½(F log2π + log|Σ| + mᵀΣ⁻¹m): recompute from
        // parts (FullGmm keeps it private); cheap at C ≤ thousands.
        let (chol, _) = crate::linalg::Cholesky::new_regularized(&g.covs[ci]);
        consts[ci] = (g.weights[ci].max(1e-300).ln()
            - 0.5
                * (f as f64 * crate::gmm::LOG_2PI
                    + chol.logdet()
                    + crate::linalg::dot(m, &lin))) as f32;
    }
    (Tensor::from_f32(w, &[c, q]), Tensor::from_f32(consts, &[c]))
}

/// Device-side frame aligner: streams frame batches through the
/// `align_topk` graph (the paper's 3000×-RT path).
pub struct AccelAligner<'rt> {
    rt: &'rt Runtime,
    dims: GraphDims,
    diag_w: Tensor,
    diag_const: Tensor,
    full_w: Tensor,
    full_const: Tensor,
}

impl<'rt> AccelAligner<'rt> {
    /// Pack GMM parameters once; graphs must already be loaded.
    pub fn new(rt: &'rt Runtime, dims: GraphDims, diag: &DiagGmm, full: &FullGmm) -> Result<Self> {
        rt.graph("align_topk")?; // fail fast if not loaded
        let (diag_w, diag_const) = pack_diag_params(diag);
        let (full_w, full_const) = pack_full_params(full);
        Ok(Self { rt, dims, diag_w, diag_const, full_w, full_const })
    }

    /// Align a flat frame block (rows ≤ BF); returns per-frame pruned
    /// postings for the first `n_rows` rows.
    pub fn align_block(&self, frames: &Mat, n_rows: usize) -> Result<Vec<Vec<Posting>>> {
        let (bf, f, k) = (self.dims.bf, self.dims.f, self.dims.k);
        assert!(n_rows <= bf && frames.cols() == f);
        let mut flat = vec![0f32; bf * f];
        for t in 0..n_rows.min(frames.rows()) {
            for (j, &v) in frames.row(t).iter().enumerate() {
                flat[t * f + j] = v as f32;
            }
        }
        // packed GMM weights are built once in `new` and borrowed per
        // block — no per-block clones of the (C, F + F²) tensors
        let frames_t = Tensor::from_f32(flat, &[bf, f]);
        let out = self.rt.graph("align_topk")?.run_refs(&[
            &frames_t,
            &self.diag_w,
            &self.diag_const,
            &self.full_w,
            &self.full_const,
        ])?;
        let posts = out[0].as_f32()?;
        let idx = out[1].as_i32()?;
        let mut result = Vec::with_capacity(n_rows);
        for t in 0..n_rows {
            let mut frame = Vec::with_capacity(4);
            for j in 0..k {
                let p = posts[t * k + j];
                if p > 0.0 {
                    frame.push(Posting { idx: idx[t * k + j] as u32, post: p });
                }
            }
            result.push(frame);
        }
        Ok(result)
    }

    /// Align a whole utterance (any number of frames) by streaming
    /// BF-sized blocks.
    pub fn align_utterance(&self, feats: &Mat) -> Result<Vec<Vec<Posting>>> {
        let bf = self.dims.bf;
        let mut out = Vec::with_capacity(feats.rows());
        let mut start = 0;
        while start < feats.rows() {
            let n = (feats.rows() - start).min(bf);
            let mut block = Mat::zeros(n, feats.cols());
            for t in 0..n {
                block.row_mut(t).copy_from_slice(feats.row(start + t));
            }
            out.extend(self.align_block(&block, n)?);
            start += n;
        }
        Ok(out)
    }
}
