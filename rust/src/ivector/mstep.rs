//! M-step (paper §3 step 3): update T_c, optionally update Σ_c.
//!
//! T update (Kenny 2005 eigenvoice, eq. used by both formulations):
//! `T_c = B_c A_c⁻¹` with `A_c = Σ_u n_c(Φ+φφᵀ)`, `B_c = Σ_u f_c φᵀ`.
//!
//! Σ update: residual covariance given the *new* T,
//! `Σ_c = (S_c − T B_cᵀ − B_c Tᵀ + T A_c Tᵀ) / N_c`
//! — the four-term symmetric form, which reduces to Kaldi's
//! `(S_c − T B_cᵀ)/N_c` when T is the exact minimizer but stays
//! correct (and symmetric) under regularized solves. (Paper footnote 1:
//! Kaldi's variance update is equivalent to [10].)

use crate::linalg::{Cholesky, Mat};

use super::estep::EstepAccum;
use super::model::TvModel;

/// Globally-accumulated second-order statistics (per component) +
/// total occupancies — the Σ-update inputs. Computed once per
/// alignment round (they do not depend on the latent posteriors).
#[derive(Debug, Clone)]
pub struct GlobalSecondOrder {
    /// Σ_u S_c(u), centered for the standard formulation, raw for the
    /// augmented one (same convention as the first-order stats).
    pub s: Vec<Mat>,
    /// Σ_u n_c(u) per component.
    pub n: Vec<f64>,
}

/// Apply the M-step to the model in place. Returns the mean squared
/// change in T (diagnostic for convergence plots).
pub fn mstep(
    model: &mut TvModel,
    acc: &EstepAccum,
    second_order: Option<&GlobalSecondOrder>,
    var_floor: f64,
) -> f64 {
    let c_n = model.num_components();
    let mut delta = 0.0;
    let mut delta_n = 0.0;

    for c in 0..c_n {
        // T_c = B_c A_c⁻¹  ⇔  T_cᵀ = A_c⁻¹ B_cᵀ (A symmetric SPD-ish)
        let chol = Cholesky::new_regularized(&acc.a[c]).0;
        let t_new = chol.solve_mat(&acc.b[c].t()).t();
        delta += t_new.sub(&model.t[c]).fro_norm().powi(2);
        delta_n += (t_new.rows() * t_new.cols()) as f64;
        model.t[c] = t_new;
    }

    if let Some(so) = second_order {
        for c in 0..c_n {
            let nc = so.n[c];
            if nc < model.feat_dim() as f64 {
                continue; // starved component: keep the old covariance
            }
            let t = &model.t[c];
            let bt = acc.b[c].t(); // B_cᵀ (R, F)
            let t_bt = t.matmul(&bt); // T B_cᵀ (F, F)
            let ta = t.matmul(&acc.a[c]); // (F, R)
            let ta_tt = ta.matmul_nt(t); // (F, F)
            let mut sig = so.s[c].clone();
            sig.add_scaled(-1.0, &t_bt);
            sig.add_scaled(-1.0, &t_bt.t());
            sig.add_scaled(1.0, &ta_tt);
            sig.scale(1.0 / nc);
            sig.symmetrize();
            for i in 0..sig.rows() {
                let v = sig.get(i, i).max(var_floor);
                sig.set(i, i, v);
            }
            model.sigma[c] = sig;
        }
    }

    delta / delta_n.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::super::estep::{estep_utterance, EstepAccum, UttStats};
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::*;
    use crate::rng::Rng;

    fn synth_stats_from_model(
        model: &TvModel,
        n_utts: usize,
        rng: &mut Rng,
    ) -> (Vec<UttStats>, GlobalSecondOrder) {
        // generate utterance stats consistent with the generative model:
        // f_c = n_c (T_c ω) + noise, which the M-step should fit well.
        let c_n = model.num_components();
        let f_dim = model.feat_dim();
        let r = model.rank();
        let mut all = Vec::new();
        let mut s_tot = vec![Mat::zeros(f_dim, f_dim); c_n];
        let mut n_tot = vec![0.0; c_n];
        for _ in 0..n_utts {
            let mut omega: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
            for (o, p) in omega.iter_mut().zip(&model.prior_mean) {
                *o += p;
            }
            let n: Vec<f64> = (0..c_n).map(|_| rng.uniform_in(5.0, 40.0)).collect();
            let mut f = Mat::zeros(c_n, f_dim);
            for c in 0..c_n {
                let mu = model.t[c].matvec(&omega);
                for j in 0..f_dim {
                    let noise = 0.05 * rng.normal() * (n[c]).sqrt();
                    f.set(c, j, n[c] * mu[j] + noise);
                    // crude matching S accumulation: n * mu muᵀ + small diag
                }
                for j in 0..f_dim {
                    for k in 0..f_dim {
                        let v = s_tot[c].get(j, k) + n[c] * mu[j] * mu[k];
                        s_tot[c].set(j, k, v);
                    }
                    let v = s_tot[c].get(j, j) + 0.01 * n[c];
                    s_tot[c].set(j, j, v);
                }
                n_tot[c] += n[c];
            }
            all.push(UttStats { n, f });
        }
        (all, GlobalSecondOrder { s: s_tot, n: n_tot })
    }

    #[test]
    fn t_update_is_least_squares_solution() {
        let ubm = tiny_ubm(3, 2, 23);
        let mut model = TvModel::init(Formulation::Augmented, &ubm, 4, 10.0, 3);
        let mut rng = Rng::seed(5);
        let (stats, _so) = synth_stats_from_model(&model, 30, &mut rng);

        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(3, 2, 4);
        for s in &stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        mstep(&mut model, &acc, None, 1e-6);
        // verify normal equations: T_c A_c = B_c
        for c in 0..3 {
            let lhs = model.t[c].matmul(&acc.a[c]);
            assert!(lhs.approx_eq(&acc.b[c], 1e-6), "c={c}");
        }
    }

    #[test]
    fn em_iterations_fit_the_generating_subspace() {
        // likelihood proxy: ‖f_c − n_c T φ‖ shrinks over EM iterations
        let ubm = tiny_ubm(3, 2, 29);
        let gen_model = TvModel::init(Formulation::Augmented, &ubm, 3, 10.0, 7);
        let mut rng = Rng::seed(9);
        let (stats, _) = synth_stats_from_model(&gen_model, 60, &mut rng);

        let mut model = TvModel::init(Formulation::Augmented, &ubm, 3, 10.0, 99);
        let mut errs = Vec::new();
        for _ in 0..6 {
            let (tt_si, tt_si_t) = model.precompute();
            let mut acc = EstepAccum::zeros(3, 2, 3);
            let mut err = 0.0;
            for s in &stats {
                let phi =
                    estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
                for c in 0..3 {
                    let mu = model.t[c].matvec(&phi);
                    for j in 0..2 {
                        let e = s.f.get(c, j) - s.n[c] * mu[j];
                        err += e * e;
                    }
                }
            }
            errs.push(err);
            mstep(&mut model, &acc, None, 1e-6);
        }
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.5),
            "EM did not reduce reconstruction error: {errs:?}"
        );
    }

    #[test]
    fn sigma_update_produces_spd_floored_covariances() {
        let ubm = tiny_ubm(3, 2, 31);
        let mut model = TvModel::init(Formulation::Augmented, &ubm, 4, 10.0, 3);
        let mut rng = Rng::seed(13);
        let (stats, so) = synth_stats_from_model(&model, 40, &mut rng);
        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(3, 2, 4);
        for s in &stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        mstep(&mut model, &acc, Some(&so), 1e-4);
        for c in 0..3 {
            // symmetric
            assert!(model.sigma[c].approx_eq(&model.sigma[c].t(), 1e-12));
            // diagonal floored
            for i in 0..2 {
                assert!(model.sigma[c].get(i, i) >= 1e-4);
            }
            // choleskyable after regularization (SPD-ish)
            let (_, ridge) = Cholesky::new_regularized(&model.sigma[c]);
            assert!(ridge < 1.0, "covariance badly conditioned");
        }
    }

    #[test]
    fn starved_component_keeps_sigma() {
        let ubm = tiny_ubm(2, 2, 37);
        let mut model = TvModel::init(Formulation::Standard, &ubm, 3, 10.0, 3);
        let sigma_before = model.sigma[1].clone();
        let acc = {
            let mut acc = EstepAccum::zeros(2, 2, 3);
            // only component 0 has mass
            let mut rng = Rng::seed(3);
            let stats = UttStats {
                n: vec![20.0, 0.0],
                f: Mat::from_fn(2, 2, |_, _| rng.normal()),
            };
            let (tt_si, tt_si_t) = model.precompute();
            estep_utterance(&stats, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
            acc
        };
        let so = GlobalSecondOrder {
            s: vec![Mat::eye(2), Mat::eye(2)],
            n: vec![20.0, 0.0],
        };
        mstep(&mut model, &acc, Some(&so), 1e-6);
        assert!(model.sigma[1].approx_eq(&sigma_before, 0.0), "starved Σ must not move");
    }
}
