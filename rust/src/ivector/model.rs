//! The total-variability model: parameters, initialization, serialization.

use anyhow::Result;

use crate::gmm::FullGmm;
use crate::io::Serialize;
use crate::linalg::{Cholesky, Mat};
use crate::rng::Rng;

/// Which formulation of the model (paper §2.1 vs §2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Formulation {
    /// §2.1: separate bias m_c, centered stats, p = 0.
    Standard,
    /// §2.2: bias folded into T's first column, raw stats, p = [p₀ 0 …].
    Augmented,
}

/// A full training variant — the six curves of Fig. 2 plus the
/// realignment schedule of Fig. 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainVariant {
    pub formulation: Formulation,
    /// Apply minimum-divergence re-estimation each iteration
    /// (augmented formulation: always true, per the paper).
    pub min_divergence: bool,
    /// Update residual covariances Σ_c each iteration.
    pub sigma_update: bool,
    /// Re-align training data every k iterations (paper §3.2);
    /// `None` = never (Fig. 2 setting).
    pub realign_every: Option<usize>,
}

impl TrainVariant {
    /// The paper's recommended recipe (§5): augmented + Σ-updates +
    /// frame-alignment updates.
    pub fn recommended(realign_every: usize) -> Self {
        Self {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: true,
            realign_every: Some(realign_every),
        }
    }

    /// The six Fig. 2 variants, with their legend labels.
    pub fn fig2_set() -> Vec<(String, Self)> {
        let mut out = Vec::new();
        for &md in &[false, true] {
            for &sig in &[false, true] {
                out.push((
                    format!(
                        "standard{}{}",
                        if md { "+mindiv" } else { "" },
                        if sig { "+sigma" } else { "" }
                    ),
                    Self {
                        formulation: Formulation::Standard,
                        min_divergence: md,
                        sigma_update: sig,
                        realign_every: None,
                    },
                ));
            }
        }
        for &sig in &[false, true] {
            out.push((
                format!("augmented{}", if sig { "+sigma" } else { "" }),
                Self {
                    formulation: Formulation::Augmented,
                    min_divergence: true,
                    sigma_update: sig,
                    realign_every: None,
                },
            ));
        }
        out
    }

    /// Short variant id used in file names / logs.
    pub fn id(&self) -> String {
        format!(
            "{}{}{}{}",
            match self.formulation {
                Formulation::Standard => "std",
                Formulation::Augmented => "aug",
            },
            if self.min_divergence { "-md" } else { "" },
            if self.sigma_update { "-sig" } else { "" },
            match self.realign_every {
                Some(k) => format!("-ra{k}"),
                None => String::new(),
            }
        )
    }
}

/// The total-variability model parameters.
#[derive(Debug, Clone)]
pub struct TvModel {
    pub formulation: Formulation,
    /// Factor loading matrices T_c, C matrices of F × R.
    pub t: Vec<Mat>,
    /// Residual covariances Σ_c, C matrices of F × F.
    pub sigma: Vec<Mat>,
    /// Bias means m_c (C × F): the UBM means snapshot for the standard
    /// formulation (used for stat centering and §5-style realignment);
    /// for the augmented formulation this mirrors `bias_means()` after
    /// each update (kept for diagnostics).
    pub means: Mat,
    /// Prior mean p over the latent vector (R). Zeros for standard;
    /// `[p₀ 0 …]` (then re-estimated by min-div, eq. 12) for augmented.
    pub prior_mean: Vec<f64>,
}

impl TvModel {
    /// Random initialization (paper §2.1/§2.2): T ~ N(0,1); Σ from the
    /// UBM; augmented additionally writes m_c/p₀ into T's first column.
    pub fn init(formulation: Formulation, ubm: &FullGmm, rank: usize, prior_offset: f64, seed: u64) -> Self {
        let c_n = ubm.num_components();
        let f_dim = ubm.dim();
        let mut rng = Rng::seed(seed);
        let mut t: Vec<Mat> = (0..c_n)
            .map(|_| Mat::from_fn(f_dim, rank, |_, _| rng.normal()))
            .collect();
        let mut prior_mean = vec![0.0; rank];
        if formulation == Formulation::Augmented {
            prior_mean[0] = prior_offset;
            for (c, tc) in t.iter_mut().enumerate() {
                let col: Vec<f64> = ubm.means.row(c).iter().map(|&m| m / prior_offset).collect();
                tc.set_col(0, &col);
            }
        }
        Self {
            formulation,
            t,
            sigma: ubm.covs.clone(),
            means: ubm.means.clone(),
            prior_mean,
        }
    }

    pub fn num_components(&self) -> usize {
        self.t.len()
    }

    pub fn feat_dim(&self) -> usize {
        self.t[0].rows()
    }

    pub fn rank(&self) -> usize {
        self.t[0].cols()
    }

    /// Σ_c⁻¹ for every component (Cholesky, regularized if needed).
    pub fn sigma_inverses(&self) -> Vec<Mat> {
        self.sigma
            .iter()
            .map(|s| Cholesky::new_regularized(s).0.inverse())
            .collect()
    }

    /// Per-component `TᵀΣ⁻¹` (R × F) and `TᵀΣ⁻¹T` (R × R) — the
    /// E-step constants (CPU mirror of the `precompute` graph).
    pub fn precompute(&self) -> (Vec<Mat>, Vec<Mat>) {
        let inv = self.sigma_inverses();
        let mut tt_si = Vec::with_capacity(self.t.len());
        let mut tt_si_t = Vec::with_capacity(self.t.len());
        for (tc, ic) in self.t.iter().zip(&inv) {
            let a = tc.matmul_tn(ic); // (R, F)
            let mut b = a.matmul(tc); // (R, R)
            b.symmetrize();
            tt_si.push(a);
            tt_si_t.push(b);
        }
        (tt_si, tt_si_t)
    }

    /// The E-step constants in the batched layout consumed by
    /// [`super::estep::estep_batch_cpu`]: flat `TᵀΣ⁻¹` plus packed
    /// `TᵀΣ⁻¹T` — the CPU mirror of the device `precompute` graph's
    /// packed outputs. Rebuild after every parameter update.
    pub fn precompute_consts(&self) -> super::estep::EstepConsts {
        let (tt_si, tt_si_t) = self.precompute();
        super::estep::EstepConsts::from_parts(&tt_si, &tt_si_t, &self.prior_mean)
    }

    /// The model's current bias supervector per component (C × F):
    /// standard → `means`; augmented → first column of T_c times p[0]
    /// (paper §3.2: "take the first columns of matrices T_c and
    /// multiply them with p").
    pub fn bias_means(&self) -> Mat {
        match self.formulation {
            Formulation::Standard => self.means.clone(),
            Formulation::Augmented => {
                let c_n = self.num_components();
                let f_dim = self.feat_dim();
                let p0 = self.prior_mean[0];
                Mat::from_fn(c_n, f_dim, |c, fi| self.t[c].get(fi, 0) * p0)
            }
        }
    }
}

impl Serialize for TvModel {
    fn write(&self, w: &mut crate::io::BinWriter) -> Result<()> {
        w.write_u32(match self.formulation {
            Formulation::Standard => 0,
            Formulation::Augmented => 1,
        })?;
        self.t.write(w)?;
        self.sigma.write(w)?;
        self.means.write(w)?;
        self.prior_mean.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> Result<Self> {
        let formulation = match r.read_u32()? {
            0 => Formulation::Standard,
            1 => Formulation::Augmented,
            other => anyhow::bail!("bad formulation tag {other}"),
        };
        Ok(Self {
            formulation,
            t: Vec::<Mat>::read(r)?,
            sigma: Vec::<Mat>::read(r)?,
            means: Mat::read(r)?,
            prior_mean: Vec::<f64>::read(r)?,
        })
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Small random UBM for extractor unit tests.
    pub fn tiny_ubm(c: usize, f: usize, seed: u64) -> FullGmm {
        let mut rng = Rng::seed(seed);
        let means = Mat::from_fn(c, f, |_, _| 2.0 * rng.normal());
        let covs = (0..c)
            .map(|_| {
                let m = Mat::from_fn(f, f, |_, _| 0.3 * rng.normal());
                let mut a = m.matmul_nt(&m);
                for i in 0..f {
                    *a.get_mut(i, i) += 1.0;
                }
                a
            })
            .collect();
        let weights = rng.dirichlet(3.0, c);
        FullGmm::new(weights, means, covs).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::tiny_ubm;
    use super::*;

    #[test]
    fn init_shapes_and_prior() {
        let ubm = tiny_ubm(4, 3, 1);
        let m = TvModel::init(Formulation::Augmented, &ubm, 6, 100.0, 2);
        assert_eq!(m.num_components(), 4);
        assert_eq!(m.feat_dim(), 3);
        assert_eq!(m.rank(), 6);
        assert_eq!(m.prior_mean[0], 100.0);
        assert!(m.prior_mean[1..].iter().all(|&x| x == 0.0));
        // first column carries m_c / p0
        for c in 0..4 {
            for fi in 0..3 {
                assert!((m.t[c].get(fi, 0) - ubm.means.get(c, fi) / 100.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn standard_init_zero_prior() {
        let ubm = tiny_ubm(3, 2, 5);
        let m = TvModel::init(Formulation::Standard, &ubm, 4, 100.0, 2);
        assert!(m.prior_mean.iter().all(|&x| x == 0.0));
        assert!(m.means.approx_eq(&ubm.means, 0.0));
    }

    #[test]
    fn bias_means_roundtrip_augmented() {
        let ubm = tiny_ubm(4, 3, 7);
        let m = TvModel::init(Formulation::Augmented, &ubm, 5, 100.0, 3);
        // at init, bias_means must reproduce the UBM means exactly
        assert!(m.bias_means().approx_eq(&ubm.means, 1e-10));
    }

    #[test]
    fn precompute_dimensions_and_symmetry() {
        let ubm = tiny_ubm(3, 4, 9);
        let m = TvModel::init(Formulation::Standard, &ubm, 6, 100.0, 4);
        let (tt_si, tt_si_t) = m.precompute();
        assert_eq!(tt_si.len(), 3);
        assert_eq!((tt_si[0].rows(), tt_si[0].cols()), (6, 4));
        assert_eq!((tt_si_t[0].rows(), tt_si_t[0].cols()), (6, 6));
        for b in &tt_si_t {
            assert!(b.approx_eq(&b.t(), 1e-10));
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let ubm = tiny_ubm(3, 2, 11);
        let m = TvModel::init(Formulation::Augmented, &ubm, 4, 100.0, 5);
        let dir = std::env::temp_dir().join("ivtv_tvm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tvm.bin");
        crate::io::save(&m, &p).unwrap();
        let back: TvModel = crate::io::load(&p).unwrap();
        assert_eq!(back.formulation, Formulation::Augmented);
        assert!(back.t[2].approx_eq(&m.t[2], 0.0));
        assert_eq!(back.prior_mean, m.prior_mean);
    }

    #[test]
    fn fig2_set_has_six_variants() {
        let set = TrainVariant::fig2_set();
        assert_eq!(set.len(), 6);
        let ids: std::collections::HashSet<String> =
            set.iter().map(|(_, v)| v.id()).collect();
        assert_eq!(ids.len(), 6, "variant ids must be distinct");
    }
}
