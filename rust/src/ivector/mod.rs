//! The total-variability i-vector extractor — the paper's core.
//!
//! Two formulations (paper §2):
//!
//! * **Standard** — `μ_c(u) = m_c + T_c ω(u)`, centered Baum-Welch
//!   statistics, zero prior offset. Variants: ± minimum-divergence,
//!   ± residual-covariance update (4 training variants in Fig. 2).
//! * **Augmented** (Kaldi) — `μ_c(u) = T_c ω(u)` with the bias folded
//!   into the first column of `T_c` and a non-zero prior offset
//!   `p = [p₀ 0 …]ᵀ` (Kaldi: p₀ = 100), raw statistics. Minimum
//!   divergence always applied (with the Householder step of §3.1).
//!
//! Both are trained by the same EM skeleton ([`estep`], [`mstep`],
//! [`mindiv`]) and extracted by [`extract`]; the accelerated device
//! path ([`accel`]) reproduces the CPU reference bit-for-bit up to f32.

pub mod accel;
mod estep;
mod extract;
mod mindiv;
mod model;
mod mstep;

pub use accel::AccelTvm;
pub use estep::{
    estep_batch_cpu, estep_utterance, EstepAccum, EstepConsts, EstepWorkspace, UttStats,
};
pub use extract::extract_cpu;
pub use mindiv::min_divergence;
pub use model::{Formulation, TrainVariant, TvModel};
pub use mstep::{mstep, GlobalSecondOrder};
