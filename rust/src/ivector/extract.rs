//! I-vector extraction — CPU reference path.
//!
//! The i-vector is the posterior mean φ(u) with the prior mean
//! subtracted (Kaldi subtracts the prior offset from the first
//! coordinate; for the standard formulation p = 0 so this is a no-op).
//! Subtracting p makes the two formulations produce directly
//! comparable embeddings for the backend.

use crate::exec::map_parallel;
use crate::linalg::Mat;

use super::estep::{estep_utterance, UttStats};
use super::model::TvModel;

/// Extract i-vectors for a list of utterance stats (parallel over
/// utterances). Returns an (N × R) matrix, one i-vector per row.
pub fn extract_cpu(model: &TvModel, stats: &[UttStats], workers: usize) -> Mat {
    let (tt_si, tt_si_t) = model.precompute();
    let r = model.rank();
    let rows = map_parallel(stats.len(), workers.max(1), |i| {
        let mut phi = estep_utterance(&stats[i], &tt_si, &tt_si_t, &model.prior_mean, None);
        for (x, p) in phi.iter_mut().zip(&model.prior_mean) {
            *x -= p;
        }
        phi
    });
    let mut out = Mat::zeros(stats.len(), r);
    for (i, row) in rows.into_iter().enumerate() {
        out.row_mut(i).copy_from_slice(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn extraction_subtracts_prior() {
        let ubm = tiny_ubm(3, 2, 61);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 50.0, 3);
        // zero stats → φ = p → i-vector must be exactly 0
        let stats = vec![UttStats { n: vec![0.0; 3], f: Mat::zeros(3, 2) }];
        let iv = extract_cpu(&model, &stats, 2);
        assert!(iv.max_abs() < 1e-10, "{}", iv.max_abs());
    }

    #[test]
    fn parallel_matches_serial() {
        let ubm = tiny_ubm(4, 3, 67);
        let model = TvModel::init(Formulation::Standard, &ubm, 5, 0.0, 7);
        let mut rng = Rng::seed(3);
        let stats: Vec<UttStats> = (0..10)
            .map(|_| UttStats {
                n: (0..4).map(|_| rng.uniform_in(1.0, 30.0)).collect(),
                f: Mat::from_fn(4, 3, |_, _| rng.normal()),
            })
            .collect();
        let a = extract_cpu(&model, &stats, 1);
        let b = extract_cpu(&model, &stats, 4);
        assert!(a.approx_eq(&b, 1e-12));
        assert_eq!(a.rows(), 10);
        assert_eq!(a.cols(), 5);
    }

    #[test]
    fn more_data_shrinks_toward_zero_less() {
        // i-vector magnitude grows with evidence (posterior moves away
        // from the prior)
        let ubm = tiny_ubm(3, 2, 71);
        let model = TvModel::init(Formulation::Standard, &ubm, 4, 0.0, 9);
        let mut rng = Rng::seed(5);
        let f_dir = Mat::from_fn(3, 2, |_, _| rng.normal());
        let small = UttStats {
            n: vec![1.0; 3],
            f: {
                let mut f = f_dir.clone();
                f.scale(1.0);
                f
            },
        };
        let big = UttStats {
            n: vec![100.0; 3],
            f: {
                let mut f = f_dir.clone();
                f.scale(100.0);
                f
            },
        };
        let iv = extract_cpu(&model, &[small, big], 1);
        let norm_small = crate::linalg::norm2(iv.row(0));
        let norm_big = crate::linalg::norm2(iv.row(1));
        assert!(norm_big > norm_small, "{norm_big} vs {norm_small}");
    }
}
