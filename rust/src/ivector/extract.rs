//! I-vector extraction — CPU reference path.
//!
//! The i-vector is the posterior mean φ(u) with the prior mean
//! subtracted (Kaldi subtracts the prior offset from the first
//! coordinate; for the standard formulation p = 0 so this is a no-op).
//! Subtracting p makes the two formulations produce directly
//! comparable embeddings for the backend.

use crate::exec::map_parallel;
use crate::linalg::Mat;

use super::estep::{estep_batch_cpu, EstepWorkspace, UttStats};
use super::model::TvModel;

/// Utterances per batch of the batched CPU extractor. Batch boundaries
/// are a function of the input only (not the worker count), so results
/// are identical for any parallelism.
const EXTRACT_BATCH: usize = 32;

/// Extract i-vectors for a list of utterance stats (parallel over
/// batches, each batch one GEMM-shaped [`estep_batch_cpu`] call).
/// Returns an (N × R) matrix, one i-vector per row.
pub fn extract_cpu(model: &TvModel, stats: &[UttStats], workers: usize) -> Mat {
    let consts = model.precompute_consts();
    let r = model.rank();
    let n_batches = stats.len().div_ceil(EXTRACT_BATCH);
    let blocks = map_parallel(n_batches, workers.max(1), |k| {
        let lo = k * EXTRACT_BATCH;
        let hi = (lo + EXTRACT_BATCH).min(stats.len());
        let refs: Vec<&UttStats> = stats[lo..hi].iter().collect();
        let mut ws = EstepWorkspace::new(r, refs.len());
        let mut phi = estep_batch_cpu(&refs, &consts, &mut ws, None);
        for u in 0..phi.rows() {
            for (x, p) in phi.row_mut(u).iter_mut().zip(&consts.prior_mean) {
                *x -= p;
            }
        }
        phi
    });
    let mut out = Mat::zeros(stats.len(), r);
    let mut row = 0;
    for block in blocks {
        for u in 0..block.rows() {
            out.row_mut(row).copy_from_slice(block.row(u));
            row += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn extraction_subtracts_prior() {
        let ubm = tiny_ubm(3, 2, 61);
        let model = TvModel::init(Formulation::Augmented, &ubm, 4, 50.0, 3);
        // zero stats → φ = p → i-vector must be exactly 0
        let stats = vec![UttStats { n: vec![0.0; 3], f: Mat::zeros(3, 2) }];
        let iv = extract_cpu(&model, &stats, 2);
        assert!(iv.max_abs() < 1e-10, "{}", iv.max_abs());
    }

    #[test]
    fn parallel_matches_serial() {
        let ubm = tiny_ubm(4, 3, 67);
        let model = TvModel::init(Formulation::Standard, &ubm, 5, 0.0, 7);
        let mut rng = Rng::seed(3);
        let stats: Vec<UttStats> = (0..10)
            .map(|_| UttStats {
                n: (0..4).map(|_| rng.uniform_in(1.0, 30.0)).collect(),
                f: Mat::from_fn(4, 3, |_, _| rng.normal()),
            })
            .collect();
        let a = extract_cpu(&model, &stats, 1);
        let b = extract_cpu(&model, &stats, 4);
        assert!(a.approx_eq(&b, 1e-12));
        assert_eq!(a.rows(), 10);
        assert_eq!(a.cols(), 5);
    }

    #[test]
    fn batched_extraction_matches_per_item_reference() {
        let ubm = tiny_ubm(4, 3, 83);
        let model = TvModel::init(Formulation::Augmented, &ubm, 5, 20.0, 11);
        let mut rng = Rng::seed(7);
        // more utterances than one EXTRACT_BATCH to cross a boundary
        let stats: Vec<UttStats> = (0..(EXTRACT_BATCH + 5))
            .map(|_| UttStats {
                n: (0..4).map(|_| rng.uniform_in(0.5, 30.0)).collect(),
                f: crate::linalg::Mat::from_fn(4, 3, |_, _| rng.normal()),
            })
            .collect();
        let got = extract_cpu(&model, &stats, 3);
        let (tt_si, tt_si_t) = model.precompute();
        for (u, s) in stats.iter().enumerate() {
            let phi = super::super::estep::estep_utterance(
                s, &tt_si, &tt_si_t, &model.prior_mean, None,
            );
            for j in 0..5 {
                let want = phi[j] - model.prior_mean[j];
                assert!(
                    (got.get(u, j) - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "u={u} j={j}: {} vs {want}",
                    got.get(u, j)
                );
            }
        }
    }

    #[test]
    fn more_data_shrinks_toward_zero_less() {
        // i-vector magnitude grows with evidence (posterior moves away
        // from the prior)
        let ubm = tiny_ubm(3, 2, 71);
        let model = TvModel::init(Formulation::Standard, &ubm, 4, 0.0, 9);
        let mut rng = Rng::seed(5);
        let f_dir = Mat::from_fn(3, 2, |_, _| rng.normal());
        let small = UttStats {
            n: vec![1.0; 3],
            f: {
                let mut f = f_dir.clone();
                f.scale(1.0);
                f
            },
        };
        let big = UttStats {
            n: vec![100.0; 3],
            f: {
                let mut f = f_dir.clone();
                f.scale(100.0);
                f
            },
        };
        let iv = extract_cpu(&model, &[small, big], 1);
        let norm_small = crate::linalg::norm2(iv.row(0));
        let norm_big = crate::linalg::norm2(iv.row(1));
        assert!(norm_big > norm_small, "{norm_big} vs {norm_small}");
    }
}
