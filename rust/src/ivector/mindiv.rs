//! Minimum-divergence re-estimation (paper §3.1).
//!
//! From the E-step sums `h = (1/U)Σφ`, `H = (1/U)Σ(Φ+φφᵀ)` build
//! `G = H − hhᵀ`, whiten via the eigendecomposition `G = QΛQᵀ`
//! (`P₁ = Λ^{-½}Qᵀ`), and absorb the inverse into T: `T ← T P₁⁻¹`.
//!
//! Standard formulation: that is all. Augmented formulation: a second
//! transform `P₂` — the Householder reflection of eqs. (8)–(11) — maps
//! the whitened mean direction onto `e₁` so the prior-offset structure
//! `p = [p₀ 0 …]` is restored; finally `p ← P₂P₁h` (eq. 12).

use crate::linalg::{
    householder_apply_left, householder_apply_vec, householder_direction, jacobi_eigh,
};

use super::estep::EstepAccum;
use super::model::{Formulation, TvModel};

/// Eigenvalue floor for the whitening (guards early iterations where G
/// can be near-singular).
const EIG_FLOOR: f64 = 1e-10;

/// Apply minimum-divergence re-estimation in place. Returns the
/// whitening transform's log-volume change (diagnostic).
pub fn min_divergence(model: &mut TvModel, acc: &EstepAccum) -> f64 {
    assert!(acc.count > 0.0, "min-divergence needs accumulated utterances");
    let r = model.rank();
    let u = acc.count;

    // ĥ = h/U, Ĥ = H/U, G = Ĥ − ĥĥᵀ   (paper eqs. 6–7)
    let h: Vec<f64> = acc.h.iter().map(|&x| x / u).collect();
    let mut g = acc.hh.clone();
    g.scale(1.0 / u);
    for i in 0..r {
        for j in 0..r {
            let v = g.get(i, j) - h[i] * h[j];
            g.set(i, j, v);
        }
    }
    g.symmetrize();

    let eig = jacobi_eigh(&g);
    let p1 = eig.whitener(EIG_FLOOR); // P₁ = Λ^{-½}Qᵀ
    let p1_inv = eig.whitener_inv(EIG_FLOOR); // P₁⁻¹ = QΛ^{½}
    let logvol: f64 =
        eig.values.iter().map(|&l| 0.5 * l.max(EIG_FLOOR).ln()).sum();

    match model.formulation {
        Formulation::Standard => {
            // T ← T P₁⁻¹ whitens the i-vector distribution; prior mean
            // stays 0 (the paper keeps h out of the standard update).
            for tc in &mut model.t {
                *tc = tc.matmul(&p1_inv);
            }
        }
        Formulation::Augmented => {
            // whitened mean and its Householder direction (eqs. 9–11)
            let p1h = p1.matvec(&h);
            let norm = crate::linalg::norm2(&p1h);
            let mut h_tilde = p1h.clone();
            if norm > 0.0 {
                for x in &mut h_tilde {
                    *x /= norm;
                }
            } else {
                // degenerate (h = 0): identity reflection
                h_tilde = vec![0.0; r];
                h_tilde[0] = 1.0;
            }
            let a = householder_direction(&h_tilde);
            // T ← T P₁⁻¹ P₂⁻¹; the reflection is involutory (P₂⁻¹ = P₂),
            // and right-multiplication by the symmetric P₂ equals
            // (P₂ Mᵀ)ᵀ — reuse the left-apply kernel.
            for tc in &mut model.t {
                let tp1 = tc.matmul(&p1_inv);
                *tc = householder_apply_left(&a, &tp1.t()).t();
            }
            // p ← P₂P₁h  (eq. 12); analytically [‖P₁h‖, 0, …]
            model.prior_mean = householder_apply_vec(&a, &p1h);
            // zero the analytic tail (fp dust) so the structure is exact
            for x in model.prior_mean.iter_mut().skip(1) {
                if x.abs() < 1e-9 * norm.max(1.0) {
                    *x = 0.0;
                }
            }
        }
    }
    logvol
}

#[cfg(test)]
mod tests {
    use super::super::estep::{estep_utterance, EstepAccum, UttStats};
    use super::super::model::test_support::tiny_ubm;
    use super::super::model::{Formulation, TvModel};
    use super::super::mstep::mstep;
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn run_em_iter(model: &mut TvModel, stats: &[UttStats], min_div: bool) -> EstepAccum {
        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(model.num_components(), model.feat_dim(), model.rank());
        for s in stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        mstep(model, &acc, None, 1e-6);
        if min_div {
            min_divergence(model, &acc);
        }
        acc
    }

    fn posterior_moments(model: &TvModel, stats: &[UttStats]) -> (Vec<f64>, Mat) {
        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(model.num_components(), model.feat_dim(), model.rank());
        for s in stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        let u = acc.count;
        let h: Vec<f64> = acc.h.iter().map(|&x| x / u).collect();
        let mut g = acc.hh.clone();
        g.scale(1.0 / u);
        for i in 0..model.rank() {
            for j in 0..model.rank() {
                let v = g.get(i, j) - h[i] * h[j];
                g.set(i, j, v);
            }
        }
        (h, g)
    }

    fn random_corpus(c: usize, f: usize, n: usize, seed: u64) -> Vec<UttStats> {
        let mut rng = Rng::seed(seed);
        (0..n)
            .map(|_| UttStats {
                n: (0..c).map(|_| rng.uniform_in(5.0, 50.0)).collect(),
                f: crate::linalg::Mat::from_fn(c, f, |_, _| 4.0 * rng.normal()),
            })
            .collect()
    }

    #[test]
    fn mindiv_whitens_ivectors_augmented() {
        let ubm = tiny_ubm(4, 3, 41);
        let mut model = TvModel::init(Formulation::Augmented, &ubm, 5, 10.0, 3);
        let stats = random_corpus(4, 3, 40, 7);
        // a couple of EM+mindiv rounds
        for _ in 0..3 {
            run_em_iter(&mut model, &stats, true);
        }
        // after min-div the training i-vector covariance is ~identity
        let (_h, g) = posterior_moments(&model, &stats);
        let eye = Mat::eye(5);
        let dev = g.sub(&eye).max_abs();
        assert!(dev < 0.15, "G deviates from I by {dev}");
    }

    #[test]
    fn mindiv_restores_prior_structure_augmented() {
        let ubm = tiny_ubm(4, 3, 43);
        let mut model = TvModel::init(Formulation::Augmented, &ubm, 5, 10.0, 5);
        let stats = random_corpus(4, 3, 30, 9);
        run_em_iter(&mut model, &stats, true);
        // p = [p₀ 0 0 …] with p₀ > 0
        assert!(model.prior_mean[0] > 0.0, "prior offset must stay positive");
        for &x in &model.prior_mean[1..] {
            assert_eq!(x, 0.0, "prior tail must be exactly zero");
        }
        // and the i-vector mean aligns with e₁: h ≈ p
        let (h, _) = posterior_moments(&model, &stats);
        let tail: f64 = h[1..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(tail < 0.3 * h[0].abs(), "mean not aligned with e1: {h:?}");
    }

    #[test]
    fn mindiv_is_an_exact_reparameterization() {
        // min-div changes the *prior* (that is its purpose), but the
        // map itself is a change of variables: for any latent ω, the
        // supervector prediction T'·(P₂P₁ω) must equal T·ω. Verify by
        // round-tripping through the transforms: T'·(P₂P₁ h̄) = T·h̄,
        // and more generally on random latents re-expressed in the new
        // coordinates via the accumulated (h, H) statistics.
        let ubm = tiny_ubm(3, 2, 47);
        let mut model = TvModel::init(Formulation::Augmented, &ubm, 4, 10.0, 7);
        let stats = random_corpus(3, 2, 25, 11);
        run_em_iter(&mut model, &stats, false);

        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(3, 2, 4);
        for s in &stats {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        let t_before = model.t.clone();
        let h_bar: Vec<f64> = acc.h.iter().map(|&x| x / acc.count).collect();
        min_divergence(&mut model, &acc);

        // the new prior mean IS P₂P₁h̄ (eq. 12), so T'·p_new = T·h̄
        for c in 0..3 {
            let before = t_before[c].matvec(&h_bar);
            let after = model.t[c].matvec(&model.prior_mean);
            for (a, b) in after.iter().zip(&before) {
                assert!((a - b).abs() < 1e-8, "c={c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn mindiv_standard_whitens_covariance() {
        let ubm = tiny_ubm(4, 3, 53);
        let mut model = TvModel::init(Formulation::Standard, &ubm, 5, 0.0, 9);
        // center the random stats so the standard assumptions hold
        let stats = random_corpus(4, 3, 40, 13);
        for _ in 0..3 {
            run_em_iter(&mut model, &stats, true);
        }
        let (_h, g) = posterior_moments(&model, &stats);
        let dev = g.sub(&Mat::eye(5)).max_abs();
        assert!(dev < 0.15, "G deviates from I by {dev}");
        // prior stays zero for the standard formulation
        assert!(model.prior_mean.iter().all(|&x| x == 0.0));
    }
}
