//! Linear discriminant analysis (paper §4.1: 400 → 200 before PLDA).
//!
//! Solved as a symmetric problem: whiten by the within-class scatter
//! (Cholesky), eigendecompose the whitened between-class scatter, and
//! keep the leading directions.

use anyhow::{bail, Result};

use crate::linalg::{jacobi_eigh, Cholesky, Mat};

/// Fitted LDA projection.
#[derive(Debug, Clone)]
pub struct Lda {
    /// Projection matrix (out_dim × in_dim); rows are discriminants.
    pub w: Mat,
}

impl Lda {
    /// Fit on labeled rows. `spk_of_row[i]` is the class of row i.
    pub fn fit(x: &Mat, spk_of_row: &[usize], out_dim: usize) -> Result<Self> {
        let (n, d) = (x.rows(), x.cols());
        assert_eq!(n, spk_of_row.len());
        let n_spk = spk_of_row.iter().max().map(|&m| m + 1).unwrap_or(0);
        if out_dim > d {
            bail!("LDA out_dim {out_dim} exceeds input dim {d}");
        }
        if n_spk < 2 {
            bail!("LDA needs at least two classes");
        }

        // class means + global mean
        let mut counts = vec![0.0f64; n_spk];
        let mut means = Mat::zeros(n_spk, d);
        let mut global = vec![0.0; d];
        for i in 0..n {
            let s = spk_of_row[i];
            counts[s] += 1.0;
            crate::linalg::axpy(1.0, x.row(i), means.row_mut(s));
            crate::linalg::axpy(1.0, x.row(i), &mut global);
        }
        for s in 0..n_spk {
            let c = counts[s].max(1.0);
            for v in means.row_mut(s) {
                *v /= c;
            }
        }
        for v in &mut global {
            *v /= n as f64;
        }

        // scatters
        let mut sw = Mat::zeros(d, d);
        for i in 0..n {
            let s = spk_of_row[i];
            let diff: Vec<f64> =
                x.row(i).iter().zip(means.row(s)).map(|(a, b)| a - b).collect();
            for (ii, &di) in diff.iter().enumerate() {
                if di == 0.0 {
                    continue;
                }
                let row = sw.row_mut(ii);
                for (jj, &dj) in diff.iter().enumerate() {
                    row[jj] += di * dj;
                }
            }
        }
        sw.scale(1.0 / n as f64);
        // ridge for stability
        let tr = sw.trace() / d as f64;
        for i in 0..d {
            *sw.get_mut(i, i) += 1e-6 * tr.max(1e-12) + 1e-12;
        }

        let mut sb = Mat::zeros(d, d);
        for s in 0..n_spk {
            if counts[s] == 0.0 {
                continue;
            }
            let diff: Vec<f64> =
                means.row(s).iter().zip(&global).map(|(a, b)| a - b).collect();
            for (ii, &di) in diff.iter().enumerate() {
                if di == 0.0 {
                    continue;
                }
                let row = sb.row_mut(ii);
                for (jj, &dj) in diff.iter().enumerate() {
                    row[jj] += counts[s] * di * dj;
                }
            }
        }
        sb.scale(1.0 / n as f64);

        // whiten Sw: y = L⁻¹ x with Sw = L Lᵀ, then eigendecompose
        // L⁻¹ Sb L⁻ᵀ and take the top eigenvectors.
        let chol = Cholesky::new(&sw)?;
        // M = L⁻¹ Sb L⁻ᵀ: solve L A = Sb, then L B = Aᵀ
        let a = forward_solve_mat(&chol, &sb);
        let m = forward_solve_mat(&chol, &a.t());
        let mut msym = m;
        msym.symmetrize();
        let eig = jacobi_eigh(&msym);

        // top out_dim eigenvectors (descending eigenvalue), mapped back:
        // w = L⁻ᵀ v  ⇔ solve Lᵀ w = v
        let dtot = eig.values.len();
        let mut w = Mat::zeros(out_dim, d);
        for k in 0..out_dim {
            let v = eig.vectors.col(dtot - 1 - k);
            let wk = backward_solve_vec(&chol, &v);
            w.row_mut(k).copy_from_slice(&wk);
        }
        Ok(Self { w })
    }

    /// Project rows: (N × D) → (N × out_dim).
    pub fn apply(&self, x: &Mat) -> Mat {
        x.matmul_nt(&self.w)
    }
}

/// Solve L Y = B columnwise (forward substitution), B (d × m).
fn forward_solve_mat(chol: &Cholesky, b: &Mat) -> Mat {
    let mut out = Mat::zeros(b.rows(), b.cols());
    for j in 0..b.cols() {
        let y = chol.forward_solve_vec(&b.col(j));
        out.set_col(j, &y);
    }
    out
}

/// Solve Lᵀ w = v (backward substitution on the lower factor).
fn backward_solve_vec(chol: &Cholesky, v: &[f64]) -> Vec<f64> {
    let l = chol.l();
    let n = l.rows();
    let mut x = v.to_vec();
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            x[i] -= l.get(k, i) * x[k];
        }
        x[i] /= l.get(i, i);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Two classes separated along one axis, noise along others.
    fn two_class_data(seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Rng::seed(seed);
        let n = 200;
        let mut x = Mat::zeros(n, 5);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % 2;
            let row = x.row_mut(i);
            row[0] = if class == 0 { -2.0 } else { 2.0 } + 0.3 * rng.normal();
            for v in row.iter_mut().skip(1) {
                *v = 2.0 * rng.normal(); // big non-discriminative noise
            }
            labels.push(class);
        }
        (x, labels)
    }

    #[test]
    fn lda_finds_the_discriminative_axis() {
        let (x, labels) = two_class_data(1);
        let lda = Lda::fit(&x, &labels, 1).unwrap();
        // the first discriminant should be dominated by coordinate 0
        let w0 = lda.w.row(0);
        let lead = w0[0].abs();
        let rest: f64 = w0[1..].iter().map(|v| v.abs()).fold(0.0, f64::max);
        assert!(lead > 3.0 * rest, "w0 = {w0:?}");
    }

    #[test]
    fn projection_separates_classes() {
        let (x, labels) = two_class_data(2);
        let lda = Lda::fit(&x, &labels, 1).unwrap();
        let y = lda.apply(&x);
        // class-conditional means well separated vs within std
        let mut m = [0.0f64; 2];
        let mut cnt = [0.0f64; 2];
        for i in 0..y.rows() {
            m[labels[i]] += y.get(i, 0);
            cnt[labels[i]] += 1.0;
        }
        m[0] /= cnt[0];
        m[1] /= cnt[1];
        let mut var = 0.0;
        for i in 0..y.rows() {
            let d = y.get(i, 0) - m[labels[i]];
            var += d * d;
        }
        var /= y.rows() as f64;
        let sep = (m[0] - m[1]).abs() / var.sqrt();
        assert!(sep > 5.0, "separation {sep}");
    }

    #[test]
    fn errors_on_bad_inputs() {
        let (x, labels) = two_class_data(3);
        assert!(Lda::fit(&x, &labels, 99).is_err());
        let one_class = vec![0usize; x.rows()];
        assert!(Lda::fit(&x, &one_class, 2).is_err());
    }

    #[test]
    fn output_dims() {
        let (x, labels) = two_class_data(4);
        let lda = Lda::fit(&x, &labels, 3).unwrap();
        let y = lda.apply(&x);
        assert_eq!((y.rows(), y.cols()), (200, 3));
    }
}
