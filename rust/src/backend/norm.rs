//! Centering, whitening and length normalization
//! (Garcia-Romero & Espy-Wilson, 2011 — paper ref [24]).

use anyhow::Result;

use crate::linalg::{jacobi_eigh, Mat};

/// Mean removal fitted on the backend training set.
#[derive(Debug, Clone)]
pub struct Centering {
    pub mean: Vec<f64>,
}

impl Centering {
    pub fn fit(x: &Mat) -> Self {
        let n = x.rows().max(1);
        let mut mean = vec![0.0; x.cols()];
        for i in 0..x.rows() {
            crate::linalg::axpy(1.0, x.row(i), &mut mean);
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        Self { mean }
    }

    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for i in 0..out.rows() {
            for (v, m) in out.row_mut(i).iter_mut().zip(&self.mean) {
                *v -= m;
            }
        }
        out
    }
}

/// Whitening via the eigendecomposition of the total covariance
/// (paper §4.1: applied when min-div was not used).
#[derive(Debug, Clone)]
pub struct Whitening {
    /// `P = Λ^{-½} Qᵀ` of the covariance.
    pub p: Mat,
}

impl Whitening {
    pub fn fit(centered: &Mat) -> Result<Self> {
        let n = centered.rows().max(2);
        let mut cov = centered.matmul_tn(centered);
        cov.scale(1.0 / (n as f64 - 1.0));
        let eig = jacobi_eigh(&cov);
        Ok(Self { p: eig.whitener(1e-10) })
    }

    pub fn apply(&self, x: &Mat) -> Mat {
        x.matmul_nt(&self.p)
    }
}

/// Length normalization: scale each vector to unit Euclidean norm.
#[derive(Debug, Clone, Copy)]
pub struct LengthNorm;

impl LengthNorm {
    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = x.clone();
        for i in 0..out.rows() {
            crate::linalg::normalize(out.row_mut(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn centering_zeroes_the_mean() {
        let mut rng = Rng::seed(1);
        let x = Mat::from_fn(50, 4, |_, j| 3.0 * rng.normal() + j as f64);
        let c = Centering::fit(&x);
        let y = c.apply(&x);
        let c2 = Centering::fit(&y);
        assert!(c2.mean.iter().all(|&m| m.abs() < 1e-10));
    }

    #[test]
    fn whitening_gives_identity_covariance() {
        let mut rng = Rng::seed(2);
        // correlated data
        let x = Mat::from_fn(500, 3, |_, _| rng.normal());
        let mix = Mat::from_rows(&[&[2.0, 0.5, 0.0], &[0.0, 1.0, 0.3], &[0.0, 0.0, 0.2]]);
        let data = x.matmul(&mix);
        let centered = Centering::fit(&data).apply(&data);
        let w = Whitening::fit(&centered).unwrap();
        let white = w.apply(&centered);
        let mut cov = white.matmul_tn(&white);
        cov.scale(1.0 / (white.rows() as f64 - 1.0));
        assert!(cov.approx_eq(&Mat::eye(3), 0.05), "cov {:?}", cov);
    }

    #[test]
    fn length_norm_unit_rows() {
        let mut rng = Rng::seed(3);
        let x = Mat::from_fn(10, 5, |_, _| 4.0 * rng.normal());
        let y = LengthNorm.apply(&x);
        for i in 0..10 {
            assert!((crate::linalg::norm2(y.row(i)) - 1.0).abs() < 1e-12);
        }
    }
}
