//! Two-covariance PLDA (paper ref [24] scoring; Brümmer's two-cov
//! formulation): `x = μ + y + ε`, `y ~ N(0, B)` between speakers,
//! `ε ~ N(0, W)` within speaker. Trained by EM over speaker-labeled
//! vectors; scored with the closed-form LLR
//!
//! `llr(e, t) = ½eᵀQe + ½tᵀQt + eᵀPt + const`
//!
//! with `tot = B + W`, `Q = tot⁻¹ − (tot − B·tot⁻¹·B)⁻¹`,
//! `P = tot⁻¹·B·(tot − B·tot⁻¹·B)⁻¹` (the constant is dropped —
//! detection metrics are threshold-invariant).

use anyhow::Result;

use crate::io::Serialize;
use crate::linalg::{outer, Cholesky, Mat};

/// Trained PLDA model.
#[derive(Debug, Clone)]
pub struct Plda {
    pub mu: Vec<f64>,
    /// Between-speaker covariance.
    pub b: Mat,
    /// Within-speaker covariance.
    pub w: Mat,
    /// Scoring matrices (derived; rebuilt on fit/load).
    pub p: Mat,
    pub q: Mat,
}

impl Plda {
    /// EM fit on labeled rows.
    pub fn fit(x: &Mat, spk_of_row: &[usize], iters: usize) -> Result<Self> {
        let (n, d) = (x.rows(), x.cols());
        assert_eq!(n, spk_of_row.len());
        let n_spk = spk_of_row.iter().max().map(|&m| m + 1).unwrap_or(0);
        anyhow::ensure!(n_spk >= 2, "PLDA needs at least two speakers");

        // global mean
        let mut mu = vec![0.0; d];
        for i in 0..n {
            crate::linalg::axpy(1.0, x.row(i), &mut mu);
        }
        for v in &mut mu {
            *v /= n as f64;
        }

        // per-speaker counts and sums (centered)
        let mut counts = vec![0.0f64; n_spk];
        let mut sums = Mat::zeros(n_spk, d);
        for i in 0..n {
            let s = spk_of_row[i];
            counts[s] += 1.0;
            for (j, (&xv, m)) in x.row(i).iter().zip(&mu).enumerate() {
                *sums.get_mut(s, j) += xv - m;
            }
        }

        // init: B, W from total covariance split
        let mut total = Mat::zeros(d, d);
        for i in 0..n {
            let cx: Vec<f64> = x.row(i).iter().zip(&mu).map(|(a, b)| a - b).collect();
            total.add_scaled(1.0, &outer(&cx, &cx));
        }
        total.scale(1.0 / n as f64);
        let mut b = total.clone();
        b.scale(0.5);
        let mut w = total;
        w.scale(0.5);

        for _ in 0..iters {
            let w_inv = Cholesky::new_regularized(&w).0.inverse();
            let b_inv = Cholesky::new_regularized(&b).0.inverse();

            let mut b_acc = Mat::zeros(d, d);
            let mut w_acc = Mat::zeros(d, d);
            for s in 0..n_spk {
                let ns = counts[s];
                if ns == 0.0 {
                    continue;
                }
                // posterior of y_s: Λ = B⁻¹ + n_s W⁻¹; ŷ = Λ⁻¹ W⁻¹ Σᵢ(xᵢ−μ)
                let mut lam = b_inv.clone();
                lam.add_scaled(ns, &w_inv);
                let lam_chol = Cholesky::new_regularized(&lam).0;
                let rhs = w_inv.matvec(sums.row(s));
                let y_hat = lam_chol.solve_vec(&rhs);
                let y_cov = lam_chol.inverse();

                let mut second = y_cov.clone();
                second.add_scaled(1.0, &outer(&y_hat, &y_hat));
                b_acc.add_scaled(1.0, &second);

                // within: Σᵢ E‖xᵢ−μ−y‖² terms — expand to avoid a second
                // data pass: Σᵢ(cᵢ−ŷ)(cᵢ−ŷ)ᵀ + n_s·Cov(y).
                // We only kept per-speaker sums, so accumulate the cross
                // terms with the raw data below.
                w_acc.add_scaled(ns, &y_cov);
                // subtract 2·sym(Σc ŷᵀ) + n ŷŷᵀ, data pass adds Σ ccᵀ
                let sy = outer(sums.row(s), &y_hat);
                w_acc.add_scaled(-1.0, &sy);
                w_acc.add_scaled(-1.0, &sy.t());
                w_acc.add_scaled(ns, &outer(&y_hat, &y_hat));
            }
            // add Σᵢ cᵢcᵢᵀ (precomputed `total·n`)
            for i in 0..n {
                let cx: Vec<f64> = x.row(i).iter().zip(&mu).map(|(a, b)| a - b).collect();
                w_acc.add_scaled(1.0, &outer(&cx, &cx));
            }

            b_acc.scale(1.0 / n_spk as f64);
            w_acc.scale(1.0 / n as f64);
            b_acc.symmetrize();
            w_acc.symmetrize();
            // floors against collapse
            for m in [&mut b_acc, &mut w_acc] {
                let tr = m.trace() / d as f64;
                for i in 0..d {
                    *m.get_mut(i, i) += 1e-8 * tr.max(1e-12) + 1e-12;
                }
            }
            b = b_acc;
            w = w_acc;
        }

        let (p, q) = Self::scoring_matrices(&b, &w)?;
        Ok(Self { mu, b, w, p, q })
    }

    /// Derive the closed-form scoring matrices from (B, W).
    pub fn scoring_matrices(b: &Mat, w: &Mat) -> Result<(Mat, Mat)> {
        let tot = b.add(w);
        let tot_inv = Cholesky::new_regularized(&tot).0.inverse();
        // S = tot − B tot⁻¹ B
        let bt = b.matmul(&tot_inv).matmul(b);
        let s = tot.sub(&bt);
        let s_inv = Cholesky::new_regularized(&s).0.inverse();
        let p = tot_inv.matmul(b).matmul(&s_inv);
        let mut q = tot_inv.sub(&s_inv);
        q.symmetrize();
        let mut p_sym = p;
        p_sym.symmetrize();
        Ok((p_sym, q))
    }

    /// LLR for a single (enroll, test) pair of *centered* vectors.
    pub fn score_pair(&self, e: &[f64], t: &[f64]) -> f64 {
        let qe = crate::linalg::dot(e, &self.q.matvec(e));
        let qt = crate::linalg::dot(t, &self.q.matvec(t));
        let pt = crate::linalg::dot(e, &self.p.matvec(t));
        0.5 * qe + 0.5 * qt + pt
    }

    /// Full (N × M) score matrix — the CPU mirror of the `plda_score`
    /// graph.
    pub fn score_matrix(&self, enroll: &Mat, test: &Mat) -> Mat {
        let qe: Vec<f64> =
            (0..enroll.rows()).map(|i| 0.5 * crate::linalg::dot(enroll.row(i), &self.q.matvec(enroll.row(i)))).collect();
        let qt: Vec<f64> =
            (0..test.rows()).map(|j| 0.5 * crate::linalg::dot(test.row(j), &self.q.matvec(test.row(j)))).collect();
        let cross = enroll.matmul(&self.p).matmul_nt(test);
        Mat::from_fn(enroll.rows(), test.rows(), |i, j| qe[i] + qt[j] + cross.get(i, j))
    }
}

impl Serialize for Plda {
    fn write(&self, w: &mut crate::io::BinWriter) -> Result<()> {
        self.mu.write(w)?;
        self.b.write(w)?;
        self.w.write(w)
    }

    fn read(r: &mut crate::io::BinReader) -> Result<Self> {
        let mu = Vec::<f64>::read(r)?;
        let b = Mat::read(r)?;
        let w = Mat::read(r)?;
        let (p, q) = Plda::scoring_matrices(&b, &w)?;
        Ok(Self { mu, b, w, p, q })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn planted_data(
        n_spk: usize,
        per_spk: usize,
        d: usize,
        b_scale: f64,
        w_scale: f64,
        seed: u64,
    ) -> (Mat, Vec<usize>) {
        let mut rng = Rng::seed(seed);
        let mut x = Mat::zeros(n_spk * per_spk, d);
        let mut labels = Vec::new();
        for s in 0..n_spk {
            let y: Vec<f64> = (0..d).map(|_| b_scale * rng.normal()).collect();
            for u in 0..per_spk {
                let row = x.row_mut(s * per_spk + u);
                for j in 0..d {
                    row[j] = y[j] + w_scale * rng.normal();
                }
                labels.push(s);
            }
        }
        (x, labels)
    }

    #[test]
    fn em_recovers_planted_covariances() {
        let (x, labels) = planted_data(200, 10, 4, 2.0, 0.7, 1);
        let plda = Plda::fit(&x, &labels, 10).unwrap();
        // B ≈ 4·I, W ≈ 0.49·I (tolerances cover the sampling error of
        // 200 speaker draws: sd(B̂) ≈ 4·√(2/200) ≈ 0.4)
        for i in 0..4 {
            assert!((plda.b.get(i, i) - 4.0).abs() < 1.2, "B[{i}][{i}] = {}", plda.b.get(i, i));
            assert!((plda.w.get(i, i) - 0.49).abs() < 0.12, "W[{i}][{i}] = {}", plda.w.get(i, i));
            // off-diagonals near zero
            for j in 0..4 {
                if i != j {
                    assert!(plda.b.get(i, j).abs() < 0.8, "B[{i}][{j}] = {}", plda.b.get(i, j));
                }
            }
        }
    }

    #[test]
    fn same_speaker_scores_higher() {
        let (x, labels) = planted_data(40, 8, 4, 1.5, 0.5, 2);
        let plda = Plda::fit(&x, &labels, 8).unwrap();
        // held-out pairs
        let (ex, el) = planted_data(10, 2, 4, 1.5, 0.5, 3);
        let centered = {
            let mut c = ex.clone();
            for i in 0..c.rows() {
                for (v, m) in c.row_mut(i).iter_mut().zip(&plda.mu) {
                    *v -= m;
                }
            }
            c
        };
        let mut same = 0.0;
        let mut diff = 0.0;
        let mut n_same = 0.0;
        let mut n_diff = 0.0;
        for i in 0..centered.rows() {
            for j in 0..centered.rows() {
                if i == j {
                    continue;
                }
                let s = plda.score_pair(centered.row(i), centered.row(j));
                if el[i] == el[j] {
                    same += s;
                    n_same += 1.0;
                } else {
                    diff += s;
                    n_diff += 1.0;
                }
            }
        }
        assert!(same / n_same > diff / n_diff + 0.5, "{} vs {}", same / n_same, diff / n_diff);
    }

    #[test]
    fn score_matrix_matches_pairs() {
        let (x, labels) = planted_data(20, 5, 3, 1.0, 0.6, 5);
        let plda = Plda::fit(&x, &labels, 5).unwrap();
        let e = Mat::from_fn(4, 3, |i, j| (i + j) as f64 * 0.2 - 0.5);
        let t = Mat::from_fn(6, 3, |i, j| (i as f64 - j as f64) * 0.3);
        let m = plda.score_matrix(&e, &t);
        for i in 0..4 {
            for j in 0..6 {
                let want = plda.score_pair(e.row(i), t.row(j));
                assert!((m.get(i, j) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn scoring_matrices_one_dimensional_sanity() {
        // d=1: closed forms are scalars we can verify by hand
        let b = Mat::from_rows(&[&[2.0]]);
        let w = Mat::from_rows(&[&[1.0]]);
        let (p, q) = Plda::scoring_matrices(&b, &w).unwrap();
        let tot = 3.0f64;
        let s = tot - 2.0 * 2.0 / tot; // tot − B²/tot
        assert!((p.get(0, 0) - (2.0 / tot) / s).abs() < 1e-10);
        assert!((q.get(0, 0) - (1.0 / tot - 1.0 / s)).abs() < 1e-10);
    }

    #[test]
    fn serialization_roundtrip() {
        let (x, labels) = planted_data(15, 4, 3, 1.0, 0.5, 7);
        let plda = Plda::fit(&x, &labels, 4).unwrap();
        let dir = std::env::temp_dir().join("ivtv_plda_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plda.bin");
        crate::io::save(&plda, &path).unwrap();
        let back: Plda = crate::io::load(&path).unwrap();
        assert!(back.p.approx_eq(&plda.p, 1e-12));
        let e = [0.4, -0.2, 0.1];
        let t = [0.1, 0.3, -0.5];
        assert!((back.score_pair(&e, &t) - plda.score_pair(&e, &t)).abs() < 1e-12);
    }
}
