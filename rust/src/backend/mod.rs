//! Scoring backend (paper §4.1): centering, whitening, length
//! normalization, LDA dimensionality reduction, and PLDA scoring.
//!
//! Recipe order (as in the paper): center → (whiten when min-div was
//! not used) → length-normalize → LDA 400→200 (scaled: R→D) → PLDA.

mod lda;
mod norm;
mod plda;

pub use lda::Lda;
pub use norm::{Centering, LengthNorm, Whitening};
pub use plda::Plda;

use anyhow::Result;

use crate::linalg::Mat;

/// The full trained backend: a processing chain + PLDA scorer.
#[derive(Debug, Clone)]
pub struct Backend {
    pub centering: Centering,
    /// Applied only when the extractor skipped minimum divergence
    /// (paper §4.1: "if minimum divergence re-estimation was not used,
    /// we also whitened the i-vectors before length normalization").
    pub whitening: Option<Whitening>,
    pub lda: Lda,
    pub plda: Plda,
}

/// Backend training configuration.
pub struct BackendOpts {
    pub lda_dim: usize,
    pub plda_iters: usize,
    /// Whiten before length-norm (set when min-div was off).
    pub whiten: bool,
}

impl Backend {
    /// Train the chain on labeled i-vectors (`spk_of_row[i]` = speaker
    /// index of row i).
    pub fn train(ivectors: &Mat, spk_of_row: &[usize], opts: &BackendOpts) -> Result<Self> {
        let centering = Centering::fit(ivectors);
        let centered = centering.apply(ivectors);
        let (whitening, white) = if opts.whiten {
            let w = Whitening::fit(&centered)?;
            let applied = w.apply(&centered);
            (Some(w), applied)
        } else {
            (None, centered)
        };
        let normed = LengthNorm.apply(&white);
        let lda = Lda::fit(&normed, spk_of_row, opts.lda_dim)?;
        let projected = lda.apply(&normed);
        let plda = Plda::fit(&projected, spk_of_row, opts.plda_iters)?;
        Ok(Self { centering, whitening, lda, plda })
    }

    /// Raw i-vector dimension the chain was trained on (what
    /// [`Backend::project`] expects as input).
    pub fn input_dim(&self) -> usize {
        self.centering.mean.len()
    }

    /// Dimension of projected vectors (what the PLDA scorer consumes).
    pub fn output_dim(&self) -> usize {
        self.lda.w.rows()
    }

    /// Project raw i-vectors through the full chain (center → [whiten]
    /// → length-norm → LDA).
    pub fn project(&self, ivectors: &Mat) -> Mat {
        let mut x = self.centering.apply(ivectors);
        if let Some(w) = &self.whitening {
            x = w.apply(&x);
        }
        self.lda.apply(&LengthNorm.apply(&x))
    }

    /// Score trial pairs given projected enroll/test vectors.
    pub fn score(&self, enroll: &Mat, test: &Mat) -> Mat {
        self.plda.score_matrix(enroll, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::trials::{det_metrics, generate_trials};

    /// Synthetic embeddings with genuine speaker structure.
    fn labeled_embeddings(
        n_spk: usize,
        per_spk: usize,
        dim: usize,
        noise: f64,
        seed: u64,
    ) -> (Mat, Vec<usize>) {
        let mut rng = Rng::seed(seed);
        let centers: Vec<Vec<f64>> = (0..n_spk).map(|_| rng.normal_vec(dim)).collect();
        let n = n_spk * per_spk;
        let mut x = Mat::zeros(n, dim);
        let mut labels = Vec::with_capacity(n);
        for s in 0..n_spk {
            for u in 0..per_spk {
                let row = x.row_mut(s * per_spk + u);
                for j in 0..dim {
                    row[j] = centers[s][j] + noise * rng.normal();
                }
                labels.push(s);
                let _ = u;
            }
        }
        (x, labels)
    }

    #[test]
    fn backend_separates_speakers_end_to_end() {
        let (train_x, train_l) = labeled_embeddings(20, 8, 16, 0.5, 1);
        let backend = Backend::train(
            &train_x,
            &train_l,
            &BackendOpts { lda_dim: 8, plda_iters: 5, whiten: true },
        )
        .unwrap();

        // held-out speakers
        let (eval_x, eval_l) = labeled_embeddings(10, 6, 16, 0.5, 2);
        let proj = backend.project(&eval_x);
        let scores = backend.score(&proj, &proj);

        let trials = generate_trials(&eval_l, 400, 3);
        let scored: Vec<(f64, bool)> = trials
            .iter()
            .map(|t| (scores.get(t.enroll, t.test), t.target))
            .collect();
        let m = det_metrics(&scored);
        assert!(m.eer_pct < 10.0, "EER {:.1}% on separable data", m.eer_pct);
    }

    #[test]
    fn backend_near_chance_on_unstructured_data() {
        // no speaker structure → EER ≈ 50%
        let mut rng = Rng::seed(5);
        let n = 120;
        let x = Mat::from_fn(n, 12, |_, _| rng.normal());
        let labels: Vec<usize> = (0..n).map(|i| i / 6).collect();
        let backend = Backend::train(
            &x,
            &labels,
            &BackendOpts { lda_dim: 6, plda_iters: 3, whiten: true },
        )
        .unwrap();
        let (ex, el) = {
            let x = Mat::from_fn(60, 12, |_, _| rng.normal());
            let l: Vec<usize> = (0..60).map(|i| i / 6).collect();
            (x, l)
        };
        let proj = backend.project(&ex);
        let scores = backend.score(&proj, &proj);
        let trials = generate_trials(&el, 300, 7);
        let scored: Vec<(f64, bool)> =
            trials.iter().map(|t| (scores.get(t.enroll, t.test), t.target)).collect();
        let m = det_metrics(&scored);
        assert!((m.eer_pct - 50.0).abs() < 20.0, "EER {:.1}%", m.eer_pct);
    }
}
