//! Delta features and energy VAD — the Kaldi-recipe analogue.
//!
//! The paper's features are 72-dimensional MFCCs = 24 cepstra + Δ + ΔΔ,
//! with energy-based voice activity detection. We reproduce the same
//! pipeline shape on the synthetic base features: regression deltas
//! over a ±2 window and a percentile energy VAD.

use crate::linalg::Mat;

/// Regression-delta window half-width (Kaldi default: 2).
pub const DELTA_WINDOW: usize = 2;

/// Append Δ and ΔΔ coefficients: (T × F) → (T × 3F).
///
/// Deltas use the standard regression formula
/// `d_t = Σ_k k (x_{t+k} − x_{t−k}) / (2 Σ_k k²)` with edge replication,
/// exactly like Kaldi's `add-deltas`.
pub fn add_deltas(feats: &Mat) -> Mat {
    let t_len = feats.rows();
    let dim = feats.cols();
    let delta = regression_delta(feats);
    let delta2 = regression_delta(&delta);
    let mut out = Mat::zeros(t_len, 3 * dim);
    for t in 0..t_len {
        out.row_mut(t)[..dim].copy_from_slice(feats.row(t));
        out.row_mut(t)[dim..2 * dim].copy_from_slice(delta.row(t));
        out.row_mut(t)[2 * dim..].copy_from_slice(delta2.row(t));
    }
    out
}

fn regression_delta(x: &Mat) -> Mat {
    let t_len = x.rows();
    let dim = x.cols();
    let denom: f64 = 2.0 * (1..=DELTA_WINDOW).map(|k| (k * k) as f64).sum::<f64>();
    let mut d = Mat::zeros(t_len, dim);
    for t in 0..t_len {
        for k in 1..=DELTA_WINDOW {
            let fwd = (t + k).min(t_len - 1);
            let bwd = t.saturating_sub(k);
            let (xf, xb) = (x.row(fwd), x.row(bwd));
            let row = d.row_mut(t);
            for j in 0..dim {
                row[j] += k as f64 * (xf[j] - xb[j]) / denom;
            }
        }
    }
    d
}

/// Energy-based VAD: keeps frames whose log-energy proxy (first base
/// coefficient, the synthetic "C0") exceeds `threshold`. Returns the
/// surviving frame indices.
pub fn energy_vad(feats: &Mat, threshold: f64) -> Vec<usize> {
    (0..feats.rows()).filter(|&t| feats.get(t, 0) > threshold).collect()
}

/// Select a subset of rows into a new matrix.
pub fn select_rows(feats: &Mat, keep: &[usize]) -> Mat {
    let mut out = Mat::zeros(keep.len(), feats.cols());
    for (i, &t) in keep.iter().enumerate() {
        out.row_mut(i).copy_from_slice(feats.row(t));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deltas_triple_the_dim() {
        let x = Mat::from_fn(10, 4, |t, j| (t * 4 + j) as f64);
        let y = add_deltas(&x);
        assert_eq!((y.rows(), y.cols()), (10, 12));
        // statics preserved
        for t in 0..10 {
            assert_eq!(&y.row(t)[..4], x.row(t));
        }
    }

    #[test]
    fn delta_of_linear_ramp_is_slope() {
        // x_t = 3t → interior deltas must equal 3
        let x = Mat::from_fn(20, 1, |t, _| 3.0 * t as f64);
        let y = add_deltas(&x);
        for t in DELTA_WINDOW..20 - DELTA_WINDOW {
            assert!((y.get(t, 1) - 3.0).abs() < 1e-12, "t={t}: {}", y.get(t, 1));
        }
        // ΔΔ needs a double-width margin: the Δ track is edge-replicated,
        // so its own regression is only exact further into the interior.
        for t in 2 * DELTA_WINDOW..20 - 2 * DELTA_WINDOW {
            assert!(y.get(t, 2).abs() < 1e-9, "t={t}: {}", y.get(t, 2));
        }
    }

    #[test]
    fn delta_of_constant_is_zero() {
        let x = Mat::from_fn(8, 3, |_, j| j as f64 + 1.0);
        let y = add_deltas(&x);
        for t in 0..8 {
            for j in 3..9 {
                assert!(y.get(t, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn vad_filters_low_energy() {
        let x = Mat::from_rows(&[&[1.0, 0.0], &[-1.0, 0.0], &[2.0, 0.0], &[0.1, 0.0]]);
        let keep = energy_vad(&x, 0.5);
        assert_eq!(keep, vec![0, 2]);
        let sel = select_rows(&x, &keep);
        assert_eq!(sel.rows(), 2);
        assert_eq!(sel.get(1, 0), 2.0);
    }
}
