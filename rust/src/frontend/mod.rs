//! Acoustic front-end substrate.
//!
//! The paper builds on Kaldi's VoxCeleb recipe: MFCC extraction, energy
//! VAD, and the VoxCeleb1+2 corpora. None of those are available here
//! (see DESIGN.md substitutions), so this module provides the synthetic
//! equivalents that exercise the same downstream code paths:
//!
//! * [`synth`] — a ground-truth generative world (full-covariance GMM +
//!   low-rank speaker and channel subspaces) from which per-utterance
//!   frame sequences are sampled with sticky-Markov temporal structure.
//! * [`features`] — delta/double-delta appending and energy-based VAD,
//!   mirroring the 24-ceps → 72-dim pipeline at 8 → 24 dims.

pub mod features;
pub mod synth;

pub use synth::{CorpusBundle, GroundTruth, TrafficGen};
