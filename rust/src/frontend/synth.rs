//! Synthetic VoxCeleb stand-in: a ground-truth generative world.
//!
//! Hierarchy (DESIGN.md substitution table):
//!
//! * a "world" GMM with `true_components` components over the base
//!   feature space (the phonetic inventory);
//! * a low-rank **speaker** subspace: each speaker shifts every
//!   component mean by a supervector offset `V·y_s`, `y_s ~ N(0, I)`;
//! * a low-rank **channel** subspace: each utterance adds `U·z_u`;
//! * frames follow a sticky-Markov component path (so Δ/ΔΔ carry
//!   information) plus leading/trailing silence (exercises VAD).
//!
//! Because speakers genuinely live in a low-rank supervector subspace,
//! total-variability modeling is *correct* for this data and the EER
//! responds to the training variants the paper ablates.

use anyhow::Result;

use super::features;
use crate::config::CorpusConfig;
use crate::io::{FeatArchive, Utterance};
use crate::linalg::Mat;
use crate::rng::Rng;

/// VAD threshold on the base "C0" coordinate. Speech components have
/// C0 ≈ +1.5, silence ≈ −2.5, so −0.5 splits them cleanly while still
/// rejecting a few low-energy speech frames (realistic VAD behaviour).
pub const VAD_THRESHOLD: f64 = -0.5;

/// The ground-truth generative world.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    /// Component weights (C).
    pub weights: Vec<f64>,
    /// Component means (C × F0).
    pub means: Mat,
    /// Per-component diagonal stds (C × F0).
    pub stds: Mat,
    /// Speaker subspace (C·F0 × speaker_rank), column-normalized.
    pub v: Mat,
    /// Channel subspace (C·F0 × channel_rank).
    pub u: Mat,
    pub cfg: CorpusConfig,
}

/// Generated corpus: train + eval archives.
pub struct CorpusBundle {
    pub train: FeatArchive,
    pub eval: FeatArchive,
}

impl GroundTruth {
    /// Sample the world from the corpus seed.
    pub fn sample(cfg: &CorpusConfig) -> Self {
        let mut rng = Rng::seed(cfg.seed);
        let c = cfg.true_components;
        let f0 = cfg.base_dim;
        let weights = rng.dirichlet(5.0, c);
        let means = Mat::from_fn(c, f0, |_, j| {
            if j == 0 {
                // "C0" energy coordinate: keep speech well above silence
                1.5 + 0.6 * rng.normal()
            } else {
                2.2 * rng.normal()
            }
        });
        let stds = Mat::from_fn(c, f0, |_, _| rng.uniform_in(0.45, 1.0));
        let sdim = c * f0;
        let v = Mat::from_fn(sdim, cfg.speaker_rank, |_, _| {
            cfg.speaker_scale * rng.normal() / (cfg.speaker_rank as f64).sqrt()
        });
        let u = Mat::from_fn(sdim, cfg.channel_rank, |_, _| {
            cfg.channel_scale * rng.normal() / (cfg.channel_rank as f64).sqrt()
        });
        Self { weights, means, stds, v, u, cfg: cfg.clone() }
    }

    /// Draw a speaker supervector offset `V y, y ~ N(0, I)`.
    pub fn sample_speaker_offset(&self, rng: &mut Rng) -> Vec<f64> {
        let y = rng.normal_vec(self.cfg.speaker_rank);
        self.v.matvec(&y)
    }

    /// Sample one utterance's base features for a given speaker offset.
    /// Returns the (frames × base_dim) matrix *before* deltas/VAD.
    pub fn sample_utterance(&self, spk_offset: &[f64], rng: &mut Rng) -> Mat {
        let cfg = &self.cfg;
        let f0 = cfg.base_dim;
        let n_speech = cfg.min_frames + rng.below(cfg.max_frames - cfg.min_frames + 1);
        let n_sil = ((n_speech as f64 * cfg.silence_frac) as usize).max(2);
        let n_total = n_speech + n_sil;

        // per-utterance channel offset U z
        let z = rng.normal_vec(cfg.channel_rank);
        let chan_offset = self.u.matvec(&z);

        let mut out = Mat::zeros(n_total, f0);
        let lead = n_sil / 2;

        // silence model: low C0, small spread
        let write_silence = |row: &mut [f64], rng: &mut Rng| {
            row[0] = -2.5 + 0.3 * rng.normal();
            for x in row.iter_mut().skip(1) {
                *x = 0.4 * rng.normal();
            }
        };

        for t in 0..lead {
            write_silence(out.row_mut(t), rng);
        }
        // sticky-Markov component path
        let mut comp = rng.categorical(&self.weights);
        for t in lead..lead + n_speech {
            if rng.uniform() > cfg.stay_prob {
                comp = rng.categorical(&self.weights);
            }
            let row = out.row_mut(t);
            let mean = self.means.row(comp);
            let std = self.stds.row(comp);
            let off = &spk_offset[comp * f0..(comp + 1) * f0];
            let ch = &chan_offset[comp * f0..(comp + 1) * f0];
            for j in 0..f0 {
                row[j] = mean[j] + off[j] + ch[j] + std[j] * rng.normal();
            }
        }
        for t in lead + n_speech..n_total {
            write_silence(out.row_mut(t), rng);
        }
        out
    }

    /// Full front-end for one utterance: sample base features, append
    /// Δ + ΔΔ, then keep VAD-surviving frames (Kaldi recipe order).
    pub fn sample_processed_utterance(&self, spk_offset: &[f64], rng: &mut Rng) -> Mat {
        let base = self.sample_utterance(spk_offset, rng);
        let with_deltas = features::add_deltas(&base);
        let keep = features::energy_vad(&base, VAD_THRESHOLD);
        features::select_rows(&with_deltas, &keep)
    }
}

/// Deterministic request-traffic source for the serving subsystem
/// ([`crate::serve`]): a set of "traffic speakers" with ground-truth
/// offsets whose utterances are sampled on demand. `utterance(s, k)`
/// is a pure function of `(seed, s, k)`, so concurrent load-test
/// clients can replay identical traffic without pre-materializing an
/// archive, and enrollment (small `k`) and verification (large `k`)
/// draws never collide.
#[derive(Debug, Clone)]
pub struct TrafficGen {
    world: GroundTruth,
    /// Per-speaker ground-truth supervector offsets.
    offsets: Vec<Vec<f64>>,
    seed: u64,
}

impl TrafficGen {
    /// Sample the world + `n_speakers` speaker identities.
    pub fn new(cfg: &CorpusConfig, n_speakers: usize, seed: u64) -> Self {
        let world = GroundTruth::sample(cfg);
        let mut rng = Rng::seed(seed ^ 0xF0AD_5EED);
        let offsets = (0..n_speakers)
            .map(|s| {
                let mut spk_rng = rng.fork(s as u64);
                world.sample_speaker_offset(&mut spk_rng)
            })
            .collect();
        Self { world, offsets, seed }
    }

    pub fn n_speakers(&self) -> usize {
        self.offsets.len()
    }

    /// Stable id of traffic speaker `s`.
    pub fn speaker_id(&self, s: usize) -> String {
        format!("traffic{s:05}")
    }

    /// The `k`-th utterance of speaker `s` (full front-end: deltas +
    /// VAD). Deterministic in `(seed, s, k)` and safe to call from many
    /// threads (`&self`, fresh rng per call).
    pub fn utterance(&self, s: usize, k: u64) -> Mat {
        let mut rng = Rng::seed(
            self.seed
                ^ (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ k.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        self.world.sample_processed_utterance(&self.offsets[s], &mut rng)
    }
}

/// Generate the train + eval corpora deterministically from the config.
pub fn generate_corpus(cfg: &CorpusConfig) -> Result<CorpusBundle> {
    let world = GroundTruth::sample(cfg);
    let mut rng = Rng::seed(cfg.seed ^ 0xC0FFEE);

    let make_split = |prefix: &str, n_spk: usize, utts_per: usize, rng: &mut Rng| {
        let mut utts = Vec::with_capacity(n_spk * utts_per);
        for s in 0..n_spk {
            let spk_id = format!("{prefix}{s:04}");
            let mut spk_rng = rng.fork(s as u64);
            let offset = world.sample_speaker_offset(&mut spk_rng);
            for k in 0..utts_per {
                let feats = world.sample_processed_utterance(&offset, &mut spk_rng);
                utts.push(Utterance {
                    utt_id: format!("{spk_id}-u{k:03}"),
                    spk_id: spk_id.clone(),
                    feats,
                });
            }
        }
        FeatArchive { utts }
    };

    let train = make_split("train", cfg.n_train_speakers, cfg.utts_per_train_speaker, &mut rng);
    // eval speakers are disjoint by construction (fresh forks from a
    // different stream)
    let mut eval_rng = Rng::seed(cfg.seed ^ 0xE7A1_57EA);
    let eval =
        make_split("eval", cfg.n_eval_speakers, cfg.utts_per_eval_speaker, &mut eval_rng);
    Ok(CorpusBundle { train, eval })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> CorpusConfig {
        CorpusConfig {
            n_train_speakers: 4,
            utts_per_train_speaker: 3,
            n_eval_speakers: 3,
            utts_per_eval_speaker: 2,
            min_frames: 40,
            max_frames: 60,
            base_dim: 6,
            true_components: 8,
            speaker_rank: 4,
            speaker_scale: 0.5,
            channel_rank: 2,
            channel_scale: 0.2,
            stay_prob: 0.85,
            silence_frac: 0.15,
            seed: 11,
        }
    }

    #[test]
    fn corpus_shapes_and_determinism() {
        let cfg = tiny_cfg();
        let a = generate_corpus(&cfg).unwrap();
        let b = generate_corpus(&cfg).unwrap();
        assert_eq!(a.train.utts.len(), 12);
        assert_eq!(a.eval.utts.len(), 6);
        assert_eq!(a.train.dim(), 18); // 3 × base_dim
        assert!(a.train.utts[0].feats.approx_eq(&b.train.utts[0].feats, 0.0));
        // train/eval speaker ids disjoint
        for u in &a.eval.utts {
            assert!(u.spk_id.starts_with("eval"));
        }
    }

    #[test]
    fn vad_removes_silence() {
        let cfg = tiny_cfg();
        let world = GroundTruth::sample(&cfg);
        let mut rng = Rng::seed(5);
        let off = world.sample_speaker_offset(&mut rng);
        let base = world.sample_utterance(&off, &mut rng);
        let keep = features::energy_vad(&base, VAD_THRESHOLD);
        // all silence frames dropped: ≥ the lead/trail count
        assert!(keep.len() < base.rows());
        // surviving frames are mostly speech (C0 above threshold)
        for &t in &keep {
            assert!(base.get(t, 0) > VAD_THRESHOLD);
        }
    }

    #[test]
    fn same_speaker_utts_share_offset_structure() {
        // the supervector mean of same-speaker utterances should be
        // closer than across speakers (sanity of the speaker subspace)
        let cfg = tiny_cfg();
        let world = GroundTruth::sample(&cfg);
        let mut rng = Rng::seed(3);
        let off_a = world.sample_speaker_offset(&mut rng);
        let off_b = world.sample_speaker_offset(&mut rng);
        let d_ab: f64 =
            off_a.iter().zip(&off_b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
        assert!(d_ab > 0.0);
        // same offset → identical; different speakers → nonzero distance
        let norm_a: f64 = off_a.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(norm_a > 0.0);
    }

    #[test]
    fn traffic_gen_is_deterministic_and_distinct() {
        let cfg = tiny_cfg();
        let a = TrafficGen::new(&cfg, 3, 7);
        let b = TrafficGen::new(&cfg, 3, 7);
        assert_eq!(a.n_speakers(), 3);
        assert_eq!(a.speaker_id(1), "traffic00001");
        // same (seed, s, k) → identical features, replayable across gens
        assert!(a.utterance(1, 5).approx_eq(&b.utterance(1, 5), 0.0));
        // different k or s → different utterances
        assert!(!a.utterance(1, 5).approx_eq(&a.utterance(1, 6), 1e-9)
            || a.utterance(1, 5).rows() != a.utterance(1, 6).rows());
        let u0 = a.utterance(0, 5);
        let u1 = a.utterance(1, 5);
        assert!(u0.rows() != u1.rows() || !u0.approx_eq(&u1, 1e-9));
        // dim matches the front-end contract
        assert_eq!(u0.cols(), 3 * cfg.base_dim);
    }

    #[test]
    fn speech_frames_have_temporal_correlation() {
        // sticky path ⇒ adjacent speech frames correlate more than
        // distant ones
        let cfg = tiny_cfg();
        let world = GroundTruth::sample(&cfg);
        let mut rng = Rng::seed(9);
        let off = world.sample_speaker_offset(&mut rng);
        let base = world.sample_utterance(&off, &mut rng);
        let keep = features::energy_vad(&base, VAD_THRESHOLD);
        let x = features::select_rows(&base, &keep);
        let t_len = x.rows();
        let mut adj = 0.0;
        let mut far = 0.0;
        let mut n = 0;
        for t in 0..t_len.saturating_sub(10) {
            adj += crate::linalg::dot(x.row(t), x.row(t + 1));
            far += crate::linalg::dot(x.row(t), x.row(t + 10));
            n += 1;
        }
        assert!(n > 0 && adj / n as f64 > far / n as f64);
    }
}
