//! Dynamic micro-batcher: coalesce concurrent extraction requests into
//! E-step batches, behind real admission control.
//!
//! Request threads do the CPU "loader" work (alignment + Baum-Welch
//! statistics, exactly the paper's pipelined-loader role) and submit a
//! [`Job`]; worker threads drain the shared queue and run one
//! GEMM-shaped [`estep_batch_cpu`] per batch — so per-request traffic
//! rides the same batched kernels as offline training. A batch closes
//! when it reaches `batch_utts` jobs (flush-on-size), when the oldest
//! job has waited `flush` since enqueue (flush-on-deadline), or as soon
//! as no announced request is still on its way (early flush — under
//! light load batching costs nothing over per-request dispatch;
//! [`MicroBatcher::begin_request`] is the announcement).
//!
//! Admission control: the queue is bounded, and [`MicroBatcher::submit`]
//! waits for space only until the caller's deadline — then it **sheds**
//! the request with a typed [`ServeError::Overloaded`] instead of
//! blocking the submitter indefinitely. Under saturation the engine
//! therefore degrades into fast, observable rejections (counted in
//! [`MicroBatcher::shed_requests`]) rather than an unbounded convoy of
//! blocked request threads; queue occupancy is tracked per enqueue in a
//! [`DepthGauge`] for the serving report.
//!
//! Hot-swap coherence: each job carries the `Arc<ServeModel>` snapshot
//! its statistics were computed with, and a batch only groups jobs that
//! share the same snapshot — a model swap mid-flight splits the batch
//! at the epoch boundary instead of mixing models.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::ivector::{estep_batch_cpu, EstepWorkspace, UttStats};
use crate::metrics::{DepthGauge, DepthSummary};
use crate::obs::{self, Counter, ObsRegistry, RequestTrace, Stage};

use super::bundle::ServeModel;
use super::error::ServeError;

/// One queued extraction request (built by [`MicroBatcher::submit`],
/// which owns the enqueue timestamp).
struct Job {
    /// Baum-Welch statistics computed on the request thread.
    stats: UttStats,
    /// The model snapshot the statistics belong to.
    model: Arc<ServeModel>,
    /// Response channel: the i-vector (posterior mean − prior mean).
    resp: SyncSender<Vec<f64>>,
    /// Stamped as the job enters the queue; the flush deadline counts
    /// from here, so a job never waits for co-riders longer than
    /// `flush` past its enqueue.
    enqueued: Instant,
    /// The caller's request deadline: past it the caller has dropped
    /// its receiver, so workers purge the job instead of burning a
    /// batch slot on dead work.
    expires: Instant,
    /// The submitting thread's current request trace (if tracing is on)
    /// — captured at submit so worker threads can attribute queue-wait
    /// and E-step time to the right request.
    trace: Option<Arc<RequestTrace>>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    batch_utts: usize,
    flush: Duration,
    queue_cap: usize,
    /// Requests announced via [`MicroBatcher::begin_request`] that have
    /// not submitted yet (still computing their statistics). While this
    /// is zero no co-rider can arrive, so workers flush a sub-size
    /// batch immediately instead of idling out the deadline — under
    /// light load batching then costs nothing over per-request
    /// dispatch, and the deadline only pays for genuine coalescing.
    inbound: AtomicUsize,
    /// Stall hook: while set, workers leave the queue untouched — the
    /// deterministic stand-in for "all workers are busy" that the
    /// overload/timeout tests and the cluster bench's deliberate-stall
    /// harness pivot on. Never set by the production request path.
    stalled: AtomicBool,
    /// The observability registry the counters below live in (also the
    /// sink for the queue-wait / estep-batch stage histograms).
    obs: Arc<ObsRegistry>,
    /// Dispatched batch count (`serve_batches_total`).
    batches: Counter,
    /// Requests that flowed through batches (`serve_batched_requests_total`).
    requests: Counter,
    /// Requests shed at admission (`serve_shed_total`).
    shed: Counter,
    /// Queued jobs purged because their caller's request deadline
    /// passed before a worker reached them (`serve_expired_jobs_total`).
    expired: Counter,
    /// Worker threads whose join reported a panic
    /// (`serve_worker_panics_total`). The in-loop `catch_unwind` keeps a
    /// poisoned *batch* from killing its worker, so a panicking *join*
    /// means the loop itself died — pool capacity silently shrank.
    worker_panics: Counter,
    /// Post-push queue depth per admitted request (`serve_queue_depth`).
    depth: Arc<DepthGauge>,
    /// Scripted fault hook: panic the next N batch dispatches inside
    /// the worker's catch_unwind (the chaos drill's deterministic
    /// stand-in for a poisoned batch); 0 in normal operation.
    panic_next: AtomicU64,
}

/// RAII announcement of an in-flight request (created before the
/// caller starts its statistics work, dropped once the job is queued
/// or the request path bails).
pub(crate) struct RequestToken<'a> {
    shared: &'a Shared,
}

impl Drop for RequestToken<'_> {
    fn drop(&mut self) {
        self.shared.inbound.fetch_sub(1, Ordering::AcqRel);
        // a worker may be holding a sub-size batch open for this request
        self.shared.cv.notify_all();
    }
}

/// The batcher: a bounded job queue plus its worker pool. Dropping it
/// drains the queue and joins the workers; [`MicroBatcher::shutdown`] +
/// [`MicroBatcher::join_workers`] expose the same teardown through
/// `&self` so an engine drain can run it early (and bounded) while the
/// batcher stays shared.
pub(crate) struct MicroBatcher {
    shared: Arc<Shared>,
    /// Behind a mutex so a `&self` drain can take handles out to join;
    /// emptied exactly once — later joins see an empty vec and return.
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl MicroBatcher {
    /// `obs` is the registry the batcher's counters and stage
    /// histograms live in; `label` is the owning engine's instance
    /// label (the instruments register as `name{engine="<label>"}`).
    pub fn new(
        batch_utts: usize,
        flush: Duration,
        workers: usize,
        queue_cap: usize,
        obs: Arc<ObsRegistry>,
        label: &str,
    ) -> Self {
        let queue_cap = queue_cap.max(1);
        let labels = [("engine", label)];
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            // a batch larger than the queue bound could never fill, so
            // the size trigger would degenerate to deadline-only under
            // saturation — clamp to keep flush-on-size reachable
            batch_utts: batch_utts.clamp(1, queue_cap),
            flush,
            queue_cap,
            inbound: AtomicUsize::new(0),
            stalled: AtomicBool::new(false),
            batches: obs.counter("serve_batches_total", &labels),
            requests: obs.counter("serve_batched_requests_total", &labels),
            shed: obs.counter("serve_shed_total", &labels),
            expired: obs.counter("serve_expired_jobs_total", &labels),
            worker_panics: obs.counter("serve_worker_panics_total", &labels),
            depth: obs.gauge("serve_queue_depth", &labels),
            panic_next: AtomicU64::new(0),
            obs,
        });
        let workers = (0..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { shared, workers: Mutex::new(workers) }
    }

    /// Begin shutdown: new [`MicroBatcher::submit`] calls fail with a
    /// typed [`ServeError::ShuttingDown`]; workers finish whatever is
    /// queued and exit. Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
    }

    /// Join the worker threads, waiting at most until `deadline`
    /// (`None` = wait forever). Returns true once every worker has been
    /// joined; handles are taken out as they finish, so a timed-out
    /// call leaves the stragglers for the next join (or for drop).
    /// Callers must [`MicroBatcher::shutdown`] first or this blocks on
    /// workers that never exit.
    pub fn join_workers(&self, deadline: Option<Instant>) -> bool {
        let mut workers =
            self.workers.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        loop {
            while let Some(i) = workers.iter().position(|h| h.is_finished()) {
                // a worker that died panicking (outside the per-batch
                // catch_unwind) silently shrank the pool — surface it
                if workers.swap_remove(i).join().is_err() {
                    self.shared.worker_panics.inc();
                }
            }
            if workers.is_empty() {
                return true;
            }
            match deadline {
                // bounded join: poll `is_finished` so a straggler past
                // the deadline is reported, not waited out
                Some(d) => {
                    if Instant::now() >= d {
                        return false;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                None => {
                    let h = workers.pop().unwrap();
                    if h.join().is_err() {
                        self.shared.worker_panics.inc();
                    }
                }
            }
        }
    }

    /// Announce an in-flight request before its statistics work starts;
    /// keep the token alive until just after [`MicroBatcher::submit`].
    pub fn begin_request(&self) -> RequestToken<'_> {
        self.shared.inbound.fetch_add(1, Ordering::AcqRel);
        RequestToken { shared: &self.shared }
    }

    /// Enqueue a request, waiting for queue space only until
    /// `submit_deadline`: past it the request is **load-shed** with a
    /// typed [`ServeError::Overloaded`] instead of blocking forever.
    /// Errors with [`ServeError::ShuttingDown`] once shutdown has
    /// begun. `expires` is the caller's request deadline: a job still
    /// queued past it is purged by the workers (the caller has dropped
    /// its receiver) instead of dispatched. On success the i-vector
    /// arrives on `resp` when the request's batch is dispatched.
    pub fn submit(
        &self,
        stats: UttStats,
        model: Arc<ServeModel>,
        resp: SyncSender<Vec<f64>>,
        submit_deadline: Instant,
        expires: Instant,
    ) -> Result<()> {
        let shared = &*self.shared;
        let start = Instant::now();
        let mut q = shared.queue.lock().unwrap();
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return Err(ServeError::ShuttingDown.into());
            }
            if q.len() < shared.queue_cap {
                break;
            }
            let now = Instant::now();
            if now >= submit_deadline {
                drop(q);
                shared.shed.inc();
                return Err(ServeError::Overloaded { waited: now - start }.into());
            }
            // bounded wait: a worker's post-drain notify_all wakes us,
            // and the residual `deadline - now` caps the sleep so a
            // missed wakeup can only cost the deadline, never a hang
            q = shared.cv.wait_timeout(q, submit_deadline - now).unwrap().0;
        }
        q.push_back(Job {
            stats,
            model,
            resp,
            enqueued: Instant::now(),
            expires,
            trace: obs::current(),
        });
        shared.depth.record(q.len() as u64);
        drop(q);
        shared.cv.notify_all();
        Ok(())
    }

    /// Batches dispatched so far.
    pub fn dispatched_batches(&self) -> u64 {
        self.shared.batches.get()
    }

    /// Requests that flowed through dispatched batches.
    pub fn batched_requests(&self) -> u64 {
        self.shared.requests.get()
    }

    /// Requests shed at admission (typed `Overloaded` rejections).
    pub fn shed_requests(&self) -> u64 {
        self.shared.shed.get()
    }

    /// Queued jobs purged because their caller's deadline passed before
    /// a worker reached them.
    pub fn expired_jobs(&self) -> u64 {
        self.shared.expired.get()
    }

    /// Worker threads found dead-by-panic at join time (the drop-path
    /// drain used to swallow these).
    pub fn worker_panics(&self) -> u64 {
        self.shared.worker_panics.get()
    }

    /// Queue-depth statistics over admitted requests.
    pub fn queue_depth(&self) -> DepthSummary {
        self.shared.depth.summary()
    }

    /// Jobs currently queued (admitted, not yet dispatched).
    pub fn queue_len(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Stall hook: freeze (or thaw) the worker pool — the deterministic
    /// stand-in for saturated workers in the overload/timeout tests and
    /// the cluster bench's deliberately-degraded replica. Compiled in
    /// every build (the cluster bench is a real binary), never touched
    /// by the serving path itself.
    pub fn set_stalled(&self, stalled: bool) {
        self.shared.stalled.store(stalled, Ordering::Release);
        self.shared.cv.notify_all();
    }

    /// Fault hook: panic the next `n` batch dispatches inside the
    /// worker's catch_unwind — each scripted panic drops one assembled
    /// batch, so every rider's response sender closes and the waiting
    /// requests error out exactly like a poisoned batch. Additive;
    /// consumed one dispatch at a time. Compiled in every build for the
    /// same reason as [`MicroBatcher::set_stalled`]: the chaos drill is
    /// a real binary.
    pub fn panic_next_batches(&self, n: u64) {
        self.shared.panic_next.fetch_add(n, Ordering::AcqRel);
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.shutdown();
        self.join_workers(None);
    }
}

fn worker_loop(shared: &Shared) {
    // per-worker scratch, reused across batches (rebuilt on rank change
    // after a hot swap or on a larger batch)
    let mut ws: Option<EstepWorkspace> = None;
    let mut ws_rank = usize::MAX;
    loop {
        let batch = {
            let mut q = shared.queue.lock().unwrap();
            // wait for the first job of the next batch (or idle while
            // the test hook stalls the pool)
            loop {
                if !q.is_empty() && !shared.stalled.load(Ordering::Acquire) {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return; // queue drained: exit
                }
                q = shared.cv.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
            }
            // a caller past its request deadline has dropped its
            // receiver — under sustained overload, dispatching those
            // jobs would leave workers serving only ghosts while fresh
            // requests keep timing out, so purge them before (and
            // after) batch assembly
            purge_expired(&mut q, shared);
            // hold for co-riders until the batch fills, the deadline
            // expires, or nobody is on the way (shutdown flushes
            // immediately); the deadline counts from the oldest job's
            // enqueue, so time already spent queued behind a busy
            // worker is not re-waited
            let deadline = match q.front() {
                Some(job) => job.enqueued + shared.flush,
                None => {
                    // everything queued had already expired; the purge
                    // freed queue space, so wake any blocked submitter
                    shared.cv.notify_all();
                    continue;
                }
            };
            while q.len() < shared.batch_utts && !shared.shutdown.load(Ordering::Acquire) {
                if shared.inbound.load(Ordering::Acquire) == 0 {
                    break; // no announced request can still join
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (queue, timeout) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                q = queue;
                if timeout.timed_out() {
                    break;
                }
            }
            // jobs may have expired during the co-rider wait
            purge_expired(&mut q, shared);
            // drain one batch of model-coherent jobs
            let mut batch: Vec<Job> = Vec::with_capacity(shared.batch_utts.min(q.len()));
            while batch.len() < shared.batch_utts {
                let coherent = match (q.front(), batch.first()) {
                    (Some(job), Some(first)) => Arc::ptr_eq(&job.model, &first.model),
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                if !coherent {
                    break;
                }
                batch.push(q.pop_front().unwrap());
            }
            batch
        };
        // queue space freed / epoch-split leftovers visible to peers
        shared.cv.notify_all();
        if batch.is_empty() {
            continue;
        }
        // queue-wait ends here: the jobs are out of the queue and about
        // to dispatch as one batch
        let drained = Instant::now();
        for job in &batch {
            let ns = drained.saturating_duration_since(job.enqueued).as_nanos() as u64;
            shared.obs.observe_stage_ns(Stage::QueueWait, ns);
            if let Some(t) = &job.trace {
                t.add_stage(Stage::QueueWait, ns);
            }
        }
        // a panicking batch (e.g. non-finite statistics blowing up the
        // E-step) must not kill the worker: catch it, drop the jobs —
        // their response senders close, so each waiting request gets an
        // error instead of hanging on a shrunken pool
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_batch(shared, &mut ws, &mut ws_rank, &batch);
        }));
        if caught.is_err() {
            ws = None; // scratch state is suspect after an unwind
            eprintln!(
                "[serve] batch worker caught a panicked dispatch ({} requests errored)",
                batch.len()
            );
        }
    }
}

/// Drop queued jobs whose caller's request deadline has passed. The
/// whole queue is scanned, not just the front: deadlines start before
/// the variable-length loader (alignment) stage, so a slow-to-align
/// request can sit *behind* a later-expiring one — expiry is not
/// monotone along the queue. The scan is a cheap pointer walk bounded
/// by `queue_cap`, once per batch assembly.
fn purge_expired(q: &mut VecDeque<Job>, shared: &Shared) {
    let now = Instant::now();
    let before = q.len();
    q.retain(|job| now < job.expires);
    let removed = (before - q.len()) as u64;
    if removed > 0 {
        shared.expired.add(removed);
    }
}

/// One batched E-step dispatch + per-request responses.
fn run_batch(
    shared: &Shared,
    ws: &mut Option<EstepWorkspace>,
    ws_rank: &mut usize,
    batch: &[Job],
) {
    // scripted fault hook: blow this dispatch up inside the caller's
    // catch_unwind (see `panic_next_batches`)
    if shared
        .panic_next
        .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
        .is_ok()
    {
        panic!("scripted batch panic (chaos drill)");
    }
    let model = &batch[0].model;
    let r = model.consts.r;
    let rebuild = match ws.as_ref() {
        Some(w) => *ws_rank != r || w.capacity() < batch.len(),
        None => true,
    };
    if rebuild {
        *ws = Some(EstepWorkspace::new(r, batch.len().max(shared.batch_utts)));
        *ws_rank = r;
    }
    let refs: Vec<&UttStats> = batch.iter().map(|j| &j.stats).collect();
    let started = Instant::now();
    let phi = estep_batch_cpu(&refs, &model.consts, ws.as_mut().unwrap(), None);
    // one histogram sample per dispatch; every rider's trace carries the
    // full batch time (that is the latency the request actually paid)
    let estep_ns = started.elapsed().as_nanos() as u64;
    shared.obs.observe_stage_ns(Stage::EstepBatch, estep_ns);
    shared.batches.inc();
    shared.requests.add(batch.len() as u64);
    for (u, job) in batch.iter().enumerate() {
        if let Some(t) = &job.trace {
            t.add_stage(Stage::EstepBatch, estep_ns);
        }
        let mut ivector = phi.row(u).to_vec();
        for (x, p) in ivector.iter_mut().zip(&model.consts.prior_mean) {
            *x -= p;
        }
        // the requester may have given up — dropping the response is fine
        let _ = job.resp.send(ivector);
    }
}
