//! Typed serving-path failures.
//!
//! Admission control and deadlines turn "the engine is saturated" from
//! an unbounded blocked thread into a *value* the caller can branch on
//! — and the cluster dispatcher ([`crate::serve::cluster`]) does
//! exactly that: it retries [`ServeError::Overloaded`] and
//! [`ServeError::ShuttingDown`] on another replica (the shed-failover
//! path), treats [`ServeError::Timeout`] as a lost request (the
//! deadline is already spent — retrying would double it), and fails
//! *stateless* requests (extract/enroll/verify) over on
//! [`ServeError::WorkerFailed`] too — a panicked batch on one replica
//! is no reason to fail the caller while healthy replicas sit idle,
//! and the health supervisor quarantines the panicking replica off the
//! routing set. Session calls never retry `WorkerFailed`: partial
//! stats are replica-pinned. The variants
//! ride inside `anyhow::Error` (every engine entry point keeps its
//! `Result` signature) and stay reachable through
//! `Error::downcast_ref`, even under added context.

use std::fmt;
use std::time::Duration;

/// Why a serving request failed without producing an i-vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Load-shed at admission: the micro-batch queue stayed at capacity
    /// for the whole submit deadline. The request did **not** enter the
    /// queue; retrying elsewhere is safe.
    Overloaded {
        /// How long admission waited for queue space before shedding.
        waited: Duration,
    },
    /// Admitted, but the response missed the request deadline (stalled
    /// or saturated workers). The job may still complete; its response
    /// is discarded.
    Timeout {
        /// Total time spent on the request before giving up.
        waited: Duration,
    },
    /// The engine is shutting down; no new requests are admitted.
    ShuttingDown,
    /// The worker dropped the response channel — the request's batch
    /// dispatch panicked (e.g. non-finite statistics).
    WorkerFailed,
    /// Streaming-session admission shed: the session table is at its
    /// configured capacity. Like [`Self::Overloaded`], nothing was
    /// created — but the caller should back off, not failover (a
    /// session opened elsewhere would still count against the cluster).
    SessionLimit {
        /// Live sessions at the instant the open was refused.
        live: usize,
    },
    /// The session id was never issued here (or its tombstone already
    /// aged out of the table).
    SessionNotFound,
    /// The session sat idle past its deadline and the eviction sweep
    /// reclaimed it; its accumulated stats are gone.
    SessionExpired,
    /// The session was already finalized — by an explicit close or an
    /// early-exit decision — and cannot accept further ops.
    SessionClosed,
    /// The session's pinned replica was swapped or retired; the cluster
    /// closes it typed instead of silently rescoring partial stats
    /// against a different total-variability space.
    SessionSwapped,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Overloaded { waited } => write!(
                f,
                "engine overloaded: shed after waiting {:.0} ms for queue space",
                waited.as_secs_f64() * 1e3
            ),
            Self::Timeout { waited } => write!(
                f,
                "request timed out after {:.0} ms waiting for its batch",
                waited.as_secs_f64() * 1e3
            ),
            Self::ShuttingDown => write!(f, "serving engine is shutting down"),
            Self::WorkerFailed => {
                write!(f, "serving worker dropped the response (batch dispatch failed)")
            }
            Self::SessionLimit { live } => {
                write!(f, "session table full ({live} live sessions) — open shed")
            }
            Self::SessionNotFound => write!(f, "unknown session id"),
            Self::SessionExpired => write!(f, "session evicted after its idle deadline"),
            Self::SessionClosed => write!(f, "session already finalized"),
            Self::SessionSwapped => {
                write!(f, "session's pinned replica was swapped out — reopen to continue")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// True for the capacity-driven rejections (queue shed, timed out,
    /// or session-table full) — the "engine is saturated, not broken"
    /// failures a load harness counts rather than propagates.
    pub fn is_rejection(&self) -> bool {
        matches!(
            self,
            Self::Overloaded { .. } | Self::Timeout { .. } | Self::SessionLimit { .. }
        )
    }

    /// True when retrying the request elsewhere is safe *and* useful:
    /// the request never entered a queue (`Overloaded` was shed at
    /// admission; `ShuttingDown` was refused by a draining engine), so
    /// another replica can still serve it within the original deadline.
    /// `Timeout` is deliberately not retriable — its deadline is
    /// already spent — hard failures would fail anywhere, and no
    /// session variant is retriable: a session's partial stats live on
    /// exactly one replica's pinned model, so "elsewhere" cannot
    /// continue it (the caller must reopen instead).
    pub fn is_retriable(&self) -> bool {
        matches!(self, Self::Overloaded { .. } | Self::ShuttingDown)
    }

    /// The failover set for *stateless* requests (extract, enroll,
    /// verify): everything in [`Self::is_retriable`] plus
    /// [`Self::WorkerFailed`]. A worker that dropped the response
    /// channel did so before any side effect — an enrollment's
    /// registry write happens only after extraction succeeds — so
    /// replaying the request on another replica cannot double-apply
    /// anything. Session operations must keep using
    /// [`Self::is_retriable`]: their partial stats live on one
    /// replica's pinned model and cannot move.
    pub fn is_retriable_stateless(&self) -> bool {
        self.is_retriable() || matches!(self, Self::WorkerFailed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_classification() {
        let shed = ServeError::Overloaded { waited: Duration::from_millis(250) };
        assert!(shed.to_string().contains("overloaded"));
        assert!(shed.to_string().contains("250 ms"));
        assert!(shed.is_rejection());
        let to = ServeError::Timeout { waited: Duration::from_millis(100) };
        assert!(to.to_string().contains("timed out"));
        assert!(to.is_rejection());
        assert!(!ServeError::ShuttingDown.is_rejection());
        assert!(!ServeError::WorkerFailed.is_rejection());
        // the dispatcher's failover set: shed + draining, never a
        // spent-deadline timeout or a hard failure
        assert!(shed.is_retriable());
        assert!(ServeError::ShuttingDown.is_retriable());
        assert!(!to.is_retriable());
        assert!(!ServeError::WorkerFailed.is_retriable());
        // stateless requests widen the set by exactly WorkerFailed:
        // nothing was applied before the drop, so replay is safe
        assert!(shed.is_retriable_stateless());
        assert!(ServeError::ShuttingDown.is_retriable_stateless());
        assert!(ServeError::WorkerFailed.is_retriable_stateless());
        assert!(!to.is_retriable_stateless(), "a spent deadline stays spent");
    }

    #[test]
    fn session_variants_classify_as_non_retriable() {
        let full = ServeError::SessionLimit { live: 1024 };
        assert!(full.to_string().contains("1024 live"));
        // a full session table is counted like a queue shed...
        assert!(full.is_rejection());
        // ...but never failed over: a session opened elsewhere still
        // counts against the cluster, and feeds are replica-pinned
        assert!(!full.is_retriable());
        for e in [
            ServeError::SessionNotFound,
            ServeError::SessionExpired,
            ServeError::SessionClosed,
            ServeError::SessionSwapped,
        ] {
            assert!(!e.is_rejection(), "{e} must propagate, not be counted as load");
            assert!(!e.is_retriable(), "{e} must not retry onto a different bundle");
            assert!(
                !e.is_retriable_stateless(),
                "{e}: the stateless set must not leak session variants"
            );
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn survives_anyhow_round_trip_with_context() {
        use anyhow::Context;
        let err: anyhow::Error = ServeError::Overloaded { waited: Duration::ZERO }.into();
        let wrapped = Err::<(), _>(err).context("verify request").unwrap_err();
        let back = wrapped.downcast_ref::<ServeError>().expect("typed error reachable");
        assert!(back.is_rejection());
    }
}
