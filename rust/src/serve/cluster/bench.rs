//! Cluster load harness: replay enroll/verify traffic against a
//! [`Dispatcher`] under deliberate saturation — the machinery behind
//! the `cluster-bench` CLI command and the `BENCH_5.json` 1-vs-N
//! replica scaling report.
//!
//! The harness reuses the serving bench's pieces (the deterministic
//! [`TrafficGen`] request source and its verify-trial plan) and adds
//! the cluster-specific probes: **live enrollments** interleaved with
//! the verify load (so a rolling swap mid-run has enrollments to
//! lose — the report's `lost_enrollments` must stay 0), an optional
//! **rolling swap** triggered a third of the way through the run, and
//! an optional **deliberately stalled replica** (the degraded-node
//! drill: the run must still complete with zero hard failures, sheds
//! failing over to the healthy replicas).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::bench_util::{variants_json, write_bench_json};
use crate::config::{Config, ServeConfig};
use crate::frontend::synth::TrafficGen;
use crate::metrics::{LatencySummary, Stopwatch};
use crate::obs::latency_summary_json;
use crate::serve::bench::{tiny_serve_config, trial_plan};
use crate::serve::{ModelBundle, ServeError};

use super::Dispatcher;

/// Cluster load-replay parameters.
#[derive(Debug, Clone)]
pub struct ClusterBenchOpts {
    /// Speakers enrolled up front (before any stall), verified under load.
    pub speakers: usize,
    /// Enrollment utterances per up-front speaker.
    pub enroll_utts: usize,
    /// Verify requests replayed (half target, half impostor trials).
    pub requests: usize,
    /// Concurrent client threads.
    pub concurrency: usize,
    /// Each client also enrolls one utterance for its own live speaker
    /// every this-many of its verify requests (0 disables) — the
    /// during-run enrollments the rolling-swap acceptance counts.
    pub live_enroll_every: usize,
    /// Freeze this replica's workers for the whole load phase (the
    /// up-front enrollments run first, on a healthy cluster). If a
    /// mid-run swap replaces the stalled engine, the stall is
    /// re-applied to the replacement so the drill really does span the
    /// whole phase.
    pub stall_replica: Option<usize>,
}

/// The deliberately-saturating engine shape the cluster bench runs
/// under when no explicit config overrides it: **one** E-step worker
/// per replica behind a shallow (8-deep) queue with a 5 ms admission
/// budget. Together with [`cluster_bench_config`]'s rank-64 extractor
/// — whose per-utterance solve (C·R² L-build + R³/3 Cholesky) dwarfs
/// the request-thread alignment — each replica's completed throughput
/// is pinned to its single worker's solve rate while the client pool
/// offers far more. That is the regime the 1-vs-N ratio is meant to
/// measure: a second replica adds a second worker (≈2× drain rate),
/// the queue stays near capacity, and over-demand degrades into fast
/// sheds the dispatcher fails over instead of convoys.
pub fn saturation_serve_config(base: &ServeConfig) -> ServeConfig {
    let mut cfg = base.clone();
    cfg.workers = 1;
    cfg.batch_utts = 4;
    cfg.flush_us = 2_000;
    cfg.queue_cap = 8;
    cfg.submit_timeout_ms = 5;
    cfg.request_timeout_ms = 2_000;
    cfg
}

/// The cluster bench's model shape: [`tiny_serve_config`] with a
/// paper-class extractor rank (64) over a small UBM and short
/// utterances. The point is the *cost profile*, not accuracy: at R=64
/// the worker-side i-vector solve dominates the client-side alignment
/// by an order of magnitude, so the replica — not the client pool — is
/// the bottleneck the scaling headline measures. Trains in seconds
/// like the tiny config.
pub fn cluster_bench_config() -> Config {
    let mut cfg = tiny_serve_config();
    cfg.corpus.min_frames = 40;
    cfg.corpus.max_frames = 80;
    cfg.ubm.components = 16;
    cfg.tvm.rank = 64;
    cfg
}

/// One cluster load run's results.
#[derive(Debug, Clone)]
pub struct ClusterBenchReport {
    pub replicas: usize,
    pub route: String,
    /// Verify requests attempted.
    pub requests: usize,
    /// Requests that produced a score (attempted minus rejected).
    pub completed: usize,
    /// Client-visible rejections after the failover budget: engine
    /// sheds/timeouts the dispatcher could not place elsewhere.
    pub rejected: usize,
    pub wall_s: f64,
    /// Completed requests per second — the scaling headline: rejections
    /// do no scoring work, so counting them would reward shedding.
    pub throughput_rps: f64,
    /// Dispatcher-level verify latency (failover retries included).
    pub verify: LatencySummary,
    /// Failover retries launched.
    pub failovers: u64,
    /// Requests whose failover budget ran out (subset of `rejected`).
    pub exhausted: u64,
    /// Engine-level admission sheds summed over replicas (pre-failover).
    pub engine_shed: u64,
    /// Engine-level request timeouts summed over replicas.
    pub engine_timeouts: u64,
    /// Rolling swaps completed during the run.
    pub swaps: u64,
    /// Enrollments acknowledged to a client (up-front + live).
    pub acked_enrollments: u64,
    /// Acked enrollments missing from the registry after the run —
    /// the rolling-swap acceptance requires exactly 0.
    pub lost_enrollments: i64,
    /// Registry WAL records appended during the run (0 on a volatile
    /// cluster registry).
    pub wal_appends: u64,
    /// Registry compactions (WAL → snapshot) completed during the run.
    pub compactions: u64,
    /// Torn WAL tails detected when the cluster registry was opened
    /// (nonzero means the run started from a crash recovery).
    pub torn_tail: u64,
    pub target_mean: f64,
    pub impostor_mean: f64,
    /// Per-stage latency summaries (admit-wait, align, queue-wait,
    /// E-step, WAL append/fsync, …) from the dispatcher's shared
    /// [`crate::obs::ObsRegistry`] — failover hops included.
    pub stages: Vec<(&'static str, LatencySummary)>,
}

impl ClusterBenchReport {
    /// One JSON object (no trailing newline) for the BENCH_5 report.
    pub fn json_fragment(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|(name, s)| format!("\"{name}\": {}", latency_summary_json(s)))
            .collect();
        format!(
            "{{\"replicas\": {}, \"route\": \"{}\", \"requests\": {}, \"completed\": {}, \
\"rejected\": {}, \"wall_s\": {:.6}, \"throughput_rps\": {:.2}, \
\"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}, \
\"failovers\": {}, \"exhausted\": {}, \"shed\": {}, \"timeouts\": {}, \"swaps\": {}, \
\"acked_enrollments\": {}, \"lost_enrollments\": {}, \
\"wal_appends\": {}, \"compactions\": {}, \"torn_tail\": {}, \
\"target_mean_score\": {:.4}, \"impostor_mean_score\": {:.4}, \"stages\": {{{}}}}}",
            self.replicas,
            self.route,
            self.requests,
            self.completed,
            self.rejected,
            self.wall_s,
            self.throughput_rps,
            self.verify.p50_s * 1e3,
            self.verify.p95_s * 1e3,
            self.verify.p99_s * 1e3,
            self.failovers,
            self.exhausted,
            self.engine_shed,
            self.engine_timeouts,
            self.swaps,
            self.acked_enrollments,
            self.lost_enrollments,
            self.wal_appends,
            self.compactions,
            self.torn_tail,
            self.target_mean,
            self.impostor_mean,
            stages.join(", "),
        )
    }
}

/// Per-client accumulator (score sums + absorbed rejections).
#[derive(Debug, Default, Clone, Copy)]
struct ClientAcc {
    target_sum: f64,
    target_n: usize,
    impostor_sum: f64,
    impostor_n: usize,
    rejected: usize,
}

/// A saturated cluster answers with typed rejections, not hangs: shed,
/// timed out, or (rarely, mid-roll everywhere at once) shutting down.
/// The harness counts these and keeps driving load; anything else is a
/// hard failure that aborts the run — "zero failed (non-shed)
/// requests" means this function returned `Ok`.
fn is_counted_rejection(e: &anyhow::Error) -> bool {
    e.downcast_ref::<ServeError>()
        .is_some_and(|s| s.is_rejection() || s.is_retriable())
}

/// Enroll `opts.speakers` up front, then replay `opts.requests` verify
/// requests from `opts.concurrency` clients — with live enrollments
/// interleaved, an optional mid-run rolling swap (`swap_with` must be
/// value-identical to the serving bundle so fingerprints keep
/// matching, i.e. a re-push of the same artifact), and an optional
/// deliberately stalled replica. Expects a fresh dispatcher.
pub fn run_cluster_load(
    dispatcher: &Dispatcher,
    traffic: &TrafficGen,
    opts: &ClusterBenchOpts,
    swap_with: Option<&ModelBundle>,
) -> Result<ClusterBenchReport> {
    let n_spk = opts.speakers.min(traffic.n_speakers());
    ensure!(
        n_spk >= 2,
        "cluster load needs at least 2 speakers for impostor trials (got {n_spk})"
    );
    if let Some(id) = opts.stall_replica {
        ensure!(
            id < dispatcher.replicas(),
            "stall replica {id} out of range ({} replicas)",
            dispatcher.replicas()
        );
    }
    // up-front enrollment on a healthy cluster (the stall is a load-
    // phase drill; a stalled replica would swallow warm-up enrollments
    // into 2 s timeouts instead)
    for s in 0..n_spk {
        let id = traffic.speaker_id(s);
        for k in 0..opts.enroll_utts.max(1) {
            dispatcher.enroll(&id, &traffic.utterance(s, k as u64))?;
        }
    }
    let acked = AtomicU64::new((n_spk * opts.enroll_utts.max(1)) as u64);

    if let Some(id) = opts.stall_replica {
        dispatcher.stall_replica(id, true);
    }

    let concurrency = opts.concurrency.max(1);
    let attempted = AtomicUsize::new(0);
    let done = AtomicBool::new(false);
    let swap_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let sw = Stopwatch::start();
    let partials: Result<Vec<ClientAcc>> = std::thread::scope(|scope| {
        // the model push: one rolling swap once a third of the load has
        // been offered, racing the clients like a real deploy would
        if let Some(bundle) = swap_with {
            let dispatcher = &dispatcher;
            let attempted = &attempted;
            let done = &done;
            let swap_err = &swap_err;
            let trigger = opts.requests / 3;
            let stalled = opts.stall_replica;
            scope.spawn(move || {
                while !done.load(Ordering::Relaxed)
                    && attempted.load(Ordering::Relaxed) < trigger
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                match dispatcher.swap_bundle(bundle.clone()) {
                    Ok(()) => {
                        // the swap installed a fresh (healthy) engine in
                        // every slot — re-freeze the drilled replica so
                        // the stall spans the whole load phase as
                        // documented, not just its first third
                        if let Some(id) = stalled {
                            dispatcher.stall_replica(id, true);
                        }
                    }
                    Err(e) => *swap_err.lock().unwrap() = Some(e),
                }
            });
        }
        let handles: Vec<_> = (0..concurrency)
            .map(|c| {
                let dispatcher = &dispatcher;
                let traffic = &traffic;
                let attempted = &attempted;
                let acked = &acked;
                scope.spawn(move || -> Result<ClientAcc> {
                    let mut acc = ClientAcc::default();
                    let mut i = c;
                    while i < opts.requests {
                        attempted.fetch_add(1, Ordering::Relaxed);
                        let (claimed, actual, target) = trial_plan(i, n_spk);
                        // verification keys live past every enrollment key
                        let feats = traffic.utterance(actual, 1_000 + i as u64);
                        match dispatcher.verify(&traffic.speaker_id(claimed), &feats) {
                            Ok(out) if target => {
                                acc.target_sum += out.score;
                                acc.target_n += 1;
                            }
                            Ok(out) => {
                                acc.impostor_sum += out.score;
                                acc.impostor_n += 1;
                            }
                            Err(e) if is_counted_rejection(&e) => acc.rejected += 1,
                            Err(e) => return Err(e),
                        }
                        // live enrollment: this client's own speaker, so
                        // a lost write is attributable — only *acked*
                        // enrollments count toward the loss check
                        if opts.live_enroll_every > 0
                            && (i / concurrency) % opts.live_enroll_every == 0
                        {
                            let id = format!("live{c:03}");
                            let feats = traffic.utterance(c % n_spk, 50_000 + i as u64);
                            match dispatcher.enroll(&id, &feats) {
                                Ok(_) => {
                                    acked.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) if is_counted_rejection(&e) => {}
                                Err(e) => return Err(e),
                            }
                        }
                        i += concurrency;
                    }
                    Ok(acc)
                })
            })
            .collect();
        let collected =
            handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect();
        done.store(true, Ordering::Relaxed);
        collected
    });
    let wall_s = sw.elapsed_s();
    if let Some(id) = opts.stall_replica {
        dispatcher.stall_replica(id, false);
    }
    if let Some(e) = swap_err.lock().unwrap().take() {
        return Err(e).context("rolling swap failed mid-run");
    }
    let partials = partials.context("cluster load failed")?;

    let mut total = ClientAcc::default();
    for p in partials {
        total.target_sum += p.target_sum;
        total.target_n += p.target_n;
        total.impostor_sum += p.impostor_sum;
        total.impostor_n += p.impostor_n;
        total.rejected += p.rejected;
    }
    let m = dispatcher.metrics();
    let acked = acked.load(Ordering::Relaxed);
    let completed = opts.requests - total.rejected;
    Ok(ClusterBenchReport {
        replicas: dispatcher.replicas(),
        route: dispatcher.route().as_str().to_string(),
        requests: opts.requests,
        completed,
        rejected: total.rejected,
        wall_s,
        throughput_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { f64::INFINITY },
        verify: m.verify,
        failovers: m.failovers,
        exhausted: m.exhausted,
        engine_shed: m.total_shed(),
        engine_timeouts: m.total_timeouts(),
        swaps: m.swaps,
        acked_enrollments: acked,
        lost_enrollments: acked as i64 - dispatcher.registry().total_enrollments() as i64,
        wal_appends: m.durability.wal_appends,
        compactions: m.durability.compactions,
        torn_tail: m.durability.torn_tail,
        target_mean: if total.target_n > 0 {
            total.target_sum / total.target_n as f64
        } else {
            0.0
        },
        impostor_mean: if total.impostor_n > 0 {
            total.impostor_sum / total.impostor_n as f64
        } else {
            0.0
        },
        stages: dispatcher.obs().stage_summaries(),
    })
}

/// Write the `BENCH_5.json` cluster scaling report from named runs
/// (canonically `replicas_1` vs `replicas_N` on the same load).
pub fn write_bench5_json(
    path: impl AsRef<std::path::Path>,
    variants: &[(String, &ClusterBenchReport)],
) -> Result<()> {
    let runs: Vec<(String, String)> =
        variants.iter().map(|(name, r)| (name.clone(), r.json_fragment())).collect();
    write_bench_json(path, 5, &[("cluster", variants_json(&runs))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, RoutePolicy};
    use crate::gmm::AlignPrecision;
    use crate::serve::bench::{shared_test_bundle, tiny_serve_config, tiny_traffic};

    fn roomy_serve() -> ServeConfig {
        ServeConfig {
            batch_utts: 4,
            flush_us: 300,
            workers: 2,
            registry_shards: 4,
            queue_cap: 256,
            submit_timeout_ms: 10_000,
            request_timeout_ms: 60_000,
            scratch_pool: 4,
            precision: AlignPrecision::F64,
            session: crate::config::SessionConfig::default(),
        }
    }

    /// End-to-end harness smoke: live enrollments + a mid-run rolling
    /// swap, zero lost enrollments, every request accounted for.
    #[test]
    fn cluster_load_with_mid_run_swap_accounts_for_everything() {
        let cfg = tiny_serve_config();
        let bundle = shared_test_bundle().clone();
        let traffic = tiny_traffic(&cfg, 4, 77);
        let cluster = ClusterConfig {
            replicas: 2,
            route: RoutePolicy::LeastDepth,
            max_failovers: 2,
            drain_timeout_ms: 5_000,
            overrides: Vec::new(),
            health: crate::config::HealthConfig::default(),
        };
        let d = Dispatcher::new(bundle.clone(), &roomy_serve(), &cluster).unwrap();
        let opts = ClusterBenchOpts {
            speakers: 4,
            enroll_utts: 2,
            requests: 80,
            concurrency: 4,
            live_enroll_every: 8,
            stall_replica: None,
        };
        let report = run_cluster_load(&d, &traffic, &opts, Some(&bundle)).unwrap();
        assert_eq!(report.replicas, 2);
        assert_eq!(report.requests, 80);
        assert_eq!(report.completed + report.rejected, 80);
        // a roomy engine under 4 clients rejects nothing
        assert_eq!(report.rejected, 0);
        assert_eq!(report.swaps, 1, "the mid-run rolling swap must have happened");
        assert_eq!(report.lost_enrollments, 0);
        // up-front (4×2) + live (4 clients × ceil(20/8) = 3 each)
        assert_eq!(report.acked_enrollments, 8 + 12);
        assert_eq!(
            d.registry().total_enrollments(),
            report.acked_enrollments,
            "every acked enrollment is in the shared registry"
        );
        assert!(report.verify.count >= report.completed as u64);
        assert!(
            report.target_mean > report.impostor_mean,
            "target mean {} vs impostor mean {}",
            report.target_mean,
            report.impostor_mean
        );
    }

    #[test]
    fn bench5_json_shape() {
        let report = ClusterBenchReport {
            replicas: 2,
            route: "least_depth".into(),
            requests: 100,
            completed: 90,
            rejected: 10,
            wall_s: 0.5,
            throughput_rps: 180.0,
            verify: LatencySummary {
                count: 90,
                invalid: 0,
                mean_s: 0.002,
                p50_s: 0.0015,
                p95_s: 0.004,
                p99_s: 0.006,
                max_s: 0.008,
            },
            failovers: 7,
            exhausted: 10,
            engine_shed: 17,
            engine_timeouts: 0,
            swaps: 1,
            acked_enrollments: 20,
            lost_enrollments: 0,
            wal_appends: 20,
            compactions: 0,
            torn_tail: 0,
            target_mean: 3.0,
            impostor_mean: -2.0,
            stages: vec![(
                "estep_batch",
                LatencySummary {
                    count: 90,
                    invalid: 0,
                    mean_s: 0.001,
                    p50_s: 0.001,
                    p95_s: 0.002,
                    p99_s: 0.003,
                    max_s: 0.004,
                },
            )],
        };
        let frag = report.json_fragment();
        assert!(frag.contains("\"replicas\": 2"), "{frag}");
        assert!(frag.contains("\"route\": \"least_depth\""), "{frag}");
        assert!(frag.contains("\"throughput_rps\": 180.00"), "{frag}");
        assert!(frag.contains("\"p99_ms\": 6.0000"), "{frag}");
        assert!(frag.contains("\"failovers\": 7"), "{frag}");
        assert!(frag.contains("\"lost_enrollments\": 0"), "{frag}");
        assert!(frag.contains("\"wal_appends\": 20"), "{frag}");
        assert!(frag.contains("\"torn_tail\": 0"), "{frag}");
        assert!(frag.contains("\"stages\": {\"estep_batch\": {\"count\": 90"), "{frag}");

        let dir = std::env::temp_dir().join("ivtv_bench5_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_5.json");
        write_bench5_json(
            &p,
            &[("replicas_1".to_string(), &report), ("replicas_2".to_string(), &report)],
        )
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"schema_version\": 1"));
        assert!(text.contains("\"issue\": 5"));
        assert!(text.contains("\"replicas_1\": {"));
        assert!(text.contains("\"replicas_2\": {"));
    }
}
