//! Deterministic chaos drill: a scripted fault schedule against a live
//! cluster — the machinery behind the `chaos-bench` CLI command and the
//! `BENCH_9.json` incident report.
//!
//! Where [`super::bench`] measures a cluster under *load*, this module
//! measures it under *failure*. The schedule is deterministic by
//! construction, so the drill is a regression test, not a dice roll:
//!
//! * at an exact attempted-request count, one replica's worker pool is
//!   frozen — and never thawed by the harness. The only cure is the
//!   supervisor ([`Dispatcher::tick`]) noticing the timeout burst,
//!   quarantining the replica, rebuilding its engine from the current
//!   bundle, and restoring it behind a canary probe;
//! * at an exact WAL mutation count, the registry's storage fails an
//!   append *and* its rollback truncate ([`poisoning_storage`]) — the
//!   one-two punch that poisons the WAL. The registry degrades to
//!   read-only (verifies keep serving, enrolls fail typed
//!   [`RegistryStoreError::WalPoisoned`]) until the supervisor tick
//!   repairs it by rebuilding storage from the intact in-memory state.
//!
//! Throughout, client threads keep offering verify + live-enroll
//! traffic and record per-request latency against the run clock, so
//! the report can quote the p99 *inside the incident window* next to
//! the steady-state p99. Hard failures abort the drill: a passing run
//! means every request either scored, was shed typed, or (enrolls
//! during the poisoned window) failed with the documented degraded-mode
//! error — and the post-run audit found every acked enrollment in the
//! registry.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::bench_util::write_bench_json;
use crate::frontend::synth::TrafficGen;
use crate::metrics::Stopwatch;
use crate::serve::bench::trial_plan;
use crate::serve::registry::{Fault, FaultInjector, MemStorage, RegistryStoreError};
use crate::serve::ServeError;

use super::{Dispatcher, HealthState};

/// Chaos drill parameters. All counts are exact — the schedule replays
/// identically for a fixed traffic seed and config.
#[derive(Debug, Clone)]
pub struct ChaosOpts {
    /// Speakers enrolled up front, before any fault fires.
    pub speakers: usize,
    /// Enrollment utterances per up-front speaker.
    pub enroll_utts: usize,
    /// Verify requests replayed by the client pool.
    pub requests: usize,
    /// Concurrent client threads.
    pub concurrency: usize,
    /// Each client enrolls one utterance for its own live speaker every
    /// this-many of its verify requests (0 disables) — the mutation
    /// stream the WAL fault lands in.
    pub live_enroll_every: usize,
    /// The replica the stall hits.
    pub faulty_replica: usize,
    /// Freeze the faulty replica's workers once this many verify
    /// requests have been attempted. The harness never thaws it — the
    /// supervisor's quarantine → rebuild → probe cycle is the only fix.
    pub stall_at: usize,
    /// Supervisor tick period.
    pub tick_ms: u64,
    /// Give the supervisor this long after the load phase to finish
    /// healing (quarantine, rebuild, probe, registry repair) before the
    /// drill declares failure.
    pub settle_ms: u64,
}

impl Default for ChaosOpts {
    fn default() -> Self {
        Self {
            speakers: 4,
            enroll_utts: 2,
            requests: 400,
            concurrency: 8,
            live_enroll_every: 10,
            faulty_replica: 0,
            stall_at: 40,
            tick_ms: 5,
            settle_ms: 10_000,
        }
    }
}

/// The engine shape the drill runs without an explicit `--config`:
/// one worker behind a shallow queue with a tight admission budget,
/// and a request deadline short enough that a stalled replica's queued
/// requests time out — feeding the fault budget — in tens of
/// milliseconds, not seconds. Everything else inherits `base`.
pub fn chaos_serve_config(base: &crate::config::ServeConfig) -> crate::config::ServeConfig {
    crate::config::ServeConfig {
        batch_utts: 4,
        flush_us: 300,
        workers: 1,
        queue_cap: 8,
        submit_timeout_ms: 5,
        request_timeout_ms: 250,
        ..base.clone()
    }
}

/// Fast-cycle health knobs for the drill: a fault budget the stalled
/// replica's queued-request timeouts blow within one deadline, an
/// effectively-unlimited shed budget (the drill *wants* failover
/// sheds), and a cooldown short enough that quarantine → rebuild →
/// probe → healthy completes while the load is still running.
pub fn chaos_health_config() -> crate::config::HealthConfig {
    crate::config::HealthConfig {
        enabled: true,
        window_ms: 2_000,
        fault_budget: 5,
        shed_budget: 1_000_000,
        cooldown_ms: 100,
        probe_frames: 16,
    }
}

/// Wrap `store` so the `at_mutation`-th durable mutation (0-based,
/// counting every WAL append across up-front and live enrollments)
/// fails its append **and** the rollback truncate that follows —
/// exactly the sequence that poisons the WAL and flips the registry
/// into degraded read-only mode.
///
/// Storage op numbering on an empty store with `wal: true`: open costs
/// ops 0–3 (read snapshot, read WAL, append header, sync header), then
/// mutation `k` is ops `4 + 2k` (append) and `5 + 2k` (sync). The
/// durable-mutation lock is held across each append+sync pair, so the
/// numbering is deterministic however many clients race.
pub fn poisoning_storage(store: &MemStorage, at_mutation: u64) -> FaultInjector {
    FaultInjector::new(Box::new(store.clone()))
        .fail_op(4 + 2 * at_mutation, Fault::Enospc)
        .fail_op(5 + 2 * at_mutation, Fault::Enospc)
}

/// The incident timeline, all offsets in seconds from the drill clock.
#[derive(Debug, Clone, Copy, Default)]
struct Timeline {
    stall: Option<f64>,
    quarantine: Option<f64>,
    recover: Option<f64>,
    poisoned: Option<f64>,
    repaired: Option<f64>,
}

/// One chaos drill's results.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub replicas: usize,
    pub requests: usize,
    /// Requests that produced a score.
    pub completed: usize,
    /// Typed rejections absorbed (sheds + timeouts, mostly from the
    /// stalled replica before its quarantine).
    pub rejected: usize,
    /// Enrolls refused typed while the WAL was poisoned (plus the one
    /// injected-fault trigger) — the degraded-mode residue, never a
    /// hard failure.
    pub degraded_enrolls: u64,
    pub wall_s: f64,
    /// Stall injection → the supervisor publishing `Quarantined`.
    pub time_to_quarantine_s: f64,
    /// Stall injection → the canary probe restoring `Healthy`.
    pub time_to_recover_s: f64,
    /// WAL poisoning → the supervisor's registry repair.
    pub time_to_repair_wal_s: f64,
    /// Client-side verify p99 inside the incident window
    /// (stall → recover), in milliseconds.
    pub incident_p99_ms: f64,
    /// Client-side verify p99 outside the incident window.
    pub steady_p99_ms: f64,
    pub quarantines: u64,
    pub probes: u64,
    pub self_heals: u64,
    pub failovers: u64,
    pub exhausted: u64,
    /// Enrollments acknowledged to a client (up-front + live).
    pub acked_enrollments: u64,
    /// Acked enrollments missing from the registry after the run —
    /// the audit the drill exists for; must be 0.
    pub lost_enrollments: i64,
    /// The WAL fault really fired (the drill observed the poisoned
    /// state).
    pub registry_poisoned: bool,
    /// The registry left degraded mode before the run ended.
    pub registry_repaired: bool,
    /// The faulty replica was serving (`Healthy`) at run end.
    pub replica_restored: bool,
}

impl ChaosReport {
    /// One JSON object (no trailing newline) for the BENCH_9 report.
    pub fn json_fragment(&self) -> String {
        format!(
            "{{\"replicas\": {}, \"requests\": {}, \"completed\": {}, \"rejected\": {}, \
\"degraded_enrolls\": {}, \"wall_s\": {:.6}, \
\"time_to_quarantine_s\": {:.6}, \"time_to_recover_s\": {:.6}, \
\"time_to_repair_wal_s\": {:.6}, \
\"incident_p99_ms\": {:.4}, \"steady_p99_ms\": {:.4}, \
\"quarantines\": {}, \"probes\": {}, \"self_heals\": {}, \
\"failovers\": {}, \"exhausted\": {}, \
\"acked_enrollments\": {}, \"lost_enrollments\": {}, \
\"registry_poisoned\": {}, \"registry_repaired\": {}, \"replica_restored\": {}}}",
            self.replicas,
            self.requests,
            self.completed,
            self.rejected,
            self.degraded_enrolls,
            self.wall_s,
            self.time_to_quarantine_s,
            self.time_to_recover_s,
            self.time_to_repair_wal_s,
            self.incident_p99_ms,
            self.steady_p99_ms,
            self.quarantines,
            self.probes,
            self.self_heals,
            self.failovers,
            self.exhausted,
            self.acked_enrollments,
            self.lost_enrollments,
            self.registry_poisoned,
            self.registry_repaired,
            self.replica_restored,
        )
    }
}

/// A drill client absorbs exactly two failure shapes without aborting:
/// the saturation rejections every load harness counts, and — on the
/// enroll path only — the degraded-mode refusals the WAL fault is
/// scripted to cause (the typed `WalPoisoned` plus the one injected
/// storage error that triggered the poisoning).
fn is_counted_rejection(e: &anyhow::Error) -> bool {
    e.downcast_ref::<ServeError>()
        .is_some_and(|s| s.is_rejection() || s.is_retriable_stateless())
}

fn is_degraded_enroll(e: &anyhow::Error) -> bool {
    matches!(e.downcast_ref::<RegistryStoreError>(), Some(RegistryStoreError::WalPoisoned))
        // the poisoning mutation itself surfaces the injected storage
        // fault (ENOSPC) to its caller, before the flag is readable
        || format!("{e:#}").contains("injected")
}

fn p99_ms(lat_s: &mut [f64]) -> f64 {
    if lat_s.is_empty() {
        return 0.0;
    }
    lat_s.sort_by(f64::total_cmp);
    let idx = ((lat_s.len() as f64 * 0.99).ceil() as usize).clamp(1, lat_s.len()) - 1;
    lat_s[idx] * 1e3
}

/// Run the drill: up-front enrolls, then `opts.requests` verifies with
/// live enrolls interleaved, a scripted stall at
/// `opts.stall_at` attempted requests, and whatever storage faults the
/// caller pre-scheduled (see [`poisoning_storage`]) — while a
/// supervisor thread ticks the dispatcher every `opts.tick_ms` and the
/// harness stamps every incident transition against one clock.
///
/// `Err` means a hard failure: an untyped error, a lost enrollment, or
/// an incident the supervisor failed to heal within `opts.settle_ms`
/// after the load phase.
pub fn run_chaos_drill(
    dispatcher: &Dispatcher,
    traffic: &TrafficGen,
    opts: &ChaosOpts,
) -> Result<ChaosReport> {
    let n_spk = opts.speakers.min(traffic.n_speakers());
    ensure!(n_spk >= 2, "chaos drill needs at least 2 speakers (got {n_spk})");
    ensure!(
        opts.faulty_replica < dispatcher.replicas(),
        "faulty replica {} out of range ({} replicas)",
        opts.faulty_replica,
        dispatcher.replicas()
    );
    ensure!(
        dispatcher.replicas() >= 2,
        "the drill needs a healthy replica to fail over to (got {})",
        dispatcher.replicas()
    );

    // phase 0: enroll on a healthy cluster
    for s in 0..n_spk {
        let id = traffic.speaker_id(s);
        for k in 0..opts.enroll_utts.max(1) {
            dispatcher.enroll(&id, &traffic.utterance(s, k as u64))?;
        }
    }
    let acked = AtomicU64::new((n_spk * opts.enroll_utts.max(1)) as u64);
    let degraded = AtomicU64::new(0);
    let attempted = AtomicUsize::new(0);
    let done = AtomicBool::new(false);
    // (completion offset s, latency s) per scored verify
    let latencies: Mutex<Vec<(f64, f64)>> = Mutex::new(Vec::new());
    let timeline: Mutex<Timeline> = Mutex::new(Timeline::default());
    let sw = Stopwatch::start();

    let partials: Result<Vec<usize>> = std::thread::scope(|scope| {
        // the supervisor: injects the scripted stall at its exact
        // request count, then ticks the self-healing loop and stamps
        // every observed transition of the faulty replica + registry
        let supervisor = {
            let dispatcher = &dispatcher;
            let attempted = &attempted;
            let done = &done;
            let timeline = &timeline;
            let sw = &sw;
            scope.spawn(move || {
                let fid = opts.faulty_replica;
                loop {
                    {
                        let mut tl = timeline.lock().unwrap();
                        if tl.stall.is_none()
                            && attempted.load(Ordering::Relaxed) >= opts.stall_at
                        {
                            dispatcher.stall_replica(fid, true);
                            tl.stall = Some(sw.elapsed_s());
                        }
                        // observe the poisoned flag BEFORE the tick:
                        // the tick repairs it, and a poisoning the very
                        // next tick fixes must still make the timeline
                        if dispatcher.registry().is_poisoned() && tl.poisoned.is_none() {
                            tl.poisoned = Some(sw.elapsed_s());
                        }
                    }
                    dispatcher.tick();
                    {
                        let mut tl = timeline.lock().unwrap();
                        let now = sw.elapsed_s();
                        match dispatcher.health_state(fid) {
                            HealthState::Quarantined if tl.quarantine.is_none() => {
                                tl.quarantine = Some(now);
                            }
                            HealthState::Healthy
                                if tl.quarantine.is_some() && tl.recover.is_none() =>
                            {
                                tl.recover = Some(now);
                            }
                            _ => {}
                        }
                        if tl.poisoned.is_some()
                            && tl.repaired.is_none()
                            && !dispatcher.registry().is_poisoned()
                        {
                            tl.repaired = Some(now);
                        }
                    }
                    if done.load(Ordering::Relaxed) {
                        let tl = *timeline.lock().unwrap();
                        let healed = tl.stall.is_none()
                            || (tl.recover.is_some()
                                && (tl.poisoned.is_none() == tl.repaired.is_none()));
                        if healed || sw.elapsed_s() * 1e3
                            > opts.settle_ms as f64 + tl.stall.unwrap_or(0.0) * 1e3
                        {
                            return;
                        }
                    }
                    std::thread::sleep(Duration::from_millis(opts.tick_ms.max(1)));
                }
            })
        };
        let handles: Vec<_> = (0..opts.concurrency.max(1))
            .map(|c| {
                let dispatcher = &dispatcher;
                let traffic = &traffic;
                let attempted = &attempted;
                let acked = &acked;
                let degraded = &degraded;
                let latencies = &latencies;
                let sw = &sw;
                scope.spawn(move || -> Result<usize> {
                    let concurrency = opts.concurrency.max(1);
                    let mut completed = 0usize;
                    let mut i = c;
                    while i < opts.requests {
                        attempted.fetch_add(1, Ordering::Relaxed);
                        let (claimed, actual, _target) = trial_plan(i, n_spk);
                        let feats = traffic.utterance(actual, 1_000 + i as u64);
                        let t0 = sw.elapsed_s();
                        match dispatcher.verify(&traffic.speaker_id(claimed), &feats) {
                            Ok(_) => {
                                let t1 = sw.elapsed_s();
                                latencies.lock().unwrap().push((t1, t1 - t0));
                                completed += 1;
                            }
                            Err(e) if is_counted_rejection(&e) => {}
                            Err(e) => return Err(e.context(format!("verify {i}"))),
                        }
                        if opts.live_enroll_every > 0
                            && (i / concurrency) % opts.live_enroll_every == 0
                        {
                            let id = format!("live{c:03}");
                            let feats = traffic.utterance(c % n_spk, 50_000 + i as u64);
                            match dispatcher.enroll(&id, &feats) {
                                Ok(_) => {
                                    acked.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) if is_degraded_enroll(&e) => {
                                    degraded.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) if is_counted_rejection(&e) => {}
                                Err(e) => return Err(e.context(format!("live enroll {i}"))),
                            }
                        }
                        i += concurrency;
                    }
                    Ok(completed)
                })
            })
            .collect();
        // join every client BEFORE signalling the supervisor: a
        // short-circuiting collect on a hard error would leave `done`
        // unset and the scope deadlocked on the supervisor loop
        let mut results = Vec::with_capacity(handles.len());
        for h in handles {
            results.push(h.join().expect("client thread panicked"));
        }
        done.store(true, Ordering::Relaxed);
        supervisor.join().expect("supervisor thread panicked");
        results.into_iter().collect()
    });
    let wall_s = sw.elapsed_s();
    let completed: usize = partials.context("chaos drill load failed")?.iter().sum();

    let tl = *timeline.lock().unwrap();
    let stall = tl.stall.context("the scripted stall never fired — raise `requests`")?;
    let m = dispatcher.metrics();
    let acked = acked.load(Ordering::Relaxed);
    let lost = acked as i64 - dispatcher.registry().total_enrollments() as i64;

    // split client latencies at the incident window
    let (mut incident, mut steady): (Vec<f64>, Vec<f64>) = (Vec::new(), Vec::new());
    let recover = tl.recover.unwrap_or(wall_s);
    for (t, lat) in latencies.lock().unwrap().iter() {
        if *t >= stall && *t <= recover {
            incident.push(*lat);
        } else {
            steady.push(*lat);
        }
    }

    Ok(ChaosReport {
        replicas: dispatcher.replicas(),
        requests: opts.requests,
        completed,
        rejected: opts.requests - completed,
        degraded_enrolls: degraded.load(Ordering::Relaxed),
        wall_s,
        time_to_quarantine_s: tl.quarantine.map_or(-1.0, |t| t - stall),
        time_to_recover_s: tl.recover.map_or(-1.0, |t| t - stall),
        time_to_repair_wal_s: match (tl.poisoned, tl.repaired) {
            (Some(p), Some(r)) => r - p,
            _ => -1.0,
        },
        incident_p99_ms: p99_ms(&mut incident),
        steady_p99_ms: p99_ms(&mut steady),
        quarantines: m.quarantines,
        probes: m.probes,
        self_heals: m.self_heals,
        failovers: m.failovers,
        exhausted: m.exhausted,
        acked_enrollments: acked,
        lost_enrollments: lost,
        registry_poisoned: tl.poisoned.is_some(),
        registry_repaired: tl.poisoned.is_some() && tl.repaired.is_some(),
        replica_restored: dispatcher.health_state(opts.faulty_replica) == HealthState::Healthy,
    })
}

/// Write the `BENCH_9.json` chaos report.
pub fn write_bench9_json(path: impl AsRef<std::path::Path>, report: &ChaosReport) -> Result<()> {
    write_bench_json(path, 9, &[("chaos", report.json_fragment())])
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::config::{ClusterConfig, RoutePolicy, WalSync};
    use crate::obs::ObsRegistry;
    use crate::serve::bench::{shared_test_bundle, tiny_serve_config, tiny_traffic};
    use crate::serve::{DurableRegistry, DurableRegistryOptions};

    fn chaos_cluster() -> ClusterConfig {
        ClusterConfig {
            replicas: 2,
            route: RoutePolicy::RoundRobin,
            max_failovers: 2,
            drain_timeout_ms: 1_000,
            overrides: Vec::new(),
            health: chaos_health_config(),
        }
    }

    /// The end-to-end drill the chaos CI job gates on: scripted stall +
    /// WAL poisoning mid-run, zero hard failures, zero lost acked
    /// enrollments, the faulty replica quarantined then restored, the
    /// registry degraded then repaired — all timed.
    #[test]
    fn scripted_stall_and_wal_fault_self_heal_end_to_end() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 177);
        let opts = ChaosOpts {
            speakers: 4,
            enroll_utts: 2,
            requests: 240,
            concurrency: 8,
            live_enroll_every: 6,
            faulty_replica: 0,
            stall_at: 30,
            tick_ms: 5,
            settle_ms: 15_000,
        };
        // the WAL fault lands a few live enrollments past the up-front
        // batch (mutation index counts all appends: 8 up-front + k)
        let store = MemStorage::new();
        let injected = poisoning_storage(&store, 12);
        let durable = DurableRegistry::with_storage(
            Box::new(injected),
            &DurableRegistryOptions {
                shards: 4,
                wal: true,
                sync: WalSync::Always,
                compact_every: 0,
            },
        )
        .unwrap();
        let d = Dispatcher::with_registry_obs(
            shared_test_bundle().clone(),
            &chaos_serve_config(&cfg.serve),
            &chaos_cluster(),
            durable.handle(),
            Arc::new(ObsRegistry::default()),
        )
        .unwrap();

        let report = run_chaos_drill(&d, &traffic, &opts).unwrap();

        // the stall incident: quarantined, rebuilt, probed, restored
        assert!(report.time_to_quarantine_s >= 0.0, "{report:?}");
        assert!(report.time_to_recover_s >= report.time_to_quarantine_s, "{report:?}");
        assert!(report.quarantines >= 1, "{report:?}");
        assert!(report.self_heals >= 1, "{report:?}");
        assert!(report.probes >= 1, "{report:?}");
        assert!(report.replica_restored, "{report:?}");

        // the WAL incident: poisoned, degraded typed, repaired
        assert!(report.registry_poisoned, "the scripted WAL fault must have fired");
        assert!(report.registry_repaired, "{report:?}");
        assert!(report.time_to_repair_wal_s >= 0.0, "{report:?}");
        assert!(report.degraded_enrolls >= 1, "{report:?}");

        // the audit: zero hard failures (we got a report at all), zero
        // acked-but-lost enrollments, and the cluster still serves
        assert_eq!(report.lost_enrollments, 0, "{report:?}");
        assert!(report.completed > 0, "{report:?}");
        d.verify(&traffic.speaker_id(0), &traffic.utterance(0, 9_999)).unwrap();
        durable.reopen().unwrap(); // healthy: no-op

        // post-run restart audit: every acked enrollment recovers from
        // the rebuilt storage alone
        let total = d.registry().total_enrollments();
        drop(d);
        drop(durable);
        let back = DurableRegistry::with_storage(
            Box::new(store.clone()),
            &DurableRegistryOptions {
                shards: 4,
                wal: true,
                sync: WalSync::Always,
                compact_every: 0,
            },
        )
        .unwrap();
        assert_eq!(back.total_enrollments(), total, "acked enrollments survive restart");
        assert_eq!(back.total_enrollments(), report.acked_enrollments);
    }

    #[test]
    fn bench9_json_shape() {
        let report = ChaosReport {
            replicas: 2,
            requests: 400,
            completed: 380,
            rejected: 20,
            degraded_enrolls: 3,
            wall_s: 2.5,
            time_to_quarantine_s: 0.31,
            time_to_recover_s: 0.44,
            time_to_repair_wal_s: 0.01,
            incident_p99_ms: 240.0,
            steady_p99_ms: 6.5,
            quarantines: 1,
            probes: 1,
            self_heals: 1,
            failovers: 12,
            exhausted: 8,
            acked_enrollments: 40,
            lost_enrollments: 0,
            registry_poisoned: true,
            registry_repaired: true,
            replica_restored: true,
        };
        let frag = report.json_fragment();
        assert!(frag.contains("\"time_to_quarantine_s\": 0.310000"), "{frag}");
        assert!(frag.contains("\"time_to_recover_s\": 0.440000"), "{frag}");
        assert!(frag.contains("\"incident_p99_ms\": 240.0000"), "{frag}");
        assert!(frag.contains("\"lost_enrollments\": 0"), "{frag}");
        assert!(frag.contains("\"registry_repaired\": true"), "{frag}");
        assert!(frag.contains("\"replica_restored\": true"), "{frag}");

        let dir = std::env::temp_dir().join("ivtv_bench9_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_9.json");
        write_bench9_json(&p, &report).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"schema_version\": 1"));
        assert!(text.contains("\"issue\": 9"));
        assert!(text.contains("\"chaos\": {"));
    }
}
