//! Per-replica health supervision: the cluster's circuit breaker.
//!
//! Every supervisor tick the [`Dispatcher`](super::Dispatcher) samples
//! the failure signals each replica already emits — admission sheds,
//! request timeouts, worker panics, and dispatcher-observed hard
//! errors — and feeds the cumulative counters to a [`HealthTracker`].
//! The tracker turns them into per-tick deltas, keeps a sliding
//! error-budget window, and drives a three-state machine per replica:
//!
//! ```text
//!            faults ≥ ⌈budget/2⌉           faults ≥ budget
//!            or sheds ≥ shed_budget
//!   Healthy ──────────────────────▶ Degraded ─────────────▶ Quarantined
//!      ▲                               │  faults ≥ budget        │
//!      │                               └──────────────────────────┤
//!      │        canary probe OK                 rebuild engine,   │
//!      └────────────────────────────────────────cooldown, probe ◀─┘
//! ```
//!
//! *Degraded* is advisory — the replica keeps routing (sheds are a
//! weak signal: a healthy replica at saturation sheds constantly, so
//! sheds alone can never quarantine). *Quarantined* removes the
//! replica from routing and asks the supervisor for repair actions:
//! first [`HealthAction::Rebuild`] (replace the engine from the
//! current bundle via the rolling-swap machinery), then — after the
//! circuit-breaker cooldown — [`HealthAction::Probe`] (one canary
//! extraction in half-open state decides restore-vs-stay-quarantined).
//!
//! All transitions happen in [`HealthTracker::observe`] /
//! [`HealthTracker::probe_result`] with an explicit `now`, so the
//! state machine is deterministic under test. The published state
//! lives in lock-free atomics so the routing hot path
//! ([`HealthTracker::is_routable`]) never takes the per-replica lock.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::config::HealthConfig;

/// One replica's health classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Inside the error budget; routed normally.
    Healthy,
    /// Burning budget (or shedding hard) but still serving; routed,
    /// surfaced to operators via the `cluster_replica_health` gauge.
    Degraded,
    /// Out of budget: excluded from routing while the supervisor
    /// rebuilds and probes it.
    Quarantined,
}

impl HealthState {
    /// Stable lowercase name (metrics labels, reports, logs).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Healthy => "healthy",
            Self::Degraded => "degraded",
            Self::Quarantined => "quarantined",
        }
    }

    /// Severity level exported on the health gauge (0/1/2).
    pub fn level(&self) -> u8 {
        match self {
            Self::Healthy => 0,
            Self::Degraded => 1,
            Self::Quarantined => 2,
        }
    }
}

/// Cumulative failure counters for one replica, sampled once per
/// supervisor tick (the tracker diffs consecutive samples itself).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthSample {
    /// Admission sheds (engine `shed_requests`).
    pub sheds: u64,
    /// Deadline expiries (engine `timed_out_requests`).
    pub timeouts: u64,
    /// Batch-worker panics caught by the micro-batcher.
    pub worker_panics: u64,
    /// Hard errors the dispatcher saw from this replica (e.g.
    /// `WorkerFailed`).
    pub hard_errors: u64,
}

/// What the supervisor should do to a replica after a tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthAction {
    /// Nothing — healthy, degraded-but-serving, or cooling down.
    None,
    /// Quarantined with a suspect engine: rebuild it from the current
    /// bundle, then call [`HealthTracker::healed`].
    Rebuild,
    /// Rebuilt and cooled down: send one canary request, then call
    /// [`HealthTracker::probe_result`].
    Probe,
}

/// Result of one [`HealthTracker::observe`] call.
#[derive(Debug, Clone, Copy)]
pub struct TickOutcome {
    pub state: HealthState,
    /// The state changed on this tick (quarantine entries are counted
    /// off this edge).
    pub changed: bool,
    pub action: HealthAction,
}

/// Per-replica bookkeeping behind the lock: last cumulative sample,
/// the sliding (timestamp, faults, sheds) window, and the
/// circuit-breaker sub-state while quarantined.
#[derive(Debug)]
struct ReplicaHealth {
    state: HealthState,
    prev: HealthSample,
    window: VecDeque<(Instant, u64, u64)>,
    /// The quarantined engine has been rebuilt (set by `healed`);
    /// false means the supervisor still owes a rebuild.
    healed: bool,
    /// Half-open gate: probes may run once `now` passes this.
    cooldown_until: Option<Instant>,
}

impl ReplicaHealth {
    fn new() -> Self {
        Self {
            state: HealthState::Healthy,
            prev: HealthSample::default(),
            window: VecDeque::new(),
            healed: false,
            cooldown_until: None,
        }
    }
}

/// Sliding-window error-budget tracker for every replica in a cluster.
#[derive(Debug)]
pub struct HealthTracker {
    cfg: HealthConfig,
    replicas: Vec<Mutex<ReplicaHealth>>,
    /// Published `HealthState::level` per replica — the lock-free view
    /// the routing hot path reads.
    published: Vec<AtomicU8>,
}

impl HealthTracker {
    pub fn new(cfg: &HealthConfig, replicas: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            replicas: (0..replicas).map(|_| Mutex::new(ReplicaHealth::new())).collect(),
            published: (0..replicas).map(|_| AtomicU8::new(0)).collect(),
        }
    }

    /// Poison-tolerant per-replica lock, same policy as the registry
    /// shard locks.
    fn lock(&self, id: usize) -> MutexGuard<'_, ReplicaHealth> {
        self.replicas[id].lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    fn publish(&self, id: usize, state: HealthState) {
        self.published[id].store(state.level(), Ordering::Release);
    }

    /// Supervision disabled entirely (`[cluster.health] enabled = false`)?
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Lock-free routing check: everything short of quarantine routes.
    pub fn is_routable(&self, id: usize) -> bool {
        self.published[id].load(Ordering::Acquire) < HealthState::Quarantined.level()
    }

    /// Current state of one replica (reports/metrics; reads the
    /// published atomic, not the lock).
    pub fn state(&self, id: usize) -> HealthState {
        match self.published[id].load(Ordering::Acquire) {
            0 => HealthState::Healthy,
            1 => HealthState::Degraded,
            _ => HealthState::Quarantined,
        }
    }

    /// Feed one replica's cumulative failure counters at time `now`;
    /// returns the post-tick state plus the repair action the
    /// supervisor owes. All healthy↔degraded↔quarantined transitions
    /// happen here (probe verdicts land in [`Self::probe_result`]).
    pub fn observe(&self, id: usize, now: Instant, sample: HealthSample) -> TickOutcome {
        if !self.cfg.enabled {
            return TickOutcome {
                state: HealthState::Healthy,
                changed: false,
                action: HealthAction::None,
            };
        }
        let mut rh = self.lock(id);
        // cumulative → per-tick deltas; saturating so an engine rebuild
        // (counters reset to zero) can never look like activity
        let faults = sample
            .timeouts
            .saturating_sub(rh.prev.timeouts)
            .saturating_add(sample.worker_panics.saturating_sub(rh.prev.worker_panics))
            .saturating_add(sample.hard_errors.saturating_sub(rh.prev.hard_errors));
        let sheds = sample.sheds.saturating_sub(rh.prev.sheds);
        rh.prev = sample;
        if faults > 0 || sheds > 0 {
            rh.window.push_back((now, faults, sheds));
        }
        let horizon = Duration::from_millis(self.cfg.window_ms);
        while let Some((t, _, _)) = rh.window.front() {
            if now.saturating_duration_since(*t) > horizon {
                rh.window.pop_front();
            } else {
                break;
            }
        }
        let (win_faults, win_sheds) = rh
            .window
            .iter()
            .fold((0u64, 0u64), |(f, s), (_, df, ds)| (f + df, s + ds));

        let mut changed = false;
        if rh.state != HealthState::Quarantined {
            // sheds alone only ever degrade — quarantine needs faults
            let next = if win_faults >= self.cfg.fault_budget.max(1) {
                HealthState::Quarantined
            } else if win_faults >= (self.cfg.fault_budget.max(1) + 1) / 2
                || win_sheds >= self.cfg.shed_budget.max(1)
            {
                HealthState::Degraded
            } else {
                HealthState::Healthy
            };
            changed = next != rh.state;
            if changed && next == HealthState::Quarantined {
                // the breaker opens: the engine is suspect until the
                // supervisor rebuilds it
                rh.healed = false;
                rh.cooldown_until = None;
            }
            rh.state = next;
        }
        let action = match rh.state {
            HealthState::Quarantined if !rh.healed => HealthAction::Rebuild,
            HealthState::Quarantined => match rh.cooldown_until {
                Some(t) if now >= t => HealthAction::Probe,
                _ => HealthAction::None,
            },
            _ => HealthAction::None,
        };
        self.publish(id, rh.state);
        TickOutcome { state: rh.state, changed, action }
    }

    /// The supervisor rebuilt the quarantined replica's engine: arm the
    /// half-open cooldown and forget the dead engine's counters (the
    /// fresh engine restarts them from zero, and the caller resets its
    /// own hard-error count to match).
    pub fn healed(&self, id: usize, now: Instant) {
        let mut rh = self.lock(id);
        rh.healed = true;
        rh.cooldown_until = Some(now + Duration::from_millis(self.cfg.cooldown_ms));
        rh.prev = HealthSample::default();
        rh.window.clear();
    }

    /// Verdict of the half-open canary probe. Success closes the
    /// breaker (replica back to `Healthy`, routable immediately);
    /// failure re-opens it — the engine is suspect again, so the next
    /// tick rebuilds before another cooldown+probe round. Returns
    /// `true` when the replica was restored.
    pub fn probe_result(&self, id: usize, ok: bool, now: Instant) -> bool {
        let mut rh = self.lock(id);
        if rh.state != HealthState::Quarantined {
            return false;
        }
        if ok {
            rh.state = HealthState::Healthy;
            rh.window.clear();
            rh.cooldown_until = None;
            self.publish(id, rh.state);
            true
        } else {
            rh.healed = false;
            rh.cooldown_until = Some(now + Duration::from_millis(self.cfg.cooldown_ms));
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            enabled: true,
            window_ms: 1_000,
            fault_budget: 4,
            shed_budget: 100,
            cooldown_ms: 250,
            probe_frames: 16,
        }
    }

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn healthy_replica_stays_healthy_under_clean_samples() {
        let t = HealthTracker::new(&cfg(), 2);
        let t0 = Instant::now();
        for k in 0..5 {
            let out = t.observe(0, t0 + ms(100 * k), HealthSample::default());
            assert_eq!(out.state, HealthState::Healthy);
            assert!(!out.changed);
            assert_eq!(out.action, HealthAction::None);
        }
        assert!(t.is_routable(0));
        assert!(t.is_routable(1));
    }

    #[test]
    fn fault_budget_quarantines_and_requests_rebuild() {
        let t = HealthTracker::new(&cfg(), 1);
        let t0 = Instant::now();
        t.observe(0, t0, HealthSample::default());
        // half the budget: degraded, still routable
        let out =
            t.observe(0, t0 + ms(100), HealthSample { timeouts: 2, ..Default::default() });
        assert_eq!(out.state, HealthState::Degraded);
        assert!(out.changed);
        assert!(t.is_routable(0));
        // budget blown (2 more timeouts + 1 panic + 1 hard error = 6 ≥ 4)
        let out = t.observe(
            0,
            t0 + ms(200),
            HealthSample { timeouts: 4, worker_panics: 1, hard_errors: 1, sheds: 3 },
        );
        assert_eq!(out.state, HealthState::Quarantined);
        assert!(out.changed);
        assert_eq!(out.action, HealthAction::Rebuild);
        assert!(!t.is_routable(0));
        // still quarantined, rebuild still owed, no double "changed"
        let out = t.observe(
            0,
            t0 + ms(300),
            HealthSample { timeouts: 4, worker_panics: 1, hard_errors: 1, sheds: 3 },
        );
        assert!(!out.changed);
        assert_eq!(out.action, HealthAction::Rebuild);
    }

    #[test]
    fn sheds_alone_degrade_but_never_quarantine() {
        let t = HealthTracker::new(&cfg(), 1);
        let t0 = Instant::now();
        t.observe(0, t0, HealthSample::default());
        let out = t.observe(
            0,
            t0 + ms(100),
            HealthSample { sheds: 1_000_000, ..Default::default() },
        );
        assert_eq!(out.state, HealthState::Degraded);
        assert!(t.is_routable(0), "a saturated-but-correct replica keeps routing");
        let out = t.observe(
            0,
            t0 + ms(200),
            HealthSample { sheds: 2_000_000, ..Default::default() },
        );
        assert_eq!(out.state, HealthState::Degraded);
        assert_ne!(out.action, HealthAction::Rebuild);
    }

    #[test]
    fn window_expiry_recovers_a_degraded_replica() {
        let t = HealthTracker::new(&cfg(), 1);
        let t0 = Instant::now();
        t.observe(0, t0, HealthSample::default());
        let s = HealthSample { timeouts: 2, ..Default::default() };
        assert_eq!(t.observe(0, t0 + ms(100), s).state, HealthState::Degraded);
        // same cumulative counters, 1.2 s later: the burst has aged out
        let out = t.observe(0, t0 + ms(1_300), s);
        assert_eq!(out.state, HealthState::Healthy);
        assert!(out.changed);
    }

    #[test]
    fn quarantine_heal_cooldown_probe_restore_cycle() {
        let t = HealthTracker::new(&cfg(), 1);
        let t0 = Instant::now();
        t.observe(0, t0, HealthSample::default());
        let bad = HealthSample { timeouts: 10, ..Default::default() };
        let out = t.observe(0, t0 + ms(100), bad);
        assert_eq!(out.action, HealthAction::Rebuild);
        // supervisor rebuilds; the fresh engine's counters are zero —
        // the zeroed next sample must not underflow or re-trip
        t.healed(0, t0 + ms(110));
        let out = t.observe(0, t0 + ms(120), HealthSample::default());
        assert_eq!(out.state, HealthState::Quarantined);
        assert_eq!(out.action, HealthAction::None, "still cooling down");
        // cooldown (250 ms) elapsed → half-open probe
        let out = t.observe(0, t0 + ms(400), HealthSample::default());
        assert_eq!(out.action, HealthAction::Probe);
        assert!(t.probe_result(0, true, t0 + ms(410)));
        assert_eq!(t.state(0), HealthState::Healthy);
        assert!(t.is_routable(0));
        // restored replica re-quarantines on a fresh budget blow
        let out =
            t.observe(0, t0 + ms(500), HealthSample { timeouts: 10, ..Default::default() });
        assert_eq!(out.state, HealthState::Quarantined);
        assert!(out.changed);
    }

    #[test]
    fn failed_probe_reopens_the_breaker_and_rebuilds_again() {
        let t = HealthTracker::new(&cfg(), 1);
        let t0 = Instant::now();
        t.observe(0, t0, HealthSample::default());
        t.observe(0, t0 + ms(100), HealthSample { timeouts: 10, ..Default::default() });
        t.healed(0, t0 + ms(110));
        let out = t.observe(0, t0 + ms(400), HealthSample::default());
        assert_eq!(out.action, HealthAction::Probe);
        assert!(!t.probe_result(0, false, t0 + ms(410)));
        assert!(!t.is_routable(0));
        // the probe failed on the *rebuilt* engine: suspect again, so
        // the supervisor owes another rebuild before the next probe
        let out = t.observe(0, t0 + ms(420), HealthSample::default());
        assert_eq!(out.action, HealthAction::Rebuild);
    }

    #[test]
    fn disabled_tracker_is_inert() {
        let t = HealthTracker::new(&HealthConfig { enabled: false, ..cfg() }, 1);
        let t0 = Instant::now();
        let out = t.observe(0, t0, HealthSample { timeouts: 1_000, ..Default::default() });
        assert_eq!(out.state, HealthState::Healthy);
        assert_eq!(out.action, HealthAction::None);
        assert!(t.is_routable(0));
    }
}
