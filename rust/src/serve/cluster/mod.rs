//! Cluster serving: N engine replicas behind one load-aware dispatcher.
//!
//! One [`crate::serve::Engine`] turns the paper's kernel speed into a
//! saturating single queue; this module turns N of them into sustained
//! multi-tenant capacity. The pieces:
//!
//! * [`Dispatcher`] — owns the replicas (each a full engine: worker
//!   pool, micro-batch queue, admission control) sharing **one**
//!   `Arc<Registry>`, and routes extract/enroll/verify requests by a
//!   pluggable [`crate::config::RoutePolicy`]: `round_robin` cycles,
//!   `least_depth` follows a per-replica in-flight counter plus the
//!   live micro-batch queue depth;
//! * **shed failover** — a typed `Overloaded` (or `ShuttingDown`)
//!   rejection from one replica retries on the next-least-loaded
//!   replica within the original request deadline, bounded by
//!   `max_failovers`, so transient per-replica saturation degrades into
//!   a retry instead of a client-visible error;
//! * **rolling swaps** — [`Dispatcher::swap_bundle`] upgrades replicas
//!   one at a time behind a per-replica [`crate::serve::Engine::drain`]
//!   (stop admitting → finish in-flight batches → join workers), so a
//!   model push never takes the whole cluster offline;
//! * **per-replica overrides** (`[cluster.replicaN]`) — precision
//!   f32/f64 today, the accel backend when that serving path lands —
//!   let heterogeneous bundles serve side by side for live A/B of
//!   extractor variants;
//! * **self-healing supervision** ([`health`]) — per-replica error
//!   budgets over a sliding window drive a `Healthy → Degraded →
//!   Quarantined` state machine with a circuit-breaker half-open
//!   probe; [`Dispatcher::tick`] excludes quarantined replicas from
//!   routing, rebuilds their engines from the current bundle, and
//!   restores them behind a canary request;
//! * [`ClusterMetrics`] — cluster-level latency histograms and routing
//!   counters over a per-replica [`crate::serve::EngineMetrics`]
//!   breakdown;
//! * [`bench`] — the saturation load harness behind `cluster-bench`
//!   and the `BENCH_5.json` 1-vs-N scaling report;
//! * [`chaos`] — the deterministic fault-schedule drill behind
//!   `chaos-bench` and the `BENCH_9.json` incident report: scripted
//!   worker panics, stalls, and WAL faults at exact request counts,
//!   with time-to-quarantine / time-to-recover measured live.

pub mod bench;
pub mod chaos;
mod dispatcher;
pub mod health;

pub use dispatcher::{ClusterMetrics, Dispatcher, ReplicaMetrics};
pub use health::{HealthSample, HealthState, HealthTracker};
