//! The multi-engine dispatcher: load-aware routing, shed failover, and
//! rolling hot swaps over N replicas sharing one speaker registry.
//!
//! Each replica is a complete [`Engine`] — its own micro-batch queue,
//! worker pool, admission control, and model snapshot — so one stalled
//! or saturated replica degrades *that replica only*. The dispatcher
//! adds the cluster layer on top:
//!
//! * **routing** ([`crate::config::RoutePolicy`]): `round_robin` cycles
//!   through admitting replicas; `least_depth` picks the replica with
//!   the smallest load, where load = the dispatcher's per-replica
//!   in-flight counter (covers the alignment stage the queue cannot
//!   see) + the live micro-batch queue depth;
//! * **failover**: a typed retriable rejection ([`ServeError`]
//!   `Overloaded` / `ShuttingDown`) retries on the least-loaded
//!   untried replica — within the original request deadline and at most
//!   `max_failovers` times. Non-retriable failures (`Timeout`: the
//!   deadline is already spent; `WorkerFailed`, bad requests) propagate
//!   immediately;
//! * **rolling swap** ([`Dispatcher::swap_bundle`]): replicas upgrade
//!   one at a time — stop routing to the replica, install a fresh
//!   engine on the shared registry, [`Engine::drain`] the retired one
//!   (finish queued batches, join workers), resume routing — so the
//!   rest of the cluster keeps serving throughout a model push;
//! * **session affinity** ([`Dispatcher::session_open`]): a streaming
//!   session's partial statistics live on exactly one replica's pinned
//!   model snapshot, so the dispatcher routes every later
//!   `session_feed`/`session_score`/`session_close` back to the engine
//!   that opened it — never failing over mid-session. When a rolling
//!   swap (or drain) retires that engine, the next touch comes back as
//!   a typed [`ServeError::SessionSwapped`] instead of a silent rescore
//!   against a different bundle;
//! * **self-healing supervision** ([`Dispatcher::tick`], backed by
//!   [`super::health`]): each tick samples every replica's failure
//!   counters into the per-replica health state machine, excludes
//!   quarantined replicas from routing (with a last-replica-standing
//!   escape hatch so a fully-quarantined cluster sheds typed errors
//!   instead of deadlocking), rebuilds a quarantined replica's engine
//!   from the current bundle via the same install + drain machinery a
//!   rolling swap uses, and restores it through a circuit-breaker
//!   half-open canary probe. The tick also sweeps idle streaming
//!   sessions and attempts recovery of a WAL-poisoned registry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{ClusterConfig, RoutePolicy, ServeConfig};
use crate::gmm::AlignPrecision;
use crate::linalg::Mat;
use crate::metrics::{DepthGauge, LatencyHistogram, LatencySummary};
use crate::obs::{self, Counter, ObsRegistry, RequestTrace, TraceOutcome};
use crate::serve::capture::{Recorder, RequestKind};
use crate::serve::cluster::health::{HealthAction, HealthSample, HealthState, HealthTracker};
use crate::serve::{
    DurabilityMetrics, Engine, EngineMetrics, FeedOutcome, ModelBundle, Registry, ServeError,
    ServeModel, VerifyOutcome,
};

/// One replica slot: the engine (replaced wholesale by a rolling swap)
/// plus the dispatcher's routing state for it.
struct Replica {
    id: usize,
    /// Swapped by [`Dispatcher::swap_bundle`]; requests clone the `Arc`
    /// once and stay on that engine end-to-end (like an engine's model
    /// snapshot, one level up).
    engine: RwLock<Arc<Engine>>,
    /// Requests routed here and not yet returned — includes the
    /// request-thread alignment stage the micro-batch queue never sees.
    in_flight: AtomicUsize,
    /// Cleared while a rolling swap is rebuilding this replica; the
    /// router skips non-admitting replicas whenever any other is up.
    admitting: AtomicBool,
    /// Hard failures the dispatcher itself observed from this replica
    /// (today: `WorkerFailed`, i.e. a panicked batch dispatch). Client
    /// mistakes — unknown speaker, bad dims — fail identically on any
    /// replica and are deliberately *not* counted: they say nothing
    /// about this replica's health. Cumulative; zeroed when a
    /// self-heal rebuild replaces the engine (whose counters restart
    /// from zero too).
    hard_errors: AtomicU64,
}

impl Replica {
    fn engine(&self) -> Arc<Engine> {
        self.engine.read().unwrap_or_else(|poisoned| poisoned.into_inner()).clone()
    }

    /// Live load signal for `least_depth` routing and failover picks.
    fn load(&self) -> usize {
        self.in_flight.load(Ordering::Acquire) + self.engine().queue_len()
    }
}

/// RAII in-flight marker: decrements on every exit path (including an
/// unwinding request) so a panic can never wedge a replica's load at
/// "busy forever".
struct Flight<'a>(&'a AtomicUsize);

impl<'a> Flight<'a> {
    fn begin(counter: &'a AtomicUsize) -> Self {
        counter.fetch_add(1, Ordering::AcqRel);
        Self(counter)
    }
}

impl Drop for Flight<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Where a streaming session's partial statistics actually live: one
/// engine on one replica. The dispatcher mints its own session ids so
/// a client handle stays meaningful across the cluster, and keeps only
/// a [`Weak`] engine reference — a rolling swap dropping the retired
/// engine is exactly the signal that the session died with it.
struct ClusterSession {
    replica: usize,
    /// The id the pinned engine knows the session by.
    engine_session: u64,
    /// The engine that opened the session. Touch-time liveness check:
    /// upgrade AND pointer-compare against the replica's current slot,
    /// so a session can never silently continue on a swapped-in engine
    /// (whose accumulator for this id simply does not exist).
    engine: Weak<Engine>,
}

/// True when the engine says the session is gone on *its* side
/// (expired, finalized, or unknown) — the cluster entry is then dead
/// weight and gets dropped too.
fn session_is_dead(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<ServeError>(),
        Some(
            ServeError::SessionExpired | ServeError::SessionClosed | ServeError::SessionNotFound
        )
    )
}

/// Point-in-time snapshot of one replica.
#[derive(Debug, Clone)]
pub struct ReplicaMetrics {
    pub id: usize,
    /// False only while a rolling swap is rebuilding this replica.
    pub admitting: bool,
    /// Requests currently routed here (dispatcher view).
    pub in_flight: usize,
    /// The alignment precision this replica currently serves at
    /// (per-replica overrides make this heterogeneous).
    pub precision: AlignPrecision,
    /// Supervision state: quarantined replicas are excluded from
    /// routing until a rebuild + canary probe restores them.
    pub health: HealthState,
    /// The replica engine's own counters. Reset by a rolling swap (the
    /// engine is rebuilt); cluster-level counters persist across swaps.
    pub engine: EngineMetrics,
}

/// Cluster-level counters: request latencies and routing outcomes that
/// persist across rolling swaps, over a per-replica breakdown.
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    /// End-to-end request latencies as the client saw them — failover
    /// retries included, which is exactly what a per-replica histogram
    /// would miss.
    pub extract: LatencySummary,
    pub enroll: LatencySummary,
    pub verify: LatencySummary,
    /// Requests dispatched (each counted once, however many retries).
    pub routed: u64,
    /// Failover retries launched after a retriable rejection.
    pub failovers: u64,
    /// Requests still rejected after the failover budget / replica set
    /// / request deadline ran out (the caller saw the last rejection).
    pub exhausted: u64,
    /// Completed rolling swaps.
    pub swaps: u64,
    /// Streaming sessions opened across the cluster's whole life.
    pub sessions_opened: u64,
    /// Sessions found dead on touch because a rolling swap (or drain)
    /// retired their pinned engine — each surfaced to the caller as a
    /// typed `SessionSwapped`, never a silent rescore elsewhere.
    pub sessions_closed_by_swap: u64,
    /// Sheds/timeouts folded in from engines retired by those swaps
    /// (their replacements restart at zero).
    pub retired_shed: u64,
    pub retired_timeouts: u64,
    /// Replicas quarantined by the health supervisor (state-entry
    /// edges, so one incident counts once however long it lasts).
    pub quarantines: u64,
    /// Half-open canary probes sent to quarantined replicas.
    pub probes: u64,
    /// Quarantined engines rebuilt from the current bundle.
    pub self_heals: u64,
    /// Durability counters of the shared registry (zeros on a volatile
    /// cluster). One registry, one WAL: these are cluster-wide however
    /// many replicas routed the mutations.
    pub durability: DurabilityMetrics,
    pub replicas: Vec<ReplicaMetrics>,
}

impl ClusterMetrics {
    /// Engine-level sheds summed over replicas — including engines
    /// retired by rolling swaps, so the total spans the cluster's whole
    /// life (the client-visible residue after failover is
    /// [`ClusterMetrics::exhausted`]).
    pub fn total_shed(&self) -> u64 {
        self.retired_shed + self.replicas.iter().map(|r| r.engine.shed_requests).sum::<u64>()
    }

    /// Engine-level request timeouts summed over replicas, retired
    /// engines included.
    pub fn total_timeouts(&self) -> u64 {
        self.retired_timeouts
            + self.replicas.iter().map(|r| r.engine.timed_out_requests).sum::<u64>()
    }

    /// Requests that flowed through E-step batches, summed over
    /// replicas (since the last swap rebuilt each engine).
    pub fn total_batched_requests(&self) -> u64 {
        self.replicas.iter().map(|r| r.engine.batched_requests).sum()
    }
}

/// The cluster dispatcher. `&Dispatcher` is `Sync`: request threads
/// call `extract`/`enroll`/`verify` concurrently while an operator
/// thread rolls a [`Dispatcher::swap_bundle`] through the replicas.
pub struct Dispatcher {
    replicas: Vec<Replica>,
    /// One speaker store for the whole cluster: an enrollment on any
    /// replica is immediately scorable on every other, and survives
    /// per-replica engine rebuilds during rolling swaps.
    registry: Arc<Registry>,
    route: RoutePolicy,
    max_failovers: usize,
    /// Per-replica drain bound during rolling swaps.
    drain_timeout: Duration,
    /// The failover loop's outer bound: no retry *launches* after the
    /// original request window (mirroring `[serve] request_timeout_ms`)
    /// is spent, whatever the remaining attempt budget. Each attempt is
    /// then bounded by the engine's own deadlines, so the worst-case
    /// client wait is one window plus the final attempt's — a shed
    /// arrives at `submit_timeout_ms`, far inside the window, so in
    /// practice failover costs sheds' submit waits, not extra windows.
    request_timeout: Duration,
    /// Shared engine shape + per-replica overrides, kept so a rolling
    /// swap rebuilds each replica exactly as it was configured.
    serve_cfg: ServeConfig,
    cluster_cfg: ClusterConfig,
    /// Serializes rolling swaps — and [`Dispatcher::drain`], which
    /// would otherwise race a swap: the swap could install a fresh,
    /// admitting engine into a slot the drain had just retired.
    swap_lock: Mutex<()>,
    /// Set by [`Dispatcher::drain`]; terminal — a retired cluster
    /// refuses further swaps instead of resurrecting worker pools.
    retired: AtomicBool,
    /// The cluster-wide observability registry: shared with every
    /// replica engine (labeled per-engine series) and the home of the
    /// unlabeled `cluster_*` counters below, which therefore persist
    /// across rolling swaps by construction.
    obs: Arc<ObsRegistry>,
    /// Shed/timeout counts carried over from engines retired by rolling
    /// swaps (a swap rebuilds the engine with zeroed counters; without
    /// this the cluster totals would silently forget everything before
    /// the last swap).
    retired_shed: Counter,
    retired_timeouts: Counter,
    /// Streaming sessions by dispatcher-minted id → the replica engine
    /// pinned at open. Entries are dropped on close/early-exit, on an
    /// engine-side eviction, or lazily on the first touch after a swap
    /// retired the pinned engine.
    sessions: Mutex<HashMap<u64, ClusterSession>>,
    next_session: AtomicU64,
    sessions_opened: Counter,
    sessions_closed_by_swap: Counter,
    /// Round-robin cursor.
    rr: AtomicUsize,
    routed: Counter,
    failovers: Counter,
    exhausted: Counter,
    swaps: Counter,
    extract_lat: Arc<LatencyHistogram>,
    enroll_lat: Arc<LatencyHistogram>,
    verify_lat: Arc<LatencyHistogram>,
    /// The bundle currently rolled out, kept so a self-heal rebuild
    /// installs the *current* model — including one swapped in after
    /// construction — not the one the cluster booted with.
    bundle: Mutex<ModelBundle>,
    /// Per-replica error budgets, quarantine, and half-open probes.
    /// The request path reads only its lock-free published state.
    health: HealthTracker,
    quarantines: Counter,
    probes: Counter,
    self_heals: Counter,
    /// Published health level per replica (0 healthy / 1 degraded /
    /// 2 quarantined), labeled by replica id, so an exported snapshot
    /// shows which replica an incident hit.
    health_gauges: Vec<Arc<DepthGauge>>,
    /// Optional flight recorder: each routed request (the whole
    /// failover loop, not per-hop) is offered to the capture log after
    /// completion, off the request's critical path. Cluster-level
    /// capture replaces engine-level capture — the replica engines see
    /// a trace already installed and skip their own offer.
    recorder: RwLock<Option<Arc<Recorder>>>,
}

impl Dispatcher {
    /// Build `cluster.replicas` engines around `bundle`, all on one
    /// fresh shared registry (sharded per `serve.registry_shards`).
    /// Each replica gets the shared `[serve]` shape with its
    /// `[cluster.replicaN]` overrides applied.
    pub fn new(bundle: ModelBundle, serve: &ServeConfig, cluster: &ClusterConfig) -> Result<Self> {
        let registry = Arc::new(Registry::new(serve.registry_shards));
        Self::with_registry(bundle, serve, cluster, registry)
    }

    /// Like [`Dispatcher::new`], but every replica shares the *given*
    /// registry — typically a [`crate::serve::DurableRegistry`] handle,
    /// so one WAL underlies the whole cluster: an enrollment routed to
    /// any replica is logged once, immediately scorable everywhere, and
    /// survives both rolling swaps and process crashes.
    pub fn with_registry(
        bundle: ModelBundle,
        serve: &ServeConfig,
        cluster: &ClusterConfig,
        registry: Arc<Registry>,
    ) -> Result<Self> {
        Self::with_registry_obs(bundle, serve, cluster, registry, Arc::new(ObsRegistry::default()))
    }

    /// Like [`Dispatcher::with_registry`] with an externally-owned
    /// observability registry — every replica engine registers its
    /// labeled instruments into it, so one snapshot covers the whole
    /// cluster plus the dispatcher's own `cluster_*` series.
    pub fn with_registry_obs(
        bundle: ModelBundle,
        serve: &ServeConfig,
        cluster: &ClusterConfig,
        registry: Arc<Registry>,
        obs: Arc<ObsRegistry>,
    ) -> Result<Self> {
        let n = cluster.replicas.max(1);
        let mut replicas = Vec::with_capacity(n);
        for id in 0..n {
            let cfg = cluster.replica_serve_cfg(serve, id);
            let engine = Engine::with_registry_obs(
                bundle.clone(),
                &cfg,
                Arc::clone(&registry),
                Arc::clone(&obs),
            )?;
            replicas.push(Replica {
                id,
                engine: RwLock::new(Arc::new(engine)),
                in_flight: AtomicUsize::new(0),
                admitting: AtomicBool::new(true),
                hard_errors: AtomicU64::new(0),
            });
        }
        let health = HealthTracker::new(&cluster.health, n);
        let health_gauges: Vec<Arc<DepthGauge>> = (0..n)
            .map(|id| obs.gauge("cluster_replica_health", &[("replica", &id.to_string())]))
            .collect();
        Ok(Self {
            replicas,
            registry,
            route: cluster.route,
            max_failovers: cluster.max_failovers,
            drain_timeout: Duration::from_millis(cluster.drain_timeout_ms.max(1)),
            request_timeout: Duration::from_millis(serve.request_timeout_ms.max(1)),
            serve_cfg: serve.clone(),
            cluster_cfg: cluster.clone(),
            swap_lock: Mutex::new(()),
            retired: AtomicBool::new(false),
            retired_shed: obs.counter("cluster_retired_shed_total", &[]),
            retired_timeouts: obs.counter("cluster_retired_timeouts_total", &[]),
            sessions: Mutex::new(HashMap::new()),
            next_session: AtomicU64::new(0),
            sessions_opened: obs.counter("cluster_sessions_opened_total", &[]),
            sessions_closed_by_swap: obs.counter("cluster_sessions_closed_by_swap_total", &[]),
            rr: AtomicUsize::new(0),
            routed: obs.counter("cluster_routed_total", &[]),
            failovers: obs.counter("cluster_failovers_total", &[]),
            exhausted: obs.counter("cluster_exhausted_total", &[]),
            swaps: obs.counter("cluster_swaps_total", &[]),
            extract_lat: obs.histogram("cluster_extract_latency_seconds", &[]),
            enroll_lat: obs.histogram("cluster_enroll_latency_seconds", &[]),
            verify_lat: obs.histogram("cluster_verify_latency_seconds", &[]),
            bundle: Mutex::new(bundle),
            health,
            quarantines: obs.counter("cluster_quarantines_total", &[]),
            probes: obs.counter("cluster_probes_total", &[]),
            self_heals: obs.counter("cluster_self_heals_total", &[]),
            health_gauges,
            recorder: RwLock::new(None),
            obs,
        })
    }

    /// Attach (or detach, with `None`) a flight recorder. Every routed
    /// request — with its full failover span set — is offered to the
    /// capture queue after completion; a slow or full sink drops
    /// records (counted), never blocks a request thread.
    pub fn set_recorder(&self, rec: Option<Arc<Recorder>>) {
        *self.recorder.write().unwrap_or_else(|p| p.into_inner()) = rec;
    }

    /// The observability registry the cluster reports into.
    pub fn obs(&self) -> &Arc<ObsRegistry> {
        &self.obs
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The routing policy in force.
    pub fn route(&self) -> RoutePolicy {
        self.route
    }

    /// The cluster-wide speaker registry (persistence, admin).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// A shared handle to the cluster registry.
    pub fn registry_handle(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// The model snapshot replica `id` currently serves (panics on an
    /// out-of-range id, like any index).
    pub fn replica_model(&self, id: usize) -> Arc<ServeModel> {
        self.replicas[id].engine().model()
    }

    /// Route one extraction across the cluster (failover included).
    pub fn extract(&self, feats: &Mat) -> Result<Vec<f64>> {
        let t0 = Instant::now();
        let iv = self.dispatch_recorded(RequestKind::Extract, "", feats, |_| None, |engine| {
            engine.extract(feats)
        })?;
        self.extract_lat.record_duration(t0.elapsed());
        Ok(iv)
    }

    /// Route one enrollment across the cluster. The registry is shared,
    /// so the resulting profile is scorable on every replica at once.
    pub fn enroll(&self, speaker_id: &str, feats: &Mat) -> Result<u64> {
        let t0 = Instant::now();
        let count = self.dispatch_recorded(
            RequestKind::Enroll,
            speaker_id,
            feats,
            |count| Some(*count as f64),
            |engine| engine.enroll(speaker_id, feats),
        )?;
        self.enroll_lat.record_duration(t0.elapsed());
        Ok(count)
    }

    /// Route one verification across the cluster.
    pub fn verify(&self, speaker_id: &str, feats: &Mat) -> Result<VerifyOutcome> {
        let t0 = Instant::now();
        let out = self.dispatch_recorded(
            RequestKind::Verify,
            speaker_id,
            feats,
            |out| Some(out.score),
            |engine| engine.verify(speaker_id, feats),
        )?;
        self.verify_lat.record_duration(t0.elapsed());
        Ok(out)
    }

    /// Open a streaming session for an enrolled speaker somewhere in
    /// the cluster (the first attempt follows the routing policy; a
    /// typed rejection fails over like any request, since nothing was
    /// created) and pin it to the replica that accepted: the returned
    /// id is dispatcher-minted, and every later `session_*` call goes
    /// back to that exact engine — partial statistics never migrate.
    pub fn session_open(&self, speaker_id: &str) -> Result<u64> {
        let cid = self.dispatch_full(false, |id, engine| {
            let engine_session = engine.session_open(speaker_id)?;
            let cid = self.next_session.fetch_add(1, Ordering::Relaxed) + 1;
            self.sessions.lock().unwrap_or_else(|p| p.into_inner()).insert(
                cid,
                ClusterSession { replica: id, engine_session, engine: Arc::downgrade(engine) },
            );
            Ok(cid)
        })?;
        self.sessions_opened.inc();
        Ok(cid)
    }

    /// Feed a chunk to a session on its pinned replica. No failover:
    /// if a rolling swap retired the pinned engine this comes back as
    /// a typed [`ServeError::SessionSwapped`] — rescoring the partial
    /// stats on another replica is impossible (they live over there)
    /// and pretending otherwise would mix model spaces silently.
    pub fn session_feed(&self, id: u64, chunk: &Mat) -> Result<FeedOutcome> {
        let (rid, engine, sid) = self.session_route(id)?;
        let _flight = Flight::begin(&self.replicas[rid].in_flight);
        let out = engine.session_feed(sid, chunk);
        match &out {
            // an early-exit decision finalized the engine-side session
            Ok(FeedOutcome::Decided { .. }) => self.forget(id),
            Err(e) if session_is_dead(e) => self.forget(id),
            _ => {}
        }
        out
    }

    /// Score a session's accumulated statistics without closing it —
    /// on its pinned replica, same no-failover contract as
    /// [`Dispatcher::session_feed`].
    pub fn session_score(&self, id: u64) -> Result<VerifyOutcome> {
        let (rid, engine, sid) = self.session_route(id)?;
        let _flight = Flight::begin(&self.replicas[rid].in_flight);
        let out = engine.session_score(sid);
        if let Err(e) = &out {
            if session_is_dead(e) {
                self.forget(id);
            }
        }
        out
    }

    /// Final score and close, on the pinned replica. The cluster entry
    /// is dropped whatever the engine answered — there is nothing left
    /// to route to afterwards.
    pub fn session_close(&self, id: u64) -> Result<VerifyOutcome> {
        let (rid, engine, sid) = self.session_route(id)?;
        let _flight = Flight::begin(&self.replicas[rid].in_flight);
        let out = engine.session_close(sid);
        self.forget(id);
        out
    }

    /// Sessions the dispatcher is still routing (engine-side evictions
    /// and swap casualties leave until their next touch reaps them).
    pub fn live_sessions(&self) -> usize {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Resolve a session id to its pinned engine — and reap it typed
    /// if the engine is gone. The liveness check is both halves: the
    /// [`Weak`] must still upgrade (a swap dropping the retired engine
    /// kills it) *and* the upgraded `Arc` must still be the replica's
    /// current slot (an in-flight clone keeping the retired engine
    /// alive must not masquerade as live routing).
    fn session_route(&self, id: u64) -> Result<(usize, Arc<Engine>, u64)> {
        let mut map = self.sessions.lock().unwrap_or_else(|p| p.into_inner());
        let Some(s) = map.get(&id) else {
            return Err(ServeError::SessionNotFound.into());
        };
        let live =
            s.engine.upgrade().filter(|e| Arc::ptr_eq(e, &self.replicas[s.replica].engine()));
        match live {
            Some(engine) => Ok((s.replica, engine, s.engine_session)),
            None => {
                map.remove(&id);
                self.sessions_closed_by_swap.inc();
                Err(ServeError::SessionSwapped.into())
            }
        }
    }

    fn forget(&self, id: u64) {
        self.sessions.lock().unwrap_or_else(|p| p.into_inner()).remove(&id);
    }

    /// The routed request core: pick a replica, run the operation, and
    /// on a typed retriable rejection (`Overloaded` from admission
    /// control, `ShuttingDown` from a draining replica) retry on the
    /// least-loaded untried replica — bounded by `max_failovers`, and
    /// launched only while the original request window has time left
    /// (each attempt then carries the engine's own deadlines; see the
    /// `request_timeout` field note for the worst-case bound). Stateless
    /// requests (everything routed through here: extract, enroll,
    /// verify) additionally retry `WorkerFailed` — nothing was applied
    /// before the worker dropped the response, so replay is safe, and
    /// the health tracker charges the panicking replica. Anything else
    /// propagates as-is: a `Timeout` request has already spent its
    /// deadline, and the remaining hard errors (unknown speaker, model
    /// mismatch) would fail identically anywhere.
    fn dispatch<T>(&self, f: impl Fn(&Engine) -> Result<T>) -> Result<T> {
        self.dispatch_full(true, move |_, engine| f(engine))
    }

    /// [`Dispatcher::dispatch`] plus an offer to the attached flight
    /// recorder (if any): one capture record per *routed request*, so
    /// a rescued request appears once with its failover hops in the
    /// span set, not once per attempt. The capture outcome is the
    /// caller-visible one — what a replayed cluster must reproduce.
    fn dispatch_recorded<T>(
        &self,
        kind: RequestKind,
        speaker: &str,
        feats: &Mat,
        score_of: impl Fn(&T) -> Option<f64>,
        f: impl Fn(&Engine) -> Result<T>,
    ) -> Result<T> {
        let rec = self.recorder.read().unwrap_or_else(|p| p.into_inner()).clone();
        let trace = self.obs.mint();
        let t0 = Instant::now();
        let scope = trace.as_ref().map(|t| obs::enter(Arc::clone(t)));
        let r = self.dispatch_attempts(true, trace.as_deref(), move |_, engine| f(engine));
        drop(scope);
        if let Some(t) = &trace {
            self.obs.complete(t, TraceOutcome::of(&r));
        }
        if let Some(rec) = rec {
            let score = r.as_ref().ok().and_then(&score_of);
            rec.observe(
                kind,
                speaker,
                feats,
                TraceOutcome::of(&r),
                score,
                t0.elapsed(),
                trace.as_deref(),
            );
        }
        r
    }

    /// Like [`Dispatcher::dispatch`], but the operation also sees which
    /// replica it landed on and the engine `Arc` itself — what
    /// [`Dispatcher::session_open`] needs to pin the session where it
    /// was created. `stateless` selects the failover set:
    /// [`ServeError::is_retriable_stateless`] for replayable requests,
    /// [`ServeError::is_retriable`] for session opens (a `WorkerFailed`
    /// open could in principle retry too, but opens do no batch work —
    /// keeping them on the narrow set keeps the contract simple).
    fn dispatch_full<T>(
        &self,
        stateless: bool,
        f: impl Fn(usize, &Arc<Engine>) -> Result<T>,
    ) -> Result<T> {
        // the trace spans the whole failover loop: hops, retries, and
        // the engines' stage spans (which join this thread's scope) all
        // accumulate into one record, so a rescued request shows every
        // replica it touched
        let trace = self.obs.mint();
        let scope = trace.as_ref().map(|t| obs::enter(Arc::clone(t)));
        let r = self.dispatch_attempts(stateless, trace.as_deref(), f);
        drop(scope);
        if let Some(t) = &trace {
            self.obs.complete(t, TraceOutcome::of(&r));
        }
        r
    }

    fn dispatch_attempts<T>(
        &self,
        stateless: bool,
        trace: Option<&RequestTrace>,
        f: impl Fn(usize, &Arc<Engine>) -> Result<T>,
    ) -> Result<T> {
        let deadline = Instant::now() + self.request_timeout;
        self.routed.inc();
        let mut tried: Vec<usize> = Vec::with_capacity(2);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..=self.max_failovers {
            let Some(id) = self.pick(&tried, attempt == 0) else { break };
            let replica = &self.replicas[id];
            let engine = replica.engine();
            let _flight = Flight::begin(&replica.in_flight);
            if let Some(t) = trace {
                t.add_hop(id);
            }
            match f(id, &engine) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let serve_err = e.downcast_ref::<ServeError>();
                    let retriable = serve_err.is_some_and(|s| {
                        if stateless { s.is_retriable_stateless() } else { s.is_retriable() }
                    });
                    // the one typed failure that indicts the replica
                    // itself rather than the request or the cluster's
                    // load: charge it to the replica's error budget
                    if matches!(serve_err, Some(ServeError::WorkerFailed)) {
                        replica.hard_errors.fetch_add(1, Ordering::Relaxed);
                    }
                    // `Overloaded` disqualifies the replica for this
                    // request (its queue is full). `ShuttingDown` does
                    // not: the engine the request held was retiring,
                    // and a rolling swap installs the replacement
                    // *before* draining it — a retry on the same
                    // replica picks up the fresh engine.
                    if !matches!(serve_err, Some(ServeError::ShuttingDown)) {
                        tried.push(id);
                    }
                    last = Some(e);
                    if !retriable {
                        break;
                    }
                    if attempt == self.max_failovers
                        || tried.len() >= self.replicas.len()
                        || Instant::now() >= deadline
                    {
                        // still retriable, but the budget (attempts,
                        // replicas, or time) is spent: the caller sees
                        // the last rejection
                        self.exhausted.inc();
                        break;
                    }
                    self.failovers.inc();
                    if let Some(t) = trace {
                        t.record_failover();
                    }
                }
            }
        }
        Err(last.unwrap_or_else(|| anyhow!("cluster has no replica to route to")))
    }

    /// Choose a replica not in `tried`: by the configured policy for a
    /// request's first attempt, always least-loaded for failover
    /// retries. Quarantined replicas are excluded outright; among the
    /// routable it prefers admitting ones, falling back (a rolling swap
    /// on a small cluster) to any routable untried replica — the engine
    /// itself then answers with a typed error the failover loop
    /// understands, rather than the router inventing its own. Last
    /// resort, when *every* untried replica is quarantined: route
    /// anyway. A fully-quarantined cluster must still answer — a
    /// quarantined engine sheds typed errors the caller can branch on,
    /// where an empty pool would deadlock the request into an untyped
    /// "no replica" failure after zero attempts.
    fn pick(&self, tried: &[usize], primary: bool) -> Option<usize> {
        let untried = |r: &&Replica| !tried.contains(&r.id);
        let routable = |r: &&Replica| self.health.is_routable(r.id);
        let mut pool: Vec<&Replica> = self
            .replicas
            .iter()
            .filter(untried)
            .filter(routable)
            .filter(|r| r.admitting.load(Ordering::Acquire))
            .collect();
        if pool.is_empty() {
            pool = self.replicas.iter().filter(untried).filter(routable).collect();
        }
        if pool.is_empty() {
            // last-replica-standing escape hatch
            pool = self.replicas.iter().filter(untried).collect();
        }
        if pool.is_empty() {
            return None;
        }
        if primary && self.route == RoutePolicy::RoundRobin {
            let n = self.replicas.len();
            let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
            for k in 0..n {
                let id = (start + k) % n;
                if pool.iter().any(|r| r.id == id) {
                    return Some(id);
                }
            }
        }
        pool.iter().map(|r| (r.load(), r.id)).min().map(|(_, id)| id)
    }

    /// Roll a new bundle through the cluster, one replica at a time:
    /// stop routing to the replica → install a fresh engine (same
    /// shared registry, same per-replica overrides) → drain the retired
    /// engine (it finishes everything already queued, then its workers
    /// join, bounded by `drain_timeout_ms`) → resume routing. Every
    /// other replica keeps serving throughout, so a model push never
    /// takes the cluster offline. In-flight requests on a retiring
    /// engine either complete on their snapshot or come back as typed
    /// `ShuttingDown` rejections, which the failover path retries on an
    /// already-upgraded replica.
    ///
    /// A bundle whose backend disagrees with its extractor is rejected
    /// up front — before any replica is touched.
    ///
    /// Streaming sessions pinned to a retired engine die with it (their
    /// partial statistics lived in that engine's table); the dispatcher
    /// reaps each one on its next touch with a typed
    /// [`ServeError::SessionSwapped`], so callers reopen instead of
    /// silently rescoring against the new bundle.
    pub fn swap_bundle(&self, bundle: ModelBundle) -> Result<()> {
        bundle.check_backend_dims()?;
        let _serialized = self.swap_lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        anyhow::ensure!(
            !self.retired.load(Ordering::Acquire),
            "cluster has been drained — a swap would resurrect retired replicas"
        );
        for replica in &self.replicas {
            let cfg = self.cluster_cfg.replica_serve_cfg(&self.serve_cfg, replica.id);
            let next = Arc::new(Engine::with_registry_obs(
                bundle.clone(),
                &cfg,
                Arc::clone(&self.registry),
                Arc::clone(&self.obs),
            )?);
            replica.admitting.store(false, Ordering::Release);
            let old = {
                let mut slot =
                    replica.engine.write().unwrap_or_else(|poisoned| poisoned.into_inner());
                std::mem::replace(&mut *slot, next)
            };
            // the slot now holds the fresh engine, so the replica is
            // fully serviceable — resume routing *before* the old
            // engine's drain, or the drain (up to drain_timeout_ms)
            // would dent cluster capacity for no reason
            replica.admitting.store(true, Ordering::Release);
            if !old.drain(self.drain_timeout) {
                eprintln!(
                    "[cluster] replica {}: drain exceeded {:?} — a worker is still \
                     finishing its batch; its engine retires when that batch ends",
                    replica.id, self.drain_timeout
                );
            }
            // fold the retired engine's rejection counters into the
            // cluster totals — the replacement starts at zero, and the
            // report must not forget the pre-swap load. (A request
            // still waiting on the old engine can time out after this
            // read; that residue is the one count this can miss.)
            let old_metrics = old.metrics();
            self.retired_shed.add(old_metrics.shed_requests);
            self.retired_timeouts.add(old_metrics.timed_out_requests);
        }
        // self-heal rebuilds must install what is serving *now*
        *self.bundle.lock().unwrap_or_else(|poisoned| poisoned.into_inner()) = bundle;
        self.swaps.inc();
        Ok(())
    }

    /// Drain the whole cluster: stop routing everywhere, then drain
    /// each replica (bounded by `timeout` per replica). Returns true
    /// when every worker on every replica joined in time. New requests
    /// fail with typed `ShuttingDown`. Terminal, and serialized with
    /// [`Dispatcher::swap_bundle`]: an in-flight swap finishes first,
    /// its fresh engines are drained here too, and later swaps are
    /// refused instead of resurrecting worker pools.
    pub fn drain(&self, timeout: Duration) -> bool {
        let _serialized = self.swap_lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        self.retired.store(true, Ordering::Release);
        let mut all = true;
        for replica in &self.replicas {
            replica.admitting.store(false, Ordering::Release);
            all &= replica.engine().drain(timeout);
        }
        all
    }

    /// Deliberately freeze (or thaw) one replica's worker pool — the
    /// degraded-replica stand-in used by the failover tests and
    /// `cluster-bench --stall-replica` (via
    /// [`super::bench::run_cluster_load`]). Crate-only: outside code
    /// must never be able to stall a serving replica.
    pub(crate) fn stall_replica(&self, id: usize, stalled: bool) {
        self.replicas[id].engine().stall_workers(stalled);
    }

    /// Script the next `n` batch dispatches on replica `id` to panic —
    /// the chaos drill's deterministic worker-crash injector (each
    /// panicked batch surfaces as typed `WorkerFailed` to its callers).
    /// Crate-only, like [`Dispatcher::stall_replica`].
    pub(crate) fn panic_replica(&self, id: usize, n: u64) {
        self.replicas[id].engine().panic_next_batches(n);
    }

    /// The supervisor's current view of one replica (tests, bench
    /// reporting; the request path reads the same published state).
    pub fn health_state(&self, id: usize) -> HealthState {
        self.health.state(id)
    }

    /// One supervision pass — the self-healing heartbeat. Run it
    /// periodically from an operator thread (the chaos harness ticks
    /// every few milliseconds; production would tick ~once a second).
    /// Each pass, per replica:
    ///
    /// 1. sweep idle streaming sessions (the engine-side eviction that
    ///    otherwise only runs lazily on touches),
    /// 2. feed the replica's cumulative failure counters to the health
    ///    tracker and publish the health gauge,
    /// 3. on a fresh quarantine *or* a pending one, rebuild the engine
    ///    from the current bundle (the breaker opens),
    /// 4. once a rebuilt replica's cooldown expires, send one canary
    ///    probe (half-open) and restore it on success — a failed canary
    ///    re-opens the breaker and the next tick rebuilds again;
    ///
    /// then attempt recovery of a WAL-poisoned registry, so degraded
    /// read-only mode ends without operator intervention when the
    /// fault was transient.
    pub fn tick(&self) {
        for replica in &self.replicas {
            let engine = replica.engine();
            engine.sessions().sweep();
            let m = engine.metrics();
            let sample = HealthSample {
                sheds: m.shed_requests,
                timeouts: m.timed_out_requests,
                worker_panics: m.worker_panics,
                hard_errors: replica.hard_errors.load(Ordering::Relaxed),
            };
            let out = self.health.observe(replica.id, Instant::now(), sample);
            self.health_gauges[replica.id].record(u64::from(out.state.level()));
            if out.changed && out.state == HealthState::Quarantined {
                self.quarantines.inc();
                eprintln!(
                    "[cluster] replica {}: quarantined (error budget exhausted) — \
                     rebuilding its engine",
                    replica.id
                );
            }
            match out.action {
                HealthAction::None => {}
                HealthAction::Rebuild => match self.rebuild_replica(replica) {
                    Ok(()) => {
                        self.self_heals.inc();
                        self.health.healed(replica.id, Instant::now());
                    }
                    Err(e) => eprintln!(
                        "[cluster] replica {}: self-heal rebuild failed ({e}); \
                         retrying next tick",
                        replica.id
                    ),
                },
                HealthAction::Probe => {
                    self.probes.inc();
                    let ok = self.probe(&replica.engine());
                    if self.health.probe_result(replica.id, ok, Instant::now()) {
                        eprintln!(
                            "[cluster] replica {}: canary passed — restored to routing",
                            replica.id
                        );
                    }
                }
            }
        }
        if self.registry.is_poisoned() && self.registry.repair().is_ok() {
            eprintln!("[cluster] registry: WAL repaired — enrollments accepted again");
        }
    }

    /// Replace a quarantined replica's engine with a fresh one built
    /// from the *current* bundle — the single-replica version of the
    /// install + drain sequence [`Dispatcher::swap_bundle`] rolls
    /// through the cluster, so in-flight requests either finish on
    /// their snapshot or come back typed and fail over. A stalled
    /// engine drains cleanly here: shutdown wakes its parked workers
    /// regardless of the stall flag, and queued jobs' response channels
    /// drop (typed `WorkerFailed` to any caller still waiting).
    fn rebuild_replica(&self, replica: &Replica) -> Result<()> {
        let bundle = self.bundle.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).clone();
        let _serialized = self.swap_lock.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
        anyhow::ensure!(
            !self.retired.load(Ordering::Acquire),
            "cluster has been drained — a self-heal would resurrect a retired replica"
        );
        let cfg = self.cluster_cfg.replica_serve_cfg(&self.serve_cfg, replica.id);
        let next = Arc::new(Engine::with_registry_obs(
            bundle,
            &cfg,
            Arc::clone(&self.registry),
            Arc::clone(&self.obs),
        )?);
        replica.admitting.store(false, Ordering::Release);
        let old = {
            let mut slot = replica.engine.write().unwrap_or_else(|poisoned| poisoned.into_inner());
            std::mem::replace(&mut *slot, next)
        };
        replica.admitting.store(true, Ordering::Release);
        if !old.drain(self.drain_timeout) {
            eprintln!(
                "[cluster] replica {}: quarantined engine exceeded {:?} draining — \
                 it retires when its last batch ends",
                replica.id, self.drain_timeout
            );
        }
        let old_metrics = old.metrics();
        self.retired_shed.add(old_metrics.shed_requests);
        self.retired_timeouts.add(old_metrics.timed_out_requests);
        // the fresh engine restarts every counter at zero and `healed`
        // resets the tracker's baseline to match — this atomic must
        // reset too, or the next tick's delta would see a phantom
        // burst and re-quarantine the healthy replacement
        replica.hard_errors.store(0, Ordering::Relaxed);
        Ok(())
    }

    /// The half-open canary: one synthetic extraction through the
    /// replica's full serving path — admission, micro-batching, worker
    /// dispatch. Deterministic content, because the probe judges the
    /// engine's plumbing, not the model's output.
    fn probe(&self, engine: &Engine) -> bool {
        let frames = self.cluster_cfg.health.probe_frames.max(1);
        let dim = engine.model().bundle.tvm.feat_dim();
        let feats = Mat::from_fn(frames, dim, |t, j| ((t * 31 + j * 7) % 13) as f64 * 0.1 - 0.6);
        engine.extract(&feats).is_ok()
    }

    /// Cluster counters plus the per-replica breakdown.
    pub fn metrics(&self) -> ClusterMetrics {
        ClusterMetrics {
            extract: self.extract_lat.summary(),
            enroll: self.enroll_lat.summary(),
            verify: self.verify_lat.summary(),
            routed: self.routed.get(),
            failovers: self.failovers.get(),
            exhausted: self.exhausted.get(),
            swaps: self.swaps.get(),
            sessions_opened: self.sessions_opened.get(),
            sessions_closed_by_swap: self.sessions_closed_by_swap.get(),
            retired_shed: self.retired_shed.get(),
            retired_timeouts: self.retired_timeouts.get(),
            quarantines: self.quarantines.get(),
            probes: self.probes.get(),
            self_heals: self.self_heals.get(),
            durability: self.registry.durability_metrics(),
            replicas: self
                .replicas
                .iter()
                .map(|r| {
                    let engine = r.engine();
                    ReplicaMetrics {
                        id: r.id,
                        admitting: r.admitting.load(Ordering::Acquire),
                        in_flight: r.in_flight.load(Ordering::Acquire),
                        precision: engine.model().precision(),
                        health: self.health.state(r.id),
                        engine: engine.metrics(),
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::AtomicBool;

    use super::*;
    use crate::serve::bench::{shared_test_bundle, tiny_serve_config, tiny_traffic};

    /// Generous request-path deadlines: these tests exercise routing
    /// and swap correctness, not admission control (the failover test
    /// tightens them explicitly).
    fn serve_opts() -> ServeConfig {
        ServeConfig {
            batch_utts: 4,
            flush_us: 300,
            workers: 2,
            registry_shards: 4,
            queue_cap: 256,
            submit_timeout_ms: 10_000,
            request_timeout_ms: 60_000,
            scratch_pool: 4,
            precision: AlignPrecision::F64,
            session: crate::config::SessionConfig::default(),
        }
    }

    fn cluster_opts(replicas: usize, route: RoutePolicy) -> ClusterConfig {
        ClusterConfig {
            replicas,
            route,
            max_failovers: 2,
            drain_timeout_ms: 5_000,
            overrides: Vec::new(),
            health: crate::config::HealthConfig::default(),
        }
    }

    #[test]
    fn least_depth_prefers_the_idle_lowest_id_replica() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 11);
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::LeastDepth),
        )
        .unwrap();
        // sequential requests always see both replicas idle — the tie
        // breaks to the lowest id every time, deterministically
        for k in 0..4 {
            d.extract(&traffic.utterance(0, k)).unwrap();
        }
        let m = d.metrics();
        assert_eq!(m.routed, 4);
        assert_eq!(m.failovers, 0);
        assert_eq!(m.replicas[0].engine.batched_requests, 4);
        assert_eq!(m.replicas[1].engine.batched_requests, 0);
        assert_eq!(m.extract.count, 4);
    }

    #[test]
    fn round_robin_spreads_requests_across_replicas() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 13);
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::RoundRobin),
        )
        .unwrap();
        for k in 0..6 {
            d.extract(&traffic.utterance(0, k)).unwrap();
        }
        let m = d.metrics();
        assert_eq!(m.replicas[0].engine.batched_requests, 3);
        assert_eq!(m.replicas[1].engine.batched_requests, 3);
    }

    /// Tentpole acceptance: a stalled replica's `Overloaded` sheds are
    /// transparently retried on the healthy replica, and every rescued
    /// request still matches the serial oracle to 1e-10.
    #[test]
    fn failover_rescues_shed_requests_bit_exactly() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 55);
        let mut serve = serve_opts();
        serve.queue_cap = 1;
        serve.submit_timeout_ms = 120;
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve,
            &cluster_opts(2, RoutePolicy::RoundRobin),
        )
        .unwrap();

        // freeze replica 0 and park one direct request in its queue so
        // the queue sits at capacity — every dispatcher request routed
        // there must now shed (deterministically) and fail over
        d.stall_replica(0, true);
        let stalled_engine = self::engine_of(&d, 0);
        let filler_feats = traffic.utterance(0, 99);
        std::thread::scope(|scope| {
            let filler = {
                let engine = Arc::clone(&stalled_engine);
                let feats = &filler_feats;
                scope.spawn(move || engine.extract(feats))
            };
            let t0 = Instant::now();
            while stalled_engine.queue_len() != 1 {
                assert!(t0.elapsed() < Duration::from_secs(10), "filler never queued");
                std::thread::sleep(Duration::from_millis(1));
            }

            // round robin alternates 0,1,0,1: half the requests shed on
            // the stalled replica and must be rescued by replica 1
            let oracle = d.replica_model(1);
            for k in 0..4u64 {
                let feats = traffic.utterance((k % 2) as usize, k);
                let got = d.extract(&feats).unwrap();
                let want = oracle.extract_serial(&feats);
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-10 * (1.0 + w.abs()),
                        "req {k} coord {j}: {g} vs {w}"
                    );
                }
            }
            let m = d.metrics();
            assert_eq!(m.routed, 4);
            assert_eq!(m.failovers, 2, "the two requests routed to the stalled replica");
            assert_eq!(m.exhausted, 0);
            assert_eq!(m.replicas[0].engine.shed_requests, 2);
            assert_eq!(m.replicas[1].engine.shed_requests, 0);

            // thaw: the parked filler completes bit-correctly too
            d.stall_replica(0, false);
            let got = filler.join().unwrap().unwrap();
            let want = d.replica_model(0).extract_serial(&filler_feats);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "filler coord {j}: {g} vs {w}");
            }
        });
    }

    fn engine_of(d: &Dispatcher, id: usize) -> Arc<Engine> {
        d.replicas[id].engine()
    }

    /// Tentpole acceptance: a failover-rescued request's trace lands in
    /// the slow-trace ring showing *both* replica hops (the shedding one
    /// and the rescuing one) plus the retry count.
    #[test]
    fn failover_trace_lands_in_ring_with_both_hops() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 91);
        let mut serve = serve_opts();
        serve.queue_cap = 1;
        serve.submit_timeout_ms = 120;
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve,
            &cluster_opts(2, RoutePolicy::RoundRobin),
        )
        .unwrap();

        // freeze replica 0 and park a direct request in its queue so
        // every dispatcher request routed there sheds deterministically
        d.stall_replica(0, true);
        let stalled_engine = engine_of(&d, 0);
        let filler_feats = traffic.utterance(0, 99);
        std::thread::scope(|scope| {
            let filler = {
                let engine = Arc::clone(&stalled_engine);
                let feats = &filler_feats;
                scope.spawn(move || engine.extract(feats))
            };
            let t0 = Instant::now();
            while stalled_engine.queue_len() != 1 {
                assert!(t0.elapsed() < Duration::from_secs(10), "filler never queued");
                std::thread::sleep(Duration::from_millis(1));
            }

            // round robin alternates 0,1,0,1: two requests shed on the
            // stalled replica and get rescued by replica 1
            for k in 0..4u64 {
                d.extract(&traffic.utterance((k % 2) as usize, k)).unwrap();
            }

            let traces = d.obs().slow_traces();
            let rescued: Vec<_> = traces.iter().filter(|t| t.failovers >= 1).collect();
            assert_eq!(rescued.len(), 2, "two requests hit the stalled replica: {traces:?}");
            for t in &rescued {
                assert_eq!(t.hops, vec![0, 1], "failed hop then rescuing hop: {t:?}");
                assert_eq!(t.outcome, TraceOutcome::Ok, "{t:?}");
                assert_eq!(t.failovers, 1, "{t:?}");
                assert!(
                    t.stage_sum_ns() <= t.total_ns,
                    "stage sum {} vs end-to-end {}",
                    t.stage_sum_ns(),
                    t.total_ns
                );
                // the rescue rode a real batch: alignment (run on both
                // hops) and E-step time are attributed to this request
                assert!(t.stage_ns[crate::obs::Stage::Align.index()] > 0, "{t:?}");
                assert!(t.stage_ns[crate::obs::Stage::EstepBatch.index()] > 0, "{t:?}");
            }
            let direct: Vec<_> = traces.iter().filter(|t| t.failovers == 0).collect();
            assert_eq!(direct.len(), 2);
            for t in &direct {
                assert_eq!(t.hops, vec![1], "healthy replica served first try: {t:?}");
            }

            d.stall_replica(0, false);
            filler.join().unwrap().unwrap();
        });
        // after the thaw, the parked request's own engine-minted trace
        // completed too — a direct engine call records no replica hops
        let all = d.obs().slow_traces();
        assert_eq!(all.len(), 5);
        assert!(all.iter().any(|t| t.hops.is_empty()), "{all:?}");
    }

    /// Satellite acceptance: a rolling swap under concurrent
    /// enroll/verify traffic loses no enrollments and produces no
    /// cross-fingerprint verifies — every request either succeeds with
    /// an oracle-identical score or (transiently) failed over, never
    /// a mixed-space score.
    #[test]
    fn rolling_swap_under_traffic_loses_nothing() {
        let cfg = tiny_serve_config();
        let bundle = shared_test_bundle().clone();
        let oracle = ServeModel::new(bundle.clone());
        // speakers 0..8 owned by worker threads; 8 is the voice of the
        // shared contended speaker
        let traffic = tiny_traffic(&cfg, 9, 99);
        let d = Dispatcher::new(
            bundle.clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::LeastDepth),
        )
        .unwrap();
        let n_threads = 4usize;
        let enroll_utts = 2usize;
        let running = AtomicBool::new(true);
        let scores: Mutex<Vec<(usize, f64, f64)>> = Mutex::new(Vec::new());

        std::thread::scope(|scope| {
            // the model push: rolling swaps (value-identical bundle, so
            // fingerprints match and profiles stay scorable) while
            // requests are in flight
            let swapper = {
                let d = &d;
                let bundle = &bundle;
                let running = &running;
                scope.spawn(move || {
                    let mut swaps = 0u64;
                    while running.load(Ordering::Relaxed) {
                        d.swap_bundle(bundle.clone()).unwrap();
                        swaps += 1;
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    swaps
                })
            };
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let d = &d;
                    let traffic = &traffic;
                    let scores = &scores;
                    scope.spawn(move || {
                        for rep in 0..2 {
                            let spk = t * 2 + rep;
                            let id = traffic.speaker_id(spk);
                            for k in 0..enroll_utts {
                                d.enroll(&id, &traffic.utterance(spk, k as u64)).unwrap();
                            }
                            // contended speaker: identical utterance
                            // from every thread ⇒ exact running sum in
                            // any interleaving
                            d.enroll("shared", &traffic.utterance(8, 0)).unwrap();
                            let target =
                                d.verify(&id, &traffic.utterance(spk, 100)).unwrap();
                            let impostor = d
                                .verify(&id, &traffic.utterance((spk + 1) % 8, 100))
                                .unwrap();
                            scores.lock().unwrap().push((spk, target.score, impostor.score));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            running.store(false, Ordering::Relaxed);
            let swaps = swapper.join().unwrap();
            assert!(swaps >= 1, "at least one rolling swap must have run mid-traffic");
            assert_eq!(d.metrics().swaps, swaps);
        });

        // zero lost enrollments across swaps (shared registry outlives
        // every per-replica engine rebuild)
        let reg = d.registry();
        assert_eq!(reg.len(), 9, "8 per-thread speakers + the shared one");
        assert_eq!(reg.profile("shared").unwrap().count, (n_threads * 2) as u64);
        assert_eq!(reg.total_enrollments(), (8 * enroll_utts + n_threads * 2) as u64);

        // no cross-fingerprint verifies: every score equals the
        // single-threaded oracle (a mixed-space score could not)
        let results = scores.into_inner().unwrap();
        assert_eq!(results.len(), 8);
        for (spk, target, impostor) in results {
            let mut sum = vec![0.0; oracle.rank()];
            for k in 0..enroll_utts {
                let iv = oracle.extract_serial(&traffic.utterance(spk, k as u64));
                for (s, x) in sum.iter_mut().zip(&iv) {
                    *s += x;
                }
            }
            let mean: Vec<f64> = sum.iter().map(|&x| x / enroll_utts as f64).collect();
            let want_t =
                oracle.score(&mean, &oracle.extract_serial(&traffic.utterance(spk, 100)));
            let want_i = oracle.score(
                &mean,
                &oracle.extract_serial(&traffic.utterance((spk + 1) % 8, 100)),
            );
            assert!(
                (target - want_t).abs() <= 1e-12 * (1.0 + want_t.abs()),
                "spk {spk}: target {target} vs oracle {want_t}"
            );
            assert!(
                (impostor - want_i).abs() <= 1e-12 * (1.0 + want_i.abs()),
                "spk {spk}: impostor {impostor} vs oracle {want_i}"
            );
        }

        // the cluster is fully back: both replicas admitting, serving
        let m = d.metrics();
        assert!(m.replicas.iter().all(|r| r.admitting));
        d.extract(&traffic.utterance(0, 500)).unwrap();
    }

    /// Per-replica overrides: an f32 replica serves next to the f64
    /// one, and a rolling swap preserves each replica's precision.
    #[test]
    fn per_replica_precision_overrides_serve_side_by_side() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 17);
        let mut cluster = cluster_opts(2, RoutePolicy::RoundRobin);
        cluster.overrides = vec![
            crate::config::ReplicaOverride::default(),
            crate::config::ReplicaOverride {
                precision: Some(AlignPrecision::F32),
                workers: Some(1),
                batch_utts: None,
            },
        ];
        let bundle = shared_test_bundle().clone();
        let d = Dispatcher::new(bundle.clone(), &serve_opts(), &cluster).unwrap();
        assert_eq!(d.replica_model(0).precision(), AlignPrecision::F64);
        assert_eq!(d.replica_model(1).precision(), AlignPrecision::F32);

        // both serve, and the f32 replica tracks the f64 one within the
        // established f32 alignment tolerance
        let feats = traffic.utterance(0, 3);
        let f64_iv = d.replica_model(0).extract_serial(&feats);
        let f32_iv = d.replica_model(1).extract_serial(&feats);
        let scale = 1.0 + f64_iv.iter().map(|x| x.abs()).fold(0.0, f64::max);
        for (x, y) in f64_iv.iter().zip(&f32_iv) {
            assert!((x - y).abs() < 5e-3 * scale, "{x} vs {y}");
        }
        for k in 0..2 {
            d.extract(&traffic.utterance(0, k)).unwrap();
        }
        let m = d.metrics();
        assert_eq!(m.replicas[0].precision, AlignPrecision::F64);
        assert_eq!(m.replicas[1].precision, AlignPrecision::F32);
        assert_eq!(m.replicas[0].engine.batched_requests, 1);
        assert_eq!(m.replicas[1].engine.batched_requests, 1);

        // overrides survive a rolling swap (the rebuild reapplies them)
        d.swap_bundle(bundle).unwrap();
        assert_eq!(d.replica_model(0).precision(), AlignPrecision::F64);
        assert_eq!(d.replica_model(1).precision(), AlignPrecision::F32);
    }

    #[test]
    fn drained_cluster_rejects_with_typed_shutdown() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 7);
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::LeastDepth),
        )
        .unwrap();
        d.extract(&traffic.utterance(0, 0)).unwrap();
        assert!(d.drain(Duration::from_secs(10)), "all replicas must join");
        let err = d.extract(&traffic.utterance(0, 1)).unwrap_err();
        let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(typed, ServeError::ShuttingDown), "{typed:?}");
        // the retriable rejection ran out of replicas, not silently
        assert_eq!(d.metrics().exhausted, 1);
        // drained is terminal: a later swap must not resurrect workers
        let err = d.swap_bundle(shared_test_bundle().clone()).unwrap_err();
        assert!(err.to_string().contains("drained"), "{err}");
        assert_eq!(d.metrics().swaps, 0);
    }

    /// Durable cluster: every replica shares one [`DurableRegistry`]
    /// handle, so enrollments routed to *different* replicas land in
    /// the same WAL — and all of them survive a full cluster teardown
    /// and reopen, after which a fresh cluster serves the recovered
    /// profiles verbatim.
    #[test]
    fn cluster_on_durable_registry_survives_reopen() {
        use crate::config::WalSync;
        use crate::serve::registry::MemStorage;
        use crate::serve::{DurableRegistry, DurableRegistryOptions};

        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 77);
        let store = MemStorage::new();
        let dopts = DurableRegistryOptions {
            shards: 4,
            wal: true,
            sync: WalSync::Always,
            compact_every: 0,
        };
        let durable =
            DurableRegistry::with_storage(Box::new(store.clone()), &dopts).unwrap();
        let d = Dispatcher::with_registry(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::RoundRobin),
            durable.handle(),
        )
        .unwrap();

        // round robin spreads the four enrollments over both replicas;
        // the shared WAL records all of them regardless of the route
        let mut want = Vec::new();
        for spk in 0..4 {
            let id = traffic.speaker_id(spk);
            d.enroll(&id, &traffic.utterance(spk, 0)).unwrap();
            want.push((id.clone(), d.registry().profile(&id).unwrap()));
        }
        let m = d.metrics();
        assert_eq!(m.durability.wal_appends, 4, "one WAL record per enrollment");
        assert_eq!(m.durability.wal_synced, 4, "sync policy is `always`");
        assert!(
            m.replicas[0].engine.batched_requests > 0
                && m.replicas[1].engine.batched_requests > 0,
            "both replicas must have routed enrollments into the one WAL"
        );
        assert!(d.drain(Duration::from_secs(10)));
        drop(d);
        drop(durable);

        // "process restart": recover from the shared storage alone,
        // then serve the recovered profiles from a brand-new cluster
        let back = DurableRegistry::with_storage(Box::new(store.clone()), &dopts).unwrap();
        assert_eq!(back.recovery().replayed, 4);
        for (id, profile) in &want {
            assert_eq!(back.profile(id).as_ref(), Some(profile), "{id}");
        }
        let d2 = Dispatcher::with_registry(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::RoundRobin),
            back.handle(),
        )
        .unwrap();
        let outcome = d2.verify(&want[0].0, &traffic.utterance(0, 0)).unwrap();
        assert!(outcome.score.is_finite());
        assert_eq!(d2.metrics().durability.replayed, 4);
    }

    fn chunk(utt: &Mat, lo: usize, hi: usize) -> Mat {
        Mat::from_fn(hi - lo, utt.cols(), |t, j| utt.get(lo + t, j))
    }

    /// Satellite acceptance: session affinity pins a streaming session
    /// to its opening replica across interleaved one-shot traffic (the
    /// chunked score still matches the serial oracle exactly), a
    /// rolling swap closes pinned sessions *typed* — never a silent
    /// rescore on the swapped-in engine — and no enrollment is lost.
    #[test]
    fn session_affinity_pins_replica_and_swap_closes_typed() {
        let cfg = tiny_serve_config();
        let bundle = shared_test_bundle().clone();
        let oracle = ServeModel::new(bundle.clone());
        let traffic = tiny_traffic(&cfg, 2, 41);
        let d = Dispatcher::new(
            bundle.clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::RoundRobin),
        )
        .unwrap();
        let spk = traffic.speaker_id(0);
        let enroll_utts = 2usize;
        for k in 0..enroll_utts {
            d.enroll(&spk, &traffic.utterance(0, k as u64)).unwrap();
        }

        let s1 = d.session_open(&spk).unwrap();
        let s2 = d.session_open(&spk).unwrap();
        assert_eq!(d.live_sessions(), 2);

        // feed s1 the whole probe utterance in small chunks, with
        // one-shot extractions interleaved so the round-robin router
        // keeps cycling replicas — affinity must not care
        let utt = traffic.utterance(0, 100);
        let mut lo = 0;
        while lo < utt.rows() {
            let hi = (lo + 17).min(utt.rows());
            let out = d.session_feed(s1, &chunk(&utt, lo, hi)).unwrap();
            assert!(matches!(out, FeedOutcome::Pending { .. }), "{out:?}");
            d.extract(&traffic.utterance(1, lo as u64)).unwrap();
            lo = hi;
        }
        let interim = d.session_score(s1).unwrap();
        let closed = d.session_close(s1).unwrap();

        // chunked-session score == one-shot oracle on the same frames
        let mut sum = vec![0.0; oracle.rank()];
        for k in 0..enroll_utts {
            let iv = oracle.extract_serial(&traffic.utterance(0, k as u64));
            for (s, x) in sum.iter_mut().zip(&iv) {
                *s += x;
            }
        }
        let mean: Vec<f64> = sum.iter().map(|&x| x / enroll_utts as f64).collect();
        let want = oracle.score(&mean, &oracle.extract_serial(&utt));
        for (label, got) in [("interim", interim.score), ("close", closed.score)] {
            assert!(
                (got - want).abs() <= 1e-10 * (1.0 + want.abs()),
                "{label}: {got} vs oracle {want}"
            );
        }
        // the closed session is gone cluster-wide, typed on re-touch
        let err = d.session_close(s1).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionNotFound)),
            "{err}"
        );

        // the rolling swap retires s2's pinned engine: even a
        // value-identical bundle cannot save it — the partial stats
        // died with the engine — so the next touch is typed, the entry
        // is reaped, and a later touch says NotFound
        d.swap_bundle(bundle.clone()).unwrap();
        let err = d.session_feed(s2, &chunk(&utt, 0, 17)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionSwapped)),
            "{err}"
        );
        let err = d.session_score(s2).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionNotFound)),
            "{err}"
        );
        let m = d.metrics();
        assert_eq!(m.sessions_opened, 2);
        assert_eq!(m.sessions_closed_by_swap, 1);
        assert_eq!(m.swaps, 1);
        assert_eq!(d.live_sessions(), 0);

        // zero lost enrollments, and fresh sessions open on the new
        // engines and score identically (fingerprints match)
        assert_eq!(d.registry().profile(&spk).unwrap().count, enroll_utts as u64);
        let s3 = d.session_open(&spk).unwrap();
        let mut lo = 0;
        while lo < utt.rows() {
            let hi = (lo + 29).min(utt.rows());
            d.session_feed(s3, &chunk(&utt, lo, hi)).unwrap();
            lo = hi;
        }
        let rescored = d.session_close(s3).unwrap();
        assert!(
            (rescored.score - want).abs() <= 1e-10 * (1.0 + want.abs()),
            "{} vs oracle {want}",
            rescored.score
        );
        assert_eq!(d.metrics().sessions_opened, 3);
    }

    #[test]
    fn swap_rejects_mismatched_bundle_and_keeps_serving() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 23);
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::LeastDepth),
        )
        .unwrap();
        let mut bad = shared_test_bundle().clone();
        bad.backend.centering.mean.push(0.0); // backend now expects rank+1
        let err = d.swap_bundle(bad).unwrap_err();
        assert!(err.to_string().contains("different extractor"), "{err}");
        // no replica was touched: zero swaps, everyone admitting, serving
        let m = d.metrics();
        assert_eq!(m.swaps, 0);
        assert!(m.replicas.iter().all(|r| r.admitting));
        d.extract(&traffic.utterance(0, 0)).unwrap();
    }

    /// A health config tuned for tests: tight fault budget, short
    /// cooldown, a window long enough that nothing expires mid-test.
    fn test_health(fault_budget: u64, cooldown_ms: u64) -> crate::config::HealthConfig {
        crate::config::HealthConfig {
            enabled: true,
            window_ms: 60_000,
            fault_budget,
            shed_budget: 1_000_000,
            cooldown_ms,
            probe_frames: 16,
        }
    }

    /// Satellite acceptance: a panicked batch (typed `WorkerFailed`)
    /// fails a *stateless* request over to the healthy replica instead
    /// of surfacing to the caller, is charged to the faulty replica,
    /// and — one panic being under budget — does not quarantine it.
    #[test]
    fn worker_failure_fails_over_statelessly() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 61);
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve_opts(),
            &cluster_opts(2, RoutePolicy::LeastDepth),
        )
        .unwrap();
        // least-depth on an idle cluster deterministically picks
        // replica 0 first; its next batch is scripted to panic
        d.panic_replica(0, 1);
        let got = d.extract(&traffic.utterance(0, 0)).unwrap();
        let want = d.replica_model(1).extract_serial(&traffic.utterance(0, 0));
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "{g} vs {w}");
        }
        let m = d.metrics();
        assert_eq!(m.routed, 1);
        assert_eq!(m.failovers, 1, "the panicked attempt must have been retried");
        assert_eq!(m.exhausted, 0);
        assert_eq!(m.replicas[0].engine.worker_panics, 1);
        // one fault is far under the default budget: still routable
        d.tick();
        assert_eq!(d.health_state(0), HealthState::Healthy);
    }

    /// Tentpole acceptance: the full breaker cycle. A replica whose
    /// batches keep panicking exhausts its error budget, is
    /// quarantined off the routing set, gets its engine rebuilt by the
    /// supervisor tick, and after the cooldown a canary probe restores
    /// it — all while the healthy replica keeps every request whole.
    #[test]
    fn quarantine_rebuild_probe_cycle_restores_a_panicking_replica() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 67);
        let mut cluster = cluster_opts(2, RoutePolicy::LeastDepth);
        cluster.health = test_health(3, 40);
        let d =
            Dispatcher::new(shared_test_bundle().clone(), &serve_opts(), &cluster).unwrap();

        // every batch on replica 0 panics for the next 8 dispatches;
        // each request sheds typed WorkerFailed there and is rescued
        d.panic_replica(0, 8);
        for k in 0..4u64 {
            d.extract(&traffic.utterance(0, k)).unwrap();
        }
        let m = d.metrics();
        assert_eq!(m.failovers, 4);
        assert_eq!(m.exhausted, 0);

        // the supervisor notices: budget blown → quarantine + rebuild
        // in one tick (the breaker opens and the engine is replaced)
        d.tick();
        assert_eq!(d.health_state(0), HealthState::Quarantined);
        let m = d.metrics();
        assert_eq!(m.quarantines, 1);
        assert_eq!(m.self_heals, 1, "the rebuild runs in the same tick");
        assert_eq!(m.replicas[0].health, HealthState::Quarantined);
        // the rebuilt engine starts with zeroed counters
        assert_eq!(m.replicas[0].engine.worker_panics, 0);

        // during cooldown the replica stays out of the routing set:
        // requests all land on replica 1
        let routed_before = d.metrics().replicas[1].engine.batched_requests;
        for k in 0..3u64 {
            d.extract(&traffic.utterance(0, 10 + k)).unwrap();
        }
        let m = d.metrics();
        assert_eq!(m.replicas[1].engine.batched_requests, routed_before + 3);
        assert_eq!(m.failovers, 4, "no new failovers: the router skipped the quarantine");

        // cooldown expires → half-open: one canary probe through the
        // fresh engine's full batch path restores the replica
        std::thread::sleep(Duration::from_millis(60));
        d.tick();
        assert_eq!(d.health_state(0), HealthState::Healthy);
        let m = d.metrics();
        assert_eq!(m.probes, 1);
        assert_eq!(m.quarantines, 1, "one incident, counted once");

        // and it serves again, bit-exactly
        let feats = traffic.utterance(0, 50);
        let got = d.extract(&feats).unwrap();
        let want = d.replica_model(0).extract_serial(&feats);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    /// Escape hatch: a cluster whose *every* replica is quarantined
    /// still answers — typed — rather than deadlocking on an empty
    /// routing pool. (Single replica: quarantined, mid-cooldown.)
    #[test]
    fn fully_quarantined_cluster_still_answers() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 71);
        let mut cluster = cluster_opts(1, RoutePolicy::LeastDepth);
        // cooldown far past the test: the replica stays quarantined
        cluster.health = test_health(2, 600_000);
        let d =
            Dispatcher::new(shared_test_bundle().clone(), &serve_opts(), &cluster).unwrap();

        d.panic_replica(0, 3);
        for k in 0..3u64 {
            // sole replica: the typed WorkerFailed propagates (the
            // failover loop has nowhere to go and reports exhausted)
            let err = d.extract(&traffic.utterance(0, k)).unwrap_err();
            assert!(
                matches!(err.downcast_ref::<ServeError>(), Some(ServeError::WorkerFailed)),
                "{err}"
            );
        }
        assert_eq!(d.metrics().exhausted, 3);
        d.tick();
        assert_eq!(d.health_state(0), HealthState::Quarantined);
        assert_eq!(d.metrics().self_heals, 1);

        // quarantined — but it is the last replica standing, so the
        // escape hatch still routes to it; the rebuilt engine answers
        d.extract(&traffic.utterance(0, 9)).unwrap();
        assert_eq!(d.health_state(0), HealthState::Quarantined, "no probe ran: mid-cooldown");
    }

    /// Satellite acceptance: the supervisor tick sweeps idle streaming
    /// sessions, so eviction happens on the heartbeat — not only
    /// lazily when some later touch happens to collide.
    #[test]
    fn tick_sweeps_idle_sessions() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 73);
        let mut serve = serve_opts();
        serve.session.idle_ms = 25;
        let d = Dispatcher::new(
            shared_test_bundle().clone(),
            &serve,
            &cluster_opts(2, RoutePolicy::RoundRobin),
        )
        .unwrap();
        let spk = traffic.speaker_id(0);
        d.enroll(&spk, &traffic.utterance(0, 0)).unwrap();
        let sid = d.session_open(&spk).unwrap();

        std::thread::sleep(Duration::from_millis(40));
        d.tick();

        // the engine-side session is gone before any touch: the next
        // op comes back typed Expired (not a stale partial score)
        let err = d.session_score(sid).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionExpired)),
            "{err}"
        );
        assert_eq!(d.live_sessions(), 0, "the dead entry was reaped on touch");
    }
}
