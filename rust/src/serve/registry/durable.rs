//! The durable layer: WAL-state bookkeeping ([`Durability`]), recovery
//! ([`DurableRegistry::open`] = snapshot + replay-only-newer), and the
//! crash-guarantee test suite the fault injector drives.
//!
//! The contract callers get from a [`DurableRegistry`] handle:
//!
//! * an `Ok` from `enroll`/`remove` means the mutation's WAL record
//!   reached storage under the configured sync policy **before** the
//!   in-memory shards changed — acknowledged mutations survive a crash;
//! * an `Err` means the registry (memory *and* log) is unchanged: a
//!   failed append or fsync rolls the partial record back out of the
//!   file, and if even that repair fails the durable path poisons
//!   itself and refuses further mutations rather than risk mid-log
//!   garbage;
//! * recovery tolerates exactly the damage a crash can cause (a torn
//!   final record — counted, truncated, replay continues) and refuses
//!   everything a crash cannot (mid-log corruption is a typed error).

use std::fmt;
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{RegistryConfig, WalSync};
use crate::obs::{self, Counter, ObsRegistry, Stage};

use super::storage::{FileStorage, RegistryStorage};
use super::wal::{self, WalOp, WalRecord};
use super::{DurabilityMetrics, Registry, RegistryStoreError};

/// How a [`DurableRegistry`] opens: shard count plus the `[registry]`
/// durability knobs.
#[derive(Debug, Clone)]
pub struct DurableRegistryOptions {
    /// Lock shards for the in-memory map (mirrors `[serve] registry_shards`).
    pub shards: usize,
    /// Write-ahead log mutations. With `false`, durability is
    /// snapshot-only: compaction still runs on the mutation counter,
    /// but anything after the last snapshot dies with the process.
    pub wal: bool,
    /// WAL fsync policy.
    pub sync: WalSync,
    /// Compact the WAL into a snapshot after this many records
    /// (0 = never compact automatically).
    pub compact_every: u64,
}

impl Default for DurableRegistryOptions {
    fn default() -> Self {
        Self { shards: 16, wal: true, sync: WalSync::Always, compact_every: 10_000 }
    }
}

impl DurableRegistryOptions {
    /// Build from the `[registry]` config section plus the `[serve]`
    /// shard count.
    pub fn from_config(cfg: &RegistryConfig, shards: usize) -> Self {
        Self { shards, wal: cfg.wal, sync: cfg.sync, compact_every: cfg.compact_every }
    }
}

/// Mutable WAL bookkeeping, guarded by the one durable-mutation lock.
pub(super) struct WalState {
    /// Sequence number the next record will carry (seqs start at 1).
    pub(super) next_seq: u64,
    /// Bytes of valid, applied log — the rollback point for a failed
    /// append.
    pub(super) wal_len: u64,
    /// Appended records not yet fsynced (the every-N policy's counter).
    pub(super) unsynced: u64,
    /// Mutations since the last compaction (includes records replayed
    /// from an existing WAL at open, so the file length still bounds
    /// recovery time).
    pub(super) since_compact: u64,
    /// Set when a failed append/fsync could not be truncated back out;
    /// every later durable mutation fails fast with
    /// [`RegistryStoreError::WalPoisoned`].
    pub(super) poisoned: bool,
}

/// The storage attachment of a durable registry: backend + policy +
/// counters. Shared via `Arc` so `Registry` clones of the handle see
/// one WAL.
pub(super) struct Durability {
    pub(super) storage: Box<dyn RegistryStorage>,
    pub(super) wal_enabled: bool,
    pub(super) sync: WalSync,
    pub(super) compact_every: u64,
    state: Mutex<WalState>,
    /// When an [`ObsRegistry`] is attached the counters below are its
    /// canonical `registry_*_total` series (cumulative across reopens
    /// that share the registry); otherwise they are standalone and
    /// zeroed per open, preserving the historical
    /// [`DurabilityMetrics`] semantics.
    obs: Option<Arc<ObsRegistry>>,
    pub(super) wal_appends: Counter,
    pub(super) wal_synced: Counter,
    pub(super) compactions: Counter,
    replayed: Counter,
    torn_tail: Counter,
}

impl fmt::Debug for Durability {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Durability")
            .field("storage", &self.storage.describe())
            .field("wal_enabled", &self.wal_enabled)
            .field("sync", &self.sync)
            .field("appends", &self.wal_appends.get())
            .finish()
    }
}

impl Durability {
    /// Poison-tolerant state lock, same policy as the shard locks.
    pub(super) fn lock_state(&self) -> MutexGuard<'_, WalState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub(super) fn metrics(&self) -> DurabilityMetrics {
        DurabilityMetrics {
            wal_enabled: self.wal_enabled,
            wal_appends: self.wal_appends.get(),
            wal_synced: self.wal_synced.get(),
            compactions: self.compactions.get(),
            replayed: self.replayed.get(),
            torn_tail: self.torn_tail.get(),
        }
    }

    /// Attribute `ns` of WAL work to the per-stage histograms and the
    /// in-flight request trace (an enrollment routed through an engine
    /// carries one). Timing only — this must never touch the storage
    /// trait, because the fault-injection suite addresses storage
    /// operations by absolute index.
    fn observe_stage(&self, stage: Stage, ns: u64) {
        if let Some(o) = &self.obs {
            o.observe_stage_ns(stage, ns);
        }
        obs::add_current_stage(stage, ns);
    }

    /// Append `rec` to the WAL and make it as durable as the sync
    /// policy promises. On any failure the file is restored to the
    /// last known-good length (or the path is poisoned), so an `Err`
    /// always means "nothing changed".
    pub(super) fn log(&self, st: &mut WalState, rec: &WalRecord) -> Result<()> {
        debug_assert_eq!(rec.seq, st.next_seq);
        if !self.wal_enabled {
            // snapshot-only mode: no record, but the sequence still
            // advances so compacted snapshots stay ordered
            st.next_seq += 1;
            return Ok(());
        }
        if st.poisoned {
            return Err(RegistryStoreError::WalPoisoned.into());
        }
        let buf = wal::encode_record(rec);
        let append_t0 = Instant::now();
        let appended = self.storage.append_wal(&buf);
        self.observe_stage(Stage::WalAppend, append_t0.elapsed().as_nanos() as u64);
        if let Err(e) = appended {
            // a partial append would sit as garbage in front of later
            // records and turn a torn *tail* into mid-log corruption —
            // cut the file back to the last known-good byte
            if self.storage.truncate_wal(st.wal_len).is_err() {
                st.poisoned = true;
            }
            return Err(e.context("registry WAL append failed — the mutation was not applied"));
        }
        st.wal_len += buf.len() as u64;
        st.unsynced += 1;
        self.wal_appends.inc();
        let must_sync = match self.sync {
            WalSync::Always => true,
            WalSync::EveryN(n) => st.unsynced >= n,
        };
        if must_sync {
            let sync_t0 = Instant::now();
            let synced = self.storage.sync_wal();
            self.observe_stage(Stage::WalFsync, sync_t0.elapsed().as_nanos() as u64);
            if let Err(e) = synced {
                // durability cannot be promised: roll the record back
                // out so the acked prefix stays exactly the synced one
                st.wal_len -= buf.len() as u64;
                st.unsynced -= 1;
                if self.storage.truncate_wal(st.wal_len).is_err() {
                    st.poisoned = true;
                }
                return Err(
                    e.context("registry WAL fsync failed — the mutation was not applied")
                );
            }
            self.wal_synced.inc();
            st.unsynced = 0;
        }
        st.next_seq += 1;
        Ok(())
    }
}

/// What recovery found when the registry was opened.
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// A snapshot existed and loaded.
    pub snapshot_loaded: bool,
    /// Last WAL sequence the snapshot covers (0 when none/legacy).
    pub snapshot_seq: u64,
    /// WAL records applied on top of the snapshot.
    pub replayed: u64,
    /// WAL records skipped as already covered by the snapshot.
    pub skipped: u64,
    /// A torn final record was found (tolerated and truncated).
    pub torn_tail: bool,
    /// Speakers enrolled after recovery.
    pub speakers: usize,
    /// Total enrollment utterances after recovery.
    pub enrollments: u64,
    /// Wall-clock recovery time.
    pub wall_s: f64,
}

/// A [`Registry`] with storage attached: opening one **is** recovery.
/// `Deref`s to [`Registry`], and [`DurableRegistry::handle`] yields the
/// `Arc<Registry>` the engine/cluster constructors take — every replica
/// sharing the handle shares the one WAL.
pub struct DurableRegistry {
    inner: Arc<Registry>,
    report: RecoveryReport,
}

impl DurableRegistry {
    /// Open (or create) the durable registry in `dir` with the real
    /// file backend, running recovery if state exists.
    pub fn open(dir: impl AsRef<Path>, opts: &DurableRegistryOptions) -> Result<Self> {
        Self::with_storage(Box::new(FileStorage::open(dir)?), opts)
    }

    /// [`DurableRegistry::open`] with an [`ObsRegistry`] attached: the
    /// durability counters become its canonical `registry_*_total`
    /// series and WAL append/fsync latencies feed the per-stage
    /// histograms and in-flight request traces.
    pub fn open_obs(
        dir: impl AsRef<Path>,
        opts: &DurableRegistryOptions,
        obs: Option<Arc<ObsRegistry>>,
    ) -> Result<Self> {
        Self::with_storage_obs(Box::new(FileStorage::open(dir)?), opts, obs)
    }

    /// Open on any storage backend (the fault-injection suite and the
    /// recovery bench pass [`super::MemStorage`] / [`super::FaultInjector`]).
    ///
    /// Recovery = load the snapshot (if any), replay WAL records with
    /// seq beyond the snapshot's, tolerate-and-truncate a torn tail,
    /// and refuse mid-log corruption with a typed error.
    pub fn with_storage(
        storage: Box<dyn RegistryStorage>,
        opts: &DurableRegistryOptions,
    ) -> Result<Self> {
        Self::with_storage_obs(storage, opts, None)
    }

    /// [`DurableRegistry::with_storage`] with an optional
    /// [`ObsRegistry`] (see [`DurableRegistry::open_obs`]).
    pub fn with_storage_obs(
        storage: Box<dyn RegistryStorage>,
        opts: &DurableRegistryOptions,
        obs: Option<Arc<ObsRegistry>>,
    ) -> Result<Self> {
        let t0 = Instant::now();
        let place = storage.describe();
        let (reg, snapshot_seq, snapshot_loaded) = match storage
            .read_snapshot()
            .with_context(|| format!("read registry snapshot ({place})"))?
        {
            Some(bytes) => {
                let (reg, seq) = Registry::decode_snapshot(&bytes, opts.shards)
                    .with_context(|| format!("registry snapshot ({place})"))?;
                (reg, seq, true)
            }
            None => (Registry::new(opts.shards), 0, false),
        };
        let wal_bytes =
            storage.read_wal().with_context(|| format!("read registry WAL ({place})"))?;
        let rep = wal::replay(&wal_bytes).with_context(|| format!("registry WAL ({place})"))?;
        let mut replayed = 0u64;
        let mut skipped = 0u64;
        for rec in &rep.records {
            if rec.seq <= snapshot_seq {
                skipped += 1; // the snapshot already covers it
                continue;
            }
            match &rec.op {
                WalOp::Enroll { speaker, model_fp, ivector } => {
                    reg.enroll_mem(speaker, ivector, *model_fp).with_context(|| {
                        format!("replay WAL record seq {} ({place})", rec.seq)
                    })?;
                }
                WalOp::Remove { speaker } => {
                    reg.remove_mem(speaker);
                }
            }
            replayed += 1;
        }
        // repair the file so appends resume on a clean prefix: chop any
        // torn tail, and (re)write the header when even it was torn
        let mut wal_len = rep.valid_len;
        if (wal_bytes.len() as u64) > rep.valid_len {
            storage
                .truncate_wal(rep.valid_len)
                .with_context(|| format!("truncate torn WAL tail ({place})"))?;
        }
        if opts.wal && wal_len < wal::HEADER_LEN {
            storage
                .append_wal(&wal::header())
                .and_then(|()| storage.sync_wal())
                .with_context(|| format!("initialize WAL header ({place})"))?;
            wal_len = wal::HEADER_LEN;
        }
        // with an obs registry the counters are the shared canonical
        // series; standalone counters keep per-open semantics otherwise
        let counter = |name: &'static str| match &obs {
            Some(o) => o.counter(name, &[]),
            None => Counter::default(),
        };
        let wal_appends = counter("registry_wal_appends_total");
        let wal_synced = counter("registry_wal_synced_total");
        let compactions = counter("registry_compactions_total");
        let replayed_counter = counter("registry_replayed_total");
        replayed_counter.add(replayed);
        let torn_counter = counter("registry_torn_tail_total");
        torn_counter.add(u64::from(rep.torn_tail));
        drop(counter);
        let durability = Durability {
            storage,
            wal_enabled: opts.wal,
            sync: opts.sync,
            compact_every: opts.compact_every,
            state: Mutex::new(WalState {
                next_seq: rep.last_seq.max(snapshot_seq) + 1,
                wal_len,
                unsynced: 0,
                since_compact: rep.records.len() as u64,
                poisoned: false,
            }),
            wal_appends,
            wal_synced,
            compactions,
            replayed: replayed_counter,
            torn_tail: torn_counter,
            obs,
        };
        let inner = Arc::new(reg.with_durability(Arc::new(durability)));
        let report = RecoveryReport {
            snapshot_loaded,
            snapshot_seq,
            replayed,
            skipped,
            torn_tail: rep.torn_tail,
            speakers: inner.len(),
            enrollments: inner.total_enrollments(),
            wall_s: t0.elapsed().as_secs_f64(),
        };
        Ok(Self { inner, report })
    }

    /// The shared handle engines and dispatchers take.
    pub fn handle(&self) -> Arc<Registry> {
        Arc::clone(&self.inner)
    }

    /// What recovery found at open.
    pub fn recovery(&self) -> &RecoveryReport {
        &self.report
    }

    /// Compact the WAL into a fresh snapshot now, regardless of the
    /// threshold.
    pub fn compact(&self) -> Result<()> {
        self.inner.force_compact()
    }

    /// End degraded read-only mode after a WAL poisoning, without
    /// tearing the registry (or the engines holding its handle) down:
    /// rebuild durable storage from the intact in-memory profiles —
    /// snapshot every shard, truncate the WAL, clear the poison flag.
    /// `Ok` when the registry is healthy again (no-op if it never
    /// degraded); `Err` when storage is still failing, in which case
    /// the registry stays degraded (verifies serve, mutations fail
    /// typed [`RegistryStoreError::WalPoisoned`]) and the call is safe
    /// to retry. Nothing enrolled before the poisoning — and nothing
    /// *acked* during it, since degraded mode acks no mutation — can
    /// be lost: the snapshot is cut from the same in-memory state that
    /// served reads throughout.
    pub fn reopen(&self) -> Result<()> {
        self.inner.repair()
    }
}

impl std::ops::Deref for DurableRegistry {
    type Target = Registry;

    fn deref(&self) -> &Registry {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::super::storage::{Fault, FaultInjector, MemStorage};
    use super::super::SpeakerProfile;
    use super::*;

    const FP: u64 = 11;

    fn opts(compact_every: u64) -> DurableRegistryOptions {
        DurableRegistryOptions { shards: 4, wal: true, sync: WalSync::Always, compact_every }
    }

    fn open_mem(store: &MemStorage, o: &DurableRegistryOptions) -> Result<DurableRegistry> {
        DurableRegistry::with_storage(Box::new(store.clone()), o)
    }

    #[test]
    fn mutations_survive_reopen_via_wal_replay_alone() {
        let store = MemStorage::new();
        let o = opts(0); // never compact: everything rides the WAL
        let reg = open_mem(&store, &o).unwrap();
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        reg.enroll("alice", &[3.0, 4.0], FP).unwrap();
        reg.enroll("bob", &[9.0, -1.0], FP).unwrap();
        assert!(reg.remove("bob").unwrap());
        // removing an absent speaker consumes no WAL record
        assert!(!reg.remove("ghost").unwrap());
        let m = reg.durability_metrics();
        assert!(m.wal_enabled);
        assert_eq!(m.wal_appends, 4);
        assert_eq!(m.wal_synced, 4, "sync=always fsyncs every record");
        drop(reg);

        let back = open_mem(&store, &o).unwrap();
        let r = back.recovery();
        assert!(!r.snapshot_loaded);
        assert_eq!(r.replayed, 4);
        assert!(!r.torn_tail);
        assert_eq!(back.len(), 1);
        assert_eq!(
            back.profile("alice").unwrap(),
            SpeakerProfile { count: 2, sum: vec![4.0, 6.0], model_fp: FP }
        );
        assert!(back.profile("bob").is_none());
    }

    #[test]
    fn compaction_threshold_snapshots_and_truncates_the_wal() {
        let store = MemStorage::new();
        let o = opts(10);
        let reg = open_mem(&store, &o).unwrap();
        for i in 0..25 {
            reg.enroll(&format!("spk{i:02}"), &[i as f64], FP).unwrap();
        }
        let m = reg.durability_metrics();
        assert_eq!(m.compactions, 2, "25 mutations at threshold 10");
        assert!(store.snapshot_bytes().is_some());
        // the WAL holds only the 5 post-compaction records
        let live = wal::replay(&store.wal_bytes()).unwrap();
        assert_eq!(live.records.len(), 5);
        drop(reg);

        let back = open_mem(&store, &o).unwrap();
        let r = back.recovery();
        assert!(r.snapshot_loaded);
        assert_eq!(r.snapshot_seq, 20);
        assert_eq!(r.replayed, 5);
        assert_eq!(r.skipped, 0);
        assert_eq!(back.len(), 25);
        for i in 0..25 {
            assert_eq!(back.profile(&format!("spk{i:02}")).unwrap().sum, vec![i as f64]);
        }
    }

    #[test]
    fn explicit_compact_then_crash_between_swap_and_truncate_is_safe() {
        // compaction wrote the snapshot but "crashed" before the WAL
        // truncate: recovery must skip the already-covered records
        // instead of double-applying them
        let store = MemStorage::new();
        let o = opts(0);
        let reg = open_mem(&store, &o).unwrap();
        reg.enroll("a", &[1.0], FP).unwrap();
        reg.enroll("a", &[2.0], FP).unwrap();
        reg.compact().unwrap();
        assert_eq!(reg.durability_metrics().compactions, 1);
        drop(reg);
        // resurrect the pre-truncate WAL: replace it with records 1..=2
        // as if the truncate never happened
        let mut bytes = wal::header();
        for (seq, x) in [(1u64, 1.0f64), (2, 2.0)] {
            bytes.extend_from_slice(&wal::encode_record(&WalRecord {
                seq,
                op: WalOp::Enroll { speaker: "a".into(), model_fp: FP, ivector: vec![x] },
            }));
        }
        let resurrected = MemStorage::seeded(bytes, store.snapshot_bytes());
        let back = open_mem(&resurrected, &o).unwrap();
        let r = back.recovery();
        assert_eq!(r.snapshot_seq, 2);
        assert_eq!(r.skipped, 2, "snapshot-covered records must not replay");
        assert_eq!(r.replayed, 0);
        let p = back.profile("a").unwrap();
        assert_eq!(p.count, 2);
        assert_eq!(p.sum, vec![3.0], "double-applied records would make this 6.0");
    }

    /// The headline tentpole guarantee, end to end: enrollments
    /// acknowledged before an injected crash are all present after
    /// recovery, the torn tail is tolerated and counted, and the dead
    /// path fails fast instead of lying.
    #[test]
    fn acked_enrollments_all_survive_an_injected_crash() {
        let store = MemStorage::new();
        let o = opts(25);
        // append 0 is the WAL header; enrollment k is append k+1. Crash
        // on the 42nd enrollment, persisting 7 bytes of its record.
        let injected = FaultInjector::new(Box::new(store.clone())).crash_at_append(42, 7);
        let reg = DurableRegistry::with_storage(Box::new(injected), &o).unwrap();
        let mut acked: Vec<String> = Vec::new();
        let mut failed = None;
        for i in 0..200 {
            let id = format!("spk{i:03}");
            match reg.enroll(&id, &[i as f64, 0.5], FP) {
                Ok(_) => acked.push(id),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        }
        let failed = failed.expect("the injected crash must fire");
        assert!(failed.to_string().contains("not applied"), "{failed}");
        assert_eq!(acked.len(), 41, "41 enrollments acked before the crash");
        // the unacked enrollment did not half-apply to memory either
        assert_eq!(reg.len(), 41);
        // after the crash the durable path fails fast, never a silent ack
        assert!(reg.enroll("late", &[1.0, 1.0], FP).is_err());
        drop(reg);

        // recovery on a fresh handle over what the dead process persisted
        let back = open_mem(&store, &o).unwrap();
        let r = back.recovery();
        assert!(r.torn_tail, "the 7-byte partial record is a torn tail");
        assert_eq!(back.durability_metrics().torn_tail, 1);
        assert!(r.snapshot_loaded, "compaction ran at enrollment 25");
        assert_eq!(r.snapshot_seq, 25);
        assert_eq!(r.replayed, 16, "seqs 26..=41 ride the WAL");
        assert_eq!(back.len(), acked.len(), "no acked enrollment lost, no phantom gained");
        for (i, id) in acked.iter().enumerate() {
            let p = back.profile(id).unwrap_or_else(|| panic!("acked `{id}` lost"));
            assert_eq!(p.sum, vec![i as f64, 0.5], "acked `{id}` has wrong state");
            assert_eq!(p.count, 1);
        }
        // and the recovered registry keeps taking durable mutations
        back.enroll("after", &[4.0, 4.0], FP).unwrap();
        assert_eq!(back.durability_metrics().wal_appends, 1);
    }

    #[test]
    fn mid_log_corruption_refuses_recovery_with_a_typed_error() {
        let store = MemStorage::new();
        let o = opts(0);
        let reg = open_mem(&store, &o).unwrap();
        for i in 0..10 {
            reg.enroll(&format!("spk{i}"), &[i as f64], FP).unwrap();
        }
        drop(reg);
        // read-side bit rot inside record 0's payload: op 0 is
        // read_snapshot, op 1 is read_wal
        let corrupted = FaultInjector::new(Box::new(store.clone()))
            .fail_op(1, Fault::CorruptRead { offset: wal::HEADER_LEN as usize + 12, xor: 0x40 });
        let err = DurableRegistry::with_storage(Box::new(corrupted), &o).unwrap_err();
        match err.downcast_ref::<RegistryStoreError>() {
            Some(RegistryStoreError::WalCorrupt { record, .. }) => assert_eq!(*record, 0),
            other => panic!("expected WalCorrupt, got {other:?}: {err:#}"),
        }
        // the same bytes read clean recover fine — the rot was read-side
        assert_eq!(open_mem(&store, &o).unwrap().len(), 10);
    }

    #[test]
    fn enospc_fails_the_caller_but_the_registry_keeps_serving() {
        let store = MemStorage::new();
        let o = opts(0);
        // ops at open: read_snapshot, read_wal, append header, sync.
        // Enrollment k is then ops 4+2k (append) and 5+2k (sync).
        let injected = FaultInjector::new(Box::new(store.clone()))
            .fail_op(6, Fault::Enospc); // the second enrollment's append
        let reg = DurableRegistry::with_storage(Box::new(injected), &o).unwrap();
        reg.enroll("a", &[1.0], FP).unwrap();
        let err = reg.enroll("b", &[2.0], FP).unwrap_err();
        assert!(err.to_string().contains("No space left"), "{err}");
        // the failed enrollment left no trace in memory
        assert!(reg.profile("b").is_none());
        // and the path is NOT poisoned: the disk "recovered", later
        // mutations flow again
        reg.enroll("c", &[3.0], FP).unwrap();
        drop(reg);
        let back = open_mem(&store, &o).unwrap();
        assert_eq!(back.speaker_ids(), vec!["a", "c"]);
        assert!(!back.recovery().torn_tail, "ENOSPC persisted nothing — no torn tail");
    }

    #[test]
    fn failed_fsync_rolls_the_record_back_out() {
        let store = MemStorage::new();
        let o = opts(0);
        let injected = FaultInjector::new(Box::new(store.clone()))
            .fail_op(5, Fault::SyncFail); // the first enrollment's fsync
        let reg = DurableRegistry::with_storage(Box::new(injected), &o).unwrap();
        let err = reg.enroll("a", &[1.0], FP).unwrap_err();
        assert!(err.to_string().contains("fsync"), "{err}");
        assert!(reg.is_empty(), "an unsynced enrollment must not be acked or applied");
        // the appended-then-unsyncable record was truncated back out
        let rep = wal::replay(&store.wal_bytes()).unwrap();
        assert!(rep.records.is_empty());
        assert!(!rep.torn_tail);
        // the path keeps working afterwards
        reg.enroll("a", &[2.0], FP).unwrap();
        drop(reg);
        assert_eq!(open_mem(&store, &o).unwrap().profile("a").unwrap().sum, vec![2.0]);
    }

    /// Satellite acceptance: degraded read-only mode. A failed append
    /// whose rollback truncate *also* fails poisons the WAL; from then
    /// on mutations fail fast with typed `WalPoisoned` while reads keep
    /// serving the intact in-memory profiles. [`DurableRegistry::reopen`]
    /// rebuilds storage from memory and clears the poison without
    /// tearing the registry down — and the post-recovery audit shows
    /// zero acked-but-lost enrollments across a real restart.
    #[test]
    fn poisoned_wal_degrades_to_read_only_and_reopen_recovers() {
        let store = MemStorage::new();
        let o = opts(0);
        // ops at open: read_snapshot, read_wal, append header, sync.
        // Enrollment k is ops 4+2k (append) and 5+2k (sync); a failed
        // append's rollback truncate is the injector's next op.
        let injected = FaultInjector::new(Box::new(store.clone()))
            .fail_op(8, Fault::Enospc) // enrollment 2's append
            .fail_op(9, Fault::Enospc); // ...and its rollback truncate
        let reg = DurableRegistry::with_storage(Box::new(injected), &o).unwrap();
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        reg.enroll("bob", &[3.0, 4.0], FP).unwrap();
        let acked = 2u64;
        assert!(!reg.is_poisoned());

        // the append fails AND the rollback fails: garbage may sit at
        // the WAL tail, so the path poisons itself
        let err = reg.enroll("carol", &[5.0, 6.0], FP).unwrap_err();
        assert!(err.to_string().contains("No space left"), "{err}");
        assert!(reg.is_poisoned(), "a failed rollback must poison the WAL");

        // degraded mode: every mutation fails fast and typed...
        for attempt in 0..2 {
            let err = reg.enroll("dave", &[7.0], FP).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<RegistryStoreError>(),
                    Some(RegistryStoreError::WalPoisoned)
                ),
                "attempt {attempt}: {err}"
            );
        }
        let err = reg.remove("alice").unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<RegistryStoreError>(),
                Some(RegistryStoreError::WalPoisoned)
            ),
            "{err}"
        );
        // ...while reads keep serving the intact in-memory state
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.profile("alice").unwrap().sum, vec![1.0, 2.0]);
        assert_eq!(reg.profile("bob").unwrap().sum, vec![3.0, 4.0]);
        assert!(reg.profile("carol").is_none(), "the failed enrollment left no trace");
        assert!(reg.profile("dave").is_none(), "degraded-mode enrolls left no trace");

        // reopen: snapshot the in-memory profiles, truncate the WAL,
        // clear the poison — mutations flow again
        reg.reopen().unwrap();
        assert!(!reg.is_poisoned());
        assert_eq!(reg.durability_metrics().compactions, 1);
        reg.enroll("carol", &[5.0, 6.0], FP).unwrap();
        assert_eq!(reg.total_enrollments(), acked + 1);
        // reopen on a healthy registry is a no-op Ok
        reg.reopen().unwrap();

        // audit across a real restart: every acked enrollment (before
        // the incident and after recovery) is durable; nothing acked
        // during degraded mode because nothing was acked at all
        drop(reg);
        let back = open_mem(&store, &o).unwrap();
        assert_eq!(back.speaker_ids(), vec!["alice", "bob", "carol"]);
        assert_eq!(
            back.total_enrollments(),
            acked + 1,
            "zero acked-but-lost enrollments after the poison/recover cycle"
        );
        assert_eq!(back.recovery().replayed, 1, "only carol rode the rebuilt WAL");
    }

    #[test]
    fn wal_truncation_sweep_through_storage_recovers_every_prefix() {
        // satellite sweep, this time through the storage/recovery stack:
        // build a real WAL, then hand recovery every possible prefix
        let store = MemStorage::new();
        let o = opts(0);
        let reg = open_mem(&store, &o).unwrap();
        let mut expect: Vec<(String, Vec<f64>)> = Vec::new();
        for i in 0..6 {
            let id = format!("spk{i}");
            let iv = vec![i as f64, -(i as f64)];
            reg.enroll(&id, &iv, FP).unwrap();
            expect.push((id, iv));
        }
        drop(reg);
        let bytes = store.wal_bytes();
        for cut in 0..=bytes.len() {
            let prefix = MemStorage::seeded(bytes[..cut].to_vec(), None);
            let back = open_mem(&prefix, &o).unwrap_or_else(|e| {
                panic!("prefix of {cut} bytes must recover, got: {e:#}")
            });
            // recovered speakers are exactly a prefix of the originals
            let n = back.len();
            assert!(n <= expect.len());
            for (id, iv) in &expect[..n] {
                let p = back
                    .profile(id)
                    .unwrap_or_else(|| panic!("cut {cut}: `{id}` missing from prefix"));
                assert_eq!(&p.sum, iv, "cut {cut}: wrong profile for `{id}`");
            }
            for (id, _) in &expect[n..] {
                assert!(back.profile(id).is_none(), "cut {cut}: phantom `{id}`");
            }
        }
    }

    #[test]
    fn wal_bitflip_sweep_through_storage_never_loads_wrong_profiles() {
        let store = MemStorage::new();
        let o = opts(0);
        let reg = open_mem(&store, &o).unwrap();
        let mut expect: Vec<(String, Vec<f64>)> = Vec::new();
        for i in 0..4 {
            let id = format!("spk{i}");
            let iv = vec![0.5 + i as f64];
            reg.enroll(&id, &iv, FP).unwrap();
            expect.push((id, iv));
        }
        drop(reg);
        let bytes = store.wal_bytes();
        // sampled offsets (every 3rd byte) via the injector's read-side
        // corruption, exercising the exact recovery entry path. Each
        // iteration gets a freshly seeded store: recovery repairs torn
        // tails in place, which must not bleed into the next flip.
        for offset in (0..bytes.len()).step_by(3) {
            let xor = 1u8 << (offset % 8);
            let seeded = MemStorage::seeded(bytes.clone(), None);
            let injected = FaultInjector::new(Box::new(seeded))
                .fail_op(1, Fault::CorruptRead { offset, xor });
            match DurableRegistry::with_storage(Box::new(injected), &o) {
                Ok(back) => {
                    // a tolerated flip may only drop a tail, never load
                    // a wrong profile or invent a speaker
                    let n = back.len();
                    assert!(n <= expect.len(), "flip at {offset}: phantom speakers");
                    for (id, iv) in &expect[..n] {
                        let p = back.profile(id).unwrap_or_else(|| {
                            panic!("flip at {offset}: `{id}` missing")
                        });
                        assert_eq!(&p.sum, iv, "flip at {offset}: wrong profile loaded");
                    }
                }
                Err(e) => {
                    assert!(
                        e.downcast_ref::<RegistryStoreError>().is_some(),
                        "flip at {offset}: untyped error {e:#}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_n_sync_policy_batches_fsyncs() {
        let store = MemStorage::new();
        let o = DurableRegistryOptions {
            shards: 2,
            wal: true,
            sync: WalSync::EveryN(4),
            compact_every: 0,
        };
        let reg = open_mem(&store, &o).unwrap();
        for i in 0..10 {
            reg.enroll(&format!("s{i}"), &[1.0], FP).unwrap();
        }
        let m = reg.durability_metrics();
        assert_eq!(m.wal_appends, 10);
        assert_eq!(m.wal_synced, 2, "10 appends at every-4 → fsyncs at 4 and 8");
    }

    #[test]
    fn snapshot_only_mode_survives_via_compaction() {
        let store = MemStorage::new();
        let o = DurableRegistryOptions {
            shards: 2,
            wal: false,
            sync: WalSync::Always,
            compact_every: 5,
        };
        let reg = open_mem(&store, &o).unwrap();
        for i in 0..12 {
            reg.enroll(&format!("s{i:02}"), &[i as f64], FP).unwrap();
        }
        let m = reg.durability_metrics();
        assert!(!m.wal_enabled);
        assert_eq!(m.wal_appends, 0, "wal=false must not append");
        assert_eq!(m.compactions, 2);
        drop(reg);
        let back = open_mem(&store, &o).unwrap();
        // mutations past the last compaction (10) died with the process
        // — the documented snapshot-only tradeoff
        assert_eq!(back.len(), 10);
        assert!(back.recovery().snapshot_loaded);
    }

    #[test]
    fn concurrent_durable_enrollments_are_not_lost() {
        let store = MemStorage::new();
        let o = opts(40);
        let reg = Arc::new(open_mem(&store, &o).unwrap());
        let threads = 4;
        let per_thread = 50;
        let mut handles = Vec::new();
        for t in 0..threads {
            let reg = Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    reg.enroll("shared", &[1.0], FP).unwrap();
                    reg.enroll(&format!("t{t}_s{i}"), &[i as f64], FP).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = (2 * threads * per_thread) as u64;
        assert_eq!(reg.total_enrollments(), total);
        drop(reg);
        let back = open_mem(&store, &o).unwrap();
        assert_eq!(back.total_enrollments(), total, "recovery must see every ack");
        assert_eq!(back.profile("shared").unwrap().count, (threads * per_thread) as u64);
    }

    #[test]
    fn obs_attachment_feeds_canonical_counters_and_wal_stages() {
        let store = MemStorage::new();
        let o = opts(0);
        let obs = Arc::new(ObsRegistry::default());
        let reg =
            DurableRegistry::with_storage_obs(Box::new(store.clone()), &o, Some(Arc::clone(&obs)))
                .unwrap();
        reg.enroll("alice", &[1.0], FP).unwrap();
        reg.enroll("bob", &[2.0], FP).unwrap();
        assert_eq!(obs.counter("registry_wal_appends_total", &[]).get(), 2);
        assert_eq!(obs.counter("registry_wal_synced_total", &[]).get(), 2);
        let stages: std::collections::HashMap<_, _> =
            obs.stage_summaries().into_iter().collect();
        assert_eq!(stages["wal_append"].count, 2);
        assert_eq!(stages["wal_fsync"].count, 2, "sync=always times every fsync");
        assert_eq!(stages["align"].count, 0, "serving stages stay untouched");
        drop(reg);

        // reopening against the same obs registry accumulates onto the
        // one canonical series instead of minting a duplicate
        let back =
            DurableRegistry::with_storage_obs(Box::new(store.clone()), &o, Some(Arc::clone(&obs)))
                .unwrap();
        assert_eq!(obs.counter("registry_replayed_total", &[]).get(), 2);
        assert_eq!(back.durability_metrics().replayed, 2);
        assert_eq!(back.len(), 2);
    }

    #[test]
    fn recover_on_file_storage_round_trips() {
        // the same contract on the real backend
        let dir = std::env::temp_dir().join("ivtv_registry_durable_file_test");
        let _ = std::fs::remove_dir_all(&dir);
        let o = opts(3);
        let reg = DurableRegistry::open(&dir, &o).unwrap();
        for i in 0..8 {
            reg.enroll(&format!("spk{i}"), &[i as f64, 1.0], FP).unwrap();
        }
        assert!(reg.remove("spk3").unwrap());
        drop(reg);
        let back = DurableRegistry::open(&dir, &o).unwrap();
        assert_eq!(back.len(), 7);
        assert!(back.profile("spk3").is_none());
        assert_eq!(back.profile("spk7").unwrap().sum, vec![7.0, 1.0]);
        assert!(back.recovery().snapshot_loaded, "threshold 3 must have compacted");
    }
}
