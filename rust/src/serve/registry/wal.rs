//! The enrollment write-ahead log: length-prefixed, CRC-checksummed
//! `Enroll`/`Remove` records behind an 8-byte `IVWL` header.
//!
//! On-disk layout (all little-endian):
//!
//! ```text
//! "IVWL" u32:version                                  — file header
//! u32:payload_len u32:crc32(payload) payload          — per record
//! payload = u64:seq u8:op u32:id_len id
//!           [op=Enroll: u64:model_fp u32:dim dim×f64] — record body
//! ```
//!
//! Replay distinguishes the two ways a log goes bad:
//!
//! * **torn tail** — the *final* record is short or fails its CRC, with
//!   no bytes after it. That is exactly what a crash mid-append leaves
//!   behind; replay stops cleanly at the last intact record, reports
//!   `torn_tail`, and the opener truncates the file there. Tolerated,
//!   counted, never a panic.
//! * **mid-log corruption** — a short length, bad CRC, or sequence
//!   regression with more bytes *after* it. No crash produces that
//!   (appends are sequential); it means bit rot or a foreign writer, so
//!   replay refuses the whole log with a typed
//!   [`RegistryStoreError::WalCorrupt`] rather than guess at state.

use anyhow::{ensure, Result};

use super::codec::{self, Cur};
use super::RegistryStoreError;

pub(crate) const WAL_MAGIC: &[u8; 4] = b"IVWL";
pub(crate) const WAL_VERSION: u32 = 1;
/// Bytes of the file header (`IVWL` + version).
pub(crate) const HEADER_LEN: u64 = 8;
/// Upper bound on one record's payload: a single enrollment i-vector is
/// a few KB, so anything near this is corruption, not data.
const MAX_RECORD: u32 = 1 << 24;

const OP_ENROLL: u8 = 1;
const OP_REMOVE: u8 = 2;

/// One logged mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    Enroll { speaker: String, model_fp: u64, ivector: Vec<f64> },
    Remove { speaker: String },
}

/// A mutation with its log sequence number (strictly increasing within
/// one WAL; snapshots record the last seq they cover).
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub op: WalOp,
}

/// The 8-byte file header.
pub(crate) fn header() -> Vec<u8> {
    let mut h = Vec::with_capacity(HEADER_LEN as usize);
    h.extend_from_slice(WAL_MAGIC);
    codec::put_u32(&mut h, WAL_VERSION);
    h
}

/// Serialize one record (length prefix + CRC + payload).
pub(crate) fn encode_record(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    codec::put_u64(&mut payload, rec.seq);
    match &rec.op {
        WalOp::Enroll { speaker, model_fp, ivector } => {
            payload.push(OP_ENROLL);
            codec::put_str(&mut payload, speaker);
            codec::put_u64(&mut payload, *model_fp);
            codec::put_u32(&mut payload, ivector.len() as u32);
            codec::put_f64_slice(&mut payload, ivector);
        }
        WalOp::Remove { speaker } => {
            payload.push(OP_REMOVE);
            codec::put_str(&mut payload, speaker);
        }
    }
    let mut out = Vec::with_capacity(payload.len() + 8);
    codec::put_u32(&mut out, payload.len() as u32);
    codec::put_u32(&mut out, codec::crc32(&payload));
    out.extend_from_slice(&payload);
    out
}

/// What [`replay`] recovered from a WAL's bytes.
#[derive(Debug, Default)]
pub struct WalReplay {
    /// Intact records, in log order.
    pub records: Vec<WalRecord>,
    /// True when the log ended in a short or CRC-failing final record —
    /// the signature of a crash mid-append.
    pub torn_tail: bool,
    /// Bytes of the valid prefix (header + intact records). Recovery
    /// truncates the file here before appending again.
    pub valid_len: u64,
    /// Highest sequence number seen (0 when no records).
    pub last_seq: u64,
}

fn corrupt(record: u64, offset: usize, detail: impl Into<String>) -> anyhow::Error {
    RegistryStoreError::WalCorrupt { record, offset: offset as u64, detail: detail.into() }
        .into()
}

/// Parse a WAL image: every intact record up to a clean EOF or a torn
/// tail. Mid-log corruption is a typed error; a torn tail never is.
pub(crate) fn replay(bytes: &[u8]) -> Result<WalReplay> {
    let mut rep = WalReplay::default();
    if (bytes.len() as u64) < HEADER_LEN {
        // empty (fresh store) or header-torn: nothing to replay; the
        // opener rewrites the header
        rep.torn_tail = !bytes.is_empty();
        return Ok(rep);
    }
    if &bytes[..4] != WAL_MAGIC {
        return Err(corrupt(0, 0, "bad magic — not a registry WAL"));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != WAL_VERSION {
        return Err(corrupt(0, 4, format!("unsupported WAL version {version}")));
    }
    rep.valid_len = HEADER_LEN;
    let mut pos = HEADER_LEN as usize;
    let mut index = 0u64;
    while pos < bytes.len() {
        let rem = bytes.len() - pos;
        if rem < 8 {
            rep.torn_tail = true; // not even a record header made it out
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        let end = pos as u64 + 8 + u64::from(len);
        if len > MAX_RECORD {
            if end > bytes.len() as u64 {
                rep.torn_tail = true; // garbage length in a torn header
                break;
            }
            // an absurd length with real bytes behind it is bit rot,
            // not a crash
            return Err(corrupt(index, pos, format!("record length {len} implausible")));
        }
        if end > bytes.len() as u64 {
            rep.torn_tail = true; // the record's bytes never all landed
            break;
        }
        let end = end as usize;
        let payload = &bytes[pos + 8..end];
        if codec::crc32(payload) != crc {
            if end == bytes.len() {
                rep.torn_tail = true; // garbage final record from a crashed write
                break;
            }
            return Err(corrupt(index, pos, "record checksum mismatch"));
        }
        let rec =
            decode_payload(payload).map_err(|e| corrupt(index, pos, format!("{e:#}")))?;
        if rec.seq <= rep.last_seq {
            return Err(corrupt(
                index,
                pos,
                format!("sequence {} does not advance past {}", rec.seq, rep.last_seq),
            ));
        }
        rep.last_seq = rec.seq;
        rep.records.push(rec);
        pos = end;
        rep.valid_len = pos as u64;
        index += 1;
    }
    Ok(rep)
}

/// Decode a CRC-verified payload. A failure here means the bytes are
/// exactly what some writer produced — a format bug or foreign writer,
/// so the caller treats it as corruption, torn tail or not.
fn decode_payload(payload: &[u8]) -> Result<WalRecord> {
    let mut c = Cur::new(payload);
    let seq = c.u64()?;
    ensure!(seq > 0, "record sequence 0 is reserved");
    let op = match c.u8()? {
        OP_ENROLL => {
            let speaker = c.str_u32()?;
            let model_fp = c.u64()?;
            let dim = c.u32()? as usize;
            ensure!(dim <= 1 << 20, "i-vector dim {dim} implausible");
            let ivector = c.f64_vec(dim)?;
            WalOp::Enroll { speaker, model_fp, ivector }
        }
        OP_REMOVE => WalOp::Remove { speaker: c.str_u32()? },
        other => anyhow::bail!("unknown op tag {other}"),
    };
    ensure!(c.at_end(), "{} trailing bytes in record payload", c.remaining());
    Ok(WalRecord { seq, op })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord {
                seq: 1,
                op: WalOp::Enroll {
                    speaker: "alice".into(),
                    model_fp: 7,
                    ivector: vec![1.0, -2.5, 0.125],
                },
            },
            WalRecord { seq: 2, op: WalOp::Remove { speaker: "bob".into() } },
            WalRecord {
                seq: 5, // gaps are fine; only regressions are corrupt
                op: WalOp::Enroll { speaker: "bob".into(), model_fp: 7, ivector: vec![4.0] },
            },
        ]
    }

    fn sample_wal() -> Vec<u8> {
        let mut bytes = header();
        for r in sample_records() {
            bytes.extend_from_slice(&encode_record(&r));
        }
        bytes
    }

    #[test]
    fn encode_replay_round_trip() {
        let bytes = sample_wal();
        let rep = replay(&bytes).unwrap();
        assert_eq!(rep.records, sample_records());
        assert!(!rep.torn_tail);
        assert_eq!(rep.valid_len, bytes.len() as u64);
        assert_eq!(rep.last_seq, 5);
    }

    #[test]
    fn empty_and_header_only_logs_are_clean() {
        let rep = replay(&[]).unwrap();
        assert!(rep.records.is_empty() && !rep.torn_tail && rep.valid_len == 0);
        let rep = replay(&header()).unwrap();
        assert!(rep.records.is_empty() && !rep.torn_tail);
        assert_eq!(rep.valid_len, HEADER_LEN);
    }

    #[test]
    fn every_truncation_is_a_tolerated_torn_tail() {
        // satellite sweep (byte level): chop the log at every prefix
        // length — replay must never panic, never error, and always
        // return an exact prefix of the original records
        let bytes = sample_wal();
        let full = sample_records();
        for cut in 0..bytes.len() {
            let rep = replay(&bytes[..cut]).unwrap_or_else(|e| {
                panic!("cut at {cut} must be a torn tail, got error: {e:#}")
            });
            assert!(
                full.starts_with(&rep.records),
                "cut at {cut}: recovered records are not a prefix"
            );
            assert!(rep.valid_len <= cut as u64);
            // torn exactly when partial bytes dangle past the valid prefix
            assert_eq!(
                rep.torn_tail,
                (rep.valid_len as usize) < cut,
                "cut at {cut}: torn_tail disagrees with the dangling bytes"
            );
        }
    }

    #[test]
    fn bit_flips_are_torn_tail_or_typed_corruption_never_wrong_data() {
        let bytes = sample_wal();
        let full = sample_records();
        for offset in 0..bytes.len() {
            for bit in [0u8, 3, 7] {
                let mut bad = bytes.clone();
                bad[offset] ^= 1 << bit;
                match replay(&bad) {
                    Ok(rep) => {
                        // tolerated only as a torn *tail*: the surviving
                        // records must be an exact prefix
                        assert!(
                            full.starts_with(&rep.records),
                            "flip at {offset} bit {bit} loaded wrong records"
                        );
                        // the flipped byte is inside *some* record, so a
                        // tolerated outcome must have dropped at least it
                        assert!(rep.records.len() < full.len());
                    }
                    Err(e) => {
                        let typed = e
                            .downcast_ref::<RegistryStoreError>()
                            .unwrap_or_else(|| panic!("untyped error for flip at {offset}: {e:#}"));
                        assert!(matches!(typed, RegistryStoreError::WalCorrupt { .. }));
                    }
                }
            }
        }
    }

    #[test]
    fn mid_log_corruption_is_rejected_with_record_and_offset() {
        let mut bytes = sample_wal();
        // flip a payload byte of the FIRST record — bytes follow it, so
        // this must never be shrugged off as a torn tail
        let flip_at = HEADER_LEN as usize + 8 + 2;
        bytes[flip_at] ^= 0x10;
        let err = replay(&bytes).unwrap_err();
        match err.downcast_ref::<RegistryStoreError>() {
            Some(RegistryStoreError::WalCorrupt { record, offset, detail }) => {
                assert_eq!(*record, 0);
                assert_eq!(*offset, HEADER_LEN);
                assert!(detail.contains("checksum"), "{detail}");
            }
            other => panic!("expected WalCorrupt, got {other:?} / {err:#}"),
        }
        assert!(err.to_string().contains("registry WAL corrupt"), "{err}");
    }

    #[test]
    fn sequence_regression_is_corruption() {
        let mut bytes = header();
        let r1 = WalRecord { seq: 3, op: WalOp::Remove { speaker: "a".into() } };
        let r2 = WalRecord { seq: 3, op: WalOp::Remove { speaker: "b".into() } };
        bytes.extend_from_slice(&encode_record(&r1));
        bytes.extend_from_slice(&encode_record(&r2));
        let err = replay(&bytes).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<RegistryStoreError>(),
                Some(RegistryStoreError::WalCorrupt { record: 1, .. })
            ),
            "{err:#}"
        );
    }

    #[test]
    fn foreign_magic_and_version_are_typed_errors() {
        let mut bytes = sample_wal();
        bytes[0] = b'X';
        assert!(replay(&bytes).unwrap_err().downcast_ref::<RegistryStoreError>().is_some());
        let mut bytes = sample_wal();
        bytes[4] = 9; // version 9
        let err = replay(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }
}
