//! Byte-level helpers shared by the registry snapshot codec and the
//! enrollment WAL: little-endian append helpers, a bounds-checked
//! cursor (corrupt inputs become errors, never panics or huge
//! allocations), and the CRC-32 both formats checksum with.

use std::sync::OnceLock;

use anyhow::{bail, ensure, Result};

/// CRC-32 (IEEE 802.3 polynomial, the zlib/`cksum -o3` variant) over
/// `bytes`. Table-driven; the table is built once per process.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    });
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = table[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64_slice(out: &mut Vec<u8>, xs: &[f64]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// u32 length prefix + UTF-8 bytes (the `BinWriter::write_string`
/// layout, so legacy snapshot records parse with the same cursor).
pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian reader over a byte slice.
pub(crate) struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub(crate) fn pos(&self) -> usize {
        self.pos
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "unexpected end of data at byte {} (wanted {n} more, {} left) — truncated?",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64_vec(&mut self, n: usize) -> Result<Vec<f64>> {
        // `take` bounds the allocation: n*8 must already be present
        let raw = self.take(n.checked_mul(8).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// u32-length-prefixed UTF-8 string (mirror of [`put_str`]).
    pub(crate) fn str_u32(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n <= 1 << 20, "string length {n} implausible — corrupt data?");
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| anyhow::anyhow!("string is not UTF-8"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // sensitive to single-bit flips
        assert_ne!(crc32(b"123456788"), crc32(b"123456789"));
    }

    #[test]
    fn cursor_round_trips_and_bounds_checks() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 7);
        put_u64(&mut buf, u64::MAX - 1);
        put_str(&mut buf, "spk");
        put_f64_slice(&mut buf, &[1.5, -2.5]);
        let mut c = Cur::new(&buf);
        assert_eq!(c.u32().unwrap(), 7);
        assert_eq!(c.u64().unwrap(), u64::MAX - 1);
        assert_eq!(c.str_u32().unwrap(), "spk");
        assert_eq!(c.f64_vec(2).unwrap(), vec![1.5, -2.5]);
        assert!(c.at_end());
        // past the end: an error, never a panic
        assert!(c.u8().is_err());
        // absurd string length is rejected before allocating
        let mut junk = Vec::new();
        put_u32(&mut junk, u32::MAX);
        assert!(Cur::new(&junk).str_u32().is_err());
    }
}
