//! Concurrent speaker registry: enrollment state behind sharded locks,
//! with optional write-ahead durability layered underneath.
//!
//! Enrollment is *averaging*: a speaker's profile accumulates the sum
//! of raw enrollment i-vectors and the count, and verification scores
//! against the running mean (the standard multi-session enrollment
//! recipe — scoring the averaged i-vector). Shards keep unrelated
//! speakers off the same mutex so enroll/verify traffic scales with
//! cores instead of serializing on one registry lock.
//!
//! Every profile carries the fingerprint of the model it was enrolled
//! under ([`crate::serve::ModelBundle::fingerprint`]): i-vectors from
//! different total-variability spaces are not comparable, so mixing
//! model epochs in one profile — or scoring across them — is an error
//! the engine surfaces instead of a silently meaningless score.
//!
//! # Durability
//!
//! A plain [`Registry::new`] registry is volatile. [`DurableRegistry`]
//! ([`durable`]) attaches a [`storage::RegistryStorage`] backend and
//! write-ahead-logs every mutation ([`wal`]) *before* applying it to
//! the shards: an enrollment is acknowledged only once its WAL record
//! is appended (and, under the `always` sync policy, fsynced). Past a
//! configurable record threshold the WAL compacts into the crash-atomic
//! snapshot; the snapshot carries the last WAL sequence it covers, so
//! recovery is "load snapshot, replay only newer records".
//!
//! Lock order is fixed: **WAL state first, shard second** — mutations
//! and compaction both take it, so durable mutations serialize on the
//! WAL (they are fsync-bound anyway) and can never deadlock against a
//! compaction that snapshots every shard. Volatile registries never
//! touch the WAL lock and keep the fully sharded fast path.

pub(crate) mod codec;
pub mod bench;
mod durable;
pub mod storage;
pub mod wal;

pub use durable::{DurableRegistry, DurableRegistryOptions, RecoveryReport};
pub use storage::{Fault, FaultInjector, FileStorage, MemStorage, RegistryStorage};

use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{bail, ensure, Context, Result};

use codec::Cur;
use durable::{Durability, WalState};
use wal::{WalOp, WalRecord};

/// One lock shard.
type Shard = Mutex<HashMap<String, SpeakerProfile>>;

/// Poison-tolerant shard lock. A panic while a shard is held (a bug in
/// the holder, or a caller's unwind crossing an enrollment) must not
/// convert into a permanent shard-wide outage: every profile update is
/// a running `(sum, count)` pair mutated in place, so the worst a
/// mid-update unwind leaves behind is one speaker's partially-applied
/// enrollment — strictly better than poisoning `lock().unwrap()` for
/// every later caller of that shard.
fn lock(shard: &Shard) -> MutexGuard<'_, HashMap<String, SpeakerProfile>> {
    shard.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Accumulated enrollment state of one speaker.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeakerProfile {
    /// Number of enrollment utterances.
    pub count: u64,
    /// Sum of raw enrollment i-vectors (dim R).
    pub sum: Vec<f64>,
    /// Fingerprint of the model every enrollment used.
    pub model_fp: u64,
}

impl SpeakerProfile {
    /// The averaged enrollment i-vector. Zero-count profiles are
    /// rejected at load and unreachable via enroll, so a zero here is
    /// corruption — fail loudly in tests instead of silently returning
    /// a bogus all-zeros mean.
    pub fn mean(&self) -> Vec<f64> {
        debug_assert!(self.count > 0, "zero-count profile: corrupt registry state");
        let n = self.count as f64;
        self.sum.iter().map(|&x| x / n).collect()
    }
}

/// Typed persistence failures. These ride inside `anyhow::Error` (every
/// entry point keeps its `Result` signature) and stay reachable through
/// `Error::downcast_ref`, like [`crate::serve::ServeError`] on the
/// request path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryStoreError {
    /// A registry snapshot failed its checksum or structural
    /// validation; nothing was loaded.
    SnapshotCorrupt { detail: String },
    /// The WAL is corrupt *before* its final record — bit rot or a
    /// foreign writer, not a crash — so replay refuses to guess.
    WalCorrupt { record: u64, offset: u64, detail: String },
    /// An earlier storage failure could not be repaired in place;
    /// durable mutations are refused until the registry is reopened
    /// (recovery re-validates the log end to end).
    WalPoisoned,
}

impl fmt::Display for RegistryStoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::SnapshotCorrupt { detail } => {
                write!(f, "registry snapshot corrupt: {detail}")
            }
            Self::WalCorrupt { record, offset, detail } => {
                write!(f, "registry WAL corrupt at record {record} (byte {offset}): {detail}")
            }
            Self::WalPoisoned => write!(
                f,
                "registry WAL is poisoned by an earlier unrepaired storage failure — \
                 reopen the registry to recover"
            ),
        }
    }
}

impl std::error::Error for RegistryStoreError {}

/// Point-in-time durability counters, zeroed for volatile registries.
/// Surfaced through `EngineMetrics`/`ClusterMetrics` and the bench
/// reports so overload runs show whether persistence kept pace.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DurabilityMetrics {
    /// True when mutations are write-ahead logged (not just volatile or
    /// snapshot-only).
    pub wal_enabled: bool,
    /// Records appended to the WAL since open.
    pub wal_appends: u64,
    /// WAL fsyncs that completed (== appends under the `always` policy).
    pub wal_synced: u64,
    /// WAL-into-snapshot compactions completed.
    pub compactions: u64,
    /// Records replayed from the WAL at the last open.
    pub replayed: u64,
    /// Torn WAL tails tolerated at the last open (0 or 1).
    pub torn_tail: u64,
}

/// Sharded concurrent speaker store.
#[derive(Debug)]
pub struct Registry {
    shards: Vec<Shard>,
    /// Present on registries opened through [`DurableRegistry`]; every
    /// mutation then WALs before touching a shard.
    durability: Option<Arc<Durability>>,
}

// ---- snapshot format ----------------------------------------------------
//
// Both formats share the repo's container header (`IVTV` + version) so
// a snapshot still looks like "one of our files" to generic tooling.
//
//   versioned: IVTV u32:1 | u64:SNAP_MAGIC u32:snap_version
//              u32:crc32(payload) | payload
//              payload = u64:last_wal_seq u64:n  n × record
//   legacy:    IVTV u32:1 | u64:n  n × record
//   record:    u32:id_len id u64:count u64:model_fp u64:dim dim×f64
//
// The discriminator is the first u64 after the container header: the
// legacy format put the record count there, and no plausible count
// collides with SNAP_MAGIC (~5.8e18) — a bound the legacy path enforces
// explicitly, which is also what stops a bit-flipped magic (or a
// foreign `IVTV` artifact, the pre-versioning failure mode) from being
// misread as billions of records.

/// `b"IVREGSNP"` as a little-endian u64.
pub(crate) const SNAP_MAGIC: u64 = u64::from_le_bytes(*b"IVREGSNP");
pub(crate) const SNAP_VERSION: u32 = 1;
/// Minimal encoded record (empty id, dim 0): 4 + 8 + 8 + 8 bytes.
const MIN_RECORD_BYTES: u64 = 28;

impl Registry {
    /// Create a volatile registry with `n_shards` lock shards (clamped
    /// to ≥ 1).
    pub fn new(n_shards: usize) -> Self {
        Self {
            shards: (0..n_shards.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
            durability: None,
        }
    }

    /// Attach the durable layer (consuming `self`: only
    /// [`DurableRegistry`] construction does this, after recovery).
    pub(crate) fn with_durability(mut self, d: Arc<Durability>) -> Self {
        self.durability = Some(d);
        self
    }

    fn shard(&self, speaker_id: &str) -> &Shard {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        speaker_id.hash(&mut h);
        &self.shards[(h.finish() % self.shards.len() as u64) as usize]
    }

    /// Add one enrollment i-vector to `speaker_id` (creating the
    /// profile on first enrollment); returns the new utterance count.
    /// Fails if the speaker already holds enrollments from a different
    /// model epoch — averaging across total-variability spaces would
    /// corrupt the profile — or if the i-vector dimension disagrees
    /// with the existing profile. Both are *errors to that caller*,
    /// never panics: a panic here would fire while the shard mutex is
    /// held and cascade one malformed request into a shard-wide outage.
    ///
    /// On a durable registry the mutation is write-ahead logged first;
    /// an `Ok` means the record reached the WAL under the configured
    /// sync policy, and an `Err` means the registry state is unchanged.
    pub fn enroll(&self, speaker_id: &str, ivector: &[f64], model_fp: u64) -> Result<u64> {
        let Some(d) = &self.durability else {
            return self.enroll_mem(speaker_id, ivector, model_fp);
        };
        // lock order: WAL state first, shard second (see module docs)
        let mut st = d.lock_state();
        let count = {
            let mut shard = lock(self.shard(speaker_id));
            // validate *before* logging: a rejected enrollment must
            // reach neither the WAL nor the map
            if let Some(profile) = shard.get(speaker_id) {
                validate_enrollment(profile, speaker_id, ivector, model_fp)?;
            }
            let rec = WalRecord {
                seq: st.next_seq,
                op: WalOp::Enroll {
                    speaker: speaker_id.to_string(),
                    model_fp,
                    ivector: ivector.to_vec(),
                },
            };
            d.log(&mut st, &rec)?;
            apply_enroll(&mut shard, speaker_id, ivector, model_fp)?
        };
        self.compact_if_due(d, &mut st);
        Ok(count)
    }

    /// Memory-only enrollment: the volatile path, and WAL replay during
    /// recovery (those records were already logged).
    pub(crate) fn enroll_mem(
        &self,
        speaker_id: &str,
        ivector: &[f64],
        model_fp: u64,
    ) -> Result<u64> {
        let mut shard = lock(self.shard(speaker_id));
        apply_enroll(&mut shard, speaker_id, ivector, model_fp)
    }

    /// Snapshot a speaker's profile (sum + count), if enrolled.
    pub fn profile(&self, speaker_id: &str) -> Option<SpeakerProfile> {
        lock(self.shard(speaker_id)).get(speaker_id).cloned()
    }

    /// Remove a speaker; returns whether it existed. On a durable
    /// registry the removal is write-ahead logged first — an `Err`
    /// means the speaker is still enrolled (and still durable).
    pub fn remove(&self, speaker_id: &str) -> Result<bool> {
        let Some(d) = &self.durability else {
            return Ok(self.remove_mem(speaker_id));
        };
        let mut st = d.lock_state();
        let removed = {
            let mut shard = lock(self.shard(speaker_id));
            if !shard.contains_key(speaker_id) {
                false // nothing to log: absent speakers consume no WAL records
            } else {
                let rec = WalRecord {
                    seq: st.next_seq,
                    op: WalOp::Remove { speaker: speaker_id.to_string() },
                };
                d.log(&mut st, &rec)?;
                shard.remove(speaker_id).is_some()
            }
        };
        if removed {
            self.compact_if_due(d, &mut st);
        }
        Ok(removed)
    }

    /// Memory-only removal (volatile path and WAL replay).
    pub(crate) fn remove_mem(&self, speaker_id: &str) -> bool {
        lock(self.shard(speaker_id)).remove(speaker_id).is_some()
    }

    /// Number of enrolled speakers.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock(s).len()).sum()
    }

    /// True when no speaker is enrolled.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total enrollment utterances across all speakers.
    pub fn total_enrollments(&self) -> u64 {
        self.shards.iter().map(|s| lock(s).values().map(|p| p.count).sum::<u64>()).sum()
    }

    /// All enrolled speaker ids, sorted (stable across shard layouts).
    pub fn speaker_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| lock(s).keys().cloned().collect::<Vec<_>>())
            .collect();
        ids.sort();
        ids
    }

    /// Durability counters; all-zero (and `wal_enabled: false`) for a
    /// volatile registry.
    pub fn durability_metrics(&self) -> DurabilityMetrics {
        match &self.durability {
            Some(d) => d.metrics(),
            None => DurabilityMetrics::default(),
        }
    }

    /// True while the WAL is poisoned — the degraded read-only mode: a
    /// mutation's append (or its rollback) failed, so further mutations
    /// are refused with typed [`RegistryStoreError::WalPoisoned`]
    /// while reads (verify, profile lookups) keep serving from the
    /// intact in-memory state. Always false on a volatile registry.
    pub fn is_poisoned(&self) -> bool {
        match &self.durability {
            Some(d) => d.lock_state().poisoned,
            None => false,
        }
    }

    /// Attempt recovery from the poisoned state by rebuilding durable
    /// storage from the intact in-memory profiles: snapshot every
    /// shard, truncate the WAL, clear the poison flag. No-op `Ok` when
    /// the registry is not poisoned; `Err` (still poisoned, still
    /// read-only-degraded, safe to retry) when storage keeps failing.
    /// This is what [`DurableRegistry::reopen`] and the cluster
    /// supervisor tick call.
    pub fn repair(&self) -> Result<()> {
        let Some(d) = &self.durability else {
            return Ok(());
        };
        let mut st = d.lock_state();
        if !st.poisoned {
            return Ok(());
        }
        self.compact_locked(d, &mut st)
    }

    /// Every profile, sorted by id (deterministic files regardless of
    /// shard count or enrollment order). Shard-at-a-time: concurrent
    /// mutations on *other* shards can land mid-collection — callers
    /// needing a consistent cut hold the WAL lock (compaction does).
    fn collect_profiles(&self) -> Vec<(String, SpeakerProfile)> {
        let mut all: Vec<(String, SpeakerProfile)> = Vec::new();
        for s in &self.shards {
            let shard = lock(s);
            all.extend(shard.iter().map(|(id, p)| (id.clone(), p.clone())));
        }
        all.sort_by(|a, b| a.0.cmp(&b.0));
        all
    }

    /// Bump the mutation counter and compact once the threshold trips.
    /// Infallible on purpose: the mutation that tripped it is already
    /// durable in the WAL, so a failed compaction must not fail that
    /// caller's ack — it resets the counter and retries a threshold
    /// later.
    fn compact_if_due(&self, d: &Durability, st: &mut WalState) {
        st.since_compact += 1;
        if d.compact_every == 0 || st.since_compact < d.compact_every {
            return;
        }
        if let Err(e) = self.compact_locked(d, st) {
            st.since_compact = 0;
            eprintln!("[registry] WAL compaction failed (state is safe; will retry): {e:#}");
        }
    }

    /// Snapshot every shard and truncate the WAL, under the held WAL
    /// lock — no mutation can be between its append and its apply, so
    /// the snapshot provably covers every logged record. A crash
    /// between the swap and the truncate is safe: recovery skips WAL
    /// records at or below the snapshot's sequence number.
    pub(crate) fn compact_locked(&self, d: &Durability, st: &mut WalState) -> Result<()> {
        let snapshot = self.collect_profiles();
        let bytes = encode_snapshot(&snapshot, st.next_seq - 1);
        d.storage.swap_snapshot(&bytes).context("swap registry snapshot")?;
        if d.wal_enabled && st.wal_len > wal::HEADER_LEN {
            d.storage.truncate_wal(wal::HEADER_LEN).context("truncate compacted WAL")?;
            st.wal_len = wal::HEADER_LEN;
            st.unsynced = 0;
        }
        st.since_compact = 0;
        // a rebuilt-clean WAL clears an earlier failed tail repair
        st.poisoned = false;
        d.compactions.inc();
        Ok(())
    }

    /// Force a compaction now (the [`DurableRegistry::compact`] and
    /// `registry-recover --compact` entry point).
    pub(crate) fn force_compact(&self) -> Result<()> {
        let Some(d) = &self.durability else {
            bail!("registry has no durable storage attached");
        };
        let mut st = d.lock_state();
        self.compact_locked(d, &mut st)
    }

    /// Persist all profiles to `path` as a versioned snapshot. The
    /// write is **atomic at the file level**: bytes go to a fresh
    /// same-directory temp file (`rename(2)` is only atomic within one
    /// filesystem) fsynced and renamed into place — a crash mid-save
    /// leaves the previous snapshot intact instead of a truncated file.
    /// On a durable registry the WAL lock is held across collection so
    /// the embedded sequence number agrees with the profiles.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let (snapshot, last_seq) = match &self.durability {
            Some(d) => {
                let st = d.lock_state();
                (self.collect_profiles(), st.next_seq - 1)
            }
            None => (self.collect_profiles(), 0),
        };
        let bytes = encode_snapshot(&snapshot, last_seq);
        storage::atomic_write_synced(path, &bytes)
            .with_context(|| format!("save registry snapshot {}", path.display()))
    }

    /// Load a registry written by [`Registry::save`], distributing the
    /// profiles over `n_shards` fresh shards. Accepts both the
    /// versioned format (checksum-verified) and the legacy pre-magic
    /// format via an explicit fallback. Every record is validated: a
    /// zero enrollment count, a duplicate speaker id (silent
    /// last-record-wins), or a non-finite sum (NaN/∞ would poison every
    /// later verify score) all reject the file instead of loading
    /// corrupt state.
    pub fn load(path: impl AsRef<Path>, n_shards: usize) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("open registry snapshot {}", path.display()))?;
        let (reg, _last_seq) = Self::decode_snapshot(&bytes, n_shards)
            .with_context(|| format!("load registry snapshot {}", path.display()))?;
        Ok(reg)
    }

    /// Decode a snapshot image; returns the registry and the last WAL
    /// sequence number it covers (0 for legacy files). Every failure is
    /// a typed [`RegistryStoreError::SnapshotCorrupt`].
    pub(crate) fn decode_snapshot(bytes: &[u8], n_shards: usize) -> Result<(Self, u64)> {
        Self::decode_snapshot_inner(bytes, n_shards).map_err(|e| {
            anyhow::Error::new(RegistryStoreError::SnapshotCorrupt { detail: format!("{e:#}") })
        })
    }

    fn decode_snapshot_inner(bytes: &[u8], n_shards: usize) -> Result<(Self, u64)> {
        let mut c = Cur::new(bytes);
        let magic = c.take(4)?;
        ensure!(magic == crate::io::CONTAINER_MAGIC, "bad magic — not an ivector-tv file");
        let container = c.u32()?;
        ensure!(
            container == crate::io::CONTAINER_VERSION,
            "unsupported container version {container}"
        );
        let probe = c.u64()?;
        let (last_seq, n) = if probe == SNAP_MAGIC {
            let version = c.u32()?;
            ensure!(version == SNAP_VERSION, "unsupported registry snapshot version {version}");
            let crc = c.u32()?;
            // checksum the whole payload before trusting any of it: a
            // bit flip anywhere past this point is caught here, never
            // loaded as a wrong profile
            let payload = &bytes[c.pos()..];
            ensure!(
                codec::crc32(payload) == crc,
                "snapshot checksum mismatch — corrupt registry file?"
            );
            (c.u64()?, c.u64()?)
        } else {
            // legacy pre-versioning snapshot: that u64 is the record
            // count. No checksum to lean on, so bound it hard — this is
            // what rejects foreign `IVTV` artifacts (or a bit-flipped
            // magic) instead of looping on garbage records.
            ensure!(
                probe <= bytes.len() as u64 / MIN_RECORD_BYTES + 1,
                "record count {probe} implausible — corrupt or foreign registry file?"
            );
            (0, probe)
        };
        let reg = Self::new(n_shards);
        for _ in 0..n {
            let (id, p) = read_profile_record(&mut c)?;
            let mut shard = lock(reg.shard(&id));
            if shard.insert(id.clone(), p).is_some() {
                bail!("duplicate speaker `{id}` — corrupt registry file?");
            }
        }
        ensure!(
            c.at_end(),
            "{} trailing bytes after the last record — corrupt registry file?",
            c.remaining()
        );
        Ok((reg, last_seq))
    }
}

/// The profile-level guards `enroll` promises, split out so the durable
/// path can validate *before* appending to the WAL.
fn validate_enrollment(
    profile: &SpeakerProfile,
    speaker_id: &str,
    ivector: &[f64],
    model_fp: u64,
) -> Result<()> {
    ensure!(
        profile.model_fp == model_fp,
        "speaker `{speaker_id}` was enrolled under a different model — \
         remove and re-enroll after a bundle swap"
    );
    ensure!(
        profile.sum.len() == ivector.len(),
        "enrollment dim {} does not match speaker `{speaker_id}`'s existing profile \
         dim {}",
        ivector.len(),
        profile.sum.len()
    );
    Ok(())
}

/// Apply one enrollment to a locked shard map (validating as it goes —
/// the memory-only path arrives here without a prior
/// [`validate_enrollment`]).
fn apply_enroll(
    shard: &mut HashMap<String, SpeakerProfile>,
    speaker_id: &str,
    ivector: &[f64],
    model_fp: u64,
) -> Result<u64> {
    let profile = shard.entry(speaker_id.to_string()).or_insert_with(|| SpeakerProfile {
        count: 0,
        sum: vec![0.0; ivector.len()],
        model_fp,
    });
    validate_enrollment(profile, speaker_id, ivector, model_fp)?;
    for (s, &x) in profile.sum.iter_mut().zip(ivector) {
        *s += x;
    }
    profile.count += 1;
    Ok(profile.count)
}

/// One snapshot record (shared by both formats).
fn read_profile_record(c: &mut Cur<'_>) -> Result<(String, SpeakerProfile)> {
    let id = c.str_u32()?;
    let count = c.u64()?;
    let model_fp = c.u64()?;
    let dim = c.u64()? as usize;
    if count == 0 {
        bail!("speaker `{id}` has zero enrollments — corrupt registry file?");
    }
    if dim > 1 << 20 {
        bail!("i-vector dim {dim} implausible — corrupt registry file?");
    }
    let sum = c.f64_vec(dim)?;
    if !sum.iter().all(|x| x.is_finite()) {
        bail!("speaker `{id}` has a non-finite enrollment sum — corrupt registry file?");
    }
    Ok((id, SpeakerProfile { count, sum, model_fp }))
}

/// Serialize profiles as a versioned snapshot image covering WAL
/// records up to `last_seq`.
pub(crate) fn encode_snapshot(profiles: &[(String, SpeakerProfile)], last_seq: u64) -> Vec<u8> {
    let mut payload = Vec::new();
    codec::put_u64(&mut payload, last_seq);
    codec::put_u64(&mut payload, profiles.len() as u64);
    for (id, p) in profiles {
        codec::put_str(&mut payload, id);
        codec::put_u64(&mut payload, p.count);
        codec::put_u64(&mut payload, p.model_fp);
        codec::put_u64(&mut payload, p.sum.len() as u64);
        codec::put_f64_slice(&mut payload, &p.sum);
    }
    let mut out = Vec::with_capacity(payload.len() + 24);
    out.extend_from_slice(crate::io::CONTAINER_MAGIC);
    codec::put_u32(&mut out, crate::io::CONTAINER_VERSION);
    codec::put_u64(&mut out, SNAP_MAGIC);
    codec::put_u32(&mut out, SNAP_VERSION);
    codec::put_u32(&mut out, codec::crc32(&payload));
    out.extend_from_slice(&payload);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::BinWriter;

    const FP: u64 = 7;

    #[test]
    fn enrollment_averages() {
        let reg = Registry::new(4);
        assert!(reg.is_empty());
        assert_eq!(reg.enroll("alice", &[1.0, 2.0], FP).unwrap(), 1);
        assert_eq!(reg.enroll("alice", &[3.0, 4.0], FP).unwrap(), 2);
        let p = reg.profile("alice").unwrap();
        assert_eq!(p.count, 2);
        assert_eq!(p.mean(), vec![2.0, 3.0]);
        assert!(reg.profile("bob").is_none());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.total_enrollments(), 2);
        // a volatile registry reports zeroed durability counters
        assert_eq!(reg.durability_metrics(), DurabilityMetrics::default());
    }

    #[test]
    fn mixed_model_epochs_rejected() {
        let reg = Registry::new(2);
        reg.enroll("a", &[1.0], 1).unwrap();
        let err = reg.enroll("a", &[1.0], 2).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // count unchanged by the rejected enrollment
        assert_eq!(reg.profile("a").unwrap().count, 1);
        // after removal the speaker can enroll under the new model
        assert!(reg.remove("a").unwrap());
        assert_eq!(reg.enroll("a", &[1.0], 2).unwrap(), 1);
    }

    #[test]
    fn remove_and_ids() {
        let reg = Registry::new(3);
        for id in ["s2", "s0", "s1"] {
            reg.enroll(id, &[1.0], FP).unwrap();
        }
        assert_eq!(reg.speaker_ids(), vec!["s0", "s1", "s2"]);
        assert!(reg.remove("s1").unwrap());
        assert!(!reg.remove("s1").unwrap());
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn dim_mismatch_is_an_error_and_the_shard_survives() {
        // satellite acceptance: a dimension-mismatched enrollment is an
        // error to that caller, and the shard keeps serving everyone
        let reg = Registry::new(1); // one shard: every id shares the lock
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        let err = reg.enroll("alice", &[1.0, 2.0, 3.0], FP).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        // profile untouched by the rejected enrollment
        let p = reg.profile("alice").unwrap();
        assert_eq!(p.count, 1);
        assert_eq!(p.sum, vec![1.0, 2.0]);
        // the same shard still takes enrollments — no poisoned lock
        assert_eq!(reg.enroll("bob", &[0.5, 0.5], FP).unwrap(), 1);
        assert_eq!(reg.enroll("alice", &[3.0, 4.0], FP).unwrap(), 2);
    }

    #[test]
    fn poisoned_shard_lock_is_tolerated() {
        // a panic while holding a shard mutex (a buggy holder) must not
        // take the shard down for every later caller
        let reg = Registry::new(1);
        reg.enroll("alice", &[1.0], FP).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = reg.shard("alice").lock().unwrap();
            panic!("holder bug");
        }));
        assert!(caught.is_err());
        assert!(reg.shard("alice").is_poisoned(), "the mutex really was poisoned");
        // every accessor keeps working through the poison
        assert_eq!(reg.profile("alice").unwrap().count, 1);
        assert_eq!(reg.enroll("alice", &[2.0], FP).unwrap(), 2);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.total_enrollments(), 2);
        assert_eq!(reg.speaker_ids(), vec!["alice"]);
        assert!(reg.remove("alice").unwrap());
    }

    /// Hand-write a **legacy** (pre-versioning) registry file from raw
    /// records — exactly what `Registry::save` produced before the
    /// magic + version header, so these tests double as the legacy
    /// fallback's fixtures.
    fn write_legacy_registry_file(
        path: &std::path::Path,
        records: &[(&str, u64, u64, &[f64])],
    ) -> Result<()> {
        let mut w = BinWriter::create(path)?;
        w.write_u64(records.len() as u64)?;
        for (id, count, fp, sum) in records {
            w.write_string(id)?;
            w.write_u64(*count)?;
            w.write_u64(*fp)?;
            w.write_u64(sum.len() as u64)?;
            w.write_f64_slice(sum)?;
        }
        w.finish()
    }

    #[test]
    fn load_rejects_corrupt_records() {
        let dir = std::env::temp_dir().join("ivtv_registry_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();

        // zero-count profile: mean() would divide by zero
        let p = dir.join("zero_count.bin");
        write_legacy_registry_file(&p, &[("a", 0, FP, &[1.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("zero enrollments"), "{err}");
        // the failure is typed all the way through the context chain
        assert!(matches!(
            err.downcast_ref::<RegistryStoreError>(),
            Some(RegistryStoreError::SnapshotCorrupt { .. })
        ));

        // duplicate speaker ids: last record would silently win
        let p = dir.join("dup.bin");
        write_legacy_registry_file(&p, &[("a", 1, FP, &[1.0]), ("a", 2, FP, &[9.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("duplicate speaker"), "{err}");

        // non-finite sums: NaN would poison every later verify score
        let p = dir.join("nan.bin");
        write_legacy_registry_file(&p, &[("a", 1, FP, &[f64::NAN, 1.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let p = dir.join("inf.bin");
        write_legacy_registry_file(&p, &[("a", 1, FP, &[f64::INFINITY])]).unwrap();
        assert!(Registry::load(&p, 2).is_err());

        // a well-formed legacy file with the same shapes still loads
        let p = dir.join("ok.bin");
        write_legacy_registry_file(&p, &[("a", 1, FP, &[1.0]), ("b", 2, FP, &[4.0])]).unwrap();
        let reg = Registry::load(&p, 2).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.profile("b").unwrap().mean(), vec![2.0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let reg = Registry::new(5);
        reg.enroll("a", &[1.0, -1.0], FP).unwrap();
        reg.enroll("a", &[2.0, -2.0], FP).unwrap();
        reg.enroll("b", &[0.5, 0.25], 9).unwrap();
        let dir = std::env::temp_dir().join("ivtv_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("reg.bin");
        reg.save(&p).unwrap();
        // the file on disk is the *versioned* format now
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[8..16], &SNAP_MAGIC.to_le_bytes());
        // reload into a *different* shard count
        let back = Registry::load(&p, 2).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.profile("a").unwrap(), reg.profile("a").unwrap());
        assert_eq!(back.profile("b").unwrap(), reg.profile("b").unwrap());
    }

    #[test]
    fn legacy_snapshot_loads_through_the_fallback() {
        // satellite acceptance: both formats round-trip through `load`
        let dir = std::env::temp_dir().join("ivtv_registry_legacy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("legacy.bin");
        write_legacy_registry_file(&p, &[("a", 2, FP, &[3.0, -1.0]), ("b", 1, 9, &[0.5])])
            .unwrap();
        let reg = Registry::load(&p, 4).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.profile("a").unwrap().mean(), vec![1.5, -0.5]);
        assert_eq!(reg.profile("b").unwrap().model_fp, 9);
        // and a re-save upgrades it to the versioned format
        let p2 = dir.join("upgraded.bin");
        reg.save(&p2).unwrap();
        let bytes = std::fs::read(&p2).unwrap();
        assert_eq!(&bytes[8..16], &SNAP_MAGIC.to_le_bytes());
        let back = Registry::load(&p2, 2).unwrap();
        assert_eq!(back.profile("a").unwrap(), reg.profile("a").unwrap());
    }

    #[test]
    fn foreign_ivtv_artifact_is_rejected_not_misparsed() {
        // the pre-versioning failure mode: any `IVTV` container (say, a
        // model bundle) parsed its first u64 as a record count. The
        // legacy fallback now bounds that count.
        let dir = std::env::temp_dir().join("ivtv_registry_foreign_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("foreign.bin");
        let mut w = BinWriter::create(&p).unwrap();
        w.write_u64(u64::MAX / 2).unwrap(); // "record count": absurd
        w.write_f64_slice(&[1.0; 16]).unwrap();
        w.finish().unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("implausible"), "{err}");
    }

    #[test]
    fn versioned_snapshot_carries_the_wal_seq() {
        let reg = Registry::new(2);
        reg.enroll("a", &[1.0], FP).unwrap();
        let bytes = encode_snapshot(&reg.collect_profiles(), 42);
        let (back, seq) = Registry::decode_snapshot(&bytes, 3).unwrap();
        assert_eq!(seq, 42);
        assert_eq!(back.profile("a").unwrap(), reg.profile("a").unwrap());
    }

    #[test]
    fn snapshot_truncation_sweep_always_errors_typed() {
        // satellite sweep: a versioned snapshot truncated at EVERY
        // prefix length must error (typed), never panic, never load
        let reg = Registry::new(2);
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        reg.enroll("bob", &[3.0], 9).unwrap();
        reg.enroll("carol", &[4.0, 5.0], FP).unwrap();
        let bytes = encode_snapshot(&reg.collect_profiles(), 3);
        for cut in 0..bytes.len() {
            let err = match Registry::decode_snapshot(&bytes[..cut], 2) {
                Ok(_) => panic!("truncation at {cut} must not load"),
                Err(e) => e,
            };
            assert!(
                matches!(
                    err.downcast_ref::<RegistryStoreError>(),
                    Some(RegistryStoreError::SnapshotCorrupt { .. })
                ),
                "cut at {cut}: {err:#}"
            );
        }
    }

    #[test]
    fn snapshot_bitflip_sweep_never_loads_wrong_profiles() {
        // satellite sweep: flip bits at sampled offsets across the
        // whole image — the checksum (or, for header bytes, the magic /
        // version / count-bound checks) must reject every one
        let reg = Registry::new(2);
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        reg.enroll("bob", &[-0.5, 0.25], FP).unwrap();
        let bytes = encode_snapshot(&reg.collect_profiles(), 17);
        for offset in 0..bytes.len() {
            for bit in [0u8, 4, 7] {
                let mut bad = bytes.clone();
                bad[offset] ^= 1 << bit;
                assert!(
                    Registry::decode_snapshot(&bad, 2).is_err(),
                    "flip at {offset} bit {bit} silently loaded"
                );
            }
        }
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join("ivtv_registry_atomic_test");
        // fresh dir: the leftover-file assertion below must see only
        // what this test writes
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("reg.bin");

        let reg = Registry::new(3);
        reg.enroll("a", &[1.0, 2.0], FP).unwrap();
        reg.save(&p).unwrap();

        // overwrite with a bigger registry: the target is replaced wholesale
        reg.enroll("b", &[3.0, 4.0], FP).unwrap();
        reg.enroll("c", &[5.0, 6.0], FP).unwrap();
        reg.save(&p).unwrap();
        let back = Registry::load(&p, 2).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.profile("c").unwrap().sum, vec![5.0, 6.0]);

        // nothing but the snapshot itself remains in the directory
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "reg.bin")
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");

        // a failed save (unwritable target directory) reports an error
        // and leaves the existing snapshot untouched
        let bad = dir.join("no_such_subdir_parent.bin");
        std::fs::write(&bad, b"sentinel").unwrap();
        let unwritable = bad.join("reg.bin"); // parent is a file → create fails
        assert!(reg.save(&unwritable).is_err());
        let still = Registry::load(&p, 2).unwrap();
        assert_eq!(still.len(), 3, "failed save must not touch the good snapshot");
    }

    #[test]
    fn concurrent_enrollments_are_not_lost() {
        let reg = std::sync::Arc::new(Registry::new(8));
        let threads = 8;
        let per_thread = 200;
        let mut handles = Vec::new();
        for t in 0..threads {
            let reg = std::sync::Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    // contended speaker + a per-thread speaker
                    reg.enroll("shared", &[1.0, 1.0], FP).unwrap();
                    reg.enroll(&format!("spk{t}"), &[i as f64, 0.0], FP).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let shared = reg.profile("shared").unwrap();
        assert_eq!(shared.count, (threads * per_thread) as u64);
        // identical addends ⇒ the sum is exact regardless of order
        assert_eq!(shared.mean(), vec![1.0, 1.0]);
        assert_eq!(reg.len(), threads + 1);
        assert_eq!(reg.total_enrollments(), (2 * threads * per_thread) as u64);
    }
}
