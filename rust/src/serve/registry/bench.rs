//! Crash/recovery benchmark for the durable registry — the machinery
//! behind the `registry-bench` CLI command and `BENCH_6.json`.
//!
//! Three phases:
//!
//! 1. **baseline** — enroll the whole synthetic population into a
//!    volatile registry (pure in-memory rate, the fsync-free ceiling);
//! 2. **durable + crash** — enroll the same population through the WAL
//!    with a [`FaultInjector`] scripted to kill persistence mid-stream
//!    (torn append, then the backend is dead), counting exactly which
//!    enrollments were *acknowledged*;
//! 3. **recover** — reopen on a fresh storage handle, time recovery,
//!    and verify every acknowledged enrollment is present with exactly
//!    the vector it enrolled. `lost > 0` fails the bench: that is the
//!    headline guarantee.

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::bench_util::write_bench_json;
use crate::config::WalSync;
use crate::metrics::{LatencySummary, Stopwatch};
use crate::obs::{latency_summary_json, ObsRegistry, Stage};

use super::durable::{DurableRegistry, DurableRegistryOptions};
use super::storage::{FaultInjector, RegistryStorage};
use super::Registry;

/// Model fingerprint the synthetic enrollments carry.
const BENCH_FP: u64 = 0x1_5EED;

/// Crash/recovery bench parameters.
#[derive(Debug, Clone)]
pub struct RegistryBenchOpts {
    /// Synthetic speakers to enroll (one utterance each).
    pub speakers: usize,
    /// I-vector dimension of each enrollment.
    pub dim: usize,
    /// Lock shards for the in-memory map.
    pub shards: usize,
    /// WAL sync policy under test.
    pub sync: WalSync,
    /// Compaction threshold (records between snapshots; 0 = never).
    pub compact_every: u64,
    /// Enrollment index at which persistence dies mid-append. Values at
    /// or past `speakers` mean the crash never fires.
    pub crash_at: usize,
}

impl Default for RegistryBenchOpts {
    fn default() -> Self {
        Self {
            speakers: 100_000,
            dim: 64,
            shards: 16,
            sync: WalSync::Always,
            compact_every: 20_000,
            crash_at: 50_000,
        }
    }
}

/// One crash/recovery run's results.
#[derive(Debug, Clone)]
pub struct RegistryBenchReport {
    pub speakers: usize,
    pub dim: usize,
    /// Sync policy the run used (`always` / `every-N`).
    pub wal_sync: String,
    /// Volatile (no-WAL) enrollment rate — the fsync-free ceiling.
    pub mem_enroll_rps: f64,
    /// Durable enrollment rate up to the crash.
    pub wal_enroll_rps: f64,
    /// `mem_enroll_rps / wal_enroll_rps`: how much the WAL + sync
    /// policy costs (1.0 = free).
    pub fsync_overhead_x: f64,
    /// Enrollments acknowledged before the injected crash.
    pub acked: usize,
    /// Acked enrollments found intact after recovery.
    pub recovered: usize,
    /// Acked enrollments missing or wrong after recovery — the number
    /// the whole subsystem exists to keep at zero.
    pub lost: usize,
    /// The torn final record was detected at recovery (1 expected when
    /// the crash fired mid-append).
    pub torn_tail: u64,
    /// WAL records replayed on top of the snapshot at recovery.
    pub replayed: u64,
    /// Compactions completed before the crash.
    pub compactions: u64,
    /// Wall-clock seconds to reopen + replay after the crash.
    pub recovery_s: f64,
    /// WAL append/fsync latency summaries from the attached
    /// [`ObsRegistry`] (empty when the bench ran without one).
    pub wal_stages: Vec<(&'static str, LatencySummary)>,
}

impl RegistryBenchReport {
    /// One JSON object (no trailing newline) for the BENCH_6 report.
    pub fn json_fragment(&self) -> String {
        let stages: Vec<String> = self
            .wal_stages
            .iter()
            .map(|(name, s)| format!("\"{name}\": {}", latency_summary_json(s)))
            .collect();
        format!(
            "{{\"speakers\": {}, \"dim\": {}, \"wal_sync\": \"{}\", \
\"mem_enroll_rps\": {:.1}, \"wal_enroll_rps\": {:.1}, \"fsync_overhead_x\": {:.2}, \
\"acked\": {}, \"recovered\": {}, \"lost\": {}, \"torn_tail\": {}, \
\"replayed\": {}, \"compactions\": {}, \"recovery_s\": {:.6}, \"stages\": {{{}}}}}",
            self.speakers,
            self.dim,
            self.wal_sync,
            self.mem_enroll_rps,
            self.wal_enroll_rps,
            self.fsync_overhead_x,
            self.acked,
            self.recovered,
            self.lost,
            self.torn_tail,
            self.replayed,
            self.compactions,
            self.recovery_s,
            stages.join(", "),
        )
    }
}

/// Deterministic synthetic enrollment vector for speaker `i`.
fn bench_vector(i: usize, dim: usize) -> Vec<f64> {
    (0..dim).map(|j| ((i * 31 + j * 7) % 1000) as f64 / 1000.0).collect()
}

fn bench_id(i: usize) -> String {
    format!("spk{i:06}")
}

/// Run the three-phase crash/recovery bench. `fresh_storage` must
/// return a *new handle onto the same persistent state* each call —
/// `FileStorage::open` on one directory, or clones of one
/// [`super::MemStorage`] — because phase 3's recovery has to see
/// exactly the bytes phase 2's dying instance persisted.
pub fn run_registry_bench(
    opts: &RegistryBenchOpts,
    fresh_storage: impl Fn() -> Result<Box<dyn RegistryStorage>>,
    obs: Option<Arc<ObsRegistry>>,
) -> Result<RegistryBenchReport> {
    ensure!(opts.speakers >= 2, "registry bench needs at least 2 speakers");
    ensure!(opts.dim >= 1, "registry bench needs dim >= 1");
    let dopts = DurableRegistryOptions {
        shards: opts.shards,
        wal: true,
        sync: opts.sync,
        compact_every: opts.compact_every,
    };

    // phase 1: volatile baseline — the rate with no durability at all
    let volatile = Registry::new(opts.shards);
    let sw = Stopwatch::start();
    for i in 0..opts.speakers {
        volatile.enroll(&bench_id(i), &bench_vector(i, opts.dim), BENCH_FP)?;
    }
    let mem_wall = sw.elapsed_s().max(1e-9);
    let mem_enroll_rps = opts.speakers as f64 / mem_wall;

    // phase 2: durable enrollment with a scripted mid-stream crash.
    // Append 0 is the WAL header, so enrollment `i` is append `i + 1`;
    // the dying append persists a 9-byte torn prefix of its record.
    let injected = FaultInjector::new(fresh_storage().context("open bench storage")?)
        .crash_at_append(opts.crash_at as u64 + 1, 9);
    let reg = DurableRegistry::with_storage_obs(Box::new(injected), &dopts, obs.clone())
        .context("open durable registry for the crash phase")?;
    let sw = Stopwatch::start();
    let mut acked = 0usize;
    for i in 0..opts.speakers {
        match reg.enroll(&bench_id(i), &bench_vector(i, opts.dim), BENCH_FP) {
            Ok(_) => acked += 1,
            Err(_) => break, // the injected crash: nothing after it acks
        }
    }
    let wal_wall = sw.elapsed_s().max(1e-9);
    let wal_enroll_rps = acked as f64 / wal_wall;
    let compactions = reg.durability_metrics().compactions;
    drop(reg);

    // phase 3: recovery on a fresh handle — time it, then audit every
    // acknowledged enrollment against what was enrolled
    let sw = Stopwatch::start();
    let back = DurableRegistry::with_storage_obs(
        fresh_storage().context("reopen bench storage")?,
        &dopts,
        obs.clone(),
    )
    .context("recover registry after the injected crash")?;
    let recovery_s = sw.elapsed_s();
    let mut recovered = 0usize;
    for i in 0..acked {
        match back.profile(&bench_id(i)) {
            Some(p) if p.count == 1 && p.sum == bench_vector(i, opts.dim) => recovered += 1,
            _ => {}
        }
    }
    let m = back.durability_metrics();
    Ok(RegistryBenchReport {
        speakers: opts.speakers,
        dim: opts.dim,
        wal_sync: opts.sync.to_string(),
        mem_enroll_rps,
        wal_enroll_rps,
        fsync_overhead_x: mem_enroll_rps / wal_enroll_rps.max(1e-9),
        acked,
        recovered,
        lost: acked - recovered,
        torn_tail: m.torn_tail,
        replayed: m.replayed,
        compactions,
        recovery_s,
        wal_stages: match &obs {
            Some(o) => o
                .stage_summaries()
                .into_iter()
                .filter(|(name, _)| {
                    *name == Stage::WalAppend.as_str() || *name == Stage::WalFsync.as_str()
                })
                .collect(),
            None => Vec::new(),
        },
    })
}

/// Write the `BENCH_6.json` crash/recovery report.
pub fn write_bench6_json(path: impl AsRef<Path>, report: &RegistryBenchReport) -> Result<()> {
    write_bench_json(path, 6, &[("registry_recovery", report.json_fragment())])
}

#[cfg(test)]
mod tests {
    use super::super::storage::MemStorage;
    use super::*;

    #[test]
    fn crash_bench_recovers_every_acked_enrollment() {
        let store = MemStorage::new();
        let opts = RegistryBenchOpts {
            speakers: 400,
            dim: 4,
            shards: 8,
            sync: WalSync::Always,
            compact_every: 64,
            crash_at: 150,
        };
        let store_for_factory = store.clone();
        let obs = Arc::new(ObsRegistry::default());
        let report = run_registry_bench(
            &opts,
            move || Ok(Box::new(store_for_factory.clone()) as Box<dyn RegistryStorage>),
            Some(Arc::clone(&obs)),
        )
        .unwrap();
        assert_eq!(report.acked, 150, "enrollment `crash_at` must be the first failure");
        // the attached obs registry timed the WAL work per stage
        assert_eq!(report.wal_stages.len(), 2);
        assert_eq!(report.wal_stages[0].0, "wal_append");
        assert_eq!(report.wal_stages[1].0, "wal_fsync");
        assert!(report.wal_stages[0].1.count >= 150, "{:?}", report.wal_stages);
        assert!(report.json_fragment().contains("\"stages\": {\"wal_append\": {"));
        assert_eq!(report.lost, 0, "acked-but-lost enrollments: the headline guarantee");
        assert_eq!(report.recovered, 150);
        assert_eq!(report.torn_tail, 1, "the 9-byte torn prefix must be detected");
        assert_eq!(report.compactions, 2, "150 mutations at threshold 64");
        // snapshot covers 128, the WAL replays 129..=150
        assert_eq!(report.replayed, 22);
        assert!(report.recovery_s >= 0.0);
        assert!(report.mem_enroll_rps > 0.0 && report.wal_enroll_rps > 0.0);
    }

    #[test]
    fn crash_past_the_population_means_everything_acks() {
        let store = MemStorage::new();
        let opts = RegistryBenchOpts {
            speakers: 50,
            dim: 3,
            shards: 4,
            sync: WalSync::EveryN(8),
            compact_every: 0,
            crash_at: 10_000, // never fires
        };
        let store_for_factory = store.clone();
        let report = run_registry_bench(
            &opts,
            move || Ok(Box::new(store_for_factory.clone()) as Box<dyn RegistryStorage>),
            None,
        )
        .unwrap();
        assert_eq!(report.acked, 50);
        assert_eq!(report.lost, 0);
        assert_eq!(report.torn_tail, 0, "no crash, no torn tail");
        assert_eq!(report.wal_sync, "every-8");
        assert!(report.wal_stages.is_empty(), "no obs registry, no stage summaries");
    }

    #[test]
    fn bench6_json_shape() {
        let report = RegistryBenchReport {
            speakers: 1000,
            dim: 8,
            wal_sync: "always".into(),
            mem_enroll_rps: 50_000.0,
            wal_enroll_rps: 9_000.0,
            fsync_overhead_x: 5.56,
            acked: 500,
            recovered: 500,
            lost: 0,
            torn_tail: 1,
            replayed: 100,
            compactions: 2,
            recovery_s: 0.012345,
            wal_stages: Vec::new(),
        };
        let frag = report.json_fragment();
        assert!(frag.contains("\"lost\": 0"), "{frag}");
        assert!(frag.contains("\"wal_sync\": \"always\""), "{frag}");
        assert!(frag.contains("\"fsync_overhead_x\": 5.56"), "{frag}");
        let dir = std::env::temp_dir().join("ivtv_bench6_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_6.json");
        write_bench6_json(&p, &report).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"schema_version\": 1"));
        assert!(text.contains("\"issue\": 6"));
        assert!(text.contains("\"registry_recovery\": {"));
    }
}
