//! Pluggable registry persistence: the [`RegistryStorage`] trait, the
//! real file backend, an in-memory backend (tests, benches), and the
//! deterministic [`FaultInjector`] the crash-recovery suite scripts.
//!
//! The durable registry never touches the filesystem directly — every
//! byte goes through this trait, which is what makes the fault
//! injection honest: a scripted torn write or failed fsync exercises
//! exactly the code paths a real one would.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

/// Byte-level persistence for one registry: an append-only WAL plus a
/// single swappable snapshot. Implementations must be safe to call from
/// concurrent threads; the durable layer already serializes mutations
/// on its WAL lock, but reads and admin calls can overlap.
pub trait RegistryStorage: Send + Sync {
    /// Append raw bytes to the WAL. No durability is implied until
    /// [`RegistryStorage::sync_wal`] returns.
    fn append_wal(&self, buf: &[u8]) -> Result<()>;
    /// Force all appended WAL bytes to stable storage.
    fn sync_wal(&self) -> Result<()>;
    /// The whole WAL as last written; empty when none exists yet.
    fn read_wal(&self) -> Result<Vec<u8>>;
    /// Truncate the WAL to `len` bytes (torn-tail repair, compaction).
    fn truncate_wal(&self, len: u64) -> Result<()>;
    /// The current snapshot bytes, if a snapshot has been written.
    fn read_snapshot(&self) -> Result<Option<Vec<u8>>>;
    /// Atomically replace the snapshot (write-aside + durable rename —
    /// a crash mid-swap must leave the previous snapshot intact).
    fn swap_snapshot(&self, bytes: &[u8]) -> Result<()>;
    /// Human-readable location for error context and logs.
    fn describe(&self) -> String;
}

/// Write `bytes` to `path` crash-atomically: fresh same-directory temp
/// file → `fsync` → `rename(2)` → best-effort directory fsync. Shared
/// by [`FileStorage::swap_snapshot`] and `Registry::save`.
pub(crate) fn atomic_write_synced(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create directory {}", dir.display()))?;
        }
    }
    // unique per (process, write): concurrent writers to one path must
    // not scribble over each other's half-written temp file
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = WRITE_SEQ.fetch_add(1, Ordering::Relaxed);
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "registry".into());
    let tmp = path.with_file_name(format!(".{name}.tmp.{}.{seq}", std::process::id()));
    let write = (|| -> Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(bytes)?;
        // fsync before the rename: the swap is only crash-atomic if the
        // temp file's data blocks reach stable storage before the
        // rename is journaled
        f.sync_all()?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename {} into place", tmp.display()))?;
        // best effort: persist the directory entry too, so the rename
        // itself survives a power loss (failure leaves the old, intact
        // file — not corruption — so it is not fatal)
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                if let Ok(d) = std::fs::File::open(dir) {
                    let _ = d.sync_all();
                }
            }
        }
        Ok(())
    })();
    if write.is_err() {
        // never leave a half-written temp file behind
        let _ = std::fs::remove_file(&tmp);
    }
    write
}

/// The real backend: `registry.wal` + `registry.snap` inside one
/// directory. The WAL append handle is opened once (`O_APPEND`) and
/// cached; `O_APPEND` writes land at the current end of file even after
/// an out-of-band truncate, so compaction never has to reopen it.
///
/// Other append-only logs (the capture flight recorder) reuse this
/// backend under their own file names via [`FileStorage::open_named`],
/// so one durability implementation — and one fault-injection surface —
/// covers every log the serving stack writes.
pub struct FileStorage {
    dir: PathBuf,
    wal_name: String,
    snap_name: String,
    wal: Mutex<Option<std::fs::File>>,
}

impl FileStorage {
    /// Open (creating the directory if needed).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_named(dir, "registry.wal", "registry.snap")
    }

    /// Open with explicit file names inside `dir` — lets non-registry
    /// logs (e.g. the capture log) share the backend without colliding
    /// with a registry living in the same directory.
    pub fn open_named(
        dir: impl AsRef<Path>,
        wal_name: impl Into<String>,
        snap_name: impl Into<String>,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create storage directory {}", dir.display()))?;
        Ok(Self {
            dir,
            wal_name: wal_name.into(),
            snap_name: snap_name.into(),
            wal: Mutex::new(None),
        })
    }

    /// Path of the append-only WAL inside the directory.
    pub fn wal_path(&self) -> PathBuf {
        self.dir.join(&self.wal_name)
    }

    /// Path of the compacted snapshot inside the directory.
    pub fn snapshot_path(&self) -> PathBuf {
        self.dir.join(&self.snap_name)
    }
}

impl RegistryStorage for FileStorage {
    fn append_wal(&self, buf: &[u8]) -> Result<()> {
        use std::io::Write;
        let mut guard = self.wal.lock().unwrap_or_else(|p| p.into_inner());
        if guard.is_none() {
            let f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(self.wal_path())
                .with_context(|| format!("open {} for append", self.wal_path().display()))?;
            *guard = Some(f);
        }
        guard.as_mut().unwrap().write_all(buf).context("append to registry WAL")
    }

    fn sync_wal(&self) -> Result<()> {
        let guard = self.wal.lock().unwrap_or_else(|p| p.into_inner());
        match guard.as_ref() {
            Some(f) => f.sync_data().context("fsync registry WAL"),
            None => Ok(()), // nothing appended through this handle yet
        }
    }

    fn read_wal(&self) -> Result<Vec<u8>> {
        match std::fs::read(self.wal_path()) {
            Ok(b) => Ok(b),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
            Err(e) => Err(e).with_context(|| format!("read {}", self.wal_path().display())),
        }
    }

    fn truncate_wal(&self, len: u64) -> Result<()> {
        // hold the append-handle lock so a truncate cannot interleave
        // with a concurrent append's write_all
        let _guard = self.wal.lock().unwrap_or_else(|p| p.into_inner());
        let f = std::fs::OpenOptions::new()
            .create(true)
            .write(true)
            .open(self.wal_path())
            .with_context(|| format!("open {} for truncate", self.wal_path().display()))?;
        f.set_len(len).context("truncate registry WAL")?;
        f.sync_all().context("fsync truncated registry WAL")
    }

    fn read_snapshot(&self) -> Result<Option<Vec<u8>>> {
        match std::fs::read(self.snapshot_path()) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("read {}", self.snapshot_path().display())),
        }
    }

    fn swap_snapshot(&self, bytes: &[u8]) -> Result<()> {
        atomic_write_synced(&self.snapshot_path(), bytes)
    }

    fn describe(&self) -> String {
        format!("file:{}", self.dir.display())
    }
}

/// In-memory backend whose clones share one store — "reopening after a
/// crash" is a fresh [`MemStorage::clone`], exactly the bytes the dying
/// instance managed to persist. Used by the fault-injection suite and
/// the recovery bench's deterministic mode.
#[derive(Clone, Default)]
pub struct MemStorage {
    inner: Arc<MemInner>,
}

#[derive(Default)]
struct MemInner {
    wal: Mutex<Vec<u8>>,
    snap: Mutex<Option<Vec<u8>>>,
}

impl MemStorage {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from exact preset bytes (the corruption sweeps construct
    /// truncated/bit-flipped files directly).
    pub fn seeded(wal: Vec<u8>, snap: Option<Vec<u8>>) -> Self {
        Self { inner: Arc::new(MemInner { wal: Mutex::new(wal), snap: Mutex::new(snap) }) }
    }

    /// Current WAL bytes (test inspection).
    pub fn wal_bytes(&self) -> Vec<u8> {
        self.inner.wal.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Current snapshot bytes (test inspection).
    pub fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        self.inner.snap.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

impl RegistryStorage for MemStorage {
    fn append_wal(&self, buf: &[u8]) -> Result<()> {
        self.inner.wal.lock().unwrap_or_else(|p| p.into_inner()).extend_from_slice(buf);
        Ok(())
    }

    fn sync_wal(&self) -> Result<()> {
        Ok(()) // memory is "durable" the moment it is written
    }

    fn read_wal(&self) -> Result<Vec<u8>> {
        Ok(self.wal_bytes())
    }

    fn truncate_wal(&self, len: u64) -> Result<()> {
        let mut wal = self.inner.wal.lock().unwrap_or_else(|p| p.into_inner());
        let len = usize::try_from(len).unwrap_or(usize::MAX);
        if len < wal.len() {
            wal.truncate(len);
        }
        Ok(())
    }

    fn read_snapshot(&self) -> Result<Option<Vec<u8>>> {
        Ok(self.snapshot_bytes())
    }

    fn swap_snapshot(&self, bytes: &[u8]) -> Result<()> {
        *self.inner.snap.lock().unwrap_or_else(|p| p.into_inner()) = Some(bytes.to_vec());
        Ok(())
    }

    fn describe(&self) -> String {
        "mem".into()
    }
}

/// One scripted storage failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// The append persists only its first `keep` bytes, then errors —
    /// a torn write (partial page, interrupted `write(2)`).
    TornWrite { keep: usize },
    /// The op fails up front, nothing reaches the backend (`ENOSPC`).
    Enospc,
    /// The fsync fails; bytes already appended may or may not be
    /// durable.
    SyncFail,
    /// Torn write, then the backend is dead: every later operation
    /// fails. A crashed process/disk — only a *fresh* storage handle
    /// (recovery) can see the bytes again.
    Crash { keep: usize },
    /// The read succeeds but the byte at `offset` comes back XORed
    /// with `xor` — read-side bit rot.
    CorruptRead { offset: usize, xor: u8 },
}

#[derive(Default)]
struct Plan {
    /// Operations seen so far (every trait call counts).
    op: u64,
    /// Appends seen so far (appends only; sync-policy independent).
    appends: u64,
    faults: Vec<(u64, Fault)>,
    crash_at_append: Option<(u64, usize)>,
    dead: bool,
}

/// Deterministic fault-injecting wrapper around any backend. Faults are
/// scripted at operation counts (every trait call increments the
/// counter) or, for crash drills, at append counts — append numbering
/// does not shift when the sync policy changes.
pub struct FaultInjector {
    inner: Box<dyn RegistryStorage>,
    plan: Mutex<Plan>,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn RegistryStorage>) -> Self {
        Self { inner, plan: Mutex::new(Plan::default()) }
    }

    /// Schedule `fault` for the `op`-th storage operation (0-based).
    pub fn fail_op(self, op: u64, fault: Fault) -> Self {
        self.plan.lock().unwrap_or_else(|p| p.into_inner()).faults.push((op, fault));
        self
    }

    /// Crash on the `n`-th WAL append (0-based, counting appends only):
    /// persist `keep` bytes of it, then fail every later operation.
    pub fn crash_at_append(self, n: u64, keep: usize) -> Self {
        self.plan.lock().unwrap_or_else(|p| p.into_inner()).crash_at_append = Some((n, keep));
        self
    }

    /// Operations observed so far (script calibration in tests).
    pub fn ops(&self) -> u64 {
        self.plan.lock().unwrap_or_else(|p| p.into_inner()).op
    }

    /// Count the op; return the fault scheduled for it, if any. Errors
    /// immediately once the backend has "crashed".
    fn next(&self, is_append: bool) -> Result<Option<Fault>> {
        let mut plan = self.plan.lock().unwrap_or_else(|p| p.into_inner());
        if plan.dead {
            bail!("injected: storage backend is dead (crashed earlier in the script)");
        }
        let op = plan.op;
        plan.op += 1;
        let mut fault =
            plan.faults.iter().find(|(at, _)| *at == op).map(|(_, f)| f.clone());
        if is_append {
            let append = plan.appends;
            plan.appends += 1;
            if let Some((at, keep)) = plan.crash_at_append {
                if append == at {
                    fault = Some(Fault::Crash { keep });
                }
            }
        }
        if matches!(fault, Some(Fault::Crash { .. })) {
            plan.dead = true;
        }
        Ok(fault)
    }
}

impl RegistryStorage for FaultInjector {
    fn append_wal(&self, buf: &[u8]) -> Result<()> {
        match self.next(true)? {
            None => self.inner.append_wal(buf),
            Some(Fault::TornWrite { keep }) | Some(Fault::Crash { keep }) => {
                let keep = keep.min(buf.len());
                if keep > 0 {
                    // the torn prefix really lands in the backend — that
                    // is the whole point of the drill
                    let _ = self.inner.append_wal(&buf[..keep]);
                }
                bail!("injected: torn append ({keep} of {} bytes persisted)", buf.len())
            }
            Some(Fault::Enospc) => bail!("injected: No space left on device"),
            Some(f) => bail!("injected: fault {f:?} scripted on append"),
        }
    }

    fn sync_wal(&self) -> Result<()> {
        match self.next(false)? {
            None => self.inner.sync_wal(),
            Some(Fault::SyncFail) => bail!("injected: fsync failed"),
            Some(f) => bail!("injected: fault {f:?} scripted on sync"),
        }
    }

    fn read_wal(&self) -> Result<Vec<u8>> {
        match self.next(false)? {
            None => self.inner.read_wal(),
            Some(Fault::CorruptRead { offset, xor }) => {
                let mut b = self.inner.read_wal()?;
                if offset < b.len() {
                    b[offset] ^= xor;
                }
                Ok(b)
            }
            Some(f) => bail!("injected: fault {f:?} scripted on read_wal"),
        }
    }

    fn truncate_wal(&self, len: u64) -> Result<()> {
        match self.next(false)? {
            None => self.inner.truncate_wal(len),
            Some(f) => bail!("injected: fault {f:?} scripted on truncate"),
        }
    }

    fn read_snapshot(&self) -> Result<Option<Vec<u8>>> {
        match self.next(false)? {
            None => self.inner.read_snapshot(),
            Some(Fault::CorruptRead { offset, xor }) => {
                let mut b = self.inner.read_snapshot()?;
                if let Some(bytes) = b.as_mut() {
                    if offset < bytes.len() {
                        bytes[offset] ^= xor;
                    }
                }
                Ok(b)
            }
            Some(f) => bail!("injected: fault {f:?} scripted on read_snapshot"),
        }
    }

    fn swap_snapshot(&self, bytes: &[u8]) -> Result<()> {
        match self.next(false)? {
            None => self.inner.swap_snapshot(bytes),
            Some(f) => bail!("injected: fault {f:?} scripted on snapshot swap"),
        }
    }

    fn describe(&self) -> String {
        format!("fault-injected({})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_storage_round_trips_and_truncates() {
        let dir = std::env::temp_dir().join("ivtv_registry_storage_test");
        let _ = std::fs::remove_dir_all(&dir);
        let s = FileStorage::open(&dir).unwrap();
        assert_eq!(s.read_wal().unwrap(), Vec::<u8>::new());
        assert!(s.read_snapshot().unwrap().is_none());
        s.append_wal(b"hello ").unwrap();
        s.append_wal(b"world").unwrap();
        s.sync_wal().unwrap();
        assert_eq!(s.read_wal().unwrap(), b"hello world");
        s.truncate_wal(5).unwrap();
        assert_eq!(s.read_wal().unwrap(), b"hello");
        // O_APPEND handle keeps appending at the *new* end after truncate
        s.append_wal(b"!").unwrap();
        assert_eq!(s.read_wal().unwrap(), b"hello!");
        s.swap_snapshot(b"snap-v1").unwrap();
        s.swap_snapshot(b"snap-v2").unwrap();
        assert_eq!(s.read_snapshot().unwrap().unwrap(), b"snap-v2");
        // the snapshot swap leaves no temp files behind
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "registry.wal" && n != "registry.snap")
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        // a second handle on the same directory sees the same bytes
        let s2 = FileStorage::open(&dir).unwrap();
        assert_eq!(s2.read_wal().unwrap(), b"hello!");
    }

    #[test]
    fn mem_storage_clones_share_the_store() {
        let a = MemStorage::new();
        let b = a.clone();
        a.append_wal(b"abc").unwrap();
        assert_eq!(b.read_wal().unwrap(), b"abc");
        b.swap_snapshot(b"s").unwrap();
        assert_eq!(a.read_snapshot().unwrap().unwrap(), b"s");
    }

    #[test]
    fn injector_scripts_are_deterministic() {
        let mem = MemStorage::new();
        let inj = FaultInjector::new(Box::new(mem.clone()))
            .fail_op(1, Fault::Enospc)
            .fail_op(3, Fault::SyncFail);
        inj.append_wal(b"ok0").unwrap(); // op 0
        let e = inj.append_wal(b"gone").unwrap_err(); // op 1: ENOSPC
        assert!(e.to_string().contains("No space left"), "{e}");
        // nothing from the failed append reached the backend
        assert_eq!(mem.wal_bytes(), b"ok0");
        inj.append_wal(b"ok1").unwrap(); // op 2
        assert!(inj.sync_wal().is_err()); // op 3: fsync fails
        inj.sync_wal().unwrap(); // op 4
        assert_eq!(inj.ops(), 5);
    }

    #[test]
    fn crash_leaves_a_torn_prefix_then_kills_the_backend() {
        let mem = MemStorage::new();
        let inj = FaultInjector::new(Box::new(mem.clone())).crash_at_append(2, 3);
        inj.append_wal(b"aaaa").unwrap();
        // an interleaved sync must not shift append numbering
        inj.sync_wal().unwrap();
        inj.append_wal(b"bbbb").unwrap();
        let e = inj.append_wal(b"cccccc").unwrap_err();
        assert!(e.to_string().contains("torn append"), "{e}");
        // 3 bytes of the dying write persisted — the torn tail
        assert_eq!(mem.wal_bytes(), b"aaaabbbbccc");
        // everything after the crash fails, reads included
        assert!(inj.read_wal().is_err());
        assert!(inj.sync_wal().is_err());
        assert!(inj.swap_snapshot(b"x").is_err());
        // but a *fresh* handle on the backend (recovery) sees the bytes
        assert_eq!(mem.read_wal().unwrap(), b"aaaabbbbccc");
    }

    #[test]
    fn corrupt_read_flips_exactly_one_byte() {
        let mem = MemStorage::new();
        mem.append_wal(b"\x00\x00\x00\x00").unwrap();
        let inj = FaultInjector::new(Box::new(mem))
            .fail_op(0, Fault::CorruptRead { offset: 2, xor: 0x80 });
        assert_eq!(inj.read_wal().unwrap(), b"\x00\x00\x80\x00");
        // the corruption was read-side only: the next read is clean
        assert_eq!(inj.read_wal().unwrap(), b"\x00\x00\x00\x00");
    }
}
