//! Concurrent speaker registry: enrollment state behind sharded locks.
//!
//! Enrollment is *averaging*: a speaker's profile accumulates the sum
//! of raw enrollment i-vectors and the count, and verification scores
//! against the running mean (the standard multi-session enrollment
//! recipe — scoring the averaged i-vector). Shards keep unrelated
//! speakers off the same mutex so enroll/verify traffic scales with
//! cores instead of serializing on one registry lock.
//!
//! Every profile carries the fingerprint of the model it was enrolled
//! under ([`crate::serve::ModelBundle::fingerprint`]): i-vectors from
//! different total-variability spaces are not comparable, so mixing
//! model epochs in one profile — or scoring across them — is an error
//! the engine surfaces instead of a silently meaningless score.

use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, ensure, Context, Result};

use crate::io::{BinReader, BinWriter};

/// One lock shard.
type Shard = Mutex<HashMap<String, SpeakerProfile>>;

/// Poison-tolerant shard lock. A panic while a shard is held (a bug in
/// the holder, or a caller's unwind crossing an enrollment) must not
/// convert into a permanent shard-wide outage: every profile update is
/// a running `(sum, count)` pair mutated in place, so the worst a
/// mid-update unwind leaves behind is one speaker's partially-applied
/// enrollment — strictly better than poisoning `lock().unwrap()` for
/// every later caller of that shard.
fn lock(shard: &Shard) -> MutexGuard<'_, HashMap<String, SpeakerProfile>> {
    shard.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Accumulated enrollment state of one speaker.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeakerProfile {
    /// Number of enrollment utterances.
    pub count: u64,
    /// Sum of raw enrollment i-vectors (dim R).
    pub sum: Vec<f64>,
    /// Fingerprint of the model every enrollment used.
    pub model_fp: u64,
}

impl SpeakerProfile {
    /// The averaged enrollment i-vector.
    pub fn mean(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum.iter().map(|&x| x / n).collect()
    }
}

/// Sharded concurrent speaker store.
#[derive(Debug)]
pub struct Registry {
    shards: Vec<Shard>,
}

impl Registry {
    /// Create with `n_shards` lock shards (clamped to ≥ 1).
    pub fn new(n_shards: usize) -> Self {
        Self {
            shards: (0..n_shards.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, speaker_id: &str) -> &Shard {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        speaker_id.hash(&mut h);
        &self.shards[(h.finish() % self.shards.len() as u64) as usize]
    }

    /// Add one enrollment i-vector to `speaker_id` (creating the
    /// profile on first enrollment); returns the new utterance count.
    /// Fails if the speaker already holds enrollments from a different
    /// model epoch — averaging across total-variability spaces would
    /// corrupt the profile — or if the i-vector dimension disagrees
    /// with the existing profile. Both are *errors to that caller*,
    /// never panics: a panic here would fire while the shard mutex is
    /// held and cascade one malformed request into a shard-wide outage.
    pub fn enroll(&self, speaker_id: &str, ivector: &[f64], model_fp: u64) -> Result<u64> {
        let mut shard = lock(self.shard(speaker_id));
        let profile = shard.entry(speaker_id.to_string()).or_insert_with(|| SpeakerProfile {
            count: 0,
            sum: vec![0.0; ivector.len()],
            model_fp,
        });
        ensure!(
            profile.model_fp == model_fp,
            "speaker `{speaker_id}` was enrolled under a different model — \
             remove and re-enroll after a bundle swap"
        );
        ensure!(
            profile.sum.len() == ivector.len(),
            "enrollment dim {} does not match speaker `{speaker_id}`'s existing profile \
             dim {}",
            ivector.len(),
            profile.sum.len()
        );
        for (s, &x) in profile.sum.iter_mut().zip(ivector) {
            *s += x;
        }
        profile.count += 1;
        Ok(profile.count)
    }

    /// Snapshot a speaker's profile (mean + count), if enrolled.
    pub fn profile(&self, speaker_id: &str) -> Option<SpeakerProfile> {
        lock(self.shard(speaker_id)).get(speaker_id).cloned()
    }

    /// Remove a speaker; returns whether it existed.
    pub fn remove(&self, speaker_id: &str) -> bool {
        lock(self.shard(speaker_id)).remove(speaker_id).is_some()
    }

    /// Number of enrolled speakers.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock(s).len()).sum()
    }

    /// True when no speaker is enrolled.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total enrollment utterances across all speakers.
    pub fn total_enrollments(&self) -> u64 {
        self.shards.iter().map(|s| lock(s).values().map(|p| p.count).sum::<u64>()).sum()
    }

    /// All enrolled speaker ids, sorted (stable across shard layouts).
    pub fn speaker_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| lock(s).keys().cloned().collect::<Vec<_>>())
            .collect();
        ids.sort();
        ids
    }

    /// Persist all profiles (sorted by id, so files are deterministic
    /// regardless of shard count or enrollment order). The snapshot is
    /// taken per speaker before the header is written, so a concurrent
    /// `remove` between listing and reading simply drops that id from
    /// the file instead of failing the save.
    ///
    /// The write is **atomic at the file level**: bytes go to a fresh
    /// temp file next to `path` (same directory — `rename(2)` is only
    /// atomic within one filesystem) which is renamed into place once
    /// fully written. A crash mid-save therefore leaves the previous
    /// snapshot intact instead of a truncated file — the durability
    /// floor the future enrollment WAL will compact into.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let snapshot: Vec<(String, SpeakerProfile)> = self
            .speaker_ids()
            .into_iter()
            .filter_map(|id| self.profile(&id).map(|p| (id, p)))
            .collect();
        // unique per (process, save): concurrent saves to one path must
        // not scribble over each other's half-written temp file
        static SAVE_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SAVE_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "registry".into());
        let tmp = path.with_file_name(format!(".{name}.tmp.{}.{seq}", std::process::id()));
        let write = write_snapshot_then_rename(&snapshot, &tmp, path);
        if write.is_err() {
            // best effort: never leave a half-written temp file behind
            let _ = std::fs::remove_file(&tmp);
        }
        write
    }

    /// Load a registry written by [`Registry::save`], distributing the
    /// profiles over `n_shards` fresh shards. Every record is validated
    /// the way the dim guard already was: a zero enrollment count
    /// (whose bogus mean `mean()`'s `count.max(1)` would silently
    /// mask), a duplicate speaker id (silent last-record-wins), or a
    /// non-finite sum (NaN/∞ would poison every later verify score) all
    /// reject the file instead of loading corrupt state.
    pub fn load(path: impl AsRef<Path>, n_shards: usize) -> Result<Self> {
        let mut r = BinReader::open(path)?;
        let n = r.read_u64()? as usize;
        let reg = Self::new(n_shards);
        for _ in 0..n {
            let id = r.read_string()?;
            let count = r.read_u64()?;
            let model_fp = r.read_u64()?;
            let dim = r.read_u64()? as usize;
            if count == 0 {
                bail!("speaker `{id}` has zero enrollments — corrupt registry file?");
            }
            if dim > 1 << 20 {
                bail!("i-vector dim {dim} implausible — corrupt registry file?");
            }
            let sum = r.read_f64_vec(dim)?;
            if !sum.iter().all(|x| x.is_finite()) {
                bail!("speaker `{id}` has a non-finite enrollment sum — corrupt registry file?");
            }
            let mut shard = lock(reg.shard(&id));
            if shard.insert(id.clone(), SpeakerProfile { count, sum, model_fp }).is_some() {
                bail!("duplicate speaker `{id}` — corrupt registry file?");
            }
        }
        Ok(reg)
    }
}

/// [`Registry::save`]'s write stage: serialize the snapshot into `tmp`
/// and rename it over `path` — split out so the caller can clean up the
/// temp file on any failure along the way.
fn write_snapshot_then_rename(
    snapshot: &[(String, SpeakerProfile)],
    tmp: &Path,
    path: &Path,
) -> Result<()> {
    let mut w = BinWriter::create(tmp)?;
    w.write_u64(snapshot.len() as u64)?;
    for (id, p) in snapshot {
        w.write_string(id)?;
        w.write_u64(p.count)?;
        w.write_u64(p.model_fp)?;
        w.write_u64(p.sum.len() as u64)?;
        w.write_f64_slice(&p.sum)?;
    }
    // fsync before the rename: the swap is only crash-atomic if the
    // temp file's data blocks reach stable storage before the rename
    // is journaled
    w.finish_synced()?;
    std::fs::rename(tmp, path)
        .with_context(|| format!("rename {} into place", tmp.display()))?;
    // best effort: persist the directory entry too, so the rename
    // itself survives a power loss (failure here leaves the old,
    // intact snapshot — not corruption — so it is not fatal)
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: u64 = 7;

    #[test]
    fn enrollment_averages() {
        let reg = Registry::new(4);
        assert!(reg.is_empty());
        assert_eq!(reg.enroll("alice", &[1.0, 2.0], FP).unwrap(), 1);
        assert_eq!(reg.enroll("alice", &[3.0, 4.0], FP).unwrap(), 2);
        let p = reg.profile("alice").unwrap();
        assert_eq!(p.count, 2);
        assert_eq!(p.mean(), vec![2.0, 3.0]);
        assert!(reg.profile("bob").is_none());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.total_enrollments(), 2);
    }

    #[test]
    fn mixed_model_epochs_rejected() {
        let reg = Registry::new(2);
        reg.enroll("a", &[1.0], 1).unwrap();
        let err = reg.enroll("a", &[1.0], 2).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // count unchanged by the rejected enrollment
        assert_eq!(reg.profile("a").unwrap().count, 1);
        // after removal the speaker can enroll under the new model
        assert!(reg.remove("a"));
        assert_eq!(reg.enroll("a", &[1.0], 2).unwrap(), 1);
    }

    #[test]
    fn remove_and_ids() {
        let reg = Registry::new(3);
        for id in ["s2", "s0", "s1"] {
            reg.enroll(id, &[1.0], FP).unwrap();
        }
        assert_eq!(reg.speaker_ids(), vec!["s0", "s1", "s2"]);
        assert!(reg.remove("s1"));
        assert!(!reg.remove("s1"));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn dim_mismatch_is_an_error_and_the_shard_survives() {
        // satellite acceptance: a dimension-mismatched enrollment is an
        // error to that caller, and the shard keeps serving everyone
        let reg = Registry::new(1); // one shard: every id shares the lock
        reg.enroll("alice", &[1.0, 2.0], FP).unwrap();
        let err = reg.enroll("alice", &[1.0, 2.0, 3.0], FP).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        // profile untouched by the rejected enrollment
        let p = reg.profile("alice").unwrap();
        assert_eq!(p.count, 1);
        assert_eq!(p.sum, vec![1.0, 2.0]);
        // the same shard still takes enrollments — no poisoned lock
        assert_eq!(reg.enroll("bob", &[0.5, 0.5], FP).unwrap(), 1);
        assert_eq!(reg.enroll("alice", &[3.0, 4.0], FP).unwrap(), 2);
    }

    #[test]
    fn poisoned_shard_lock_is_tolerated() {
        // a panic while holding a shard mutex (a buggy holder) must not
        // take the shard down for every later caller
        let reg = Registry::new(1);
        reg.enroll("alice", &[1.0], FP).unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = reg.shard("alice").lock().unwrap();
            panic!("holder bug");
        }));
        assert!(caught.is_err());
        assert!(reg.shard("alice").is_poisoned(), "the mutex really was poisoned");
        // every accessor keeps working through the poison
        assert_eq!(reg.profile("alice").unwrap().count, 1);
        assert_eq!(reg.enroll("alice", &[2.0], FP).unwrap(), 2);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.total_enrollments(), 2);
        assert_eq!(reg.speaker_ids(), vec!["alice"]);
        assert!(reg.remove("alice"));
    }

    /// Hand-write a registry file in the `save` format from raw records.
    fn write_registry_file(
        path: &std::path::Path,
        records: &[(&str, u64, u64, &[f64])],
    ) -> Result<()> {
        let mut w = BinWriter::create(path)?;
        w.write_u64(records.len() as u64)?;
        for (id, count, fp, sum) in records {
            w.write_string(id)?;
            w.write_u64(*count)?;
            w.write_u64(*fp)?;
            w.write_u64(sum.len() as u64)?;
            w.write_f64_slice(sum)?;
        }
        w.finish()
    }

    #[test]
    fn load_rejects_corrupt_records() {
        let dir = std::env::temp_dir().join("ivtv_registry_corrupt_test");
        std::fs::create_dir_all(&dir).unwrap();

        // zero-count profile: mean() would silently divide by max(1)
        let p = dir.join("zero_count.bin");
        write_registry_file(&p, &[("a", 0, FP, &[1.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("zero enrollments"), "{err}");

        // duplicate speaker ids: last record would silently win
        let p = dir.join("dup.bin");
        write_registry_file(&p, &[("a", 1, FP, &[1.0]), ("a", 2, FP, &[9.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("duplicate speaker"), "{err}");

        // non-finite sums: NaN would poison every later verify score
        let p = dir.join("nan.bin");
        write_registry_file(&p, &[("a", 1, FP, &[f64::NAN, 1.0])]).unwrap();
        let err = Registry::load(&p, 2).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let p = dir.join("inf.bin");
        write_registry_file(&p, &[("a", 1, FP, &[f64::INFINITY])]).unwrap();
        assert!(Registry::load(&p, 2).is_err());

        // a well-formed file with the same shapes still loads
        let p = dir.join("ok.bin");
        write_registry_file(&p, &[("a", 1, FP, &[1.0]), ("b", 2, FP, &[4.0])]).unwrap();
        let reg = Registry::load(&p, 2).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.profile("b").unwrap().mean(), vec![2.0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let reg = Registry::new(5);
        reg.enroll("a", &[1.0, -1.0], FP).unwrap();
        reg.enroll("a", &[2.0, -2.0], FP).unwrap();
        reg.enroll("b", &[0.5, 0.25], 9).unwrap();
        let dir = std::env::temp_dir().join("ivtv_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("reg.bin");
        reg.save(&p).unwrap();
        // reload into a *different* shard count
        let back = Registry::load(&p, 2).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.profile("a").unwrap(), reg.profile("a").unwrap());
        assert_eq!(back.profile("b").unwrap(), reg.profile("b").unwrap());
    }

    /// Satellite acceptance: `save` goes through a same-directory temp
    /// file renamed into place — an interrupted save can no longer
    /// truncate the only snapshot, an overwrite is all-or-nothing, and
    /// no temp files are left behind.
    #[test]
    fn save_is_atomic_and_leaves_no_temp_files() {
        let dir = std::env::temp_dir().join("ivtv_registry_atomic_test");
        // fresh dir: the leftover-file assertion below must see only
        // what this test writes
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("reg.bin");

        let reg = Registry::new(3);
        reg.enroll("a", &[1.0, 2.0], FP).unwrap();
        reg.save(&p).unwrap();

        // overwrite with a bigger registry: the target is replaced wholesale
        reg.enroll("b", &[3.0, 4.0], FP).unwrap();
        reg.enroll("c", &[5.0, 6.0], FP).unwrap();
        reg.save(&p).unwrap();
        let back = Registry::load(&p, 2).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.profile("c").unwrap().sum, vec![5.0, 6.0]);

        // nothing but the snapshot itself remains in the directory
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "reg.bin")
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");

        // a failed save (unwritable target directory) reports an error
        // and leaves the existing snapshot untouched
        let bad = dir.join("no_such_subdir_parent.bin");
        std::fs::write(&bad, b"sentinel").unwrap();
        let unwritable = bad.join("reg.bin"); // parent is a file → create fails
        assert!(reg.save(&unwritable).is_err());
        let still = Registry::load(&p, 2).unwrap();
        assert_eq!(still.len(), 3, "failed save must not touch the good snapshot");
    }

    #[test]
    fn concurrent_enrollments_are_not_lost() {
        let reg = std::sync::Arc::new(Registry::new(8));
        let threads = 8;
        let per_thread = 200;
        let mut handles = Vec::new();
        for t in 0..threads {
            let reg = std::sync::Arc::clone(&reg);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    // contended speaker + a per-thread speaker
                    reg.enroll("shared", &[1.0, 1.0], FP).unwrap();
                    reg.enroll(&format!("spk{t}"), &[i as f64, 0.0], FP).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let shared = reg.profile("shared").unwrap();
        assert_eq!(shared.count, (threads * per_thread) as u64);
        // identical addends ⇒ the sum is exact regardless of order
        assert_eq!(shared.mean(), vec![1.0, 1.0]);
        assert_eq!(reg.len(), threads + 1);
        assert_eq!(reg.total_enrollments(), (2 * threads * per_thread) as u64);
    }
}
