//! The serving model bundle: every artifact a request needs, loaded as
//! one immutable unit so the engine can hot-swap it atomically.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use crate::backend::Backend;
use crate::config::Config;
use crate::gmm::{
    AlignPrecision, AlignScratch, BatchAligner, DiagGmm, FullGmm, PackedDiag, PackedDiagF32,
};
use crate::io::Serialize;
use crate::ivector::{extract_cpu, EstepConsts, TvModel, UttStats};
use crate::linalg::Mat;
use crate::stats::BwStats;

/// Everything the online paths need: the UBM pair for alignment, the
/// total-variability model for extraction, the LDA+PLDA backend for
/// scoring, and the alignment pruning parameters (baked in so a bundle
/// is self-contained — serving must not depend on the offline config
/// that trained it).
#[derive(Debug, Clone)]
pub struct ModelBundle {
    pub diag: DiagGmm,
    pub full: FullGmm,
    pub tvm: TvModel,
    pub backend: Backend,
    /// Top-K Gaussians kept per frame in alignment.
    pub top_k: usize,
    /// Posterior pruning threshold.
    pub min_post: f64,
}

impl ModelBundle {
    /// Assemble from the per-stage artifacts the offline `pipeline`
    /// writes into a work dir (preferring the realignment-updated
    /// `ubm_final.*` the extractor was trained against, falling back to
    /// the pre-training UBM).
    pub fn from_work_dir(work: &str, cfg: &Config) -> Result<Self> {
        let (diag, full) = if Path::new(&format!("{work}/ubm_final.diag")).exists() {
            (
                crate::io::load(format!("{work}/ubm_final.diag"))?,
                crate::io::load(format!("{work}/ubm_final.full"))?,
            )
        } else {
            (
                crate::io::load(format!("{work}/ubm.diag"))
                    .context("no UBM in work dir — run `ivector-tv pipeline` first")?,
                crate::io::load(format!("{work}/ubm.full"))?,
            )
        };
        let tvm = crate::io::load(format!("{work}/tvm.bin"))
            .context("no extractor in work dir — run `ivector-tv train` first")?;
        let backend = crate::io::load(format!("{work}/backend.bin"))
            .context("no backend in work dir — run `ivector-tv backend` first")?;
        Ok(Self { diag, full, tvm, backend, top_k: cfg.tvm.top_k, min_post: cfg.tvm.min_post })
    }

    /// Cheap content fingerprint (FNV-1a over the dims, the alignment
    /// parameters, the prior mean, and bounded stride-samples of every
    /// parameter block that shapes an i-vector: T, **and** the diag +
    /// full UBM the alignment runs on — a changed UBM changes the
    /// Baum-Welch statistics, which is a different i-vector space even
    /// under an identical T). Enrollments are tagged with it, so
    /// verification can refuse to score across genuinely different
    /// models after a hot swap while a value-identical bundle reload
    /// keeps matching. Not cryptographic — a collision needs a
    /// retrained model agreeing on every sampled parameter bit.
    pub fn fingerprint(&self) -> u64 {
        fn fold(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0000_0100_0000_01B3)
        }
        // stride-sample a flat f64 block so each block costs O(16k)
        // elements at any scale
        fn fold_slice(mut h: u64, data: &[f64]) -> u64 {
            let stride = (data.len() >> 14).max(1);
            let mut idx = 0usize;
            while idx < data.len() {
                h = fold(h, data[idx].to_bits());
                idx += stride;
            }
            h
        }
        let (c, f, r) = (self.tvm.num_components(), self.tvm.feat_dim(), self.tvm.rank());
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        for d in [c as u64, f as u64, r as u64, self.top_k as u64] {
            h = fold(h, d);
        }
        h = fold(h, self.min_post.to_bits());
        for &p in &self.tvm.prior_mean {
            h = fold(h, p.to_bits());
        }
        // T (the extractor space)
        let per = f * r;
        let total = c * per;
        let stride = (total >> 16).max(1);
        let mut idx = 0usize;
        while idx < total {
            h = fold(h, self.tvm.t[idx / per].as_slice()[idx % per].to_bits());
            idx += stride;
        }
        // the alignment models (statistics space)
        h = fold_slice(h, &self.diag.weights);
        h = fold_slice(h, self.diag.means.as_slice());
        h = fold_slice(h, self.diag.vars.as_slice());
        h = fold_slice(h, &self.full.weights);
        h = fold_slice(h, self.full.means.as_slice());
        for cov in &self.full.covs {
            h = fold_slice(h, cov.as_slice());
        }
        h
    }

    /// Load `work/bundle.bin` when present (written by `pipeline`),
    /// falling back to assembling from the per-stage artifacts. Rejects
    /// a bundle whose feature dim disagrees with `cfg` — serving
    /// callers sample traffic at the config's dims, so a mismatch would
    /// otherwise surface as an assert deep inside the aligner — and a
    /// backend whose chain dims disagree with the extractor, which
    /// would otherwise load fine and panic deep inside `project` on the
    /// first verify.
    pub fn load_auto(work: &str, cfg: &Config) -> Result<Self> {
        let bundled = format!("{work}/bundle.bin");
        let bundle: Self = if Path::new(&bundled).exists() {
            crate::io::load(&bundled)?
        } else {
            Self::from_work_dir(work, cfg)?
        };
        anyhow::ensure!(
            bundle.tvm.feat_dim() == cfg.feat_dim(),
            "bundle feature dim {} does not match config dim {} — pass the \
             --config the pipeline was trained with",
            bundle.tvm.feat_dim(),
            cfg.feat_dim()
        );
        bundle.check_backend_dims()?;
        Ok(bundle)
    }

    /// Reject a backend whose processing chain disagrees with the
    /// extractor's i-vector dimension (or with itself): mixed-artifact
    /// work dirs must fail at load time with a nameable cause, not on
    /// the first verify request.
    pub fn check_backend_dims(&self) -> Result<()> {
        let r = self.tvm.rank();
        ensure!(
            self.backend.input_dim() == r,
            "bundle backend expects {}-dim i-vectors but the extractor produces rank {} — \
             the backend was trained against a different extractor",
            self.backend.input_dim(),
            r
        );
        ensure!(
            self.backend.lda.w.cols() == r,
            "bundle backend LDA takes {}-dim input but the extractor produces rank {} — \
             the backend was trained against a different extractor",
            self.backend.lda.w.cols(),
            r
        );
        if let Some(wh) = &self.backend.whitening {
            ensure!(
                wh.p.cols() == r,
                "bundle backend whitening is {}-dim but the extractor produces rank {}",
                wh.p.cols(),
                r
            );
        }
        ensure!(
            self.backend.plda.mu.len() == self.backend.output_dim(),
            "bundle backend PLDA is {}-dim but its LDA projects to {} — \
             mismatched backend artifacts",
            self.backend.plda.mu.len(),
            self.backend.output_dim()
        );
        Ok(())
    }
}

impl Serialize for ModelBundle {
    fn write(&self, w: &mut crate::io::BinWriter) -> Result<()> {
        self.diag.write(w)?;
        self.full.write(w)?;
        self.tvm.write(w)?;
        self.backend.write(w)?;
        w.write_u32(self.top_k as u32)?;
        w.write_f64(self.min_post)
    }

    fn read(r: &mut crate::io::BinReader) -> Result<Self> {
        Ok(Self {
            diag: DiagGmm::read(r)?,
            full: FullGmm::read(r)?,
            tvm: TvModel::read(r)?,
            backend: Backend::read(r)?,
            top_k: r.read_u32()? as usize,
            min_post: r.read_f64()?,
        })
    }
}

/// A bounded checkout pool of [`AlignScratch`] buffers, owned by a
/// [`ServeModel`] so every request under that model reuses aligner
/// scratch (~2 MB at paper dims) instead of rebuilding it — the serving
/// mirror of how batch workers reuse their `EstepWorkspace`. Living on
/// the model (not the engine) keeps the pool shape-correct by
/// construction: a hot swap retires the pool with its model.
#[derive(Debug)]
pub(crate) struct ScratchPool {
    slots: Mutex<Vec<AlignScratch>>,
    /// Retained buffers bound (`cap = 0` disables pooling entirely).
    cap: usize,
    /// Fresh allocations (pool empty at checkout).
    created: AtomicU64,
    /// Checkouts served from the pool.
    reused: AtomicU64,
}

impl ScratchPool {
    fn new(cap: usize) -> Self {
        Self {
            slots: Mutex::new(Vec::with_capacity(cap.min(64))),
            cap,
            created: AtomicU64::new(0),
            reused: AtomicU64::new(0),
        }
    }

    /// Pop a pooled buffer, or allocate when the pool is dry. Shape
    /// and precision are revalidated defensively even though a
    /// per-model pool only ever holds one variant.
    fn checkout(&self, precision: AlignPrecision, f_dim: usize, c_n: usize) -> AlignScratch {
        if let Some(s) = self.slots.lock().unwrap().pop() {
            if s.fits(f_dim, c_n) && s.precision() == precision {
                self.reused.fetch_add(1, Ordering::Relaxed);
                return s;
            }
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        AlignScratch::with_precision(precision, f_dim, c_n)
    }

    /// Return a buffer; dropped silently once the pool is at capacity
    /// (a burst of concurrent requests must not ratchet memory up
    /// forever).
    fn checkin(&self, scratch: AlignScratch) {
        let mut slots = self.slots.lock().unwrap();
        if slots.len() < self.cap {
            slots.push(scratch);
        }
    }

    /// (fresh allocations, pooled reuses) so far.
    fn stats(&self) -> (u64, u64) {
        (self.created.load(Ordering::Relaxed), self.reused.load(Ordering::Relaxed))
    }
}

/// The per-model alignment weight pack at the model's configured
/// scoring precision — exactly one variant is built per bundle load.
#[derive(Debug)]
enum ModelPack {
    F64(PackedDiag),
    F32(PackedDiagF32),
}

impl ModelPack {
    fn feat_dim(&self) -> usize {
        match self {
            ModelPack::F64(p) => p.feat_dim(),
            ModelPack::F32(p) => p.feat_dim(),
        }
    }

    fn num_components(&self) -> usize {
        match self {
            ModelPack::F64(p) => p.num_components(),
            ModelPack::F32(p) => p.num_components(),
        }
    }

    /// The precision is the variant — no separate field to drift.
    fn precision(&self) -> AlignPrecision {
        match self {
            ModelPack::F64(_) => AlignPrecision::F64,
            ModelPack::F32(_) => AlignPrecision::F32,
        }
    }
}

/// Incremental Baum-Welch accumulator — the chunk-feedable half of the
/// former one-shot `utt_stats` path, and the per-session state of the
/// streaming layer. Feature chunks are aligned and absorbed as they
/// arrive ([`ServeModel::absorb`]); the partial zeroth/first-order
/// statistics can be finalized into an [`UttStats`] (and an i-vector)
/// at any instant.
#[derive(Debug, Clone)]
pub struct StatAccum {
    /// Running raw statistics (merged exactly, chunk by chunk).
    bw: BwStats,
    /// Feature frames absorbed so far.
    frames: usize,
}

impl StatAccum {
    /// Feature frames absorbed so far (the early-exit frame budget).
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Total posterior occupancy Σ_c n_c absorbed so far.
    pub fn total_occupancy(&self) -> f64 {
        self.bw.total_count()
    }
}

/// An immutable bundle plus its derived per-bundle constants, shared as
/// `Arc<ServeModel>` between request threads and batch workers. Built
/// once per (hot-)load; the batched E-step constants are the serving
/// mirror of what the trainer rebuilds each EM iteration.
#[derive(Debug)]
pub struct ServeModel {
    pub bundle: ModelBundle,
    /// Batched E-step constants (flat `TᵀΣ⁻¹`, packed `TᵀΣ⁻¹T`).
    pub consts: EstepConsts,
    /// Packed diagonal alignment weights at the configured precision
    /// (`[align] precision` — the variant *is* the precision), shared
    /// by every request's aligner (the pack is per-model, not
    /// per-request).
    pack: ModelPack,
    /// Checkout pool of aligner scratch shared by every request's
    /// aligner (the scratch is per-request-in-flight, not per-request).
    scratch: ScratchPool,
    /// [`ModelBundle::fingerprint`], precomputed — tags enrollments so
    /// cross-model scoring after a hot swap is refused.
    pub fingerprint: u64,
}

/// Scratch buffers retained when a caller does not configure the pool
/// (covers a handful of concurrent request threads).
const DEFAULT_SCRATCH_POOL: usize = 8;

impl ServeModel {
    pub fn new(bundle: ModelBundle) -> Self {
        Self::with_scratch_pool(bundle, DEFAULT_SCRATCH_POOL)
    }

    /// Build with an explicit scratch-pool bound (`[serve] scratch_pool`;
    /// 0 disables pooling) at the default f64 precision.
    pub fn with_scratch_pool(bundle: ModelBundle, scratch_pool: usize) -> Self {
        Self::with_options(bundle, scratch_pool, AlignPrecision::F64)
    }

    /// Build with an explicit scratch-pool bound and alignment scoring
    /// precision — the serving entry point for `[align] precision`.
    pub fn with_options(
        bundle: ModelBundle,
        scratch_pool: usize,
        precision: AlignPrecision,
    ) -> Self {
        let consts = bundle.tvm.precompute_consts();
        let pack = match precision {
            AlignPrecision::F64 => ModelPack::F64(PackedDiag::new(&bundle.diag)),
            AlignPrecision::F32 => ModelPack::F32(PackedDiagF32::new(&bundle.diag)),
        };
        let fingerprint = bundle.fingerprint();
        Self { bundle, consts, pack, scratch: ScratchPool::new(scratch_pool), fingerprint }
    }

    /// i-vector dimension.
    pub fn rank(&self) -> usize {
        self.consts.r
    }

    /// Alignment scoring precision this model serves at.
    pub fn precision(&self) -> AlignPrecision {
        self.pack.precision()
    }

    /// (fresh scratch allocations, pooled reuses) — the serving
    /// report's measure of per-request buffer churn.
    pub fn scratch_stats(&self) -> (u64, u64) {
        self.scratch.stats()
    }

    /// Fresh chunk-feedable accumulator shaped for this model — the
    /// streaming entry point ([`ServeModel::absorb`] feeds it).
    pub fn stat_accum(&self) -> StatAccum {
        StatAccum {
            bw: BwStats::zeros(self.bundle.diag.num_components(), self.pack.feat_dim(), false),
            frames: 0,
        }
    }

    /// Align one feature chunk and fold its Baum-Welch statistics into
    /// `acc`. Alignment is frame-local (the aligner's internal BLOCK
    /// grouping only batches GEMMs — per-frame posteriors never depend
    /// on neighbouring frames) and [`BwStats::merge`] is exactly
    /// additive, so absorbing an utterance in chunks of any size yields
    /// the same statistics as one [`ServeModel::utt_stats`] call — the
    /// invariant the chunked-equivalence suite pins down. Aligner
    /// scratch is checked out of the model's pool and returned after
    /// alignment, so steady-state streaming allocates nothing here.
    pub fn absorb(&self, acc: &mut StatAccum, chunk: &Mat) {
        assert_eq!(
            acc.bw.n.len(),
            self.bundle.diag.num_components(),
            "accumulator belongs to a different model"
        );
        if chunk.rows() == 0 {
            return;
        }
        assert_eq!(chunk.cols(), self.pack.feat_dim(), "chunk feature dim mismatch");
        let scratch = self.scratch.checkout(
            self.pack.precision(),
            self.pack.feat_dim(),
            self.pack.num_components(),
        );
        let mut aligner = match &self.pack {
            ModelPack::F64(p) => BatchAligner::with_scratch(
                p,
                &self.bundle.full,
                self.bundle.top_k,
                self.bundle.min_post,
                scratch,
            ),
            ModelPack::F32(p) => BatchAligner::with_scratch_f32(
                p,
                &self.bundle.full,
                self.bundle.top_k,
                self.bundle.min_post,
                scratch,
            ),
        };
        let posts = aligner.align_utterance(chunk);
        self.scratch.checkin(aligner.into_scratch());
        let bw = BwStats::accumulate(chunk, &posts, self.bundle.diag.num_components(), false);
        acc.bw.merge(&bw);
        acc.frames += chunk.rows();
    }

    /// Finalize an accumulator's partial statistics into the
    /// fixed-size [`UttStats`] the E-step consumes — valid at any
    /// instant (formulation centering is linear in the raw stats, so a
    /// partial finalize is exact for the frames absorbed so far).
    pub fn finalize_accum(&self, acc: &StatAccum) -> UttStats {
        UttStats::from_bw(&acc.bw, &self.bundle.tvm)
    }

    /// Single-threaded i-vector from an accumulator's partial stats
    /// (no batcher) — the streaming mirror of
    /// [`ServeModel::extract_serial`]. An empty accumulator yields the
    /// zero i-vector (posterior = prior).
    pub fn extract_from_accum(&self, acc: &StatAccum) -> Vec<f64> {
        let stats = self.finalize_accum(acc);
        extract_cpu(&self.bundle.tvm, std::slice::from_ref(&stats), 1).row(0).to_vec()
    }

    /// The request-thread "loader" stage: align the utterance with the
    /// batched CPU aligner and accumulate its Baum-Welch statistics —
    /// the fixed-size representation the micro-batched E-step consumes
    /// (identical to the offline `extract` stage's per-utterance path).
    /// Thin wrapper over the chunk-feedable path: one absorb of the
    /// whole utterance, then finalize.
    pub fn utt_stats(&self, feats: &Mat) -> UttStats {
        let mut acc = self.stat_accum();
        self.absorb(&mut acc, feats);
        self.finalize_accum(&acc)
    }

    /// Single-threaded oracle extraction (no batcher): exactly the
    /// offline [`extract_cpu`] path on this utterance.
    pub fn extract_serial(&self, feats: &Mat) -> Vec<f64> {
        let stats = self.utt_stats(feats);
        extract_cpu(&self.bundle.tvm, std::slice::from_ref(&stats), 1).row(0).to_vec()
    }

    /// Project one raw i-vector through the backend chain
    /// (center → [whiten] → length-norm → LDA).
    pub fn project(&self, ivector: &[f64]) -> Vec<f64> {
        let x = Mat::from_vec(ivector.to_vec(), 1, ivector.len());
        self.bundle.backend.project(&x).row(0).to_vec()
    }

    /// PLDA log-likelihood ratio between an enrolled (mean) i-vector
    /// and a test i-vector, both raw — projection happens here.
    pub fn score(&self, enrolled: &[f64], test: &[f64]) -> f64 {
        let e = self.project(enrolled);
        let t = self.project(test);
        self.bundle.backend.plda.score_pair(&e, &t)
    }
}

#[cfg(test)]
mod tests {
    use super::super::bench::{tiny_serve_config, train_tiny_bundle};
    use super::*;

    #[test]
    fn bundle_roundtrips_through_disk() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let dir = std::env::temp_dir().join("ivtv_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bundle.bin");
        crate::io::save(&bundle, &p).unwrap();
        let back: ModelBundle = crate::io::load(&p).unwrap();
        assert_eq!(back.top_k, bundle.top_k);
        assert_eq!(back.min_post, bundle.min_post);
        assert!(back.tvm.t[0].approx_eq(&bundle.tvm.t[0], 0.0));
        assert!(back.full.means.approx_eq(&bundle.full.means, 0.0));
        // the reloaded bundle scores identically
        let world = super::super::bench::tiny_traffic(&cfg, 2, 9);
        let a = ServeModel::new(bundle);
        let b = ServeModel::new(back);
        let u = world.utterance(0, 0);
        let iva = a.extract_serial(&u);
        let ivb = b.extract_serial(&u);
        for (x, y) in iva.iter().zip(&ivb) {
            assert!((x - y).abs() < 1e-12);
        }
        assert!((a.score(&iva, &iva) - b.score(&ivb, &ivb)).abs() < 1e-9);
    }

    #[test]
    fn scratch_pool_reuses_buffers_across_requests() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let model = ServeModel::with_scratch_pool(bundle, 2);
        let world = super::super::bench::tiny_traffic(&cfg, 1, 11);
        let first = model.utt_stats(&world.utterance(0, 0));
        let (created, reused) = model.scratch_stats();
        assert_eq!((created, reused), (1, 0));
        // every sequential request after the first rides the pool
        for k in 1..5 {
            let again = model.utt_stats(&world.utterance(0, k));
            assert_eq!(again.n.len(), first.n.len());
        }
        let (created, reused) = model.scratch_stats();
        assert_eq!(created, 1, "sequential traffic must not allocate again");
        assert_eq!(reused, 4);
        // pooling is semantically invisible
        let k0 = model.utt_stats(&world.utterance(0, 0));
        assert_eq!(k0.n, first.n);
        assert!(k0.f.approx_eq(&first.f, 0.0));
    }

    #[test]
    fn f32_serve_model_matches_f64_within_tolerance() {
        // serving-path acceptance of the precision knob: an f32 model
        // extracts i-vectors equal to the f64 model's up to the f32
        // alignment tolerance, and its scratch pool recycles f32
        // buffers like the f64 pool does
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let f64_model = ServeModel::new(bundle.clone());
        let f32_model = ServeModel::with_options(bundle, 2, AlignPrecision::F32);
        assert_eq!(f64_model.precision(), AlignPrecision::F64);
        assert_eq!(f32_model.precision(), AlignPrecision::F32);
        let world = super::super::bench::tiny_traffic(&cfg, 2, 19);
        for s in 0..2 {
            for k in 0..3 {
                let u = world.utterance(s, k);
                let a = f64_model.extract_serial(&u);
                let b = f32_model.extract_serial(&u);
                // posting values agree to ~1e-4; the i-vector solve is
                // well-conditioned at tiny dims, so the i-vectors track
                let scale = 1.0 + a.iter().map(|x| x.abs()).fold(0.0, f64::max);
                for (x, y) in a.iter().zip(&b) {
                    assert!((x - y).abs() < 5e-3 * scale, "{x} vs {y}");
                }
            }
        }
        let (created, reused) = f32_model.scratch_stats();
        assert_eq!(created, 1, "sequential f32 traffic must reuse pooled scratch");
        assert_eq!(reused, 5);
    }

    #[test]
    fn scratch_pool_zero_disables_pooling() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let model = ServeModel::with_scratch_pool(bundle, 0);
        let world = super::super::bench::tiny_traffic(&cfg, 1, 11);
        for k in 0..3 {
            model.utt_stats(&world.utterance(0, k));
        }
        let (created, reused) = model.scratch_stats();
        assert_eq!((created, reused), (3, 0));
    }

    /// Satellite: chunked accumulation is exact. 1/3/7-frame chunks, a
    /// chunk size straddling the aligner's 128-frame BLOCK seam, and
    /// both alignment precisions all reproduce the one-shot stats and
    /// i-vector ≤ 1e-10 (alignment is frame-local; merging is additive).
    #[test]
    fn chunked_absorb_matches_one_shot_exactly() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let world = super::super::bench::tiny_traffic(&cfg, 2, 47);
        // a long utterance so chunk boundaries fall both inside and
        // across the aligner's internal 128-frame GEMM blocks
        let base = world.utterance(0, 0);
        let long = Mat::from_fn(300, base.cols(), |t, j| base.get(t % base.rows(), j));
        for precision in [AlignPrecision::F64, AlignPrecision::F32] {
            let model = ServeModel::with_options(bundle.clone(), 4, precision);
            let oracle_stats = model.utt_stats(&long);
            let oracle_iv = model.extract_serial(&long);
            for chunk in [1usize, 3, 7, 100, 128] {
                let mut acc = model.stat_accum();
                let mut t = 0;
                while t < long.rows() {
                    let hi = (t + chunk).min(long.rows());
                    let part = Mat::from_fn(hi - t, long.cols(), |r, j| long.get(t + r, j));
                    model.absorb(&mut acc, &part);
                    t = hi;
                }
                assert_eq!(acc.frames(), long.rows());
                let stats = model.finalize_accum(&acc);
                for (c, (a, b)) in stats.n.iter().zip(&oracle_stats.n).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
                        "{precision:?} chunk {chunk}: n[{c}] {a} vs {b}"
                    );
                }
                assert!(
                    stats.f.approx_eq(&oracle_stats.f, 1e-10 * (1.0 + oracle_stats.f.max_abs())),
                    "{precision:?} chunk {chunk}: f deviates by {}",
                    stats.f.sub(&oracle_stats.f).max_abs()
                );
                let iv = model.extract_from_accum(&acc);
                for (j, (a, b)) in iv.iter().zip(&oracle_iv).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
                        "{precision:?} chunk {chunk}: iv[{j}] {a} vs {b}"
                    );
                }
            }
        }
    }

    /// A mid-stream finalize is exact for the frames absorbed so far:
    /// the partial i-vector equals the one-shot i-vector of the prefix.
    #[test]
    fn chunked_partial_finalize_matches_prefix_one_shot() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let model = ServeModel::new(bundle);
        let world = super::super::bench::tiny_traffic(&cfg, 1, 53);
        let utt = world.utterance(0, 2);
        let cut = utt.rows() / 2;
        let prefix = Mat::from_fn(cut, utt.cols(), |t, j| utt.get(t, j));
        let suffix = Mat::from_fn(utt.rows() - cut, utt.cols(), |t, j| utt.get(cut + t, j));

        let mut acc = model.stat_accum();
        model.absorb(&mut acc, &prefix);
        let mid_iv = model.extract_from_accum(&acc);
        let oracle_mid = model.extract_serial(&prefix);
        for (a, b) in mid_iv.iter().zip(&oracle_mid) {
            assert!((a - b).abs() <= 1e-10 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // absorbing the rest converges on the full-utterance i-vector
        model.absorb(&mut acc, &suffix);
        assert_eq!(acc.frames(), utt.rows());
        let full_iv = model.extract_from_accum(&acc);
        let oracle_full = model.extract_serial(&utt);
        for (a, b) in full_iv.iter().zip(&oracle_full) {
            assert!((a - b).abs() <= 1e-10 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // an empty accumulator is the prior: the zero i-vector
        let empty = model.extract_from_accum(&model.stat_accum());
        assert!(empty.iter().all(|x| x.abs() < 1e-10));
    }

    #[test]
    fn load_auto_rejects_backend_dim_mismatch() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        bundle.check_backend_dims().unwrap();

        // a backend trained against a different extractor: every chain
        // stage is internally coherent at rank+1, so only the
        // backend-vs-extractor check can catch it
        let wrong_rank = bundle.tvm.rank() + 1;
        let mut rng = crate::rng::Rng::seed(99);
        let ivecs = Mat::from_fn(24, wrong_rank, |_, _| rng.normal());
        let labels: Vec<usize> = (0..24).map(|i| i % 4).collect();
        let foreign = crate::backend::Backend::train(
            &ivecs,
            &labels,
            &crate::backend::BackendOpts { lda_dim: 3, plda_iters: 2, whiten: false },
        )
        .unwrap();
        let mut mixed = bundle;
        mixed.backend = foreign;
        let err = mixed.check_backend_dims().unwrap_err();
        assert!(err.to_string().contains("different extractor"), "{err}");

        // and load_auto refuses the same bundle from disk
        let dir = std::env::temp_dir().join("ivtv_bundle_dim_test");
        std::fs::create_dir_all(&dir).unwrap();
        crate::io::save(&mixed, dir.join("bundle.bin")).unwrap();
        let err = ModelBundle::load_auto(dir.to_str().unwrap(), &cfg).unwrap_err();
        assert!(err.to_string().contains("different extractor"), "{err}");
    }

    #[test]
    fn serve_model_scores_separate_speakers() {
        let cfg = tiny_serve_config();
        let bundle = train_tiny_bundle(&cfg, 5).unwrap();
        let model = ServeModel::new(bundle);
        let world = super::super::bench::tiny_traffic(&cfg, 2, 31);
        // average enrollment, mean score over several test draws (a
        // single trial pair at tiny dims would be noise-dominated)
        let mut enroll = vec![0.0; model.rank()];
        for k in 0..3 {
            let iv = model.extract_serial(&world.utterance(0, k));
            for (e, x) in enroll.iter_mut().zip(&iv) {
                *e += x / 3.0;
            }
        }
        let mut target = 0.0;
        let mut impostor = 0.0;
        let trials = 6;
        for k in 0..trials {
            target += model.score(&enroll, &model.extract_serial(&world.utterance(0, 100 + k)));
            impostor +=
                model.score(&enroll, &model.extract_serial(&world.utterance(1, 100 + k)));
        }
        assert!(
            target > impostor,
            "mean target {} must out-score mean impostor {}",
            target / trials as f64,
            impostor / trials as f64
        );
    }
}
