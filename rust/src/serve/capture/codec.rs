//! Capture-log wire format: length-prefixed, CRC-checksummed,
//! seq-numbered request records behind a 20-byte `IVCL` header that
//! pins the bundle fingerprint the traffic was captured under.
//!
//! On-disk layout (all little-endian):
//!
//! ```text
//! "IVCL" u32:version u64:bundle_fp u32:crc32(first 16)   — file header
//! u32:payload_len u32:crc32(payload) payload             — per record
//! payload = u64:seq u8:kind u32:id_len id
//!           u32:rows u32:cols rows×cols×f64              — features
//!           u64:arrival_offset_ns u64:deadline_ms
//!           u8:outcome u8:has_score [f64:score]
//!           u8:n_spans n_spans×(u8:stage u64:ns)         — trace spans
//! ```
//!
//! Replay inherits `registry/wal.rs`'s two-way split exactly: a short or
//! CRC-failing **final** record is a torn tail (a crash mid-append —
//! tolerated, counted, never a panic), while the same damage with bytes
//! after it is mid-log corruption and refuses the whole log with a
//! typed [`CaptureError::Corrupt`]. The header carries its own CRC so a
//! bit-flipped bundle fingerprint can never silently pass the replayer's
//! same-bundle check.

use std::fmt;

use anyhow::{ensure, Result};

use crate::linalg::Mat;
use crate::obs::{Stage, TraceOutcome};
use crate::serve::registry::codec::{self, Cur};

pub(crate) const CAPTURE_MAGIC: &[u8; 4] = b"IVCL";
pub(crate) const CAPTURE_VERSION: u32 = 1;
/// Bytes of the file header (`IVCL` + version + fingerprint + CRC).
pub(crate) const HEADER_LEN: u64 = 20;
/// Upper bound on one record's payload. A captured utterance is frames
/// × feature-dim f64s — tens of KB at production dims — so anything
/// near 16 MB is corruption, not data.
const MAX_RECORD: u32 = 1 << 24;

const KIND_EXTRACT: u8 = 0;
const KIND_ENROLL: u8 = 1;
const KIND_VERIFY: u8 = 2;

/// The capture log went bad in a way that is *not* a torn tail.
#[derive(Debug)]
pub enum CaptureError {
    /// Mid-log damage: bad magic/version, a failed header or record
    /// checksum with bytes after it, or a sequence regression.
    Corrupt { record: u64, offset: u64, detail: String },
    /// The replayer refused to score: the serving bundle's fingerprint
    /// does not match the one the corpus was captured under.
    BundleMismatch { captured: u64, serving: u64 },
}

impl fmt::Display for CaptureError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Corrupt { record, offset, detail } => write!(
                f,
                "capture log corrupt at record {record} (byte offset {offset}): {detail}"
            ),
            Self::BundleMismatch { captured, serving } => write!(
                f,
                "capture bundle mismatch: corpus captured under fingerprint \
                 {captured:#018x}, serving bundle is {serving:#018x}"
            ),
        }
    }
}

impl std::error::Error for CaptureError {}

/// What kind of request a record holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    Extract,
    Enroll,
    Verify,
}

impl RequestKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Extract => "extract",
            Self::Enroll => "enroll",
            Self::Verify => "verify",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Self::Extract => KIND_EXTRACT,
            Self::Enroll => KIND_ENROLL,
            Self::Verify => KIND_VERIFY,
        }
    }
}

/// One captured request: everything needed to re-issue it and to check
/// the re-issued result against what production answered.
#[derive(Debug, Clone, PartialEq)]
pub struct CaptureRecord {
    /// Log sequence number (strictly increasing within one log; 0 is
    /// reserved — [`super::CaptureLog`] assigns on append).
    pub seq: u64,
    pub kind: RequestKind,
    /// Claimed speaker id (empty for extract).
    pub speaker: String,
    /// Feature frames, flattened row-major.
    pub rows: u32,
    pub cols: u32,
    pub feats: Vec<f64>,
    /// Nanoseconds since the recorder's capture epoch when the request
    /// arrived — one monotonic clock for the whole corpus, so replay
    /// can reproduce original inter-arrival timing.
    pub arrival_offset_ns: u64,
    /// The deadline the request ran under, in milliseconds.
    pub deadline_ms: u64,
    /// How the request ended, in the obs layer's outcome classes.
    pub outcome: TraceOutcome,
    /// Verify score / enroll count, when the request produced one.
    pub score: Option<f64>,
    /// Per-stage span durations lifted from the request's trace.
    pub spans: Vec<(Stage, u64)>,
}

impl CaptureRecord {
    /// The captured features as the engine's matrix type.
    pub fn mat(&self) -> Mat {
        Mat::from_vec(self.feats.clone(), self.rows as usize, self.cols as usize)
    }
}

fn outcome_tag(o: TraceOutcome) -> u8 {
    match o {
        TraceOutcome::Ok => 0,
        TraceOutcome::Shed => 1,
        TraceOutcome::Timeout => 2,
        TraceOutcome::Failed => 3,
    }
}

fn outcome_from_tag(tag: u8) -> Result<TraceOutcome> {
    Ok(match tag {
        0 => TraceOutcome::Ok,
        1 => TraceOutcome::Shed,
        2 => TraceOutcome::Timeout,
        3 => TraceOutcome::Failed,
        other => anyhow::bail!("unknown outcome tag {other}"),
    })
}

/// The 20-byte file header for a corpus captured under `bundle_fp`.
pub(crate) fn header(bundle_fp: u64) -> Vec<u8> {
    let mut h = Vec::with_capacity(HEADER_LEN as usize);
    h.extend_from_slice(CAPTURE_MAGIC);
    codec::put_u32(&mut h, CAPTURE_VERSION);
    codec::put_u64(&mut h, bundle_fp);
    let crc = codec::crc32(&h);
    codec::put_u32(&mut h, crc);
    h
}

/// Serialize one record (length prefix + CRC + payload).
pub(crate) fn encode_record(rec: &CaptureRecord) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64 + rec.feats.len() * 8);
    codec::put_u64(&mut payload, rec.seq);
    payload.push(rec.kind.tag());
    codec::put_str(&mut payload, &rec.speaker);
    codec::put_u32(&mut payload, rec.rows);
    codec::put_u32(&mut payload, rec.cols);
    codec::put_f64_slice(&mut payload, &rec.feats);
    codec::put_u64(&mut payload, rec.arrival_offset_ns);
    codec::put_u64(&mut payload, rec.deadline_ms);
    payload.push(outcome_tag(rec.outcome));
    match rec.score {
        Some(s) => {
            payload.push(1);
            codec::put_f64_slice(&mut payload, &[s]);
        }
        None => payload.push(0),
    }
    payload.push(rec.spans.len() as u8);
    for (stage, ns) in &rec.spans {
        payload.push(stage.index() as u8);
        codec::put_u64(&mut payload, *ns);
    }
    let mut out = Vec::with_capacity(payload.len() + 8);
    codec::put_u32(&mut out, payload.len() as u32);
    codec::put_u32(&mut out, codec::crc32(&payload));
    out.extend_from_slice(&payload);
    out
}

/// What [`replay_log`] recovered from a capture log's bytes.
#[derive(Debug, Default)]
pub struct CaptureReplay {
    /// Bundle fingerprint from the header (0 when the header never
    /// landed — an empty or header-torn log).
    pub fingerprint: u64,
    /// Intact records, in capture order.
    pub records: Vec<CaptureRecord>,
    /// True when the log ended in a short or CRC-failing final record —
    /// the signature of a crash mid-append.
    pub torn_tail: bool,
    /// Bytes of the valid prefix (header + intact records).
    pub valid_len: u64,
    /// Highest sequence number seen (0 when no records).
    pub last_seq: u64,
}

fn corrupt(record: u64, offset: usize, detail: impl Into<String>) -> anyhow::Error {
    CaptureError::Corrupt { record, offset: offset as u64, detail: detail.into() }.into()
}

/// Parse a capture-log image: every intact record up to a clean EOF or
/// a torn tail. Mid-log corruption is a typed error; a torn tail never
/// is.
pub(crate) fn replay_log(bytes: &[u8]) -> Result<CaptureReplay> {
    let mut rep = CaptureReplay::default();
    if (bytes.len() as u64) < HEADER_LEN {
        // empty (fresh log) or header-torn: nothing to replay
        rep.torn_tail = !bytes.is_empty();
        return Ok(rep);
    }
    if &bytes[..4] != CAPTURE_MAGIC {
        return Err(corrupt(0, 0, "bad magic — not a capture log"));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != CAPTURE_VERSION {
        return Err(corrupt(0, 4, format!("unsupported capture version {version}")));
    }
    let header_crc = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    if codec::crc32(&bytes[..16]) != header_crc {
        // a damaged fingerprint must never silently pass the replayer's
        // same-bundle check, so the header carries its own CRC
        return Err(corrupt(0, 16, "header checksum mismatch"));
    }
    rep.fingerprint = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    rep.valid_len = HEADER_LEN;
    let mut pos = HEADER_LEN as usize;
    let mut index = 0u64;
    while pos < bytes.len() {
        let rem = bytes.len() - pos;
        if rem < 8 {
            rep.torn_tail = true; // not even a record header made it out
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        let end = pos as u64 + 8 + u64::from(len);
        if len > MAX_RECORD {
            if end > bytes.len() as u64 {
                rep.torn_tail = true; // garbage length in a torn header
                break;
            }
            return Err(corrupt(index, pos, format!("record length {len} implausible")));
        }
        if end > bytes.len() as u64 {
            rep.torn_tail = true; // the record's bytes never all landed
            break;
        }
        let end = end as usize;
        let payload = &bytes[pos + 8..end];
        if codec::crc32(payload) != crc {
            if end == bytes.len() {
                rep.torn_tail = true; // garbage final record from a crashed write
                break;
            }
            return Err(corrupt(index, pos, "record checksum mismatch"));
        }
        let rec =
            decode_payload(payload).map_err(|e| corrupt(index, pos, format!("{e:#}")))?;
        if rec.seq <= rep.last_seq {
            return Err(corrupt(
                index,
                pos,
                format!("sequence {} does not advance past {}", rec.seq, rep.last_seq),
            ));
        }
        rep.last_seq = rec.seq;
        rep.records.push(rec);
        pos = end;
        rep.valid_len = pos as u64;
        index += 1;
    }
    Ok(rep)
}

/// Decode a CRC-verified payload. A failure here means the bytes are
/// exactly what some writer produced — a format bug or foreign writer —
/// so the caller treats it as corruption, torn tail or not.
fn decode_payload(payload: &[u8]) -> Result<CaptureRecord> {
    let mut c = Cur::new(payload);
    let seq = c.u64()?;
    ensure!(seq > 0, "record sequence 0 is reserved");
    let kind = match c.u8()? {
        KIND_EXTRACT => RequestKind::Extract,
        KIND_ENROLL => RequestKind::Enroll,
        KIND_VERIFY => RequestKind::Verify,
        other => anyhow::bail!("unknown request kind tag {other}"),
    };
    let speaker = c.str_u32()?;
    let rows = c.u32()?;
    let cols = c.u32()?;
    let n = (rows as usize)
        .checked_mul(cols as usize)
        .filter(|&n| n <= (MAX_RECORD as usize) / 8)
        .ok_or_else(|| anyhow::anyhow!("feature block {rows}x{cols} implausible"))?;
    let feats = c.f64_vec(n)?;
    let arrival_offset_ns = c.u64()?;
    let deadline_ms = c.u64()?;
    let outcome = outcome_from_tag(c.u8()?)?;
    let score = match c.u8()? {
        0 => None,
        1 => Some(c.f64_vec(1)?[0]),
        other => anyhow::bail!("bad score-presence tag {other}"),
    };
    let n_spans = c.u8()? as usize;
    let mut spans = Vec::with_capacity(n_spans);
    for _ in 0..n_spans {
        let idx = c.u8()? as usize;
        let stage = *Stage::ALL
            .get(idx)
            .ok_or_else(|| anyhow::anyhow!("unknown stage index {idx}"))?;
        spans.push((stage, c.u64()?));
    }
    ensure!(c.at_end(), "{} trailing bytes in record payload", c.remaining());
    Ok(CaptureRecord {
        seq,
        kind,
        speaker,
        rows,
        cols,
        feats,
        arrival_offset_ns,
        deadline_ms,
        outcome,
        score,
        spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_records() -> Vec<CaptureRecord> {
        vec![
            CaptureRecord {
                seq: 1,
                kind: RequestKind::Enroll,
                speaker: "spk_0".into(),
                rows: 2,
                cols: 3,
                feats: vec![1.0, -2.5, 0.125, 4.0, 0.0, -1.0],
                arrival_offset_ns: 10,
                deadline_ms: 250,
                outcome: TraceOutcome::Ok,
                score: Some(1.0),
                spans: vec![(Stage::Align, 1234), (Stage::EstepBatch, 98765)],
            },
            CaptureRecord {
                seq: 2,
                kind: RequestKind::Verify,
                speaker: "spk_0".into(),
                rows: 1,
                cols: 3,
                feats: vec![0.5, 0.25, -0.75],
                arrival_offset_ns: 2_000_000,
                deadline_ms: 250,
                outcome: TraceOutcome::Ok,
                score: Some(-3.75),
                spans: vec![(Stage::BackendProject, 42)],
            },
            CaptureRecord {
                seq: 5, // gaps are fine; only regressions are corrupt
                kind: RequestKind::Verify,
                speaker: "spk_1".into(),
                rows: 1,
                cols: 3,
                feats: vec![9.0, 8.0, 7.0],
                arrival_offset_ns: 3_500_000,
                deadline_ms: 250,
                outcome: TraceOutcome::Shed,
                score: None,
                spans: vec![],
            },
        ]
    }

    fn sample_log() -> Vec<u8> {
        let mut bytes = header(0xDEAD_BEEF_F00D_CAFE);
        for r in sample_records() {
            bytes.extend_from_slice(&encode_record(&r));
        }
        bytes
    }

    #[test]
    fn capture_encode_replay_round_trip() {
        let bytes = sample_log();
        let rep = replay_log(&bytes).unwrap();
        assert_eq!(rep.records, sample_records());
        assert_eq!(rep.fingerprint, 0xDEAD_BEEF_F00D_CAFE);
        assert!(!rep.torn_tail);
        assert_eq!(rep.valid_len, bytes.len() as u64);
        assert_eq!(rep.last_seq, 5);
    }

    #[test]
    fn capture_empty_and_header_only_logs_are_clean() {
        let rep = replay_log(&[]).unwrap();
        assert!(rep.records.is_empty() && !rep.torn_tail && rep.valid_len == 0);
        let rep = replay_log(&header(7)).unwrap();
        assert!(rep.records.is_empty() && !rep.torn_tail);
        assert_eq!(rep.fingerprint, 7);
        assert_eq!(rep.valid_len, HEADER_LEN);
    }

    #[test]
    fn capture_every_truncation_is_a_tolerated_torn_tail() {
        // the satellite sweep, byte level: chop the log at every prefix
        // length — replay must never panic, never error, and always
        // return an exact prefix of the original records
        let bytes = sample_log();
        let full = sample_records();
        for cut in 0..bytes.len() {
            let rep = replay_log(&bytes[..cut]).unwrap_or_else(|e| {
                panic!("cut at {cut} must be a torn tail, got error: {e:#}")
            });
            assert!(
                full.starts_with(&rep.records),
                "cut at {cut}: recovered records are not a prefix"
            );
            assert!(rep.valid_len <= cut as u64);
            // torn exactly when partial bytes dangle past the valid prefix
            assert_eq!(
                rep.torn_tail,
                (rep.valid_len as usize) < cut,
                "cut at {cut}: torn_tail disagrees with the dangling bytes"
            );
        }
    }

    #[test]
    fn capture_bit_flips_are_torn_tail_or_typed_corruption_never_wrong_data() {
        let bytes = sample_log();
        let full = sample_records();
        for offset in 0..bytes.len() {
            for bit in [0u8, 3, 7] {
                let mut bad = bytes.clone();
                bad[offset] ^= 1 << bit;
                match replay_log(&bad) {
                    Ok(rep) => {
                        // tolerated only as a torn *tail*: the surviving
                        // records must be an exact prefix, and the header
                        // (including the bundle fingerprint) must be the
                        // original — header flips are always typed errors
                        assert!(
                            full.starts_with(&rep.records),
                            "flip at {offset} bit {bit} loaded wrong records"
                        );
                        assert!(rep.records.len() < full.len());
                        assert_eq!(rep.fingerprint, 0xDEAD_BEEF_F00D_CAFE);
                    }
                    Err(e) => {
                        let typed = e.downcast_ref::<CaptureError>().unwrap_or_else(|| {
                            panic!("untyped error for flip at {offset}: {e:#}")
                        });
                        assert!(matches!(typed, CaptureError::Corrupt { .. }));
                    }
                }
            }
        }
    }

    #[test]
    fn capture_flipped_fingerprint_is_a_typed_error_not_a_wrong_bundle() {
        let mut bytes = sample_log();
        bytes[8] ^= 0x01; // low byte of the fingerprint
        let err = replay_log(&bytes).unwrap_err();
        match err.downcast_ref::<CaptureError>() {
            Some(CaptureError::Corrupt { record, offset, detail }) => {
                assert_eq!(*record, 0);
                assert_eq!(*offset, 16);
                assert!(detail.contains("header checksum"), "{detail}");
            }
            other => panic!("expected Corrupt, got {other:?} / {err:#}"),
        }
    }

    #[test]
    fn capture_mid_log_corruption_is_rejected_with_record_and_offset() {
        let mut bytes = sample_log();
        // flip a payload byte of the FIRST record — bytes follow it, so
        // this must never be shrugged off as a torn tail
        let flip_at = HEADER_LEN as usize + 8 + 2;
        bytes[flip_at] ^= 0x10;
        let err = replay_log(&bytes).unwrap_err();
        match err.downcast_ref::<CaptureError>() {
            Some(CaptureError::Corrupt { record, offset, detail }) => {
                assert_eq!(*record, 0);
                assert_eq!(*offset, HEADER_LEN);
                assert!(detail.contains("checksum"), "{detail}");
            }
            other => panic!("expected Corrupt, got {other:?} / {err:#}"),
        }
    }

    #[test]
    fn capture_sequence_regression_is_corruption() {
        let mut rec_a = sample_records().remove(1);
        rec_a.seq = 3;
        let rec_b = rec_a.clone(); // same seq twice
        let mut bytes = header(1);
        bytes.extend_from_slice(&encode_record(&rec_a));
        bytes.extend_from_slice(&encode_record(&rec_b));
        let err = replay_log(&bytes).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CaptureError>(),
                Some(CaptureError::Corrupt { record: 1, .. })
            ),
            "{err:#}"
        );
    }
}
