//! The capture tap: samples finished requests onto a bounded channel
//! drained by a background writer thread that owns the [`CaptureLog`].
//!
//! The cardinal rule is that capture must never block or slow a request
//! thread. Everything on the hot path is a policy check, a record
//! build, and a `try_send`; when the writer falls behind and the
//! channel fills, the record is dropped and `capture_dropped_total`
//! counts it — an overloaded recorder degrades the *corpus*, never the
//! traffic. Write failures latch the log dead (see [`CaptureLog`]) and
//! surface the same way: as counted drops plus a summary error, not as
//! request-path errors.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::linalg::Mat;
use crate::obs::{Counter, ObsRegistry, RequestTrace, Stage, TraceOutcome};

use super::codec::{CaptureRecord, RequestKind};
use super::CaptureLog;

// the sampling policy is config vocabulary (`[capture] policy`), so it
// lives with the other parseable knobs and is re-exported from here
pub use crate::config::SamplePolicy;

/// Construction knobs (the `[capture]` config section maps onto this).
#[derive(Debug, Clone)]
pub struct RecorderOptions {
    pub policy: SamplePolicy,
    /// Bounded channel depth between request threads and the writer.
    pub queue: usize,
    /// Fsync the log every this many appended records (and at close).
    pub sync_every: u64,
    /// `slow_only` cutoff, in milliseconds (ride `[obs]
    /// trace_threshold_ms` when wiring from config).
    pub slow_threshold_ms: f64,
    /// The request deadline the captured traffic ran under, stamped
    /// into every record so replay can reproduce it.
    pub deadline_ms: u64,
}

impl Default for RecorderOptions {
    fn default() -> Self {
        Self {
            policy: SamplePolicy::All,
            queue: 1024,
            sync_every: 64,
            slow_threshold_ms: 0.0,
            deadline_ms: 0,
        }
    }
}

impl RecorderOptions {
    /// Assemble from the full config: the `[capture]` shape plus the
    /// two knobs it rides — `[obs] trace_threshold_ms` (the `slow_only`
    /// cutoff) and `[serve] request_timeout_ms` (the deadline stamped
    /// into every record so replay knows the window traffic ran under).
    pub fn from_config(cfg: &crate::config::Config) -> Self {
        Self {
            policy: cfg.capture.policy,
            queue: cfg.capture.queue,
            sync_every: cfg.capture.sync_every,
            slow_threshold_ms: cfg.obs.trace_threshold_ms,
            deadline_ms: cfg.serve.request_timeout_ms,
        }
    }
}

/// What a capture session amounted to, reported by [`Recorder::close`].
#[derive(Debug, Clone)]
pub struct CaptureSummary {
    /// Records durably appended to the log.
    pub records: u64,
    /// Bytes appended (header included).
    pub bytes: u64,
    /// Sampled records that never reached the log: queue overflow or
    /// appends refused after a write failure.
    pub dropped: u64,
    /// First write/sync failure the writer hit, if any.
    pub write_error: Option<String>,
}

/// The request-path tap. Shared (`Arc`) between the engine/dispatcher
/// hook and the owner that eventually calls [`Recorder::close`].
pub struct Recorder {
    policy: SamplePolicy,
    slow_threshold: Duration,
    deadline_ms: u64,
    /// All arrival offsets are measured on this one clock.
    epoch: Instant,
    /// Requests offered to the sampler (drives `Rate`).
    seen: AtomicU64,
    tx: Mutex<Option<SyncSender<CaptureRecord>>>,
    writer: Mutex<Option<JoinHandle<(Option<String>, u64)>>>,
    records: Counter,
    bytes: Counter,
    dropped: Counter,
}

impl Recorder {
    /// Spawn the background writer over a freshly created log and
    /// register the capture counters on `obs`.
    pub fn new(log: CaptureLog, opts: &RecorderOptions, obs: &ObsRegistry) -> Arc<Self> {
        let records = obs.counter("capture_records_total", &[]);
        let bytes = obs.counter("capture_bytes_total", &[]);
        let dropped = obs.counter("capture_dropped_total", &[]);
        let (tx, rx) = sync_channel::<CaptureRecord>(opts.queue.max(1));
        let writer = {
            let records = records.clone();
            let bytes = bytes.clone();
            let dropped = dropped.clone();
            let sync_every = opts.sync_every.max(1);
            std::thread::Builder::new()
                .name("capture-writer".into())
                .spawn(move || {
                    let mut log = log;
                    let mut write_error: Option<String> = None;
                    let mut since_sync = 0u64;
                    while let Ok(rec) = rx.recv() {
                        match log.append(rec) {
                            Ok(n) => {
                                records.inc();
                                bytes.add(n);
                                since_sync += 1;
                                if since_sync >= sync_every {
                                    since_sync = 0;
                                    if let Err(e) = log.sync() {
                                        write_error.get_or_insert(format!("{e:#}"));
                                    }
                                }
                            }
                            Err(e) => {
                                // the log latches dead after the first
                                // failure, so every later append lands
                                // here cheaply — counted, never silent
                                dropped.inc();
                                write_error.get_or_insert(format!("{e:#}"));
                            }
                        }
                    }
                    if let Err(e) = log.sync() {
                        write_error.get_or_insert(format!("{e:#}"));
                    }
                    (write_error, log.bytes())
                })
                .expect("spawn capture writer")
        };
        Arc::new(Self {
            policy: opts.policy,
            slow_threshold: Duration::from_nanos(
                (opts.slow_threshold_ms.max(0.0) * 1e6) as u64,
            ),
            deadline_ms: opts.deadline_ms,
            epoch: Instant::now(),
            seen: AtomicU64::new(0),
            tx: Mutex::new(Some(tx)),
            writer: Mutex::new(Some(writer)),
            records,
            bytes,
            dropped,
        })
    }

    /// Offer one finished request. Non-blocking: the worst case is a
    /// policy check plus a failed `try_send` (counted as a drop).
    ///
    /// `elapsed` is the request's wall time as measured at the hook
    /// site; the arrival offset is derived from it so replay reproduces
    /// admission-time spacing, not completion-time spacing. `trace` is
    /// the request's obs trace when one was minted — its per-stage
    /// spans ride along into the record.
    pub fn observe(
        &self,
        kind: RequestKind,
        speaker: &str,
        feats: &Mat,
        outcome: TraceOutcome,
        score: Option<f64>,
        elapsed: Duration,
        trace: Option<&RequestTrace>,
    ) {
        let seen = self.seen.fetch_add(1, Ordering::Relaxed);
        let sampled = match self.policy {
            SamplePolicy::All => true,
            SamplePolicy::Rate(n) => n <= 1 || seen % u64::from(n) == 0,
            SamplePolicy::SlowOnly => elapsed >= self.slow_threshold,
            SamplePolicy::ErrorsOnly => outcome != TraceOutcome::Ok,
        };
        if !sampled {
            return;
        }
        let spans: Vec<(Stage, u64)> = match trace {
            Some(t) => Stage::ALL
                .iter()
                .filter_map(|&s| {
                    let ns = t.stage_ns(s);
                    (ns > 0).then_some((s, ns))
                })
                .collect(),
            None => Vec::new(),
        };
        let rec = CaptureRecord {
            seq: 0, // the log assigns on append
            kind,
            speaker: speaker.to_string(),
            rows: feats.rows() as u32,
            cols: feats.cols() as u32,
            feats: feats.as_slice().to_vec(),
            arrival_offset_ns: self
                .epoch
                .elapsed()
                .saturating_sub(elapsed)
                .as_nanos()
                .min(u128::from(u64::MAX)) as u64,
            deadline_ms: self.deadline_ms,
            outcome,
            score,
            spans,
        };
        let guard = self.tx.lock().unwrap_or_else(|p| p.into_inner());
        match guard.as_ref() {
            Some(tx) if tx.try_send(rec).is_ok() => {}
            // full queue, or the session is already closed
            _ => self.dropped.inc(),
        }
    }

    /// End the session: stop accepting records, drain the queue, final
    /// fsync, and report what landed. Idempotent.
    pub fn close(&self) -> CaptureSummary {
        let tx = self.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
        drop(tx); // writer's recv loop ends once the queue drains
        let handle = self.writer.lock().unwrap_or_else(|p| p.into_inner()).take();
        let write_error = match handle.map(|h| h.join()) {
            Some(Ok((err, _bytes))) => err,
            Some(Err(_)) => Some("capture writer panicked".into()),
            None => None,
        };
        CaptureSummary {
            records: self.records.get(),
            bytes: self.bytes.get(),
            dropped: self.dropped.get(),
            write_error,
        }
    }
}

impl Drop for Recorder {
    fn drop(&mut self) {
        // best effort: a forgotten close still drains and fsyncs
        let _ = self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Result;
    use crate::serve::registry::{MemStorage, RegistryStorage};

    fn feats() -> Mat {
        Mat::from_vec(vec![0.25, -0.5, 1.0, 2.0], 2, 2)
    }

    fn recorder_over(
        store: MemStorage,
        opts: RecorderOptions,
    ) -> (Arc<Recorder>, ObsRegistry) {
        let obs = ObsRegistry::default();
        let log = CaptureLog::create(Box::new(store), 9).unwrap();
        let rec = Recorder::new(log, &opts, &obs);
        (rec, obs)
    }

    fn observe_ok(rec: &Recorder, elapsed_ms: u64, outcome: TraceOutcome) {
        rec.observe(
            RequestKind::Verify,
            "spk",
            &feats(),
            outcome,
            Some(1.5),
            Duration::from_millis(elapsed_ms),
            None,
        );
    }

    #[test]
    fn capture_rate_policy_samples_one_in_n() {
        let store = MemStorage::new();
        let (rec, _obs) = recorder_over(
            store.clone(),
            RecorderOptions { policy: SamplePolicy::Rate(3), ..Default::default() },
        );
        for _ in 0..9 {
            observe_ok(&rec, 1, TraceOutcome::Ok);
        }
        let summary = rec.close();
        assert_eq!(summary.records, 3);
        assert_eq!(summary.dropped, 0);
        assert!(summary.write_error.is_none());
        let loaded = CaptureLog::load(&store).unwrap();
        assert_eq!(loaded.records.len(), 3);
    }

    #[test]
    fn capture_slow_only_policy_rides_the_trace_threshold() {
        let store = MemStorage::new();
        let (rec, _obs) = recorder_over(
            store.clone(),
            RecorderOptions {
                policy: SamplePolicy::SlowOnly,
                slow_threshold_ms: 5.0,
                ..Default::default()
            },
        );
        observe_ok(&rec, 1, TraceOutcome::Ok); // fast: skipped
        observe_ok(&rec, 10, TraceOutcome::Ok); // slow: captured
        let summary = rec.close();
        assert_eq!(summary.records, 1);
    }

    #[test]
    fn capture_errors_only_policy_records_typed_outcomes() {
        let store = MemStorage::new();
        let (rec, _obs) = recorder_over(
            store.clone(),
            RecorderOptions { policy: SamplePolicy::ErrorsOnly, ..Default::default() },
        );
        observe_ok(&rec, 1, TraceOutcome::Ok); // skipped
        observe_ok(&rec, 1, TraceOutcome::Shed);
        observe_ok(&rec, 1, TraceOutcome::Timeout);
        let summary = rec.close();
        assert_eq!(summary.records, 2);
        let loaded = CaptureLog::load(&store).unwrap();
        let outcomes: Vec<_> = loaded.records.iter().map(|r| r.outcome).collect();
        assert_eq!(outcomes, vec![TraceOutcome::Shed, TraceOutcome::Timeout]);
    }

    /// A backend whose appends stall — the writer thread gets stuck so
    /// the bounded queue genuinely fills.
    struct SlowStorage {
        inner: MemStorage,
        delay: Duration,
    }

    impl RegistryStorage for SlowStorage {
        fn append_wal(&self, buf: &[u8]) -> Result<()> {
            std::thread::sleep(self.delay);
            self.inner.append_wal(buf)
        }
        fn sync_wal(&self) -> Result<()> {
            self.inner.sync_wal()
        }
        fn read_wal(&self) -> Result<Vec<u8>> {
            self.inner.read_wal()
        }
        fn truncate_wal(&self, len: u64) -> Result<()> {
            self.inner.truncate_wal(len)
        }
        fn read_snapshot(&self) -> Result<Option<Vec<u8>>> {
            self.inner.read_snapshot()
        }
        fn swap_snapshot(&self, bytes: &[u8]) -> Result<()> {
            self.inner.swap_snapshot(bytes)
        }
        fn describe(&self) -> String {
            "slow-mem".into()
        }
    }

    #[test]
    fn capture_overflow_drops_are_counted_never_blocking() {
        // writer stuck on a 300ms append, queue of 1: most of a fast
        // burst must be dropped — and every observe must return
        // immediately rather than wait for the writer
        let store = MemStorage::new();
        let slow = SlowStorage { inner: store.clone(), delay: Duration::from_millis(300) };
        let obs = ObsRegistry::default();
        // header append stalls too, so give create its one delay first
        let log = CaptureLog::create(Box::new(slow), 9).unwrap();
        let rec = Recorder::new(
            log,
            &RecorderOptions { queue: 1, ..Default::default() },
            &obs,
        );
        let t0 = Instant::now();
        for _ in 0..10 {
            observe_ok(&rec, 0, TraceOutcome::Ok);
        }
        let offered = t0.elapsed();
        assert!(
            offered < Duration::from_millis(200),
            "observe must never block on the writer (10 calls took {offered:?})"
        );
        let summary = rec.close();
        assert_eq!(summary.records + summary.dropped, 10, "{summary:?}");
        assert!(summary.dropped > 0, "queue of 1 under a stalled writer must drop");
        // accounting matches the durable log exactly
        let loaded = CaptureLog::load(&store).unwrap();
        assert_eq!(loaded.records.len() as u64, summary.records);
    }

    #[test]
    fn capture_write_failures_surface_as_drops_and_summary_error() {
        use crate::serve::registry::{Fault, FaultInjector};
        let store = MemStorage::new();
        // ops 0..=2 are create (truncate, header, sync); op 3 = first
        // record append fails with ENOSPC and latches the log dead
        let inj = FaultInjector::new(Box::new(store.clone())).fail_op(3, Fault::Enospc);
        let obs = ObsRegistry::default();
        let log = CaptureLog::create(Box::new(inj), 9).unwrap();
        let rec = Recorder::new(log, &RecorderOptions::default(), &obs);
        observe_ok(&rec, 1, TraceOutcome::Ok);
        observe_ok(&rec, 1, TraceOutcome::Ok);
        let summary = rec.close();
        assert_eq!(summary.records, 0);
        assert_eq!(summary.dropped, 2);
        let err = summary.write_error.expect("ENOSPC must be reported");
        assert!(err.contains("No space left"), "{err}");
    }
}
