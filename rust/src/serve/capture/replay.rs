//! Regression replay: re-issue a captured corpus through a fresh
//! engine and hold the answers to what production recorded.
//!
//! Determinism is what makes this a gate instead of a smoke test: the
//! serve path's batched extraction is batch-composition-independent
//! (property-tested to 1e-10 against the scalar oracle since PR 1), a
//! speaker's profile is the running mean of its enrollment i-vectors,
//! and a capture preserves arrival order — so replaying enrolls and
//! verifies in sequence against the *same* bundle must reproduce every
//! verify score to 1e-10. A drifted kernel, a broken registry mean, or
//! a changed backend shows up as a counted mismatch
//! (`replay_mismatches_total`) and a nonzero exit in CI.
//!
//! Outcome classes are compared too (ok/shed/timeout/failed): a corpus
//! captured under overload replays its shed decisions as data, and a
//! clean corpus must stay clean. Per-stage latency distributions are
//! diffed via the shared [`crate::bench_util::latency_drift_json`]
//! helper — the capture carries each request's spans, the replay's obs
//! registry provides the fresh ones.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::bench_util::{latency_drift_json, LatencyTriple};
use crate::metrics::{LatencyHistogram, LatencySummary};
use crate::obs::{Stage, TraceOutcome};
use crate::serve::registry::MemStorage;
use crate::serve::Engine;

use super::codec::{CaptureRecord, CaptureReplay, RequestKind};
use super::recorder::{Recorder, RecorderOptions};
use super::CaptureLog;

/// Replay knobs.
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Re-issue flat out instead of at original inter-arrival timing.
    pub max_speed: bool,
    /// Score agreement bound (absolute). The acceptance bar is 1e-10.
    pub tolerance: f64,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        Self { max_speed: false, tolerance: 1e-10 }
    }
}

/// One stage's captured-vs-replayed latency distributions.
#[derive(Debug, Clone)]
pub struct StageDrift {
    pub stage: Stage,
    pub captured: LatencySummary,
    pub replayed: LatencySummary,
}

/// What a replay pass found.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Records in the corpus.
    pub total: usize,
    /// Records re-issued (all of them; the corpus is the workload).
    pub replayed: usize,
    /// Whether the serving bundle's fingerprint matched the corpus's —
    /// scores are only checked when it did.
    pub fingerprint_match: bool,
    /// Replayed requests whose recorded counterpart carried a score and
    /// completed ok on both sides.
    pub score_checked: usize,
    /// Score deltas above tolerance.
    pub score_mismatches: u64,
    /// Largest |replayed − recorded| score delta seen.
    pub max_score_delta: f64,
    /// Requests whose outcome class changed (ok/shed/timeout/failed).
    pub outcome_mismatches: u64,
    /// Outcome-class counts in the corpus, indexed ok/shed/timeout/failed.
    pub captured_outcomes: [u64; 4],
    /// Outcome-class counts of the replay, same indexing.
    pub replayed_outcomes: [u64; 4],
    /// Replay wall time.
    pub wall_s: f64,
    /// Captured-vs-replayed latency distributions for every stage that
    /// has samples on either side.
    pub stage_drift: Vec<StageDrift>,
}

impl ReplayReport {
    /// Total mismatches — the CI gate exits nonzero when this is > 0.
    pub fn mismatches(&self) -> u64 {
        self.score_mismatches + self.outcome_mismatches
    }

    fn outcomes_json(counts: &[u64; 4]) -> String {
        format!(
            "{{\"ok\": {}, \"shed\": {}, \"timeout\": {}, \"failed\": {}}}",
            counts[0], counts[1], counts[2], counts[3]
        )
    }

    /// The `replay` section of `BENCH_10.json`.
    pub fn json_fragment(&self) -> String {
        format!(
            "{{\"total\": {}, \"replayed\": {}, \"fingerprint_match\": {}, \
             \"score_checked\": {}, \"score_mismatches\": {}, \"max_score_delta\": {:e}, \
             \"outcome_mismatches\": {}, \"mismatches\": {}, \"wall_s\": {:.4}, \
             \"replay_rps\": {:.2}, \"captured_outcomes\": {}, \"replayed_outcomes\": {}}}",
            self.total,
            self.replayed,
            self.fingerprint_match,
            self.score_checked,
            self.score_mismatches,
            self.max_score_delta,
            self.outcome_mismatches,
            self.mismatches(),
            self.wall_s,
            if self.wall_s > 0.0 { self.replayed as f64 / self.wall_s } else { 0.0 },
            Self::outcomes_json(&self.captured_outcomes),
            Self::outcomes_json(&self.replayed_outcomes),
        )
    }

    /// The `stage_drift` section of `BENCH_10.json`: per-stage
    /// p50/p95/p99 old→new through the shared drift helper.
    pub fn drift_json(&self) -> String {
        let mut body = String::from("{");
        for (i, d) in self.stage_drift.iter().enumerate() {
            if i > 0 {
                body.push_str(", ");
            }
            body.push_str(&format!(
                "\"{}\": {}",
                d.stage.as_str(),
                latency_drift_json(
                    &LatencyTriple::from_summary(&d.captured),
                    &LatencyTriple::from_summary(&d.replayed),
                )
            ));
        }
        body.push('}');
        body
    }
}

fn outcome_index(o: TraceOutcome) -> usize {
    match o {
        TraceOutcome::Ok => 0,
        TraceOutcome::Shed => 1,
        TraceOutcome::Timeout => 2,
        TraceOutcome::Failed => 3,
    }
}

/// Re-issue one record; every serve error is an *outcome*, not a
/// replay failure.
fn issue(engine: &Engine, rec: &CaptureRecord) -> (TraceOutcome, Option<f64>) {
    let feats = rec.mat();
    match rec.kind {
        RequestKind::Extract => {
            let r = engine.extract(&feats);
            (TraceOutcome::of(&r), None)
        }
        RequestKind::Enroll => {
            let r = engine.enroll(&rec.speaker, &feats);
            let score = r.as_ref().ok().map(|&count| count as f64);
            (TraceOutcome::of(&r), score)
        }
        RequestKind::Verify => {
            let r = engine.verify(&rec.speaker, &feats);
            let score = r.as_ref().ok().map(|out| out.score);
            (TraceOutcome::of(&r), score)
        }
    }
}

/// Replay `corpus` through `engine`, verifying scores against the
/// recorded outcomes when the bundle fingerprint matches and diffing
/// outcome classes + per-stage latency distributions.
///
/// The engine should be fresh (empty registry, private obs registry):
/// the corpus carries its own enrollments, and the stage-drift
/// comparison reads the engine's obs stage histograms as "the replay's
/// distribution". Mismatches also increment `replay_mismatches_total`
/// on the engine's obs registry.
pub fn replay_corpus(
    corpus: &CaptureReplay,
    engine: &Engine,
    opts: &ReplayOptions,
) -> Result<ReplayReport> {
    let fingerprint_match = corpus.fingerprint == engine.model().fingerprint;
    let mismatches_counter = engine.obs().counter("replay_mismatches_total", &[]);
    let mut report = ReplayReport {
        total: corpus.records.len(),
        replayed: 0,
        fingerprint_match,
        score_checked: 0,
        score_mismatches: 0,
        max_score_delta: 0.0,
        outcome_mismatches: 0,
        captured_outcomes: [0; 4],
        replayed_outcomes: [0; 4],
        wall_s: 0.0,
        stage_drift: Vec::new(),
    };

    let epoch = Instant::now();
    let base_offset = corpus.records.first().map_or(0, |r| r.arrival_offset_ns);
    for rec in &corpus.records {
        if !opts.max_speed {
            // reproduce inter-arrival spacing relative to the first
            // record, not the recorder's epoch (which includes however
            // long the capture session idled before traffic)
            let target = Duration::from_nanos(rec.arrival_offset_ns.saturating_sub(base_offset));
            let elapsed = epoch.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        let (outcome, score) = issue(engine, rec);
        report.replayed += 1;
        report.captured_outcomes[outcome_index(rec.outcome)] += 1;
        report.replayed_outcomes[outcome_index(outcome)] += 1;
        if outcome != rec.outcome {
            report.outcome_mismatches += 1;
            mismatches_counter.inc();
        }
        if fingerprint_match && outcome == TraceOutcome::Ok && rec.outcome == TraceOutcome::Ok
        {
            if let (Some(got), Some(want)) = (score, rec.score) {
                report.score_checked += 1;
                let delta = (got - want).abs();
                if delta > report.max_score_delta {
                    report.max_score_delta = delta;
                }
                if delta > opts.tolerance {
                    report.score_mismatches += 1;
                    mismatches_counter.inc();
                }
            }
        }
    }
    report.wall_s = epoch.elapsed().as_secs_f64();

    // captured per-stage distributions, rebuilt from the recorded spans
    let captured_hists: Vec<LatencyHistogram> =
        (0..Stage::ALL.len()).map(|_| LatencyHistogram::new()).collect();
    for rec in &corpus.records {
        for (stage, ns) in &rec.spans {
            captured_hists[stage.index()].record(*ns as f64 / 1e9);
        }
    }
    let replayed = engine.obs().stage_summaries();
    for stage in Stage::ALL {
        let captured = captured_hists[stage.index()].summary();
        let (_, replayed) = replayed[stage.index()];
        if captured.count > 0 || replayed.count > 0 {
            report.stage_drift.push(StageDrift { stage, captured, replayed });
        }
    }
    Ok(report)
}

/// What a capture-on vs capture-off throughput comparison measured.
#[derive(Debug, Clone)]
pub struct CaptureOverhead {
    pub requests: usize,
    pub off_wall_s: f64,
    pub on_wall_s: f64,
    /// (on − off) / off, in percent — the cost of recording everything.
    pub overhead_pct: f64,
    /// Records the capture-on pass durably logged.
    pub captured_records: u64,
    /// Records the capture-on pass dropped on queue overflow.
    pub capture_dropped: u64,
}

impl CaptureOverhead {
    pub fn off_rps(&self) -> f64 {
        if self.off_wall_s > 0.0 { self.requests as f64 / self.off_wall_s } else { 0.0 }
    }

    pub fn on_rps(&self) -> f64 {
        if self.on_wall_s > 0.0 { self.requests as f64 / self.on_wall_s } else { 0.0 }
    }

    /// The `capture_overhead` section of `BENCH_10.json`.
    pub fn json_fragment(&self) -> String {
        format!(
            "{{\"requests\": {}, \"capture_off_wall_s\": {:.4}, \"capture_on_wall_s\": {:.4}, \
             \"capture_off_rps\": {:.2}, \"capture_on_rps\": {:.2}, \"overhead_pct\": {:.2}, \
             \"captured_records\": {}, \"capture_dropped\": {}}}",
            self.requests,
            self.off_wall_s,
            self.on_wall_s,
            self.off_rps(),
            self.on_rps(),
            self.overhead_pct,
            self.captured_records,
            self.capture_dropped,
        )
    }
}

/// Drive the corpus through `engine` twice at max speed — once bare,
/// once with an in-memory recorder capturing everything — and report
/// the throughput delta. Run this *after* the verification pass: it
/// re-enrolls the corpus's speakers (harmless for score math — a
/// profile mean is invariant under whole-set re-enrollment — but it
/// would inflate the enroll counts a verification pass checks).
pub fn run_capture_overhead(corpus: &CaptureReplay, engine: &Engine) -> Result<CaptureOverhead> {
    let n = corpus.records.len();
    // capture-off
    let t0 = Instant::now();
    for rec in &corpus.records {
        let _ = issue(engine, rec);
    }
    let off_wall_s = t0.elapsed().as_secs_f64();

    // capture-on: everything, through the real recorder machinery over
    // memory-backed storage
    let log = CaptureLog::create(Box::new(MemStorage::new()), corpus.fingerprint)
        .context("create overhead capture log")?;
    let recorder = Recorder::new(log, &RecorderOptions::default(), engine.obs());
    engine.set_recorder(Some(Arc::clone(&recorder)));
    let t0 = Instant::now();
    for rec in &corpus.records {
        let _ = issue(engine, rec);
    }
    let on_wall_s = t0.elapsed().as_secs_f64();
    engine.set_recorder(None);
    let summary = recorder.close();

    Ok(CaptureOverhead {
        requests: n,
        off_wall_s,
        on_wall_s,
        overhead_pct: if off_wall_s > 0.0 {
            (on_wall_s - off_wall_s) / off_wall_s * 100.0
        } else {
            0.0
        },
        captured_records: summary.records,
        capture_dropped: summary.dropped,
    })
}
